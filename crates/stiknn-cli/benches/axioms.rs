//! SEC3.2-AX — axiom-verification sweep: efficiency / symmetry /
//! main-term positivity / centering, checked on every Table-1 twin, plus
//! the cost of a full check.
//!
//!     cargo bench --bench axioms

use stiknn::bench::{quick, Suite};
use stiknn::data::{load_dataset, registry_names};
use stiknn::report::table::Table;
use stiknn::shapley::axioms::{all_hold, check_all};
use stiknn::shapley::sti_knn::{sti_knn, StiParams};

fn main() {
    let k = 5;
    let mut suite = Suite::new("axiom checks (n=200, t=50, k=5)").with_config(quick());
    let mut table = Table::new(&["dataset", "efficiency |Δ|", "centering |Δ|", "all axioms"]);
    for name in registry_names() {
        let ds = load_dataset(name, 200, 50, 21).unwrap();
        let phi = sti_knn(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(k),
        );
        let mut reports = Vec::new();
        suite.bench(&format!("axioms {name}"), || {
            reports = check_all(
                &phi, &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, k, 1e-9,
            );
        });
        let eff = reports.iter().find(|r| r.name == "efficiency").unwrap();
        let cen = reports.iter().find(|r| r.name == "centering").unwrap();
        table.row(&[
            name.to_string(),
            format!("{:.1e}", (eff.observed - eff.expected).abs()),
            format!("{:.1e}", (cen.observed - cen.expected).abs()),
            if all_hold(&reports) { "PASS".into() } else { "FAIL".into() },
        ]);
    }
    println!("{}", suite.render());
    println!("\naxiom results across the registry (EXPERIMENTS.md SEC3.2-AX):\n{}", table.render());
}
