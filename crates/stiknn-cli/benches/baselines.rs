//! Baseline comparison (§1 + §3.2): STI-KNN (pair interactions, O(t·n²))
//! vs KNN-Shapley (per-point, O(t·n log n)) vs LOO (per-point, closed
//! form) vs Monte-Carlo STI at several sampling budgets — wall time and,
//! for MC, the accuracy-vs-budget tradeoff against the exact matrix.
//!
//!     cargo bench --bench baselines

use stiknn::bench::{quick, Suite};
use stiknn::data::load_dataset;
use stiknn::report::table::Table;
use stiknn::shapley::loo::loo;
use stiknn::shapley::mc_sti::mc_sti;
use stiknn::shapley::knn_shapley::knn_shapley;
use stiknn::shapley::sti_knn::{sti_knn, StiParams};

fn main() {
    let k = 5;
    let n = 600;
    let t = 100;
    let ds = load_dataset("circle", n, t, 11).unwrap();

    let mut suite = Suite::new(&format!("baselines (n={n}, t={t}, k={k})")).with_config(quick());
    suite.bench("sti_knn (pair interactions)", || {
        sti_knn(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(k),
        )
    });
    suite.bench("knn_shapley (per point)", || {
        knn_shapley(&ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, k)
    });
    suite.bench("loo (per point)", || {
        loo(&ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, k)
    });
    println!("{}", suite.render());

    // MC accuracy-vs-budget on a small instance where exact MC work is
    // feasible (the alternative a practitioner would run without Alg. 1)
    let small = load_dataset("circle", 16, 12, 3).unwrap();
    let exact = sti_knn(
        &small.train_x, &small.train_y, small.d, &small.test_x, &small.test_y,
        &StiParams::new(3),
    );
    let mut mc_suite = Suite::new("monte-carlo STI (n=16, t=12, k=3)").with_config(quick());
    let mut table = Table::new(&["samples/size", "max|err| vs exact", "mean wall"]);
    for budget in [2usize, 8, 32, 128] {
        let m = mc_suite.bench(&format!("mc budget={budget}"), || {
            mc_sti(
                &small.train_x, &small.train_y, small.d, &small.test_x,
                &small.test_y, 3, budget, 99,
            )
        });
        let est = mc_sti(
            &small.train_x, &small.train_y, small.d, &small.test_x, &small.test_y,
            3, budget, 99,
        );
        table.row(&[
            budget.to_string(),
            format!("{:.2e}", est.max_abs_diff(&exact)),
            stiknn::util::timer::fmt_duration(m.mean),
        ]);
    }
    println!("{}", mc_suite.render());
    println!("\nMC accuracy vs budget (exactness is the paper's selling point):\n{}", table.render());
}
