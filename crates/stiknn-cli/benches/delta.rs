//! §DELTA — single-edit latency vs full recompute (EXPERIMENTS.md
//! §DELTA, DESIGN.md §11).
//!
//! A mutable session repairs one training-set edit in O(t·(d + n));
//! the alternative a deployment actually faces is rebuilding the
//! session from scratch — O(t·(n·d + n log n)) distances + sorts + row
//! retention over the whole test history. This bench measures both at
//! n ∈ {600, 2k, 8k, 32k} (quick mode stops at 8k so CI still exercises
//! the acceptance size) and writes `BENCH_delta.json` at the REPO ROOT.
//!
//! Edits are benchmarked as an add+remove PAIR so the session size is
//! stable across iterations (reported per-edit = pair/2); relabel is
//! measured separately (the cheapest edit — no rank shifts).
//!
//!     cargo bench --bench delta              # full: n ∈ {600, 2k, 8k, 32k}
//!     cargo bench --bench delta -- --quick   # CI:   n ∈ {600, 2k, 8k}

use stiknn::bench::{BenchConfig, Suite};
use stiknn::data::load_dataset;
use stiknn::session::{Engine, SessionConfig, ValuationSession};
use stiknn::util::json::Json;

fn mutable_session(n: usize, t: usize, k: usize) -> (ValuationSession, Vec<f32>, Vec<i32>) {
    // "pol" (d=26): a Table-1 shape where the recompute's n·d distance
    // work is realistic rather than the d=2 toy geometry.
    let ds = load_dataset("pol", n, t, 7).expect("registry dataset");
    let config = SessionConfig::new(k)
        .with_engine(Engine::Implicit)
        .with_retained_rows(true)
        .with_mutable(true);
    let mut s = ValuationSession::from_dataset(&ds, config).expect("session");
    s.ingest(&ds.test_x, &ds.test_y).expect("ingest test split");
    (s, ds.test_x.clone(), ds.test_y.clone())
}

fn main() {
    let quick_mode = std::env::args().any(|a| a == "--quick")
        || std::env::var_os("STIKNN_BENCH_QUICK").is_some();
    let k = 5;
    let t = 64;
    let sizes: Vec<usize> = if quick_mode {
        // keep 8k: the ≥10× acceptance claim lives there and the edit
        // path is cheap enough for CI
        vec![600, 2000, 8000]
    } else {
        vec![600, 2000, 8000, 32000]
    };

    let mut suite = Suite::new(&format!(
        "delta edits vs full session recompute (t={t}, k={k}, dataset=pol)"
    ));
    suite = suite.with_config(BenchConfig {
        min_time: std::time::Duration::from_millis(300),
        max_iters: 20,
        warmup_iters: 1,
    });

    let mut entries = Vec::new();
    for &n in &sizes {
        let (mut session, test_x, test_y) = mutable_session(n, t, k);
        let probe: Vec<f32> = session.train_row(0).to_vec();

        // add+remove pair: n returns to its starting value every iter
        let pair = suite.bench(&format!("edit pair (add+remove) n={n}"), || {
            let id = session.add_train(&probe, 1).expect("add");
            session.remove_train(id).expect("remove");
        });
        let edit_secs = pair.mean_secs() / 2.0;

        let relabel = suite.bench(&format!("relabel n={n}"), || {
            let y = session.train_labels()[3];
            session.relabel_train(3, 1 - y).expect("relabel");
        });

        // full recompute: rebuild the mutable session over the current
        // train set and re-ingest the whole retained test history — the
        // operation a non-delta deployment performs per edit
        let d = session.d();
        let train_x: Vec<f32> = (0..session.n())
            .flat_map(|i| session.train_row(i).to_vec())
            .collect();
        let train_y: Vec<i32> = session.train_labels().to_vec();
        let recompute = suite.bench(&format!("full recompute n={n}"), || {
            let config = SessionConfig::new(k)
                .with_engine(Engine::Implicit)
                .with_retained_rows(true)
                .with_mutable(true);
            let mut fresh =
                ValuationSession::new(train_x.clone(), train_y.clone(), d, config)
                    .expect("session");
            fresh.ingest(&test_x, &test_y).expect("ingest");
            fresh
        });

        let speedup = recompute.mean_secs() / edit_secs;
        println!(
            "n={n:>6}: edit {:.6}s, relabel {:.6}s, full recompute {:.4}s, speedup {speedup:.1}x",
            edit_secs,
            relabel.mean_secs(),
            recompute.mean_secs()
        );
        entries.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("t", Json::num(t as f64)),
            ("edit_secs", Json::num(edit_secs)),
            ("relabel_secs", Json::num(relabel.mean_secs())),
            ("full_recompute_secs", Json::num(recompute.mean_secs())),
            ("speedup_recompute_over_edit", Json::num(speedup)),
        ]));
    }

    println!("{}", suite.render());

    let artifact = Json::obj(vec![
        ("bench", Json::str("delta")),
        ("quick", Json::Bool(quick_mode)),
        ("k", Json::num(k as f64)),
        ("t", Json::num(t as f64)),
        ("dataset", Json::str("pol")),
        ("sizes", Json::arr(entries)),
        ("suite", suite.to_json()),
    ]);
    // Workspace root, not CWD: benches run with CWD = the package dir
    // but the trajectory artifact lives beside ROADMAP.md.
    let out = stiknn::bench::artifact_path(env!("CARGO_MANIFEST_DIR"), "BENCH_delta.json");
    match std::fs::write(&out, artifact.to_string()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
