//! §KERNEL — SIMD distance kernels vs the scalar seed loop
//! (EXPERIMENTS.md §KERNEL, DESIGN.md §15).
//!
//! Phase 1 of Algorithm 1 is distance-bound: every test point scans all
//! n train rows before it can rank them. This bench measures the three
//! prep-path variants over an n × d × metric grid:
//!
//! * `scalar`  — the seed loop (`knn::distance::distances_into`),
//! * `kernel`  — the runtime-dispatched kernel with a prebuilt norm
//!   cache (`knn::kernel::distances_into_kernel`),
//! * `block B` — the cache-blocked batched API (`distances_block`)
//!   amortizing each train tile over B queries (reported per query).
//!
//! The acceptance cell is SqEuclidean at n=32k, d=64 (kept in quick
//! mode): kernel ≥ 3× over scalar under AVX2, blocked ≥ 1.5× more at
//! B ≥ 8. Writes `BENCH_distance.json` at the repo root.
//!
//!     cargo bench --bench distance            # full grid
//!     cargo bench --bench distance -- --quick # CI subset

use stiknn::bench::{BenchConfig, Suite};
use stiknn::knn::distance::{distances_into, Metric};
use stiknn::knn::kernel::{distances_block, distances_into_kernel, Kernel, NormCache};
use stiknn::util::json::Json;
use stiknn::util::rng::Rng;

fn main() {
    let quick_mode = std::env::args().any(|a| a == "--quick")
        || std::env::var_os("STIKNN_BENCH_QUICK").is_some();
    let shapes: Vec<(usize, usize)> = if quick_mode {
        // keep (32k, 64): the ≥3× / ≥1.5× acceptance claims live there
        vec![(2_000, 16), (32_000, 64)]
    } else {
        vec![(2_000, 16), (8_000, 64), (32_000, 64), (32_000, 256)]
    };
    let metrics = [
        ("sqeuclidean", Metric::SqEuclidean),
        ("manhattan", Metric::Manhattan),
        ("cosine", Metric::Cosine),
    ];
    const BLOCKS: [usize; 2] = [8, 64];

    let mut suite = Suite::new(&format!(
        "distance kernels (active kernel: {})",
        Kernel::active().name()
    ));
    suite = suite.with_config(BenchConfig {
        min_time: std::time::Duration::from_millis(if quick_mode { 80 } else { 250 }),
        max_iters: 2_000,
        warmup_iters: 3,
    });

    let mut cells = Vec::new();
    for &(n, d) in &shapes {
        let mut rng = Rng::new((n * 31 + d) as u64);
        let points: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let queries: Vec<f32> = (0..64 * d).map(|_| rng.normal() as f32).collect();
        let q = &queries[..d];
        let mut out = vec![0.0f64; n];
        let mut out_blk = vec![0.0f64; 64 * n];
        for (mname, metric) in metrics {
            let norms = NormCache::build(&points, d, metric);
            let scalar = suite.bench(&format!("scalar {mname} n={n} d={d}"), || {
                distances_into(q, &points, d, metric, &mut out);
                out[n - 1]
            });
            let kernel = suite.bench(&format!("kernel {mname} n={n} d={d}"), || {
                distances_into_kernel(q, &points, d, metric, &norms, &mut out);
                out[n - 1]
            });
            let mut entry = vec![
                ("n", Json::num(n as f64)),
                ("d", Json::num(d as f64)),
                ("metric", Json::str(mname)),
                ("scalar_secs", Json::num(scalar.mean_secs())),
                ("kernel_secs", Json::num(kernel.mean_secs())),
                (
                    "speedup_kernel_over_scalar",
                    Json::num(scalar.mean_secs() / kernel.mean_secs()),
                ),
            ];
            let mut per_query_b8 = kernel.mean_secs();
            for b in BLOCKS {
                let blk = suite.bench(&format!("block B={b} {mname} n={n} d={d}"), || {
                    let qs = &queries[..b * d];
                    distances_block(qs, &points, d, metric, &norms, &mut out_blk[..b * n]);
                    out_blk[b * n - 1]
                });
                let per_query = blk.mean_secs() / b as f64;
                if b == 8 {
                    per_query_b8 = per_query;
                }
                entry.push((
                    match b {
                        8 => "block8_secs_per_query",
                        _ => "block64_secs_per_query",
                    },
                    Json::num(per_query),
                ));
            }
            entry.push((
                "speedup_block8_over_kernel",
                Json::num(kernel.mean_secs() / per_query_b8),
            ));
            println!(
                "{mname} n={n} d={d}: scalar/kernel {:.2}x, kernel/block8 {:.2}x",
                scalar.mean_secs() / kernel.mean_secs(),
                kernel.mean_secs() / per_query_b8
            );
            cells.push(Json::obj(entry));
        }
    }

    println!("{}", suite.render());

    let artifact = Json::obj(vec![
        ("bench", Json::str("distance")),
        ("quick", Json::Bool(quick_mode)),
        ("kernel", Json::str(Kernel::active().name())),
        ("cells", Json::arr(cells)),
        ("suite", suite.to_json()),
    ]);
    let out = stiknn::bench::artifact_path(env!("CARGO_MANIFEST_DIR"), "BENCH_distance.json");
    match std::fs::write(&out, artifact.to_string()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
