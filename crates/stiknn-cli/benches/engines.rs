//! Engine comparison on artifact shapes: the pure-Rust Algorithm 1 under
//! both assembly strategies (row-banded shared accumulator vs legacy
//! test-sharded private accumulators), and — when `make artifacts` has
//! run AND the build has the `xla` feature — the AOT XLA artifact
//! (L1 Pallas + L2 JAX compiled through PJRT): same numbers, different
//! substrates (EXPERIMENTS.md §E2E / §Perf).
//!
//!     cargo bench --bench engines

use std::path::Path;
use stiknn::bench::{quick, Suite};
use stiknn::coordinator::{run_job, Assembly, ValuationJob};
use stiknn::data::Dataset;
use stiknn::report::table::Table;
use stiknn::runtime::{executor_for, Manifest};
use stiknn::shapley::sti_knn::{sti_knn_partial, StiParams};
use stiknn::util::rng::Rng;

/// Synthetic dataset at an artifact shape (the registry twins don't cover
/// arbitrary (n, d, b) combinations).
fn shaped_dataset(n: usize, d: usize, t: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let ds = Dataset {
        name: format!("shaped_n{n}_d{d}"),
        d,
        classes: 2,
        train_x: (0..n * d).map(|_| rng.normal() as f32).collect(),
        train_y: (0..n).map(|_| rng.below(2) as i32).collect(),
        test_x: (0..t * d).map(|_| rng.normal() as f32).collect(),
        test_y: (0..t).map(|_| rng.below(2) as i32).collect(),
    };
    ds.validate();
    ds
}

fn main() {
    let mut suite = Suite::new("engines on artifact shapes").with_config(quick());

    // ---- rust engine: banded vs test-sharded coordinator ----------------
    let mut rust_table = Table::new(&["shape", "banded", "sharded", "sharded/banded", "max|Δ|"]);
    for (n, d, t, k) in [(600usize, 2usize, 128usize, 5usize), (1200, 8, 64, 5)] {
        let ds = shaped_dataset(n, d, t, 7);
        let banded_job = ValuationJob::new(k)
            .with_workers(4)
            .with_assembly(Assembly::RowBanded { band_rows: 0 });
        let sharded_job = ValuationJob::new(k)
            .with_workers(4)
            .with_assembly(Assembly::TestSharded);
        let mb = suite.bench(&format!("rust banded  n={n} d={d}"), || {
            run_job(&ds, &banded_job).unwrap()
        });
        let ms = suite.bench(&format!("rust sharded n={n} d={d}"), || {
            run_job(&ds, &sharded_job).unwrap()
        });
        let phi_b = run_job(&ds, &banded_job).unwrap().phi;
        let phi_s = run_job(&ds, &sharded_job).unwrap().phi;
        rust_table.row(&[
            format!("n={n} d={d} t={t} k={k}"),
            stiknn::util::timer::fmt_duration(mb.mean),
            stiknn::util::timer::fmt_duration(ms.mean),
            format!("{:.2}x", ms.mean_secs() / mb.mean_secs()),
            format!("{:.1e}", phi_b.max_abs_diff(&phi_s)),
        ]);
    }

    // ---- xla engine (needs artifacts + the `xla` build feature) ---------
    let dir = Path::new("artifacts");
    let mut xla_table = Table::new(&["shape", "rust", "xla", "xla/rust", "max|Δ|"]);
    let mut xla_rows = false;
    match Manifest::load(dir) {
        Err(_) => eprintln!("artifacts/ missing — run `make artifacts` for the XLA comparison"),
        Ok(manifest) => {
            for spec in manifest.of_program("sti") {
                let (n, d, b, k) = (spec.n, spec.d, spec.b, spec.k);
                let exec = match executor_for(&manifest, "sti", n, d, k) {
                    Ok(e) => e,
                    Err(e) => {
                        eprintln!("skipping XLA comparison: {e:#}");
                        break;
                    }
                };
                let ds = shaped_dataset(n, d, b, 7);
                let params = StiParams::new(k);
                let mr = suite.bench(&format!("rust {}", spec.name), || {
                    sti_knn_partial(&ds.train_x, &ds.train_y, d, &ds.test_x, &ds.test_y, &params)
                });
                let mx = suite.bench(&format!("xla  {}", spec.name), || {
                    exec.run_block(&ds.train_x, &ds.train_y, &ds.test_x, &ds.test_y)
                        .unwrap()
                });
                let (phi_r, _) =
                    sti_knn_partial(&ds.train_x, &ds.train_y, d, &ds.test_x, &ds.test_y, &params);
                let (phi_x, _) = exec
                    .run_block(&ds.train_x, &ds.train_y, &ds.test_x, &ds.test_y)
                    .unwrap();
                xla_table.row(&[
                    format!("n={n} d={d} b={b} k={k}"),
                    stiknn::util::timer::fmt_duration(mr.mean),
                    stiknn::util::timer::fmt_duration(mx.mean),
                    format!("{:.1}x", mx.mean_secs() / mr.mean_secs()),
                    format!("{:.1e}", phi_r.max_abs_diff(&phi_x)),
                ]);
                xla_rows = true;
            }
        }
    }

    println!("{}", suite.render());
    println!(
        "\nrust assembly comparison (EXPERIMENTS.md §Perf L3):\n{}",
        rust_table.render()
    );
    if xla_rows {
        println!(
            "\nengine comparison per block (EXPERIMENTS.md §Perf L2):\n{}",
            xla_table.render()
        );
    }
}
