//! FIG3/FIG4/FIG5 — regenerate the paper's §4 figures: interaction-matrix
//! block statistics on the balanced Circle, the unbalanced (subsampled)
//! Circle, and the mislabeled Circle, with the wall time for each.
//!
//!     cargo bench --bench figures

use stiknn::analysis::mislabel::{auc, mislabel_scores};
use stiknn::analysis::redundancy::{class_block_mean_abs, interaction_breakdown};
use stiknn::analysis::structure::block_structure;
use stiknn::bench::{quick, Suite};
use stiknn::data::{corrupt, load_dataset};
use stiknn::report::table::Table;
use stiknn::shapley::sti_knn::{sti_knn, StiParams};

fn main() {
    let k = 5;
    let mut suite = Suite::new("figure regeneration (circle n=600, t=150, k=5)")
        .with_config(quick());

    // FIG3 — balanced circle
    let ds = load_dataset("circle", 600, 150, 42).unwrap();
    let phi3 = sti_knn(
        &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, &StiParams::new(k),
    );
    suite.bench("fig3 balanced circle", || {
        sti_knn(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(k),
        )
    });

    // FIG4 — unbalanced circle (class 0 subsampled 300 -> 60)
    let ds4 = corrupt::subsample_class(&ds, 0, 60, 3);
    let phi4 = sti_knn(
        &ds4.train_x, &ds4.train_y, ds4.d, &ds4.test_x, &ds4.test_y,
        &StiParams::new(k),
    );
    suite.bench("fig4 unbalanced circle", || {
        sti_knn(
            &ds4.train_x, &ds4.train_y, ds4.d, &ds4.test_x, &ds4.test_y,
            &StiParams::new(k),
        )
    });

    // FIG5 — mislabeled circle
    let mut ds5 = load_dataset("circle", 600, 150, 42).unwrap();
    let truth = corrupt::flip_labels(&mut ds5, 0.05, 13);
    let phi5 = sti_knn(
        &ds5.train_x, &ds5.train_y, ds5.d, &ds5.test_x, &ds5.test_y,
        &StiParams::new(k),
    );
    suite.bench("fig5 mislabeled circle", || {
        sti_knn(
            &ds5.train_x, &ds5.train_y, ds5.d, &ds5.test_x, &ds5.test_y,
            &StiParams::new(k),
        )
    });

    println!("{}", suite.render());

    // the figures' content, as numbers
    let b3 = interaction_breakdown(&phi3, &ds.train_y);
    let blocks3 = block_structure(&phi3, &ds.train_y, 2);
    let mut t = Table::new(&["figure", "statistic", "value"]);
    t.row(&["FIG3".into(), "in-class mean |phi|".into(), format!("{:.3e}", b3.in_class)]);
    t.row(&["FIG3".into(), "out-class mean |phi|".into(), format!("{:.3e}", b3.out_class)]);
    t.row(&["FIG3".into(), "block (0,0)".into(), format!("{:+.3e}", blocks3.get(0, 0))]);
    t.row(&["FIG3".into(), "block (0,1)".into(), format!("{:+.3e}", blocks3.get(0, 1))]);
    t.row(&["FIG3".into(), "block (1,1)".into(), format!("{:+.3e}", blocks3.get(1, 1))]);

    let full_blue = class_block_mean_abs(&phi3, &ds.train_y, 0);
    let sub_blue = class_block_mean_abs(&phi4, &ds4.train_y, 0);
    t.row(&["FIG4".into(), "class-0 |phi| balanced".into(), format!("{:.3e}", full_blue)]);
    t.row(&["FIG4".into(), "class-0 |phi| subsampled".into(), format!("{:.3e}", sub_blue)]);
    t.row(&["FIG4".into(), "amplification".into(), format!("{:.2}x", sub_blue / full_blue)]);

    let rep = mislabel_scores(&phi5, &ds5.train_y, ds5.classes);
    t.row(&["FIG5".into(), "mislabel AUC".into(), format!("{:.3}", auc(&rep.margins, &truth))]);
    println!("\nfigure statistics (EXPERIMENTS.md FIG3/FIG4/FIG5):\n{}", t.render());
}
