//! FIG7–FIG10 — the Appendix-B figure pairs themselves: for each figure's
//! (dataset, k1, k2) compute both matrices at the paper's full dataset
//! sizes and report the flattened correlation the caption claims,
//! plus regeneration cost.
//!
//!     cargo bench --bench figures_k

use stiknn::analysis::ksens::k_sensitivity;
use stiknn::bench::{quick, Suite};
use stiknn::data::load_dataset;
use stiknn::report::table::Table;

fn main() {
    let mut suite = Suite::new("appendix-B figure pairs (registry-default sizes)")
        .with_config(quick());
    let mut table = Table::new(&[
        "figure", "dataset", "k1", "k2", "r (paper method)", "r (offdiag)", "paper claim",
    ]);
    for (fig, name, k1, k2) in [
        ("Fig. 7", "circle", 9usize, 20usize),
        ("Fig. 8", "moon", 3, 7),
        ("Fig. 9", "click", 5, 15),
        ("Fig. 10", "monksv2", 3, 4),
    ] {
        let ds = load_dataset(name, 0, 0, 42).unwrap();
        let mut rep = None;
        suite.bench(&format!("{fig} {name} k={k1},{k2}"), || {
            rep = Some(k_sensitivity(&ds, &[k1, k2]));
        });
        let rep = rep.unwrap();
        table.row(&[
            fig.to_string(),
            name.to_string(),
            k1.to_string(),
            k2.to_string(),
            format!("{:.4}", rep.min_correlation),
            format!("{:.4}", rep.min_correlation_offdiag),
            "> 0.99".to_string(),
        ]);
    }
    println!("{}", suite.render());
    println!("\nfigure-pair correlations (EXPERIMENTS.md FIG7-10):\n{}", table.render());
}
