//! SEC3.2-K — k-sensitivity sweep cost and results across the Table-1
//! registry (Figs. 7–10 + the §3.2 correlation claim).
//!
//!     cargo bench --bench ksens

use stiknn::analysis::ksens::k_sensitivity;
use stiknn::bench::{quick, Suite};
use stiknn::data::load_dataset;
use stiknn::report::table::Table;

fn main() {
    let ks = [3usize, 5, 9, 15, 20];
    let mut suite = Suite::new("k-sensitivity sweeps (n=300, t=80)").with_config(quick());
    let mut table = Table::new(&["dataset", "min r (paper)", "min r (offdiag)", "std ratio k3/k20"]);
    for name in ["circle", "moon", "click", "monksv2"] {
        let ds = load_dataset(name, 300, 80, 42).unwrap();
        let mut rep = None;
        suite.bench(&format!("ksens {name}"), || {
            rep = Some(k_sensitivity(&ds, &ks));
        });
        let rep = rep.unwrap();
        table.row(&[
            name.to_string(),
            format!("{:.4}", rep.min_correlation),
            format!("{:.4}", rep.min_correlation_offdiag),
            format!("{:.2}", rep.stds[0] / rep.stds[ks.len() - 1]),
        ]);
    }
    println!("{}", suite.render());
    println!("\nk-insensitivity results (EXPERIMENTS.md SEC3.2-K):\n{}", table.render());
}
