//! L3 coordinator throughput: assembly-strategy / worker / block-size
//! sweep on the end-to-end valuation pipeline (rust engine) — the scaling
//! behaviour the perf pass optimizes (EXPERIMENTS.md §Perf).
//!
//! Compares the row-banded assembly (one shared n×n accumulator, O(n²)
//! memory) against the legacy test-sharded assembly (private accumulator
//! per worker, O(W·n²) memory + O(shards·n²) merge).
//!
//!     cargo bench --bench pipeline

use stiknn::bench::{quick, Suite};
use stiknn::coordinator::{run_job, Assembly, ValuationJob};
use stiknn::data::load_dataset;
use stiknn::report::table::Table;

fn main() {
    let ds = load_dataset("circle", 600, 300, 5).unwrap();
    let k = 5;
    let n = ds.n_train();

    let mut suite = Suite::new("pipeline (circle n=600, t=300, k=5)").with_config(quick());
    let mut table = Table::new(&[
        "assembly",
        "workers",
        "block",
        "mean wall",
        "speedup vs 1 worker",
        "accumulators",
    ]);
    for (label, assembly) in [
        ("banded", Assembly::RowBanded { band_rows: 0 }),
        ("sharded", Assembly::TestSharded),
    ] {
        let mut base = None;
        for workers in [1usize, 2, 4, 8] {
            for block in [8usize, 32] {
                let job = ValuationJob::new(k)
                    .with_workers(workers)
                    .with_block_size(block)
                    .with_assembly(assembly);
                let m = suite.bench(
                    &format!("{label} workers={workers} block={block}"),
                    || run_job(&ds, &job).unwrap(),
                );
                let secs = m.mean_secs();
                if workers == 1 && block == 32 {
                    base = Some(secs);
                }
                // n×n f64 accumulators alive at peak: 1 for banded (by
                // construction — the WeightMerger holds no matrices); for
                // sharded, one per worker in flight plus every buffered
                // partial in the Merger (all shards, worst case).
                let accs = match assembly {
                    Assembly::RowBanded { .. } => "1".to_string(),
                    Assembly::TestSharded => {
                        format!("≤{}", workers + ds.n_test().div_ceil(block))
                    }
                };
                table.row(&[
                    label.to_string(),
                    workers.to_string(),
                    block.to_string(),
                    stiknn::util::timer::fmt_duration(m.mean),
                    base.map(|b| format!("{:.2}x", b / secs)).unwrap_or_default(),
                    accs,
                ]);
            }
        }
    }
    println!("{}", suite.render());
    println!(
        "\nscaling table (EXPERIMENTS.md §Perf L3; accumulator column = n×n \
         f64 matrices alive at peak, n={n}):\n{}",
        table.render()
    );
}
