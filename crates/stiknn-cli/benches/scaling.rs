//! SEC3.2-C — the headline complexity claim: STI-KNN is O(t·n²) while the
//! baseline Eq. (3) enumeration is O(2ⁿ), and KNN-Shapley (per-point) is
//! O(t·n log n).
//!
//! Regenerates: time-vs-n series for all three algorithms (the paper's
//! complexity discussion), empirical log-log slopes, and the t-scaling
//! series (linear in t; §3.2 "Effect of t on the complexity").
//!
//! Also writes `BENCH_scaling.json` (raw measurements + fitted slopes +
//! verdicts) — the machine-readable perf-trajectory artifact CI uploads
//! per commit so regressions show up as a series, not an anecdote.
//!
//!     cargo bench --bench scaling

use stiknn::bench::{quick, Suite};
use stiknn::data::load_dataset;
use stiknn::report::table::Table;
use stiknn::shapley::knn_shapley::knn_shapley;
use stiknn::shapley::sti_exact::sti_exact;
use stiknn::shapley::sti_knn::{sti_knn, StiParams};
use stiknn::util::json::Json;
use stiknn::util::stats::loglog_slope;

fn main() {
    let k = 5;

    // ---- n-scaling: STI-KNN vs KNN-Shapley --------------------------
    let mut suite = Suite::new("n-scaling (t=64, k=5)").with_config(quick());
    // start at 400: below that the O(n log n) sort dominates the
    // optimized O(n²) assembly (~0.65 ns/cell) and flattens the slope
    let ns = [400usize, 800, 1600, 3200];
    let mut sti_times = Vec::new();
    let mut ks_times = Vec::new();
    for &n in &ns {
        let ds = load_dataset("cpu", n, 64, 7).unwrap();
        let m = suite.bench(&format!("sti_knn n={n}"), || {
            sti_knn(
                &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
                &StiParams::new(k),
            )
        });
        sti_times.push(m.mean_secs());
        let m = suite.bench(&format!("knn_shapley n={n}"), || {
            knn_shapley(&ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, k)
        });
        ks_times.push(m.mean_secs());
    }

    // ---- brute-force O(2^n): tiny n only -----------------------------
    let mut brute = Suite::new("brute force Eq.(3) (t=8, k=3)").with_config(quick());
    let bns = [8usize, 10, 12, 14, 16];
    let mut brute_times = Vec::new();
    for &n in &bns {
        let ds = load_dataset("cpu", n, 8, 7).unwrap();
        let m = brute.bench(&format!("sti_exact n={n}"), || {
            sti_exact(&ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, 3)
        });
        brute_times.push(m.mean_secs());
    }

    // ---- t-scaling ----------------------------------------------------
    let mut tsuite = Suite::new("t-scaling (n=400, k=5)").with_config(quick());
    let ts = [25usize, 50, 100, 200, 400];
    let mut t_times = Vec::new();
    for &t in &ts {
        let ds = load_dataset("cpu", 400, t, 7).unwrap();
        let m = tsuite.bench(&format!("sti_knn t={t}"), || {
            sti_knn(
                &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
                &StiParams::new(k),
            )
        });
        t_times.push(m.mean_secs());
    }

    println!("{}", suite.render());
    println!("{}", brute.render());
    println!("{}", tsuite.render());

    // ---- the paper's claim, as numbers --------------------------------
    let nsf: Vec<f64> = ns.iter().map(|&n| n as f64).collect();
    let bnsf: Vec<f64> = bns.iter().map(|&n| n as f64).collect();
    let tsf: Vec<f64> = ts.iter().map(|&t| t as f64).collect();
    let sti_slope = loglog_slope(&nsf, &sti_times);
    let ks_slope = loglog_slope(&nsf, &ks_times);
    let t_slope = loglog_slope(&tsf, &t_times);
    // 2^n => log t = n·ln2 + c: fit ln(time) against n directly
    let lnb: Vec<f64> = brute_times.iter().map(|t| t.ln()).collect();
    let (b_slope, _) = stiknn::util::stats::linfit(&bnsf, &lnb);

    // single source of truth for the claims: (json key, table label,
    // expected label, expected value, measured, accepted range)
    let verdicts = [
        ("sti_knn_n_slope", "STI-KNN ~ n^2", "slope 2.0", 2.0, sti_slope, (1.7, 2.4)),
        ("knn_shapley_n_slope", "KNN-Shapley ~ n log n", "slope ~1.1", 1.1, ks_slope, (0.8, 1.5)),
        ("sti_knn_t_slope", "STI-KNN linear in t", "slope 1.0", 1.0, t_slope, (0.8, 1.2)),
        ("brute_force_ln_slope", "brute force ~ 2^n", "ln-slope ~0.69", 0.69, b_slope, (0.5, 0.9)),
    ];

    let mut t = Table::new(&["claim", "expected", "measured", "verdict"]);
    for &(_, label, expected_label, _, measured, (lo, hi)) in &verdicts {
        t.row(&[
            label.into(),
            expected_label.into(),
            format!("{measured:.2}"),
            pass(lo <= measured && measured <= hi),
        ]);
    }
    println!("\ncomplexity verdicts (EXPERIMENTS.md SEC3.2-C):\n{}", t.render());

    // crossover: at what n does brute force become slower than STI-KNN's
    // LARGEST measured run? extrapolate the 2^n fit
    let n_big = *ns.last().unwrap();
    let t_big = sti_times.last().unwrap();
    let cross = (t_big.ln() - (brute_times[0].ln() - b_slope * bnsf[0])) / b_slope;
    println!(
        "extrapolated: brute force exceeds STI-KNN's n={n_big} wall time already at n ≈ {cross:.0} \
         (the paper's 'no real-world applications at this level')"
    );

    // machine-readable artifact: raw suites + fitted slopes + verdicts
    let artifact = Json::obj(vec![
        ("bench", Json::str("scaling")),
        ("suites", Json::arr([suite.to_json(), brute.to_json(), tsuite.to_json()])),
        (
            "slopes",
            Json::arr(verdicts.iter().map(
                |&(name, _, _, expected, measured, (lo, hi))| {
                    Json::obj(vec![
                        ("name", Json::str(name)),
                        ("expected", Json::num(expected)),
                        ("measured", Json::num(measured)),
                        ("pass", Json::Bool(lo <= measured && measured <= hi)),
                    ])
                },
            )),
        ),
        ("brute_crossover_n", Json::num(cross)),
    ]);
    let out = stiknn::bench::artifact_path(env!("CARGO_MANIFEST_DIR"), "BENCH_scaling.json");
    match std::fs::write(&out, artifact.to_string()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}

fn pass(ok: bool) -> String {
    if ok { "PASS".into() } else { "FAIL".into() }
}
