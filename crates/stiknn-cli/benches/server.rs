//! §SERVER — concurrent multi-session serve-layer throughput
//! (EXPERIMENTS.md §SERVER).
//!
//! The server's pitch (DESIGN.md §12) is that per-session RwLocks let
//! read traffic scale with client count while writes serialize per
//! session without blocking other sessions. This bench measures
//! commands/second across a client-count × read/write-mix grid (every
//! client drives its own [`Connection`] against one shared registry,
//! round-robin over 4 sessions), plus the LRU spill→reload cycle cost,
//! an obs off/on/on+trace A/B/C on the same cell (the DESIGN.md §14/§16
//! overhead budget is < 2% per layer), and writes the trajectory artifact
//! `BENCH_server.json` at the REPO ROOT (CI uploads it per commit) —
//! including the end-of-run process-wide `metrics` snapshot, so the
//! trajectory records behavior (spills, lock waits, per-command
//! latencies), not just wall-clock.
//!
//!     cargo bench --bench server              # full size (n=600)
//!     cargo bench --bench server -- --quick   # CI size   (n=200)

use std::path::Path;
use std::sync::Arc;

use stiknn::bench::{quick, Suite};
use stiknn::data::load_dataset;
use stiknn::obs::{ObsHandle, TraceHandle};
use stiknn::server::{Connection, RegistryConfig, SessionRegistry, TrainData};
use stiknn::session::{Engine, SessionConfig};
use stiknn::util::json::Json;

const SESSIONS: usize = 4;

/// Commands per client per bench iteration.
const CMDS: usize = 64;

fn registry(
    train: &TrainData,
    config: SessionConfig,
    state: Option<(usize, &Path)>,
    obs: bool,
    traced: bool,
) -> Arc<SessionRegistry> {
    let (max_resident, state_dir) = match state {
        Some((cap, dir)) => (cap, Some(dir.to_path_buf())),
        None => (0, None),
    };
    let mut reg = SessionRegistry::new(
        train.clone(),
        RegistryConfig {
            base: config,
            max_resident,
            state_dir,
        },
    )
    .unwrap();
    if obs {
        reg = reg.with_obs(ObsHandle::enabled("bench"));
    }
    if traced {
        reg = reg.with_trace(TraceHandle::enabled());
    }
    let reg = Arc::new(reg);
    for s in 0..SESSIONS {
        reg.open(&format!("s{s}"), None, None).unwrap();
    }
    // warm every session with one batch so reads have state to serve
    let mut conn = Connection::new(Arc::clone(&reg), None);
    for s in 0..SESSIONS {
        let (r, _) = conn.execute(&format!(r#"{{"cmd":"use","name":"s{s}"}}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        let (r, _) = conn.execute(r#"{"cmd":"ingest","x":[0.5,0.5,-1.0,0.25],"y":[0,1]}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    }
    reg
}

/// One client's command for (step): `write_every` = 0 means read-only.
fn command(d: usize, step: usize, write_every: usize) -> String {
    if write_every > 0 && step % write_every == 0 {
        let x: Vec<String> = (0..d).map(|j| format!("0.{}", (step + j) % 100)).collect();
        return format!(
            r#"{{"cmd":"ingest","x":[{}],"y":[{}]}}"#,
            x.join(","),
            step % 2
        );
    }
    match step % 3 {
        0 => r#"{"cmd":"values","i":3}"#.to_string(),
        1 => r#"{"cmd":"topk","k":10,"by":"rowsum"}"#.to_string(),
        _ => r#"{"cmd":"stats"}"#.to_string(),
    }
}

/// Run `clients` threads of `CMDS` commands each; every thread sticks to
/// one session (client % SESSIONS) so writes contend only when clients
/// share a session.
fn drive(reg: &Arc<SessionRegistry>, d: usize, clients: usize, write_every: usize) {
    std::thread::scope(|scope| {
        for client in 0..clients {
            let reg = Arc::clone(reg);
            scope.spawn(move || {
                let mut conn =
                    Connection::new(reg, Some(format!("s{}", client % SESSIONS)));
                for step in 0..CMDS {
                    let (r, _) = conn.execute(&command(d, step, write_every));
                    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
                }
            });
        }
    });
}

fn main() {
    let quick_mode = std::env::args().any(|a| a == "--quick")
        || std::env::var_os("STIKNN_BENCH_QUICK").is_some();
    let n = if quick_mode { 200usize } else { 600 };
    let k = 5;
    let ds = load_dataset("cpu", n, 8, 7).unwrap();
    let train = TrainData::from_dataset(&ds);
    // implicit sessions: O(n log n) per ingested point keeps the bench
    // about lock contention, not about matrix sweeps
    let config = SessionConfig::new(k).with_engine(Engine::Implicit);

    let mut suite = Suite::new(&format!(
        "server throughput (n={n}, k={k}, {SESSIONS} sessions, {CMDS} cmds/client)"
    ));
    if quick_mode {
        suite = suite.with_config(quick());
    }

    let client_counts: &[usize] = if quick_mode { &[1, 4] } else { &[1, 2, 4, 8] };
    // write_every: 0 = read-only, 4 = 25% writes, 1 = all writes
    let mixes: &[(usize, &str)] = &[(0, "reads"), (4, "mixed"), (1, "writes")];
    let mut grid = Vec::new();
    for &clients in client_counts {
        for &(write_every, label) in mixes {
            // obs ON: grid numbers stay comparable to the production
            // default, and any regression against the prior trajectory
            // artifact is telemetry cost showing up where it matters
            let reg = registry(&train, config, None, true, false);
            let m = suite.bench(&format!("{label} x{clients}"), || {
                drive(&reg, ds.d, clients, write_every)
            });
            let cmds_per_sec = (clients * CMDS) as f64 / m.mean_secs();
            grid.push((clients, label, cmds_per_sec, m));
        }
    }

    // obs A/B/C — the same mixed cell with telemetry off vs on vs
    // on+tracing, isolating what the instrumentation itself costs
    // (DESIGN.md §14/§16 budget: <2% per layer)
    let ab_clients = *client_counts.last().unwrap();
    let reg_off = registry(&train, config, None, false, false);
    let ab_off = suite.bench(&format!("mixed x{ab_clients} obs=off"), || {
        drive(&reg_off, ds.d, ab_clients, 4)
    });
    let reg_on = registry(&train, config, None, true, false);
    let ab_on = suite.bench(&format!("mixed x{ab_clients} obs=on"), || {
        drive(&reg_on, ds.d, ab_clients, 4)
    });
    let reg_traced = registry(&train, config, None, true, true);
    let ab_traced = suite.bench(&format!("mixed x{ab_clients} obs=on trace=on"), || {
        drive(&reg_traced, ds.d, ab_clients, 4)
    });
    let off_cps = (ab_clients * CMDS) as f64 / ab_off.mean_secs();
    let on_cps = (ab_clients * CMDS) as f64 / ab_on.mean_secs();
    let traced_cps = (ab_clients * CMDS) as f64 / ab_traced.mean_secs();
    let overhead_pct = (off_cps - on_cps) / off_cps * 100.0;
    let trace_overhead_pct = (off_cps - traced_cps) / off_cps * 100.0;

    // LRU spill→reload cycle: 4 sessions behind a 2-slot cap, touched
    // round-robin — every touch beyond the cap evicts one session and
    // restores another (the save amortizes away once sessions are clean,
    // so steady state measures the reload side)
    let state = std::env::temp_dir().join(format!("stiknn_bench_server_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&state);
    let reg = registry(&train, config, Some((2, state.as_path())), true, false);
    let spill = suite.bench("lru spill+reload touch", || {
        let mut conn = Connection::new(Arc::clone(&reg), None);
        for s in 0..SESSIONS {
            let (r, _) = conn.execute(&format!(r#"{{"cmd":"use","name":"s{s}"}}"#));
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
            let (r, _) = conn.execute(r#"{"cmd":"stats"}"#);
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        }
    });
    // End-of-run telemetry from the LRU registry (the richest one:
    // per-command histograms, lock wait/hold, spill and reload counts)
    // rides along in the artifact.
    let metrics_snap = {
        let mut conn = Connection::new(Arc::clone(&reg), None);
        let (r, _) = conn.execute(r#"{"cmd":"metrics","scope":"process"}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        r.get("metrics").cloned().unwrap_or(Json::Null)
    };
    let _ = std::fs::remove_dir_all(&state);

    println!("{}", suite.render());
    for (clients, label, cmds_per_sec, _) in &grid {
        println!("{label:>6} x{clients}: {cmds_per_sec:.0} cmds/s");
    }
    println!(
        "obs A/B (mixed x{ab_clients}): off {off_cps:.0} cmds/s, on {on_cps:.0} cmds/s \
         ({overhead_pct:+.2}% overhead), on+trace {traced_cps:.0} cmds/s \
         ({trace_overhead_pct:+.2}% overhead)"
    );

    let artifact = Json::obj(vec![
        ("bench", Json::str("server")),
        ("quick", Json::Bool(quick_mode)),
        ("n", Json::num(n as f64)),
        ("k", Json::num(k as f64)),
        ("sessions", Json::num(SESSIONS as f64)),
        ("cmds_per_client", Json::num(CMDS as f64)),
        (
            "grid",
            Json::arr(grid.iter().map(|(clients, label, cmds_per_sec, m)| {
                Json::obj(vec![
                    ("clients", Json::num(*clients as f64)),
                    ("mix", Json::str(*label)),
                    ("cmds_per_sec", Json::num(*cmds_per_sec)),
                    ("mean_secs", Json::num(m.mean_secs())),
                ])
            })),
        ),
        (
            "lru_cycle_secs",
            Json::num(spill.mean_secs() / SESSIONS as f64),
        ),
        (
            "obs_ab",
            Json::obj(vec![
                ("clients", Json::num(ab_clients as f64)),
                ("mix", Json::str("mixed")),
                ("obs_off_cmds_per_sec", Json::num(off_cps)),
                ("obs_on_cmds_per_sec", Json::num(on_cps)),
                ("overhead_pct", Json::num(overhead_pct)),
                ("traced_cmds_per_sec", Json::num(traced_cps)),
                ("trace_overhead_pct", Json::num(trace_overhead_pct)),
            ]),
        ),
        ("metrics", metrics_snap),
        ("suite", suite.to_json()),
    ]);
    // Repo root, not CWD (same rationale as BENCH_session.json).
    let out = stiknn::bench::artifact_path(env!("CARGO_MANIFEST_DIR"), "BENCH_server.json");
    match std::fs::write(&out, artifact.to_string()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
