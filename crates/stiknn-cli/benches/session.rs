//! §SERVE — one-shot vs incremental session-ingest throughput
//! (EXPERIMENTS.md §SERVE).
//!
//! The session layer's pitch is that streaming ingest costs ~nothing
//! over a one-shot run (Eq. 9 additivity: same sweeps, just split across
//! calls), while tiny batches expose the per-call fixed cost (prep
//! allocation + final mirror being amortized over fewer points). This
//! bench measures both sides plus snapshot save/restore, and writes the
//! machine-readable trajectory artifact `BENCH_session.json` at the REPO
//! WORKSPACE ROOT (resolved from CARGO_MANIFEST_DIR ancestors by
//! `bench::artifact_path`, so the location does not
//! depend on the invoking working directory — CI uploads it per commit).
//!
//!     cargo bench --bench session              # full size (n=600, t=150)
//!     cargo bench --bench session -- --quick   # CI size   (n=200, t=60)

use stiknn::bench::{quick, Suite};
use stiknn::data::load_dataset;
use stiknn::session::{SessionConfig, ValuationSession};
use stiknn::shapley::sti_knn::{sti_knn, StiParams};
use stiknn::util::json::Json;

fn main() {
    let quick_mode = std::env::args().any(|a| a == "--quick")
        || std::env::var_os("STIKNN_BENCH_QUICK").is_some();
    let (n, t) = if quick_mode { (200usize, 60usize) } else { (600, 150) };
    let k = 5;
    let ds = load_dataset("cpu", n, t, 7).unwrap();

    let mut suite = Suite::new(&format!("one-shot vs incremental ingest (n={n}, t={t}, k={k})"));
    if quick_mode {
        suite = suite.with_config(quick());
    }

    let one_shot = suite.bench("one-shot sti_knn", || {
        sti_knn(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(k),
        )
    });

    // Incremental ingest across batch sizes: same t test points, cut
    // into contiguous batches of b, through a fresh session each run.
    let batch_sizes = [1usize, 8, 32, t];
    let mut batch_results = Vec::new();
    for &b in &batch_sizes {
        let m = suite.bench(&format!("session ingest batch={b}"), || {
            let mut s = ValuationSession::from_dataset(&ds, SessionConfig::new(k)).unwrap();
            let mut lo = 0;
            while lo < t {
                let hi = (lo + b).min(t);
                s.ingest(&ds.test_x[lo * ds.d..hi * ds.d], &ds.test_y[lo..hi])
                    .unwrap();
                lo = hi;
            }
            s.matrix().unwrap()
        });
        batch_results.push((b, m));
    }

    // Snapshot persistence cost at this n.
    let mut warm = ValuationSession::from_dataset(&ds, SessionConfig::new(k)).unwrap();
    warm.ingest(&ds.test_x, &ds.test_y).unwrap();
    let snap_path = std::env::temp_dir().join(format!(
        "stiknn_bench_session_{}.snap",
        std::process::id()
    ));
    let save = suite.bench("snapshot save", || warm.save(&snap_path).unwrap());
    let restore = suite.bench("snapshot restore", || {
        ValuationSession::restore(
            &snap_path,
            ds.train_x.clone(),
            ds.train_y.clone(),
            ds.d,
            SessionConfig::new(k),
        )
        .unwrap()
    });
    let _ = std::fs::remove_file(&snap_path);

    println!("{}", suite.render());
    for (b, m) in &batch_results {
        println!(
            "batch={b:>4}: {:.2}x one-shot, {:.1} test-points/s",
            m.mean_secs() / one_shot.mean_secs(),
            t as f64 / m.mean_secs()
        );
    }

    let artifact = Json::obj(vec![
        ("bench", Json::str("session")),
        ("quick", Json::Bool(quick_mode)),
        ("n", Json::num(n as f64)),
        ("t", Json::num(t as f64)),
        ("k", Json::num(k as f64)),
        ("one_shot_secs", Json::num(one_shot.mean_secs())),
        (
            "ingest",
            Json::arr(batch_results.iter().map(|(b, m)| {
                Json::obj(vec![
                    ("batch", Json::num(*b as f64)),
                    ("mean_secs", Json::num(m.mean_secs())),
                    (
                        "overhead_vs_one_shot",
                        Json::num(m.mean_secs() / one_shot.mean_secs()),
                    ),
                    (
                        "test_points_per_sec",
                        Json::num(t as f64 / m.mean_secs()),
                    ),
                ])
            })),
        ),
        ("snapshot_save_secs", Json::num(save.mean_secs())),
        ("snapshot_restore_secs", Json::num(restore.mean_secs())),
        ("suite", suite.to_json()),
    ]);
    // Workspace root, not CWD: benches run with CWD = the package dir
    // but the trajectory artifact lives beside ROADMAP.md.
    let out = stiknn::bench::artifact_path(env!("CARGO_MANIFEST_DIR"), "BENCH_session.json");
    match std::fs::write(&out, artifact.to_string()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
