//! §VALUES — dense O(t·n²) matrix sweep vs implicit O(t·n log n)
//! per-point values (EXPERIMENTS.md §VALUES, DESIGN.md §10).
//!
//! The implicit engine's pitch is asymptotic, not constant-factor: per
//! test point, the dense path walks n²/2 accumulator cells while the
//! implicit path does one O(n log n) prep + one O(n) suffix-sum fold.
//! This bench measures both single-threaded across n, runs the implicit
//! engine at an n where the dense matrix would need gigabytes (n=32k →
//! 8.2 GB of f64, deliberately NOT attempted dense), probes peak RSS
//! before/after the dense sweeps, and writes the machine-readable
//! trajectory artifact `BENCH_values.json` at the REPO ROOT.
//!
//!     cargo bench --bench values              # full (CI runs this): n ∈ {600, 2k, 8k, 32k}
//!     cargo bench --bench values -- --quick   # fast local smoke:    n ∈ {600, 2k}

use stiknn::bench::{quick, BenchConfig, Suite};
use stiknn::data::load_dataset;
use stiknn::shapley::sti_knn::{sti_knn, StiParams};
use stiknn::shapley::values::sti_values;
use stiknn::util::json::Json;

/// VmHWM (peak resident set) in kB from /proc/self/status — linux only;
/// `None` elsewhere or if the file is unreadable.
fn peak_rss_kb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("VmHWM"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse::<f64>().ok())
}

fn json_opt(v: Option<f64>) -> Json {
    // Json::num maps non-finite to null; NAN is the "absent" carrier.
    Json::num(v.unwrap_or(f64::NAN))
}

fn main() {
    let quick_mode = std::env::args().any(|a| a == "--quick")
        || std::env::var_os("STIKNN_BENCH_QUICK").is_some();
    let k = 5;
    let t = 64;
    // (n, attempt the dense sweep at this n?)
    let sizes: Vec<(usize, bool)> = if quick_mode {
        vec![(600, true), (2000, true)]
    } else {
        // n=32k: implicit only — the dense accumulator alone would be
        // 32000² × 8 B ≈ 8.2 GB, which is the point of the exercise.
        vec![(600, true), (2000, true), (8000, true), (32000, false)]
    };

    let mut suite = Suite::new(&format!(
        "dense matrix sweep vs implicit per-point values (t={t}, k={k}, single-thread)"
    ));
    suite = suite.with_config(if quick_mode {
        quick()
    } else {
        // The n=8k dense sweep runs ~seconds per iteration; keep the
        // total bounded while still averaging a few runs at small n.
        BenchConfig {
            min_time: std::time::Duration::from_millis(500),
            max_iters: 10,
            warmup_iters: 1,
        }
    });

    let mut entries = Vec::new();
    // All implicit runs first, then dense: VmHWM is a high-water mark, so
    // this order lets the implicit-only phase record its (small) peak
    // before the dense allocations raise it permanently.
    let mut implicit_secs = std::collections::BTreeMap::new();
    for &(n, _) in &sizes {
        let ds = load_dataset("cpu", n, t, 7).expect("registry dataset");
        let m = suite.bench(&format!("implicit values n={n}"), || {
            sti_values(
                &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
                &StiParams::new(k),
            )
        });
        implicit_secs.insert(n, m.mean_secs());
    }
    let rss_after_implicit_kb = peak_rss_kb();

    for &(n, dense) in &sizes {
        let implicit = implicit_secs[&n];
        let dense_secs = if dense {
            let ds = load_dataset("cpu", n, t, 7).expect("registry dataset");
            let m = suite.bench(&format!("dense sweep n={n}"), || {
                sti_knn(
                    &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
                    &StiParams::new(k),
                )
            });
            Some(m.mean_secs())
        } else {
            None
        };
        let speedup = dense_secs.map(|d| d / implicit);
        println!(
            "n={n:>6}: implicit {implicit:.4}s{}",
            match (dense_secs, speedup) {
                (Some(d), Some(s)) => format!(", dense {d:.4}s, speedup {s:.1}x"),
                _ => ", dense not attempted (matrix would not fit the budget)".to_string(),
            }
        );
        entries.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("t", Json::num(t as f64)),
            ("implicit_secs", Json::num(implicit)),
            ("dense_secs", json_opt(dense_secs)),
            ("speedup_dense_over_implicit", json_opt(speedup)),
            (
                "implicit_test_points_per_sec",
                Json::num(t as f64 / implicit),
            ),
            ("dense_attempted", Json::Bool(dense)),
        ]));
    }
    let rss_final_kb = peak_rss_kb();

    println!("{}", suite.render());
    if let (Some(a), Some(b)) = (rss_after_implicit_kb, rss_final_kb) {
        println!(
            "peak RSS: {:.0} MB after all implicit runs (incl. n={}), {:.0} MB after dense",
            a / 1024.0,
            sizes.last().unwrap().0,
            b / 1024.0
        );
    }

    let artifact = Json::obj(vec![
        ("bench", Json::str("values")),
        ("quick", Json::Bool(quick_mode)),
        ("k", Json::num(k as f64)),
        ("t", Json::num(t as f64)),
        ("sizes", Json::arr(entries)),
        ("peak_rss_kb_after_implicit", json_opt(rss_after_implicit_kb)),
        ("peak_rss_kb_final", json_opt(rss_final_kb)),
        ("suite", suite.to_json()),
    ]);
    // Workspace root, not CWD: benches run with CWD = the package dir
    // but the trajectory artifact lives beside ROADMAP.md.
    let out = stiknn::bench::artifact_path(env!("CARGO_MANIFEST_DIR"), "BENCH_values.json");
    match std::fs::write(&out, artifact.to_string()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
