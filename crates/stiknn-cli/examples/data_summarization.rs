//! Data summarization & the Fig. 4 redundancy effect — the use cases the
//! paper's introduction motivates ("training set summarization,
//! acquisition, and outlier removal").
//!
//! Part 1 (Fig. 4): subsample one Circle class and show the per-pair
//! in-class interaction magnitude RISES (the efficiency budget is split
//! across fewer, less redundant pairs).
//!
//! Part 2 (summarization): rank training points by value and remove the
//! least valuable ones, tracking test accuracy — low-value-first removal
//! retains accuracy far longer than adversarial high-value-first removal.
//!
//!     cargo run --release --example data_summarization

use stiknn::analysis::redundancy::class_block_mean_abs;
use stiknn::analysis::removal::{curve_area, order_by_value_asc, order_by_value_desc, removal_curve};
use stiknn::data::{corrupt, load_dataset};
use stiknn::report::table::Table;
use stiknn::shapley::knn_shapley::knn_shapley;
use stiknn::shapley::sti_knn::{sti_knn, StiParams};

fn main() {
    let k = 5;

    // ---- Part 1: Fig. 4 — redundancy decreases in-class interaction ----
    let ds = load_dataset("circle", 600, 150, 9).unwrap();
    let phi_full = sti_knn(
        &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
        &StiParams::new(k),
    );
    let mut t = Table::new(&["class-0 points", "mean |phi| within class 0"]);
    t.row(&[
        format!("{} (balanced)", ds.train_class_counts()[0]),
        format!("{:.4e}", class_block_mean_abs(&phi_full, &ds.train_y, 0)),
    ]);
    for keep in [120usize, 60] {
        let sub = corrupt::subsample_class(&ds, 0, keep, 3);
        let phi = sti_knn(
            &sub.train_x, &sub.train_y, sub.d, &sub.test_x, &sub.test_y,
            &StiParams::new(k),
        );
        t.row(&[
            format!("{keep} (subsampled)"),
            format!("{:.4e}", class_block_mean_abs(&phi, &sub.train_y, 0)),
        ]);
    }
    println!("Fig. 4 — redundancy decreases in-class interaction:\n");
    println!("{}", t.render());

    // ---- Part 2: summarization via per-point values ------------------
    let mut noisy = load_dataset("circle", 400, 120, 11).unwrap();
    corrupt::flip_labels(&mut noisy, 0.08, 5);
    let values = knn_shapley(
        &noisy.train_x, &noisy.train_y, noisy.d, &noisy.test_x, &noisy.test_y, k,
    );
    let low_first = removal_curve(&noisy, &order_by_value_asc(&values), 40, 60, k);
    let high_first = removal_curve(&noisy, &order_by_value_desc(&values), 40, 60, k);
    let mut t2 = Table::new(&["removed", "acc (low-value first)", "acc (high-value first)"]);
    for (a, b) in low_first.iter().zip(&high_first) {
        t2.row(&[
            a.0.to_string(),
            format!("{:.3}", a.1),
            format!("{:.3}", b.1),
        ]);
    }
    println!("summarization — remove points by Shapley value (8% labels flipped):\n");
    println!("{}", t2.render());
    println!(
        "area under curve: low-first {:.3} vs high-first {:.3}",
        curve_area(&low_first),
        curve_area(&high_first)
    );
    assert!(curve_area(&low_first) > curve_area(&high_first));
    println!("\ndata_summarization OK");
}
