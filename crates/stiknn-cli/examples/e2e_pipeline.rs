//! END-TO-END DRIVER: the full system on a real workload.
//!
//! Exercises every layer in composition:
//!   L3   — the coordinator shards the Circle test set and runs it under
//!          BOTH assembly strategies: row-banded (one shared n×n
//!          accumulator, O(n²) memory, bit-identical to single-threaded)
//!          and legacy test-sharded (private accumulator per worker)
//!   L1/L2 — when `make artifacts` has run and the build has the `xla`
//!          feature: the Pallas distance + assembly kernels inside the
//!          JAX block program, AOT-lowered to `artifacts/*.hlo.txt`,
//!          loaded and compiled by the PJRT CPU client per worker
//!
//! It cross-checks all engines against each other and the O(2ⁿ) brute
//! force (on a subsample), checks the axioms, and prints the headline
//! table recorded in EXPERIMENTS.md §E2E.
//!
//!     cargo run --release --example e2e_pipeline          # rust engines
//!     make artifacts && cargo run --release --example e2e_pipeline

use std::path::Path;
use stiknn::coordinator::{run_job_with_engine, Assembly, ValuationJob};
use stiknn::data::load_dataset;
use stiknn::report::table::Table;
use stiknn::runtime::{Engine, Manifest};
use stiknn::shapley::{axioms, sti_exact};
use stiknn::util::timer::fmt_duration;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    let have_artifacts = artifacts.join("manifest.json").exists();
    if have_artifacts {
        let manifest = Manifest::load(artifacts)?;
        println!(
            "loaded manifest: {} artifacts ({} sti, {} knn_shapley)\n",
            manifest.artifacts.len(),
            manifest.of_program("sti").len(),
            manifest.of_program("knn_shapley").len()
        );
    } else {
        println!("artifacts/ missing — rust engines only (run `make artifacts` for XLA)\n");
    }

    // The paper's headline workload: Circle, n=600, k=5 (Fig. 3 shape).
    let ds = load_dataset("circle", 600, 150, 42).unwrap();
    let k = 5;
    println!(
        "workload: {} n={} t={} d={} k={k}",
        ds.name,
        ds.n_train(),
        ds.n_test(),
        ds.d
    );

    let mut table = Table::new(&[
        "engine", "workers", "blocks", "wall", "test-pts/s", "max|Δ| vs banded@1",
    ]);

    // Reference: single-worker banded (bit-identical to single-threaded
    // sti_knn by construction).
    let job = ValuationJob::new(k).with_workers(1).with_block_size(32);
    let reference = run_job_with_engine(&ds, &job, artifacts)?;
    table.row(&[
        "rust banded".into(),
        "1".into(),
        reference.blocks.to_string(),
        fmt_duration(reference.elapsed),
        format!("{:.0}", reference.throughput),
        "0".into(),
    ]);

    for workers in [2usize, 4] {
        let job = ValuationJob::new(k).with_workers(workers).with_block_size(32);
        let res = run_job_with_engine(&ds, &job, artifacts)?;
        let delta = res.phi.max_abs_diff(&reference.phi);
        table.row(&[
            "rust banded".into(),
            workers.to_string(),
            res.blocks.to_string(),
            fmt_duration(res.elapsed),
            format!("{:.0}", res.throughput),
            format!("{:.1e}", delta),
        ]);
        // banded is bit-identical across worker counts, not merely close
        anyhow::ensure!(delta == 0.0, "banded engine not bit-deterministic");
    }

    for workers in [2usize, 4] {
        let job = ValuationJob::new(k)
            .with_workers(workers)
            .with_block_size(32)
            .with_assembly(Assembly::TestSharded);
        let res = run_job_with_engine(&ds, &job, artifacts)?;
        let delta = res.phi.max_abs_diff(&reference.phi);
        table.row(&[
            "rust sharded".into(),
            workers.to_string(),
            res.blocks.to_string(),
            fmt_duration(res.elapsed),
            format!("{:.0}", res.throughput),
            format!("{:.1e}", delta),
        ]);
        anyhow::ensure!(delta < 1e-12, "sharded/banded divergence {delta}");
    }

    if have_artifacts {
        for workers in [1usize, 2] {
            let job = ValuationJob::new(k)
                .with_engine(Engine::Xla)
                .with_workers(workers);
            match run_job_with_engine(&ds, &job, artifacts) {
                Ok(res) => {
                    let delta = res.phi.max_abs_diff(&reference.phi);
                    table.row(&[
                        "xla (AOT artifact)".into(),
                        workers.to_string(),
                        res.blocks.to_string(),
                        fmt_duration(res.elapsed),
                        format!("{:.0}", res.throughput),
                        format!("{:.1e}", delta),
                    ]);
                    anyhow::ensure!(delta < 5e-4, "XLA/rust divergence {delta}");
                }
                Err(e) => {
                    // artifacts present but no PJRT runtime in this build
                    println!("xla engine unavailable: {e:#}");
                    break;
                }
            }
        }
    }

    println!("\n{}", table.render());

    // Axioms on the final matrix (the §3.2 structural claims).
    let reports = axioms::check_all(
        &reference.phi, &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
        k, 1e-9,
    );
    println!("axioms:\n{}", axioms::format_reports(&reports));
    anyhow::ensure!(axioms::all_hold(&reports), "axiom violation");

    // Exactness vs the O(2ⁿ) baseline on a subsample (n=14 is enumerable).
    let sub = ds.retain_train(&(0..14).collect::<Vec<_>>());
    let t0 = std::time::Instant::now();
    let exact = sti_exact::sti_exact(
        &sub.train_x, &sub.train_y, sub.d, &sub.test_x[..20 * sub.d], &sub.test_y[..20], 5,
    );
    let exact_time = t0.elapsed();
    let t1 = std::time::Instant::now();
    let fast = stiknn::shapley::sti_knn(
        &sub.train_x, &sub.train_y, sub.d, &sub.test_x[..20 * sub.d], &sub.test_y[..20],
        &stiknn::shapley::StiParams::new(5),
    );
    let fast_time = t1.elapsed();
    let err = exact.max_abs_diff(&fast);
    println!(
        "exactness vs O(2ⁿ) brute force (n=14, t=20): max|Δ| = {err:.2e}; \
         brute {} vs STI-KNN {} ({}x speedup at toy scale)",
        fmt_duration(exact_time),
        fmt_duration(fast_time),
        (exact_time.as_secs_f64() / fast_time.as_secs_f64()) as u64,
    );
    anyhow::ensure!(err < 1e-12, "fast algorithm is not exact");

    println!("\ne2e_pipeline OK — record the table above in EXPERIMENTS.md §E2E");
    Ok(())
}
