//! §3.2 + Appendix B reproduction (Figs. 7–10): the parameter k has a
//! negligible effect on the pair-interaction matrix.
//!
//! Sweeps the paper's k-range over the paper's figure datasets (Circle
//! k=9/20, Moon k=3/7, Click k=5/15, MonksV2 k=3/4) plus the full
//! 3 ≤ k ≤ 20 grid over all 16 Table-1 twins, reporting both the paper's
//! methodology (full flattened matrices) and the stricter off-diagonal
//! correlation, plus the Corollary-1 std trend.
//!
//!     cargo run --release --example k_sensitivity [--full]

use stiknn::analysis::ksens::k_sensitivity;
use stiknn::data::{load_dataset, registry_names};
use stiknn::report::table::Table;

fn main() {
    let full = std::env::args().any(|a| a == "--full");

    // the paper's per-figure (dataset, k1, k2) pairs
    println!("paper figures (k1 vs k2 correlation, full-matrix / offdiag):\n");
    let mut t = Table::new(&["figure", "dataset", "k pair", "r (paper method)", "r (offdiag)"]);
    for (fig, name, k1, k2) in [
        ("Fig. 7", "circle", 9usize, 20usize),
        ("Fig. 8", "moon", 3, 7),
        ("Fig. 9", "click", 5, 15),
        ("Fig. 10", "monksv2", 3, 4),
    ] {
        let ds = load_dataset(name, 0, 0, 42).unwrap();
        let rep = k_sensitivity(&ds, &[k1, k2]);
        t.row(&[
            fig.to_string(),
            name.to_string(),
            format!("{k1} vs {k2}"),
            format!("{:.4}", rep.min_correlation),
            format!("{:.4}", rep.min_correlation_offdiag),
        ]);
    }
    println!("{}", t.render());

    // the §3.2 sweep: 3 <= k <= 20 over the registry
    let ks: Vec<usize> = if full {
        (3..=20).collect()
    } else {
        vec![3, 5, 9, 14, 20]
    };
    println!(
        "\n§3.2 sweep (k ∈ {ks:?}) over the Table-1 registry{}:\n",
        if full { "" } else { " (pass --full for every k)" }
    );
    let mut t2 = Table::new(&[
        "dataset", "min r (paper)", "min r (offdiag)", "std k=3", "std k=20", "std ratio",
    ]);
    let mut worst: f64 = 1.0;
    for name in registry_names() {
        // smaller instances keep the sweep fast; ksens is O(|ks|·t·n²)
        let ds = load_dataset(name, 300, 80, 42).unwrap();
        let rep = k_sensitivity(&ds, &ks);
        worst = worst.min(rep.min_correlation);
        t2.row(&[
            name.to_string(),
            format!("{:.4}", rep.min_correlation),
            format!("{:.4}", rep.min_correlation_offdiag),
            format!("{:.2e}", rep.stds[0]),
            format!("{:.2e}", rep.stds[rep.stds.len() - 1]),
            format!("{:.2}", rep.stds[0] / rep.stds[rep.stds.len() - 1]),
        ]);
    }
    println!("{}", t2.render());
    println!(
        "worst full-matrix correlation across registry: {worst:.4} \
         (paper claims > 0.99 on its 16 datasets)"
    );
    println!(
        "Corollary 1: std ratio ≈ k_max/k_min = {:.1} expected from 1/k scaling",
        20.0 / 3.0
    );
}
