//! Fig. 5 reproduction: mislabeled points behave like the opposite class.
//!
//! Flips 5% of the Circle training labels, recomputes the interaction
//! matrix, and detects the flips from row patterns (a point whose row
//! correlates better with the other class's template is suspicious).
//!
//!     cargo run --release --example mislabel_detection

use stiknn::analysis::mislabel::{auc, mislabel_scores, top_prevalence_recall};
use stiknn::data::{corrupt, load_dataset};
use stiknn::report::table::Table;
use stiknn::shapley::sti_knn::{sti_knn, StiParams};

fn main() {
    let k = 5;
    let mut table = Table::new(&["dataset", "flip%", "AUC", "top-prev recall"]);
    for (name, flip) in [
        ("circle", 0.05),
        ("circle", 0.10),
        ("moon", 0.05),
        ("moon", 0.10),
    ] {
        let mut ds = load_dataset(name, 600, 150, 7).unwrap();
        let truth = corrupt::flip_labels(&mut ds, flip, 0xF11F ^ flip.to_bits());
        let phi = sti_knn(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(k),
        );
        let rep = mislabel_scores(&phi, &ds.train_y, ds.classes);
        let a = auc(&rep.margins, &truth);
        let r = top_prevalence_recall(&rep.margins, &truth);
        table.row(&[
            name.to_string(),
            format!("{:.0}%", flip * 100.0),
            format!("{a:.3}"),
            format!("{r:.3}"),
        ]);
    }
    println!("mislabel detection from STI interaction patterns (paper Fig. 5):\n");
    println!("{}", table.render());
    println!(
        "interpretation: AUC ≈ 1 means flipped points' interaction rows\n\
         pattern-match the opposite class, which is exactly the paper's\n\
         visual claim in Fig. 5 (right panel)."
    );
}
