//! Perf probe for the §Perf pass: isolates the STI-KNN hot path at the
//! shapes the optimization log tracks, single-threaded and through the
//! banded coordinator. Not a paper experiment.
//!
//!     cargo run --release --example perf_probe

use stiknn::coordinator::{run_job, Assembly, ValuationJob};
use stiknn::data::load_dataset;
use stiknn::shapley::sti_knn::{sti_knn, StiParams};

fn main() {
    // single-threaded kernel
    for (n, t, k, reps) in [(600usize, 300usize, 5usize, 5u32), (1600, 64, 5, 3)] {
        let ds = load_dataset("circle", n, t, 5).unwrap();
        let params = StiParams::new(k);
        // warmup
        let _ = sti_knn(&ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, &params);
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(sti_knn(
                &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, &params,
            ));
        }
        let per = t0.elapsed() / reps;
        let cells = (n * n / 2) as f64 * t as f64;
        println!(
            "single-thread n={n} t={t} k={k}: {per:?}/run  {:.2} ns/pair-cell",
            per.as_nanos() as f64 / cells
        );
    }

    // banded coordinator: same kernel, O(n²) memory, scaling with workers
    let (n, t, k) = (1600usize, 128usize, 5usize);
    let ds = load_dataset("circle", n, t, 5).unwrap();
    let cells = (n * n / 2) as f64 * t as f64;
    for workers in [1usize, 2, 4, 8] {
        let job = ValuationJob::new(k)
            .with_workers(workers)
            .with_block_size(32)
            .with_assembly(Assembly::RowBanded { band_rows: 0 });
        let _ = run_job(&ds, &job).unwrap(); // warmup
        let t0 = std::time::Instant::now();
        let reps = 3u32;
        for _ in 0..reps {
            std::hint::black_box(run_job(&ds, &job).unwrap());
        }
        let per = t0.elapsed() / reps;
        println!(
            "banded n={n} t={t} k={k} workers={workers}: {per:?}/run  \
             {:.2} ns/pair-cell  (1 shared accumulator)",
            per.as_nanos() as f64 / cells
        );
    }
}
