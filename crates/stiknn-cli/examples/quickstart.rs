//! Quickstart: compute the exact pair-interaction Shapley matrix for the
//! paper's Circle dataset (Fig. 3) and verify the §3.2 axioms.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the pure-Rust engine (no artifacts needed); see
//! examples/e2e_pipeline.rs for the full XLA path.

use stiknn::analysis::redundancy::interaction_breakdown;
use stiknn::data::load_dataset;
use stiknn::report::heatmap::render_heatmap;
use stiknn::shapley::axioms;
use stiknn::shapley::sti_knn::{sti_knn, StiParams};

fn main() {
    // The paper's Circle dataset: 300 points per class, 2-D, k = 5.
    let ds = load_dataset("circle", 600, 150, 42).expect("registered dataset");
    let k = 5;

    println!(
        "STI-KNN on {}: n={} train, t={} test, k={k} — O(t·n²) exact",
        ds.name,
        ds.n_train(),
        ds.n_test()
    );
    let t0 = std::time::Instant::now();
    let phi = sti_knn(
        &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
        &StiParams::new(k),
    );
    println!(
        "computed {}×{} interaction matrix in {:?}\n",
        phi.rows(),
        phi.cols(),
        t0.elapsed()
    );

    // Fig. 3: class-block structure (display order: class, then features;
    // diagonal zeroed for display — main terms dwarf the interactions).
    let mut display = phi.clone();
    for i in 0..display.rows() {
        display.set(i, i, 0.0);
    }
    let order = ds.paper_display_order();
    println!("{}", render_heatmap(&display, Some(&order), 40));

    let b = interaction_breakdown(&phi, &ds.train_y);
    println!(
        "in-class mean |phi| = {:.3e}   out-of-class = {:.3e}  (ratio {:.2}x)\n",
        b.in_class,
        b.out_class,
        b.in_class / b.out_class
    );

    // §3.2 axioms.
    let reports = axioms::check_all(
        &phi, &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, k, 1e-9,
    );
    println!("axioms:\n{}", axioms::format_reports(&reports));
    assert!(axioms::all_hold(&reports), "axiom violation");
    println!("quickstart OK");
}
