//! `stiknn` CLI — the L3 entry point.
//!
//! Subcommands:
//!   value     compute the STI-KNN interaction matrix for a dataset
//!   values    per-point values (main + rowsum) via the implicit engine (§10)
//!   analyze   interaction heatmap + axiom checks + block structure (§4)
//!   ksens     k-sensitivity sweep (§3.2, Figs. 7–10)
//!   mislabel  flip labels and detect them from interaction patterns (Fig. 5)
//!   serve     concurrent multi-session NDJSON server: stdio or --listen TCP; --shard-of J/N (§9/§12/§13)
//!   metrics   fetch telemetry from a running server, Prometheus text or JSON (§14)
//!   trace     span trees from a running server; --fanout runs a traced sharded query (§16)
//!   mutate    live training-set edits with exact O(t·n) repairs (§11)
//!   session   inspect a session snapshot file (§9/§11)
//!   datasets  list the Table-1 dataset registry
//!   artifacts list the AOT artifact manifest
//!
//! `stiknn help <subcommand>` and `stiknn <subcommand> --help` both print
//! per-command usage; `stiknn --version` prints the crate version.
//! Every command accepts `--engine rust|xla` where applicable; XLA uses
//! the AOT artifacts under --artifacts (default: artifacts/).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use stiknn::analysis::ksens::k_sensitivity;
use stiknn::analysis::mislabel::{
    auc, mislabel_scores, mislabel_scores_values, precision_recall, top_prevalence_recall,
};
use stiknn::analysis::structure::block_structure;
use stiknn::coordinator::{run_job_with_engine, run_values_job, Assembly, ValuationJob};
use stiknn::data::{corrupt, csv, load_dataset_any, registry_names};
use stiknn::knn::distance::Metric;
use stiknn::obs::trace::{hex_id, render_tree};
use stiknn::obs::{prometheus_text, ObsHandle, SpanRecord, TraceHandle, TraceMode};
use stiknn::report::heatmap::render_heatmap;
use stiknn::report::session::{registry_table, snapshot_info_table, topk_table};
use stiknn::report::table::Table;
use stiknn::runtime::{Engine, Manifest};
use stiknn::server::{self, RegistryConfig, SessionRegistry, TrainData};
use stiknn::session::{store, SessionConfig, TopBy, ValuationSession};
use stiknn::shapley::axioms;
use stiknn::shapley::values::{sti_point_values, Engine as ValueEngine, PointValues};
use stiknn::shapley::StiParams;
use stiknn::util::cli::{wants_help, Args, Command};
use stiknn::util::json::Json;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("value") => cmd_value(&argv[1..]),
        Some("values") => cmd_values(&argv[1..]),
        Some("analyze") => cmd_analyze(&argv[1..]),
        Some("ksens") => cmd_ksens(&argv[1..]),
        Some("mislabel") => cmd_mislabel(&argv[1..]),
        Some("serve") => cmd_serve(&argv[1..]),
        Some("metrics") => cmd_metrics(&argv[1..]),
        Some("trace") => cmd_trace(&argv[1..]),
        Some("mutate") => cmd_mutate(&argv[1..]),
        Some("session") => cmd_session(&argv[1..]),
        Some("datasets") => cmd_datasets(&argv[1..]),
        Some("artifacts") => cmd_artifacts(&argv[1..]),
        Some("--version") | Some("-V") | Some("version") => {
            println!("stiknn {}", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        Some("help") => cmd_help(&argv[1..]),
        Some("--help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "stiknn {} — exact pair-interaction Data Shapley for KNN in O(t·n²)\n\n\
         subcommands:\n\
           value      compute the interaction matrix (CSV out)\n\
           values     per-point values via the implicit O(t·n log n) engine\n\
           analyze    heatmap + axioms + class-block structure\n\
           ksens      k-sensitivity sweep (paper §3.2)\n\
           mislabel   mislabel-detection experiment (paper Fig. 5)\n\
           serve      concurrent valuation server (NDJSON on stdio or --listen TCP)\n\
           metrics    telemetry snapshot from a running server (Prometheus text)\n\
           trace      request span trees from a running server (--fanout: sharded smoke)\n\
           mutate     live training-set edits (add/remove/relabel) with exact repairs\n\
           session    inspect a session snapshot file\n\
           datasets   list the dataset registry (paper Table 1)\n\
           artifacts  list the AOT artifact manifest\n\n\
         run `stiknn help <subcommand>` or `stiknn <subcommand> --help` for \
         options; `stiknn --version` prints the version",
        env!("CARGO_PKG_VERSION")
    );
}

/// Per-command usage text for `stiknn help <subcommand>`.
fn usage_for(name: &str) -> Option<String> {
    match name {
        "value" => Some(value_cmd().usage()),
        "values" => Some(values_cmd().usage()),
        "analyze" => Some(analyze_cmd().usage()),
        "ksens" => Some(ksens_cmd().usage()),
        "mislabel" => Some(mislabel_cmd().usage()),
        "serve" => Some(serve_cmd().usage()),
        "metrics" => Some(metrics_cmd().usage()),
        "trace" => Some(trace_cmd().usage()),
        "mutate" => Some(mutate_cmd().usage()),
        "session" => Some(session_cmd().usage()),
        "datasets" => Some("datasets — list the dataset registry (no options)\n".to_string()),
        "artifacts" => Some(artifacts_cmd().usage()),
        _ => None,
    }
}

fn cmd_help(argv: &[String]) -> anyhow::Result<()> {
    match argv.first().map(|s| s.as_str()) {
        None => {
            print_help();
            Ok(())
        }
        Some(topic) => match usage_for(topic) {
            Some(usage) => {
                println!("{usage}");
                Ok(())
            }
            None => {
                eprintln!("unknown subcommand '{topic}'\n");
                print_help();
                std::process::exit(2);
            }
        },
    }
}

fn common_opts(cmd: Command) -> Command {
    cmd.opt("dataset", "dataset name (see `stiknn datasets`) or csv:PATH", "circle")
        .opt("n-train", "training points (0 = registry default)", "0")
        .opt("n-test", "test points (0 = registry default)", "0")
        .opt("k", "KNN parameter", "5")
        .opt("seed", "dataset seed", "42")
        .opt("engine", "rust | xla", "rust")
        .opt("workers", "worker threads (0 = all cores)", "0")
        .opt("block", "test points per shard", "32")
        .opt(
            "assembly",
            "rust-engine sweep strategy: banded (O(n²) memory) | sharded (legacy O(W·n²))",
            "banded",
        )
        .opt(
            "band-rows",
            "accumulator rows per band for --assembly banded (0 = auto-balanced)",
            "0",
        )
        .opt("artifacts", "artifacts directory", "artifacts")
}

fn parse_common(args: &Args) -> anyhow::Result<(stiknn::data::Dataset, ValuationJob, PathBuf)> {
    let name = args.get_or("dataset", "circle");
    let n_train: usize = args.require("n-train")?;
    let n_test: usize = args.require("n-test")?;
    let seed: u64 = args.require("seed")?;
    let k: usize = args.require("k")?;
    let engine = Engine::parse(&args.get_or("engine", "rust"))
        .ok_or_else(|| anyhow::anyhow!("--engine must be rust or xla"))?;
    let workers: usize = args.require("workers")?;
    let block: usize = args.require("block")?;
    let ds = load_dataset_any(&name, n_train, n_test, seed)?;
    let band_rows: usize = args.require("band-rows")?;
    let assembly = match args.get_or("assembly", "banded").as_str() {
        "banded" => Assembly::RowBanded { band_rows },
        "sharded" => Assembly::TestSharded,
        other => anyhow::bail!("--assembly must be banded or sharded, got '{other}'"),
    };
    let mut job = ValuationJob::new(k)
        .with_engine(engine)
        .with_block_size(block)
        .with_assembly(assembly);
    if workers > 0 {
        job = job.with_workers(workers);
    }
    Ok((ds, job, PathBuf::from(args.get_or("artifacts", "artifacts"))))
}

fn value_cmd() -> Command {
    common_opts(Command::new("value", "compute the STI-KNN interaction matrix"))
        .opt("out", "output CSV path ('-' to skip)", "phi.csv")
}

fn cmd_value(argv: &[String]) -> anyhow::Result<()> {
    let cmd = value_cmd();
    if wants_help(argv) {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let (ds, job, artifacts) = parse_common(&args)?;
    let res = run_job_with_engine(&ds, &job, &artifacts)?;
    println!(
        "dataset={} n={} t={} k={} engine={:?} workers={}",
        ds.name,
        ds.n_train(),
        ds.n_test(),
        job.k,
        job.engine,
        job.workers
    );
    println!(
        "blocks={} elapsed={:?} throughput={:.1} test-points/s",
        res.blocks, res.elapsed, res.throughput
    );
    println!(
        "phi: mean offdiag={:+.4e} trace={:+.4e} upper-sum={:+.4e}",
        res.mean_offdiag(),
        res.phi.diagonal().iter().sum::<f64>(),
        res.phi.upper_triangle_sum()
    );
    let out = args.get_or("out", "phi.csv");
    if out != "-" {
        csv::write_matrix(Path::new(&out), &res.phi)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn values_cmd() -> Command {
    Command::new(
        "values",
        "per-point STI values (main + interaction rowsum) — implicit engine \
         by default: O(t·n log n) time, O(n) state, no n×n matrix (DESIGN.md §10)",
    )
    .opt("dataset", "dataset name (see `stiknn datasets`) or csv:PATH", "circle")
    .opt("n-train", "training points (0 = registry default)", "0")
    .opt("n-test", "test points (0 = registry default)", "0")
    .opt("k", "KNN parameter", "5")
    .opt("seed", "dataset seed", "42")
    .opt(
        "engine",
        "implicit (rank-space suffix sums) | dense (materialize the matrix)",
        "implicit",
    )
    .opt("workers", "worker threads for the implicit prep pool (0 = all cores)", "0")
    .opt("block", "test points per prep block", "32")
    .opt("top", "rows to print (0 = none)", "10")
    .opt("by", "printed ranking: main | rowsum", "rowsum")
    .opt("out", "output CSV path, lines `index,main,rowsum` ('-' to skip)", "-")
}

fn cmd_values(argv: &[String]) -> anyhow::Result<()> {
    let cmd = values_cmd();
    if wants_help(argv) {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let name = args.get_or("dataset", "circle");
    let n_train: usize = args.require("n-train")?;
    let n_test: usize = args.require("n-test")?;
    let seed: u64 = args.require("seed")?;
    let k: usize = args.require("k")?;
    let engine = ValueEngine::parse(&args.get_or("engine", "implicit"))
        .ok_or_else(|| anyhow::anyhow!("--engine must be implicit or dense"))?;
    let workers: usize = args.require("workers")?;
    let block: usize = args.require("block")?;
    let ds = load_dataset_any(&name, n_train, n_test, seed)?;

    let t0 = std::time::Instant::now();
    let pv: PointValues = match engine {
        ValueEngine::Implicit => {
            let mut job = ValuationJob::new(k).with_block_size(block);
            if workers > 0 {
                job = job.with_workers(workers);
            }
            let res = run_values_job(&ds, &job)?;
            PointValues {
                main: res.main,
                rowsum: res.rowsum,
            }
        }
        ValueEngine::Dense => sti_point_values(
            &ds.train_x,
            &ds.train_y,
            ds.d,
            &ds.test_x,
            &ds.test_y,
            &StiParams::new(k),
            ValueEngine::Dense,
        ),
    };
    let elapsed = t0.elapsed();
    println!(
        "dataset={} n={} t={} k={} engine={} elapsed={:?}",
        ds.name,
        ds.n_train(),
        ds.n_test(),
        k,
        engine.label(),
        elapsed
    );
    let top: usize = args.require("top")?;
    if top > 0 {
        let by = TopBy::parse(&args.get_or("by", "rowsum"))
            .ok_or_else(|| anyhow::anyhow!("--by must be main or rowsum"))?;
        let ranked = match by {
            TopBy::Main => &pv.main,
            TopBy::RowSum => &pv.rowsum,
        };
        let entries = stiknn::session::top_k_of(ranked, top);
        println!("{}", topk_table(&entries, by.label()));
    }
    let out = args.get_or("out", "-");
    if out != "-" {
        use std::io::Write;
        let mut f = std::fs::File::create(&out)?;
        writeln!(f, "index,main,rowsum")?;
        for i in 0..pv.main.len() {
            writeln!(f, "{i},{:.17e},{:.17e}", pv.main[i], pv.rowsum[i])?;
        }
        println!("wrote {out}");
    }
    Ok(())
}

fn analyze_cmd() -> Command {
    common_opts(Command::new(
        "analyze",
        "heatmap + axiom checks + block structure (paper §4)",
    ))
    .opt("cells", "heatmap size in characters", "48")
}

fn cmd_analyze(argv: &[String]) -> anyhow::Result<()> {
    let cmd = analyze_cmd();
    if wants_help(argv) {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let (ds, job, artifacts) = parse_common(&args)?;
    let res = run_job_with_engine(&ds, &job, &artifacts)?;
    let order = ds.paper_display_order();
    let cells: usize = args.require("cells")?;
    // display the off-diagonal structure (the paper's figures): the main
    // terms are orders of magnitude larger and would wash out the blocks
    let mut display = res.phi.clone();
    for i in 0..display.rows() {
        display.set(i, i, 0.0);
    }
    println!("{}", render_heatmap(&display, Some(&order), cells));
    let reports = axioms::check_all(
        &res.phi,
        &ds.train_x,
        &ds.train_y,
        ds.d,
        &ds.test_x,
        &ds.test_y,
        job.k,
        if job.engine == Engine::Xla { 1e-3 } else { 1e-9 },
    );
    println!("axioms (§3.2):\n{}", axioms::format_reports(&reports));
    let blocks = block_structure(&res.phi, &ds.train_y, ds.classes);
    let mut t = Table::new(&["class pair", "mean interaction"]);
    for a in 0..ds.classes {
        for b in a..ds.classes {
            t.row(&[format!("({a},{b})"), format!("{:+.4e}", blocks.get(a, b))]);
        }
    }
    println!("class-block structure (Fig. 3):\n{}", t.render());
    Ok(())
}

fn ksens_cmd() -> Command {
    common_opts(Command::new(
        "ksens",
        "Pearson correlation of STI matrices across k (paper §3.2)",
    ))
    .opt("ks", "comma-separated k values", "3,5,9,15,20")
}

fn cmd_ksens(argv: &[String]) -> anyhow::Result<()> {
    let cmd = ksens_cmd();
    if wants_help(argv) {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let (ds, _job, _) = parse_common(&args)?;
    let ks: Vec<usize> = args
        .get_or("ks", "3,5,9,15,20")
        .split(',')
        .map(|s| s.trim().parse())
        .collect::<Result<_, _>>()?;
    let rep = k_sensitivity(&ds, &ks);
    let mut t = Table::new(&["k", "std(phi offdiag)"]);
    for (i, &k) in ks.iter().enumerate() {
        t.row(&[k.to_string(), format!("{:.4e}", rep.stds[i])]);
    }
    println!("{}", t.render());
    println!(
        "min pairwise Pearson r: full-matrix {:.5} (paper methodology), offdiag {:.5}",
        rep.min_correlation, rep.min_correlation_offdiag
    );
    println!(
        "paper threshold (> 0.99): {}",
        if rep.passes_paper_threshold() { "PASS" } else { "FAIL" }
    );
    Ok(())
}

fn mislabel_cmd() -> Command {
    common_opts(Command::new(
        "mislabel",
        "flip labels, recompute STI, detect flips from patterns (Fig. 5)",
    ))
    .opt("flip", "fraction of train labels to flip", "0.05")
    .opt(
        "scores",
        "detector: template (row correlation, needs the matrix) | values \
         (class-split means via the implicit engine, no matrix)",
        "template",
    )
}

fn cmd_mislabel(argv: &[String]) -> anyhow::Result<()> {
    let cmd = mislabel_cmd();
    if wants_help(argv) {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let (mut ds, job, artifacts) = parse_common(&args)?;
    let flip: f64 = args.require("flip")?;
    let seed: u64 = args.require("seed")?;
    let truth = corrupt::flip_labels(&mut ds, flip, seed ^ 0xF11F);
    let rep = match args.get_or("scores", "template").as_str() {
        "template" => {
            let res = run_job_with_engine(&ds, &job, &artifacts)?;
            mislabel_scores(&res.phi, &ds.train_y, ds.classes)
        }
        "values" => mislabel_scores_values(
            &ds.train_x,
            &ds.train_y,
            ds.d,
            &ds.test_x,
            &ds.test_y,
            &StiParams::new(job.k),
            ds.classes,
        ),
        other => anyhow::bail!("--scores must be template or values, got '{other}'"),
    };
    let (prec, rec) = precision_recall(&rep.flagged, &truth);
    println!(
        "flipped {} of {} train points; flagged {}",
        truth.len(),
        ds.n_train(),
        rep.flagged.len()
    );
    println!(
        "precision={prec:.3} recall={rec:.3} AUC={:.3} top-prevalence recall={:.3}",
        auc(&rep.margins, &truth),
        top_prevalence_recall(&rep.margins, &truth)
    );
    Ok(())
}

fn serve_cmd() -> Command {
    Command::new(
        "serve",
        "concurrent valuation server: NDJSON commands on stdin (single connection) \
         or --listen ADDR (TCP, many clients); named sessions via open/use/close/list",
    )
    .opt(
        "listen",
        "TCP address to serve on, e.g. 127.0.0.1:7171 (port 0 picks a free port, \
         reported on stderr); '' = single connection on stdin/stdout",
        "",
    )
    .opt(
        "session",
        "name of the default session every connection starts on",
        "default",
    )
    .opt(
        "shard-of",
        "shard identity J/N for multi-node test-set sharding (DESIGN.md §13): \
         this server is member J (zero-based) of an N-member group, e.g. 0/3. \
         Reported by the `shard` verb so a ShardedSession coordinator can \
         verify it is routing to the right member; '' = unsharded",
        "",
    )
    .opt(
        "max-resident",
        "LRU cap on in-memory sessions: cold sessions spill to --state-dir and \
         reload on next touch (0 = unlimited)",
        "0",
    )
    .opt(
        "autosave",
        "checkpoint dirty sessions to --state-dir every SECS seconds (0 = off)",
        "0",
    )
    .opt(
        "state-dir",
        "directory for LRU spills and autosave checkpoints ('' = none; required \
         by --max-resident and --autosave)",
        "",
    )
    .opt(
        "obs",
        "metrics collection (DESIGN.md §14): on = counters/histograms/events \
         behind the `metrics` verb and `stiknn metrics`; off = every hook is a \
         no-op and `metrics` reports disabled",
        "on",
    )
    .opt(
        "slow-ms",
        "log commands slower than MS milliseconds to stderr as structured \
         slow-query events, counted in server.slow_queries ('' = off; 0 logs \
         every command)",
        "",
    )
    .opt(
        "trace",
        "request tracing (DESIGN.md §16): on = every command gets a span tree \
         behind the `trace` verb and `stiknn trace`; sampled:N = every N-th \
         root (propagated shard context is always recorded); off = zero \
         overhead, results bit-identical",
        "off",
    )
    .opt(
        "event-ring",
        "events retained in the bounded telemetry ring before the oldest are \
         dropped (drops are counted and reported on exit)",
        "256",
    )
    .opt("dataset", "training dataset name (see `stiknn datasets`) or csv:PATH", "circle")
    .opt("n-train", "training points (0 = registry default)", "0")
    .opt(
        "n-test",
        "test-split size used when GENERATING the train part (the generators slice \
         train after test, so this must match the session being restored; \
         0 = registry default). The split itself is dropped — test points \
         arrive via the protocol",
        "0",
    )
    .opt("k", "KNN parameter", "5")
    .opt("seed", "dataset seed", "42")
    .opt("metric", "distance metric: l2 | l1 | cosine", "l2")
    .opt(
        "engine",
        "session engine: dense (n×n matrix, every query) | implicit (O(n) value \
         vector, values/topk/stats only — see --retain-rows) | auto (dense, or \
         implicit when --mutable is set)",
        "auto",
    )
    .flag(
        "retain-rows",
        "implicit engine: keep per-test (rank, colval) rows (O(t·n) memory) so \
         cell/row queries stay answerable; ingest runs single-threaded in this \
         mode (--workers does not apply)",
    )
    .flag(
        "mutable",
        "enable live training-set edits (add_train/remove_train/relabel, \
         DESIGN.md §11): exact O(t·n)-per-edit repairs instead of recomputes. \
         Implies --engine implicit --retain-rows; snapshots become v3 (train \
         set + rows + mutation ledger persisted) and --restore expects one",
    )
    .opt("workers", "worker threads for large ingest batches (0 = all cores)", "0")
    .opt("block", "test points per prep block in parallel ingests", "32")
    .opt(
        "parallel-min",
        "batch size at which ingest switches to the parallel banded pipeline",
        "256",
    )
    .opt("restore", "resume from a snapshot file ('' = fresh session)", "")
}

fn cmd_serve(argv: &[String]) -> anyhow::Result<()> {
    let cmd = serve_cmd();
    if wants_help(argv) {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let name = args.get_or("dataset", "circle");
    let n_train: usize = args.require("n-train")?;
    let n_test: usize = args.require("n-test")?;
    let seed: u64 = args.require("seed")?;
    let k: usize = args.require("k")?;
    let metric = Metric::parse(&args.get_or("metric", "l2"))
        .ok_or_else(|| anyhow::anyhow!("--metric must be l2, l1 or cosine"))?;
    let mutable = args.flag("mutable");
    let engine = match args.get_or("engine", "auto").as_str() {
        // --mutable implies the implicit engine; an EXPLICIT --engine
        // dense alongside it is a contradiction worth failing on.
        "auto" if mutable => ValueEngine::Implicit,
        "auto" => ValueEngine::Dense,
        given => {
            let engine = ValueEngine::parse(given)
                .ok_or_else(|| anyhow::anyhow!("--engine must be dense, implicit or auto"))?;
            if mutable && engine != ValueEngine::Implicit {
                anyhow::bail!(
                    "--mutable requires the implicit engine (the delta repairs \
                     rewrite rank-space rows); drop `--engine dense`"
                );
            }
            engine
        }
    };
    let retain_rows = args.flag("retain-rows") || mutable;
    let workers: usize = args.require("workers")?;
    let block: usize = args.require("block")?;
    let parallel_min: usize = args.require("parallel-min")?;
    // The session only consumes the train part; the registry's test split
    // is generated and dropped (test points arrive through the protocol).
    // n_test still matters: the generators slice train AFTER test, so it
    // must match whatever produced the train set a --restore snapshot was
    // taken against (fingerprint-verified on restore).
    let ds = load_dataset_any(&name, n_train, n_test, seed)?;
    let mut config = SessionConfig::new(k)
        .with_metric(metric)
        .with_engine(engine)
        .with_retained_rows(retain_rows)
        .with_mutable(mutable)
        .with_block_size(block)
        .with_parallel_min(parallel_min);
    if workers > 0 {
        config = config.with_workers(workers);
    }
    let listen = args.get_or("listen", "");
    let session_name = args.get_or("session", "default");
    let shard_of = args.get_or("shard-of", "");
    let shard = (!shard_of.is_empty()).then(|| parse_shard_of(&shard_of)).transpose()?;
    let max_resident: usize = args.require("max-resident")?;
    let autosave_secs: u64 = args.require("autosave")?;
    let state_dir = args.get_or("state-dir", "");
    let state_dir = (!state_dir.is_empty()).then(|| PathBuf::from(&state_dir));
    anyhow::ensure!(
        max_resident == 0 || state_dir.is_some(),
        "--max-resident needs --state-dir (spilled sessions live there as snapshots)"
    );
    anyhow::ensure!(
        autosave_secs == 0 || state_dir.is_some(),
        "--autosave needs --state-dir (checkpoints are written there)"
    );
    let obs_on = match args.get_or("obs", "on").as_str() {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("--obs must be on or off, got '{other}'"),
    };
    let slow_ms_raw = args.get_or("slow-ms", "");
    let slow_ms: Option<u64> = (!slow_ms_raw.is_empty())
        .then(|| slow_ms_raw.parse())
        .transpose()
        .map_err(|_| anyhow::anyhow!("--slow-ms expects milliseconds, got '{slow_ms_raw}'"))?;
    let trace_mode = TraceMode::parse(&args.get_or("trace", "off"))
        .map_err(|e| anyhow::anyhow!("--trace: {e}"))?;
    let event_ring: usize = args.require("event-ring")?;

    let mut registry = SessionRegistry::new(
        TrainData::from_dataset(&ds),
        RegistryConfig {
            base: config,
            max_resident,
            state_dir,
        },
    )?;
    if let Some(id) = shard {
        registry = registry.with_shard(id);
    }
    if obs_on {
        registry = registry.with_obs(ObsHandle::enabled_with_cap("server", event_ring));
    }
    if trace_mode != TraceMode::Off {
        registry = registry.with_trace(TraceHandle::with_mode(trace_mode));
    }
    registry = registry.with_slow_ms(slow_ms);
    let registry = Arc::new(registry);
    // The default session: fresh, or restored with the CLI-derived config
    // (exactly the old single-session `--restore` semantics — mismatched
    // engine/k/fingerprint fail the process here with the same messages).
    let restore = args.get_or("restore", "");
    let snapshot = (!restore.is_empty()).then(|| PathBuf::from(&restore));
    registry.open(&session_name, snapshot.as_deref(), Some(config))?;
    let (n, d, tests) = registry
        .with_session_read(&session_name, |s| (s.n(), s.d(), s.tests_seen()))?;
    // Banner on stderr so stdout stays pure NDJSON.
    let shard_note = match shard {
        Some(id) => format!(" shard={}/{}", id.index, id.count),
        None => String::new(),
    };
    eprintln!(
        "stiknn serve: dataset={} n={n} d={d} k={} engine={}{}{shard_note} tests={tests} \
         session='{session_name}' — `{{\"cmd\":\"shutdown\"}}` ends a connection",
        ds.name,
        config.k,
        config.engine.label(),
        if config.mutable { " (mutable)" } else { "" },
    );
    let _autosave = (autosave_secs > 0).then(|| {
        server::start_autosave(
            Arc::clone(&registry),
            std::time::Duration::from_secs(autosave_secs),
        )
    });
    if listen.is_empty() {
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        let mut conn = server::Connection::new(Arc::clone(&registry), Some(session_name));
        server::serve_connection(&mut conn, stdin.lock(), stdout.lock())?;
        // Registry inspector on the way out (stderr keeps stdout
        // NDJSON-pure). Only the stdio path has a "way out" — the TCP
        // accept loop below runs until the process is killed, where the
        // last autosave checkpoint (atomic-by-rename) is the durable
        // record instead.
        eprintln!(
            "{}",
            registry_table(&registry.list(), registry.obs().events_dropped())
        );
    } else {
        let listener = std::net::TcpListener::bind(&listen)
            .map_err(|e| anyhow::anyhow!("binding --listen {listen}: {e}"))?;
        let addr = listener.local_addr()?;
        eprintln!("stiknn serve: listening on {addr} (thread per connection)");
        server::listen(Arc::clone(&registry), listener, Some(session_name))?;
    }
    Ok(())
}

/// Parse `--shard-of J/N`: member J (zero-based) of an N-shard group.
fn parse_shard_of(s: &str) -> anyhow::Result<server::ShardIdentity> {
    let (j, n) = s
        .split_once('/')
        .ok_or_else(|| anyhow::anyhow!("--shard-of expects J/N, e.g. 0/3 (got '{s}')"))?;
    let j: u64 = j
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("--shard-of member index '{j}' is not a number"))?;
    let n: u64 = n
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("--shard-of group size '{n}' is not a number"))?;
    server::ShardIdentity::new(j, n)
}

fn metrics_cmd() -> Command {
    Command::new(
        "metrics",
        "fetch a telemetry snapshot from a running `stiknn serve --listen` server \
         over NDJSON and render it as Prometheus-style text (DESIGN.md §14)",
    )
    .req("connect", "server address HOST:PORT (printed on the serve banner)")
    .opt(
        "session",
        "fetch the named session's snapshot instead of the process-wide one",
        "",
    )
    .opt(
        "metric",
        "print one metric's value by exact name instead of a full snapshot",
        "",
    )
    .flag("json", "print the raw JSON snapshot instead of Prometheus text")
}

fn cmd_metrics(argv: &[String]) -> anyhow::Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let cmd = metrics_cmd();
    if wants_help(argv) {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let addr = args.require::<String>("connect")?;
    let session = args.get_or("session", "");
    let metric = args.get_or("metric", "");

    let stream = std::net::TcpStream::connect(&addr)
        .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut round = |req: Json| -> anyhow::Result<Json> {
        writeln!(writer, "{req}")?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        anyhow::ensure!(!line.trim().is_empty(), "server closed the connection");
        Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad server response: {e}"))
    };
    let fail_of = |resp: &Json, what: &str| {
        anyhow::anyhow!(
            "{}",
            resp.get("error")
                .and_then(Json::as_str)
                .map(str::to_string)
                .unwrap_or_else(|| format!("{what} failed"))
        )
    };

    if !session.is_empty() {
        // Session scope: point the connection at the session first, then
        // ask without "scope" so protocol-level dispatch answers.
        let r = round(Json::obj(vec![
            ("cmd", Json::str("use")),
            ("name", Json::str(session.as_str())),
        ]))?;
        if r.get("ok").and_then(Json::as_bool) != Some(true) {
            return Err(fail_of(&r, "use"));
        }
    }
    let mut fields = vec![("cmd", Json::str("metrics"))];
    if session.is_empty() {
        fields.push(("scope", Json::str("process")));
    }
    if !metric.is_empty() {
        fields.push(("metric", Json::str(metric.as_str())));
    }
    let resp = round(Json::obj(fields))?;
    if resp.get("ok").and_then(Json::as_bool) != Some(true) {
        return Err(fail_of(&resp, "metrics"));
    }
    if !metric.is_empty() {
        // Single-metric form: the bare value (counter/gauge number, or a
        // histogram object) — handy for scripts either way.
        println!("{}", resp.get("value").cloned().unwrap_or(Json::Null));
        return Ok(());
    }
    let snap = resp.get("metrics").cloned().unwrap_or(Json::Null);
    if args.flag("json") {
        println!("{snap}");
    } else {
        print!("{}", prometheus_text(&snap));
    }
    Ok(())
}

fn trace_cmd() -> Command {
    Command::new(
        "trace",
        "inspect distributed request traces (DESIGN.md §16): list a running \
         server's recent root spans, render one trace's span tree by id, or \
         (--fanout) drive a traced sharded `values` across member servers and \
         render the tree assembled from every member's echoed spans",
    )
    .opt(
        "connect",
        "server address HOST:PORT (the span store lives server-side)",
        "",
    )
    .opt(
        "id",
        "16-hex-digit trace id (as printed by root listings and slow-query \
         lines): render that trace's full span tree",
        "",
    )
    .opt("limit", "recent root spans listed without --id", "16")
    .opt(
        "fanout",
        "comma-separated member addresses HOST:PORT,HOST:PORT,…: attach a \
         sharded coordinator, ingest the dataset's test split, run one traced \
         `values`, and render the assembled cross-process tree (the CI smoke \
         path; ignores --connect/--id)",
        "",
    )
    .opt("dataset", "--fanout: dataset the members were started with", "circle")
    .opt("n-train", "--fanout: members' --n-train (0 = registry default)", "0")
    .opt(
        "n-test",
        "--fanout: test points generated and ingested (0 = registry default)",
        "0",
    )
    .opt("seed", "--fanout: dataset seed (must match the members')", "42")
    .flag("json", "raw JSON spans instead of rendered trees")
}

fn cmd_trace(argv: &[String]) -> anyhow::Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let cmd = trace_cmd();
    if wants_help(argv) {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let fanout = args.get_or("fanout", "");
    if !fanout.is_empty() {
        return trace_fanout(&args, &fanout);
    }
    let addr = args.get_or("connect", "");
    anyhow::ensure!(
        !addr.is_empty(),
        "trace needs --connect HOST:PORT (or --fanout to drive a sharded query)"
    );
    let stream = std::net::TcpStream::connect(&addr)
        .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let id = args.get_or("id", "");
    let mut fields = vec![("cmd", Json::str("trace"))];
    if id.is_empty() {
        fields.push(("limit", Json::num(args.require::<usize>("limit")? as f64)));
    } else {
        fields.push(("id", Json::str(id.as_str())));
    }
    writeln!(writer, "{}", Json::obj(fields))?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    anyhow::ensure!(!line.trim().is_empty(), "server closed the connection");
    let resp = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad server response: {e}"))?;
    if resp.get("ok").and_then(Json::as_bool) != Some(true) {
        anyhow::bail!(
            "{}",
            resp.get("error")
                .and_then(Json::as_str)
                .unwrap_or("trace failed")
        );
    }
    if resp.get("enabled").and_then(Json::as_bool) == Some(false) {
        anyhow::bail!("tracing is disabled on this server (start it with `serve --trace on`)");
    }
    let key = if id.is_empty() { "roots" } else { "spans" };
    let records: Vec<SpanRecord> = resp
        .get(key)
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .iter()
        .filter_map(SpanRecord::from_json)
        .collect();
    if args.flag("json") {
        match resp.get(key) {
            Some(v) => println!("{v}"),
            None => println!("[]"),
        }
        return Ok(());
    }
    if id.is_empty() {
        if records.is_empty() {
            println!("no root spans recorded yet");
            return Ok(());
        }
        // One line per recent root, newest first — feed an id back via
        // --id for the full tree.
        for r in &records {
            let dur = format!("{:.3}ms", r.dur_ns as f64 / 1e6);
            println!("{}  {dur:>10}  {}", hex_id(r.trace_id), r.name);
        }
        if let Some(d) = resp.get("dropped").and_then(Json::as_f64) {
            if d > 0.0 {
                eprintln!("(span store evicted {d} span(s); older traces may be partial)");
            }
        }
    } else {
        anyhow::ensure!(!records.is_empty(), "no spans stored for trace {id}");
        print!("{}", render_tree(&records));
    }
    Ok(())
}

/// `stiknn trace --fanout A,B`: the coordinator side of the distributed
/// tracing smoke — run ONE traced sharded `values` and show the stitched
/// tree (root + per-member round-trips + each member's echoed server and
/// session spans + the merge fold).
fn trace_fanout(args: &Args, fanout: &str) -> anyhow::Result<()> {
    use stiknn::coordinator::shard::{ShardPlan, ShardedSession, TcpLink};
    let addrs: Vec<&str> = fanout
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(
        addrs.len() >= 2,
        "--fanout needs at least two member addresses (got {})",
        addrs.len()
    );
    let name = args.get_or("dataset", "circle");
    let n_train: usize = args.require("n-train")?;
    let n_test: usize = args.require("n-test")?;
    let seed: u64 = args.require("seed")?;
    let ds = load_dataset_any(&name, n_train, n_test, seed)?;
    let links: Vec<TcpLink> = addrs
        .iter()
        .map(|a| TcpLink::connect(*a))
        .collect::<anyhow::Result<_>>()?;
    let plan = ShardPlan::contiguous(ds.test_y.len() as u64, addrs.len());
    let mut sharded = ShardedSession::open(links, plan, ds.d)?;
    sharded.set_trace(TraceHandle::enabled());
    sharded.ingest(&ds.test_x, &ds.test_y)?;
    let merged = sharded.values()?;
    let trace = sharded.trace().clone();
    let root = trace
        .recent_roots(8)
        .into_iter()
        .find(|r| r.name == "shard.values")
        .ok_or_else(|| anyhow::anyhow!("no shard.values root span was recorded"))?;
    let spans = trace.spans_of(root.trace_id);
    if args.flag("json") {
        println!("{}", Json::arr(spans.iter().map(SpanRecord::to_json)));
    } else {
        eprintln!(
            "traced `values` across {} member(s): {} test(s) merged over n={}",
            addrs.len(),
            merged.tests,
            merged.main.len()
        );
        print!("{}", render_tree(&spans));
    }
    Ok(())
}

fn mutate_cmd() -> Command {
    Command::new(
        "mutate",
        "live training-set edits with exact O(t·n) delta repairs (DESIGN.md §11): \
         build a mutable session, ingest the test split, apply --ops in order, \
         then optionally greedily drop the lowest-value points (remove → repair → \
         re-rank each step)",
    )
    .opt("dataset", "dataset name (see `stiknn datasets`) or csv:PATH", "circle")
    .opt("n-train", "training points (0 = registry default)", "0")
    .opt("n-test", "test points (0 = registry default)", "0")
    .opt("k", "KNN parameter", "5")
    .opt("seed", "dataset seed", "42")
    .opt("metric", "distance metric: l2 | l1 | cosine", "l2")
    .opt(
        "ops",
        "comma-separated edits, applied in order: remove:IDX | relabel:IDX:LABEL \
         | add:dup:IDX[:LABEL] (append a copy of point IDX's features, with its \
         label unless LABEL is given). Indices are as-of-edit-time",
        "",
    )
    .opt(
        "drop-lowest",
        "after --ops, iteratively remove the N lowest-rowsum points, repairing \
         and re-ranking after every removal (the exact greedy curve)",
        "0",
    )
    .opt("top", "top-k point values printed after all edits (0 = none)", "10")
    .opt("by", "printed ranking: main | rowsum", "rowsum")
    .opt("snapshot", "write a v3 mutable snapshot here afterwards ('' = skip)", "")
}

enum MutateOp {
    Remove(usize),
    Relabel(usize, i32),
    AddDup(usize, Option<i32>),
}

fn parse_mutate_ops(spec: &str) -> anyhow::Result<Vec<MutateOp>> {
    let mut ops = Vec::new();
    for raw in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let parts: Vec<&str> = raw.split(':').collect();
        let op = match parts.as_slice() {
            ["remove", idx] => MutateOp::Remove(idx.parse()?),
            ["relabel", idx, label] => MutateOp::Relabel(idx.parse()?, label.parse()?),
            ["add", "dup", idx] => MutateOp::AddDup(idx.parse()?, None),
            ["add", "dup", idx, label] => MutateOp::AddDup(idx.parse()?, Some(label.parse()?)),
            _ => anyhow::bail!(
                "bad op '{raw}' (expected remove:IDX, relabel:IDX:LABEL, or \
                 add:dup:IDX[:LABEL])"
            ),
        };
        ops.push(op);
    }
    Ok(ops)
}

fn cmd_mutate(argv: &[String]) -> anyhow::Result<()> {
    let cmd = mutate_cmd();
    if wants_help(argv) {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let name = args.get_or("dataset", "circle");
    let n_train: usize = args.require("n-train")?;
    let n_test: usize = args.require("n-test")?;
    let seed: u64 = args.require("seed")?;
    let k: usize = args.require("k")?;
    let metric = Metric::parse(&args.get_or("metric", "l2"))
        .ok_or_else(|| anyhow::anyhow!("--metric must be l2, l1 or cosine"))?;
    let ds = load_dataset_any(&name, n_train, n_test, seed)?;
    let ops = parse_mutate_ops(&args.get_or("ops", ""))?;
    let drop_lowest: usize = args.require("drop-lowest")?;

    let config = SessionConfig::new(k)
        .with_metric(metric)
        .with_engine(ValueEngine::Implicit)
        .with_retained_rows(true)
        .with_mutable(true);
    let mut session = ValuationSession::from_dataset(&ds, config)?;
    session.ingest(&ds.test_x, &ds.test_y)?;
    println!(
        "dataset={} n={} t={} k={} metric={:?} (mutable session)",
        ds.name,
        session.n(),
        session.tests_seen(),
        k,
        metric
    );

    let mut edit_time = std::time::Duration::ZERO;
    for op in &ops {
        let t0 = std::time::Instant::now();
        match *op {
            MutateOp::Remove(i) => {
                session.remove_train(i)?;
                let dt = t0.elapsed();
                edit_time += dt;
                println!("remove  index={i:<6} n={:<6} ({dt:?})", session.n());
            }
            MutateOp::Relabel(i, y) => {
                session.relabel_train(i, y)?;
                let dt = t0.elapsed();
                edit_time += dt;
                println!("relabel index={i:<6} y={y:<4} n={:<6} ({dt:?})", session.n());
            }
            MutateOp::AddDup(i, label) => {
                anyhow::ensure!(
                    i < session.n(),
                    "add:dup:{i}: index out of range (n={})",
                    session.n()
                );
                let x = session.train_row(i).to_vec();
                let y = label.unwrap_or_else(|| session.train_labels()[i]);
                let t0 = std::time::Instant::now();
                let id = session.add_train(&x, y)?;
                let dt = t0.elapsed();
                edit_time += dt;
                println!("add     index={id:<6} y={y:<4} n={:<6} ({dt:?})", session.n());
            }
        }
    }

    for step in 0..drop_lowest {
        let vals = session
            .point_values(TopBy::RowSum)
            .ok_or_else(|| anyhow::anyhow!("no test points ingested"))?;
        let i = stiknn::analysis::removal::argmin_by_value(&vals);
        let value = vals[i];
        let t0 = std::time::Instant::now();
        session.remove_train(i).map_err(|e| {
            anyhow::anyhow!("drop-lowest step {step}: {e:#} (n={}, k={k})", session.n())
        })?;
        let dt = t0.elapsed();
        edit_time += dt;
        println!(
            "drop    index={i:<6} value={value:+.4e} n={:<6} ({dt:?})",
            session.n()
        );
    }

    let edits = session.mutations().len();
    println!(
        "{edits} edit(s) applied in {edit_time:?}; final n={}, mutation ledger length {}",
        session.n(),
        edits
    );

    let top: usize = args.require("top")?;
    if top > 0 {
        let by = TopBy::parse(&args.get_or("by", "rowsum"))
            .ok_or_else(|| anyhow::anyhow!("--by must be main or rowsum"))?;
        let vals = session
            .point_values(by)
            .ok_or_else(|| anyhow::anyhow!("no test points ingested"))?;
        let entries = stiknn::session::top_k_of(&vals, top);
        println!("{}", topk_table(&entries, by.label()));
    }

    let snapshot = args.get_or("snapshot", "");
    if !snapshot.is_empty() {
        let bytes = session.save(Path::new(&snapshot))?;
        println!("wrote {snapshot} ({bytes} bytes, v3 mutable snapshot)");
    }
    Ok(())
}

fn session_cmd() -> Command {
    Command::new("session", "inspect a session snapshot file")
        .req("file", "snapshot path (written by `stiknn serve` / ValuationSession::save)")
        .opt("topk", "print the top-k point values (0 = header only)", "10")
        .opt("by", "top-k ranking: main | rowsum", "main")
}

fn cmd_session(argv: &[String]) -> anyhow::Result<()> {
    let cmd = session_cmd();
    if wants_help(argv) {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let file = args.require::<String>("file")?;
    let snap = store::read_snapshot(Path::new(&file))?;
    println!("{}", snapshot_info_table(&snap));
    let topk: usize = args.require("topk")?;
    if topk > 0 {
        let by = TopBy::parse(&args.get_or("by", "main"))
            .ok_or_else(|| anyhow::anyhow!("--by must be main or rowsum"))?;
        match snap.top_k(topk, by) {
            Some(entries) => println!("{}", topk_table(&entries, by.label())),
            None => println!("(no test points ingested yet — top-k unavailable)"),
        }
    }
    Ok(())
}

fn cmd_datasets(argv: &[String]) -> anyhow::Result<()> {
    if wants_help(argv) {
        println!("{}", usage_for("datasets").unwrap());
        return Ok(());
    }
    let mut t = Table::new(&["name", "d", "classes", "n_train", "n_test", "source (paper Table 1)"]);
    for name in registry_names() {
        let s = stiknn::data::registry::spec(name).unwrap();
        t.row(&[
            s.name.to_string(),
            s.d.to_string(),
            s.classes.to_string(),
            s.n_train.to_string(),
            s.n_test.to_string(),
            s.source.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn artifacts_cmd() -> Command {
    Command::new("artifacts", "list the AOT artifact manifest")
        .opt("artifacts", "artifacts directory", "artifacts")
}

fn cmd_artifacts(argv: &[String]) -> anyhow::Result<()> {
    let cmd = artifacts_cmd();
    if wants_help(argv) {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let args = cmd.parse(argv)?;
    let dir = args.get_or("artifacts", "artifacts");
    let manifest = Manifest::load(Path::new(&dir))?;
    let mut t = Table::new(&["name", "program", "n", "d", "b", "k", "file"]);
    for a in &manifest.artifacts {
        t.row(&[
            a.name.clone(),
            a.program.clone(),
            a.n.to_string(),
            a.d.to_string(),
            a.b.to_string(),
            a.k.to_string(),
            a.file.clone(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
