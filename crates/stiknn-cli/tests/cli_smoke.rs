//! CLI smoke tests: run the built binary end-to-end for each subcommand
//! and assert on the output contract (not just exit codes).

use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_stiknn")
}

fn run(args: &[&str]) -> (String, String, bool) {
    let (stdout, stderr, code) = run_with_code(args);
    (stdout, stderr, code == Some(0))
}

/// Like [`run`] but exposes the exact exit code.
fn run_with_code(args: &[&str]) -> (String, String, Option<i32>) {
    let out = Command::new(bin())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn stiknn");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.code(),
    )
}

#[test]
fn help_lists_subcommands() {
    let (stdout, _, ok) = run(&["--help"]);
    assert!(ok);
    for sub in [
        "value", "values", "analyze", "ksens", "mislabel", "serve", "metrics", "mutate",
        "session", "datasets", "artifacts",
    ] {
        assert!(stdout.contains(sub), "help missing {sub}: {stdout}");
    }
}

#[test]
fn unknown_subcommand_fails_with_help() {
    let (_, stderr, code) = run_with_code(&["frobnicate"]);
    assert_eq!(code, Some(2), "unknown subcommand must exit 2");
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn version_flag_prints_crate_version() {
    for spelling in [&["--version"][..], &["-V"][..]] {
        let (stdout, _, ok) = run(spelling);
        assert!(ok);
        assert!(
            stdout.contains(env!("CARGO_PKG_VERSION")),
            "missing version in {stdout:?}"
        );
        assert!(stdout.starts_with("stiknn "), "{stdout:?}");
    }
}

#[test]
fn help_subcommand_prints_per_command_usage() {
    // `stiknn help <sub>` must match what `<sub> --help` prints
    let (via_help, _, ok) = run(&["help", "value"]);
    assert!(ok);
    let (via_flag, _, ok2) = run(&["value", "--help"]);
    assert!(ok2);
    assert_eq!(via_help, via_flag);
    for opt in ["--dataset", "--k", "--out"] {
        assert!(via_help.contains(opt), "help value missing {opt}: {via_help}");
    }
    // bare `help` falls back to the global overview
    let (bare, _, ok3) = run(&["help"]);
    assert!(ok3);
    assert!(bare.contains("subcommands"));
    // even the option-less subcommand honors the convention
    let (ds_help, _, ok4) = run(&["datasets", "--help"]);
    assert!(ok4);
    assert!(ds_help.contains("no options"), "{ds_help}");
}

#[test]
fn help_serve_documents_the_session_options() {
    let (stdout, _, ok) = run(&["help", "serve"]);
    assert!(ok);
    for opt in [
        "NDJSON", "--restore", "--parallel-min", "--metric", "--engine", "--retain-rows",
        "--mutable", "--listen", "--session", "--max-resident", "--autosave", "--state-dir",
        "--obs", "--slow-ms",
    ] {
        assert!(stdout.contains(opt), "help serve missing {opt}: {stdout}");
    }
}

#[test]
fn help_mutate_documents_the_edit_ops() {
    let (stdout, _, ok) = run(&["help", "mutate"]);
    assert!(ok);
    for needle in ["--ops", "--drop-lowest", "remove:IDX", "relabel:IDX:LABEL", "add:dup"] {
        assert!(stdout.contains(needle), "help mutate missing {needle}: {stdout}");
    }
}

#[test]
fn help_unknown_topic_exits_2() {
    let (_, stderr, code) = run_with_code(&["help", "frobnicate"]);
    assert_eq!(code, Some(2));
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn datasets_lists_table1() {
    let (stdout, _, ok) = run(&["datasets"]);
    assert!(ok);
    for name in ["circle", "moon", "fashionmnist", "apsfailure", "wind"] {
        assert!(stdout.contains(name), "{stdout}");
    }
}

#[test]
fn value_computes_and_writes_csv() {
    let out = std::env::temp_dir().join("stiknn_cli_phi.csv");
    let _ = std::fs::remove_file(&out);
    let (stdout, stderr, ok) = run(&[
        "value", "--dataset", "moon", "--n-train", "50", "--n-test", "12",
        "--k", "3", "--out", out.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("dataset=moon"));
    assert!(stdout.contains("throughput"));
    let text = std::fs::read_to_string(&out).unwrap();
    assert_eq!(text.lines().count(), 50, "50x50 matrix rows");
}

#[test]
fn values_computes_both_engines_and_writes_csv() {
    let out = std::env::temp_dir().join(format!("stiknn_cli_values_{}.csv", std::process::id()));
    let _ = std::fs::remove_file(&out);
    let (stdout, stderr, ok) = run(&[
        "values", "--dataset", "moon", "--n-train", "50", "--n-test", "12",
        "--k", "3", "--top", "5", "--out", out.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("engine=implicit"), "{stdout}");
    assert!(stdout.contains("top-5"), "{stdout}");
    let text = std::fs::read_to_string(&out).unwrap();
    assert_eq!(text.lines().count(), 51, "header + 50 value rows");
    assert!(text.starts_with("index,main,rowsum"), "{text}");
    let _ = std::fs::remove_file(&out);

    // dense engine runs the same command shape
    let (stdout, stderr, ok) = run(&[
        "values", "--dataset", "moon", "--n-train", "50", "--n-test", "12",
        "--k", "3", "--engine", "dense", "--top", "3",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("engine=dense"), "{stdout}");

    // bad engine is rejected with a helpful message
    let (_, stderr, ok) = run(&[
        "values", "--dataset", "moon", "--n-train", "20", "--n-test", "5",
        "--engine", "cuda",
    ]);
    assert!(!ok);
    assert!(stderr.contains("implicit or dense"), "{stderr}");
}

#[test]
fn analyze_prints_axioms_and_blocks() {
    let (stdout, stderr, ok) = run(&[
        "analyze", "--dataset", "circle", "--n-train", "80", "--n-test", "20",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("efficiency"));
    assert!(stdout.contains("OK"));
    assert!(stdout.contains("class-block structure"));
    assert!(stdout.contains("interaction heatmap"));
}

#[test]
fn ksens_reports_correlations() {
    let (stdout, stderr, ok) = run(&[
        "ksens", "--dataset", "moon", "--n-train", "60", "--n-test", "15",
        "--ks", "3,5",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("min pairwise Pearson"));
    assert!(stdout.contains("paper threshold"));
}

#[test]
fn mislabel_reports_metrics() {
    let (stdout, stderr, ok) = run(&[
        "mislabel", "--dataset", "circle", "--n-train", "100", "--n-test", "25",
        "--flip", "0.1",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("AUC"));
    assert!(stdout.contains("flipped 10 of 10"), "{stdout}"); // 100 or 101 (circle pairs)
}

#[test]
fn mislabel_value_scores_path_reports_metrics() {
    let (stdout, stderr, ok) = run(&[
        "mislabel", "--dataset", "circle", "--n-train", "100", "--n-test", "25",
        "--flip", "0.1", "--scores", "values",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("AUC"), "{stdout}");
}

#[test]
fn bad_engine_is_rejected() {
    let (_, stderr, ok) = run(&[
        "value", "--dataset", "moon", "--n-train", "20", "--n-test", "5",
        "--engine", "cuda", "--out", "-",
    ]);
    assert!(!ok);
    assert!(stderr.contains("rust or xla"));
}

#[test]
fn k_larger_than_artifact_grid_falls_back_with_clear_error() {
    // xla engine with a shape that has no artifact must tell the user how
    // to fix it (this also covers the no-artifacts-built environment)
    let (_, stderr, ok) = run(&[
        "value", "--dataset", "moon", "--n-train", "33", "--n-test", "5",
        "--engine", "xla", "--out", "-",
    ]);
    assert!(!ok);
    assert!(
        stderr.contains("make artifacts") || stderr.contains("--engine rust"),
        "unhelpful error: {stderr}"
    );
}

#[test]
fn serve_completes_an_ingest_query_snapshot_shutdown_round_trip() {
    use std::io::Write;
    use stiknn::util::json::Json;

    let snap = std::env::temp_dir().join(format!("stiknn_cli_serve_{}.snap", std::process::id()));
    let _ = std::fs::remove_file(&snap);

    let mut child = Command::new(bin())
        .args(["serve", "--dataset", "moon", "--n-train", "30", "--k", "3"])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn stiknn serve");

    {
        let stdin = child.stdin.as_mut().unwrap();
        // ping first: a load balancer health-checks before any ingest
        writeln!(stdin, r#"{{"cmd":"ping"}}"#).unwrap();
        // moon is d=2: three test points, flattened features
        writeln!(
            stdin,
            r#"{{"cmd":"ingest","x":[0.1,0.2,1.0,-0.3,0.5,0.5],"y":[0,1,0]}}"#
        )
        .unwrap();
        writeln!(stdin, r#"{{"cmd":"ping"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"query","i":0,"j":1}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"topk","k":3,"by":"rowsum"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"stats"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"snapshot","path":"{}"}}"#, snap.display()).unwrap();
        writeln!(stdin, r#"{{"cmd":"shutdown"}}"#).unwrap();
    }
    drop(child.stdin.take());

    let out = child.wait_with_output().unwrap();
    assert!(
        out.status.success(),
        "serve failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let responses: Vec<Json> = stdout
        .lines()
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("invalid NDJSON line {l:?}: {e}")))
        .collect();
    assert_eq!(responses.len(), 8, "one response per command: {stdout}");
    for r in &responses {
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    }
    // ping: engine + n before any state, t counts after ingest
    assert_eq!(responses[0].get("engine").unwrap().as_str(), Some("dense"));
    assert_eq!(responses[0].get("n").unwrap().as_usize(), Some(30));
    assert_eq!(responses[0].get("t").unwrap().as_usize(), Some(0));
    assert_eq!(responses[1].get("ingested").unwrap().as_usize(), Some(3));
    assert_eq!(responses[2].get("t").unwrap().as_usize(), Some(3));
    assert!(responses[3].get("value").unwrap().as_f64().is_some());
    assert_eq!(
        responses[4].get("points").unwrap().as_arr().unwrap().len(),
        3
    );
    assert_eq!(responses[5].get("tests").unwrap().as_usize(), Some(3));
    assert_eq!(responses[5].get("n").unwrap().as_usize(), Some(30));
    assert_eq!(responses[7].get("shutdown").unwrap().as_bool(), Some(true));

    // the snapshot the server wrote is inspectable offline
    let (stdout, stderr, ok) = run(&["session", "--file", snap.to_str().unwrap(), "--topk", "5"]);
    assert!(ok, "session inspect failed: {stderr}");
    assert!(stdout.contains("session snapshot"), "{stdout}");
    assert!(stdout.contains("tests ingested"), "{stdout}");
    assert!(stdout.contains("top-5"), "{stdout}");

    // ... and a fresh serve can resume from it
    let mut child = Command::new(bin())
        .args([
            "serve", "--dataset", "moon", "--n-train", "30", "--k", "3",
            "--restore", snap.to_str().unwrap(),
        ])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn stiknn serve --restore");
    {
        let stdin = child.stdin.as_mut().unwrap();
        writeln!(stdin, r#"{{"cmd":"stats"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"shutdown"}}"#).unwrap();
    }
    drop(child.stdin.take());
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stats = Json::parse(stdout.lines().next().unwrap()).unwrap();
    assert_eq!(stats.get("tests").unwrap().as_usize(), Some(3), "{stdout}");

    let _ = std::fs::remove_file(&snap);
}

#[test]
fn serve_implicit_engine_serves_values_and_rejects_matrix_queries() {
    use std::io::Write;
    use stiknn::util::json::Json;

    let snap = std::env::temp_dir().join(format!(
        "stiknn_cli_serve_implicit_{}.snap",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&snap);

    let mut child = Command::new(bin())
        .args([
            "serve", "--dataset", "moon", "--n-train", "30", "--k", "3",
            "--engine", "implicit",
        ])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn stiknn serve --engine implicit");
    {
        let stdin = child.stdin.as_mut().unwrap();
        writeln!(
            stdin,
            r#"{{"cmd":"ingest","x":[0.1,0.2,1.0,-0.3,0.5,0.5],"y":[0,1,0]}}"#
        )
        .unwrap();
        writeln!(stdin, r#"{{"cmd":"query","i":0,"j":1}}"#).unwrap(); // engine-rejected
        writeln!(stdin, r#"{{"cmd":"query","i":2}}"#).unwrap(); // engine-rejected
        writeln!(stdin, r#"{{"cmd":"values","i":0}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"topk","k":3,"by":"rowsum"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"stats"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"snapshot","path":"{}"}}"#, snap.display()).unwrap();
        writeln!(stdin, r#"{{"cmd":"shutdown"}}"#).unwrap();
    }
    drop(child.stdin.take());
    let out = child.wait_with_output().unwrap();
    assert!(
        out.status.success(),
        "serve failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let rs: Vec<Json> = stdout
        .lines()
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("invalid NDJSON line {l:?}: {e}")))
        .collect();
    assert_eq!(rs.len(), 8, "one response per command: {stdout}");
    assert_eq!(rs[0].get("ok").unwrap().as_bool(), Some(true), "{}", rs[0]);
    // matrix queries rejected cleanly, with the machine-checkable reason,
    // and the loop keeps serving
    for r in &rs[1..3] {
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
        assert_eq!(r.get("reason").unwrap().as_str(), Some("engine"), "{r}");
    }
    assert_eq!(rs[3].get("ok").unwrap().as_bool(), Some(true), "{}", rs[3]);
    assert!(rs[3].get("rowsum").unwrap().as_f64().is_some());
    assert_eq!(rs[4].get("ok").unwrap().as_bool(), Some(true), "{}", rs[4]);
    assert_eq!(rs[5].get("engine").unwrap().as_str(), Some("implicit"));
    assert_eq!(rs[6].get("ok").unwrap().as_bool(), Some(true), "{}", rs[6]);

    // the implicit snapshot is tiny (O(n), not O(n²)) and inspectable
    let (stdout, stderr, ok) = run(&["session", "--file", snap.to_str().unwrap(), "--topk", "5"]);
    assert!(ok, "session inspect failed: {stderr}");
    assert!(stdout.contains("implicit"), "{stdout}");
    assert!(stdout.contains("top-5"), "{stdout}");

    // ... and a fresh implicit serve resumes from it
    let mut child = Command::new(bin())
        .args([
            "serve", "--dataset", "moon", "--n-train", "30", "--k", "3",
            "--engine", "implicit", "--restore", snap.to_str().unwrap(),
        ])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn stiknn serve --restore (implicit)");
    {
        let stdin = child.stdin.as_mut().unwrap();
        writeln!(stdin, r#"{{"cmd":"stats"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"shutdown"}}"#).unwrap();
    }
    drop(child.stdin.take());
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stats = Json::parse(stdout.lines().next().unwrap()).unwrap();
    assert_eq!(stats.get("tests").unwrap().as_usize(), Some(3), "{stdout}");

    // a dense serve must refuse the implicit snapshot with a clear error
    let out = Command::new(bin())
        .args([
            "serve", "--dataset", "moon", "--n-train", "30", "--k", "3",
            "--restore", snap.to_str().unwrap(),
        ])
        .stdin(std::process::Stdio::null())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn stiknn serve (dense restore of implicit snapshot)");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("implicit"), "unhelpful error: {stderr}");

    let _ = std::fs::remove_file(&snap);
}

#[test]
fn serve_mutable_edits_snapshots_and_restores() {
    use std::io::Write;
    use stiknn::util::json::Json;

    let snap = std::env::temp_dir().join(format!(
        "stiknn_cli_serve_mutable_{}.snap",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&snap);

    let mut child = Command::new(bin())
        .args([
            "serve", "--dataset", "moon", "--n-train", "30", "--k", "3", "--mutable",
        ])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn stiknn serve --mutable");
    {
        let stdin = child.stdin.as_mut().unwrap();
        writeln!(
            stdin,
            r#"{{"cmd":"ingest","x":[0.1,0.2,1.0,-0.3,0.5,0.5],"y":[0,1,0]}}"#
        )
        .unwrap();
        writeln!(stdin, r#"{{"cmd":"add_train","x":[0.4,0.4],"y":1}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"relabel","i":0,"y":1}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"remove_train","i":2}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"query","i":0,"j":1}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"ping"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"snapshot","path":"{}"}}"#, snap.display()).unwrap();
        writeln!(stdin, r#"{{"cmd":"shutdown"}}"#).unwrap();
    }
    drop(child.stdin.take());
    let out = child.wait_with_output().unwrap();
    assert!(
        out.status.success(),
        "serve --mutable failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let rs: Vec<Json> = stdout
        .lines()
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("invalid NDJSON line {l:?}: {e}")))
        .collect();
    assert_eq!(rs.len(), 8, "one response per command: {stdout}");
    for r in &rs {
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    }
    assert_eq!(rs[1].get("index").unwrap().as_usize(), Some(30));
    assert_eq!(rs[1].get("n").unwrap().as_usize(), Some(31));
    assert_eq!(rs[3].get("n").unwrap().as_usize(), Some(30));
    assert_eq!(rs[3].get("mutations").unwrap().as_usize(), Some(3));
    assert_eq!(rs[5].get("engine").unwrap().as_str(), Some("implicit"));
    assert_eq!(rs[5].get("mutable").unwrap().as_bool(), Some(true));
    assert_eq!(rs[5].get("n").unwrap().as_usize(), Some(30));

    // the inspector reports the mutable state + mutation ledger
    let (stdout, stderr, ok) = run(&["session", "--file", snap.to_str().unwrap(), "--topk", "3"]);
    assert!(ok, "session inspect failed: {stderr}");
    assert!(stdout.contains("mutable"), "{stdout}");
    assert!(stdout.contains("mutation ledger"), "{stdout}");
    assert!(stdout.contains("top-3"), "{stdout}");

    // a mutable serve restores the edited session from the v3 snapshot
    // (no dataset fingerprint can match an edited train set)
    let mut child = Command::new(bin())
        .args([
            "serve", "--dataset", "moon", "--n-train", "30", "--k", "3", "--mutable",
            "--restore", snap.to_str().unwrap(),
        ])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn stiknn serve --mutable --restore");
    {
        let stdin = child.stdin.as_mut().unwrap();
        writeln!(stdin, r#"{{"cmd":"ping"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"remove_train","i":0}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"shutdown"}}"#).unwrap();
    }
    drop(child.stdin.take());
    let out = child.wait_with_output().unwrap();
    assert!(
        out.status.success(),
        "restore failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let rs: Vec<Json> = stdout.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(rs[0].get("t").unwrap().as_usize(), Some(3), "{stdout}");
    assert_eq!(rs[0].get("n").unwrap().as_usize(), Some(30));
    // the ledger carried over: this is mutation #4
    assert_eq!(rs[1].get("mutations").unwrap().as_usize(), Some(4));

    // an IMMUTABLE serve must refuse the mutable snapshot with a pointer
    let out = Command::new(bin())
        .args([
            "serve", "--dataset", "moon", "--n-train", "30", "--k", "3",
            "--restore", snap.to_str().unwrap(),
        ])
        .stdin(std::process::Stdio::null())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn stiknn serve (immutable restore of mutable snapshot)");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("mutable"), "unhelpful error: {stderr}");

    // --mutable contradicting an explicit dense engine is rejected
    let out = Command::new(bin())
        .args([
            "serve", "--dataset", "moon", "--n-train", "30", "--mutable",
            "--engine", "dense",
        ])
        .stdin(std::process::Stdio::null())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn stiknn serve --mutable --engine dense");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("implicit"), "unhelpful error: {stderr}");

    let _ = std::fs::remove_file(&snap);
}

#[test]
fn mutate_applies_ops_and_drops_lowest() {
    let (stdout, stderr, ok) = run(&[
        "mutate", "--dataset", "circle", "--n-train", "60", "--n-test", "15",
        "--k", "3", "--ops", "add:dup:0,relabel:5:1,remove:3", "--drop-lowest", "2",
        "--top", "5",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("mutable session"), "{stdout}");
    assert!(stdout.contains("add"), "{stdout}");
    assert!(stdout.contains("relabel"), "{stdout}");
    assert!(stdout.contains("remove"), "{stdout}");
    assert!(stdout.contains("drop"), "{stdout}");
    assert!(stdout.contains("5 edit(s) applied"), "{stdout}");
    assert!(stdout.contains("top-5"), "{stdout}");

    // bad op strings are rejected with guidance
    let (_, stderr, ok) = run(&[
        "mutate", "--dataset", "circle", "--n-train", "30", "--n-test", "8",
        "--ops", "explode:3",
    ]);
    assert!(!ok);
    assert!(stderr.contains("bad op"), "{stderr}");
}

#[test]
fn csv_datasets_load_and_malformed_csvs_fail_with_line_numbers() {
    let dir = std::env::temp_dir().join(format!("stiknn_cli_csv_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // a genuine header whose first column name is numeric must not be
    // eaten as a data row
    let good = dir.join("good.csv");
    let mut body = String::from("1,x2,label\n");
    for i in 0..12 {
        body.push_str(&format!("{i}.0,{}.5,{}\n", 12 - i, i % 2));
    }
    std::fs::write(&good, body).unwrap();
    let spec = format!("csv:{}", good.display());
    let (stdout, stderr, ok) = run(&[
        "values", "--dataset", &spec, "--n-train", "8", "--n-test", "4",
        "--k", "2", "--top", "3",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("n=8"), "{stdout}");
    assert!(stdout.contains("top-3"), "{stdout}");

    // non-integral label: rejected with the line number, never truncated
    let bad = dir.join("bad.csv");
    std::fs::write(&bad, "x,label\n1.0,0\n2.0,2.7\n3.0,1\n").unwrap();
    let spec = format!("csv:{}", bad.display());
    let (_, stderr, ok) = run(&["values", "--dataset", &spec]);
    assert!(!ok);
    assert!(stderr.contains("line 3"), "{stderr}");
    assert!(stderr.contains("not an integer"), "{stderr}");

    // ragged row
    std::fs::write(&bad, "1.0,2.0,0\n3.0,1\n").unwrap();
    let (_, stderr, ok) = run(&["values", "--dataset", &spec]);
    assert!(!ok);
    assert!(stderr.contains("line 2"), "{stderr}");
    assert!(stderr.contains("column count"), "{stderr}");

    // out-of-range label: rejected, not saturated
    std::fs::write(&bad, "1.0,0\n2.0,3000000000\n").unwrap();
    let (_, stderr, ok) = run(&["values", "--dataset", &spec]);
    assert!(!ok);
    assert!(stderr.contains("line 2"), "{stderr}");
    assert!(stderr.contains("out of i32 range"), "{stderr}");

    // unknown registry names now advertise the csv scheme
    let (_, stderr, ok) = run(&["values", "--dataset", "nope"]);
    assert!(!ok);
    assert!(stderr.contains("csv:PATH"), "{stderr}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_listen_bind_failure_and_bad_flag_combos_error_cleanly() {
    // un-parseable listen address: clean error, not a panic or a hang
    let (_, stderr, ok) = run(&[
        "serve", "--dataset", "moon", "--n-train", "30",
        "--listen", "256.256.256.256:0",
    ]);
    assert!(!ok);
    assert!(stderr.contains("binding --listen"), "{stderr}");
    // cap and autosave both need somewhere to put snapshots
    let (_, stderr, ok) = run(&[
        "serve", "--dataset", "moon", "--n-train", "30", "--max-resident", "2",
    ]);
    assert!(!ok);
    assert!(stderr.contains("state-dir"), "{stderr}");
    let (_, stderr, ok) = run(&[
        "serve", "--dataset", "moon", "--n-train", "30", "--autosave", "5",
    ]);
    assert!(!ok);
    assert!(stderr.contains("state-dir"), "{stderr}");
}

#[test]
fn serve_stdio_open_on_missing_snapshot_answers_cleanly() {
    use std::io::Write;
    use stiknn::util::json::Json;

    let mut child = Command::new(bin())
        .args(["serve", "--dataset", "moon", "--n-train", "30", "--k", "3"])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn stiknn serve");
    {
        let stdin = child.stdin.as_mut().unwrap();
        writeln!(
            stdin,
            r#"{{"cmd":"open","name":"gone","snapshot":"/nonexistent/gone.snap"}}"#
        )
        .unwrap();
        writeln!(stdin, r#"{{"cmd":"stats"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"shutdown"}}"#).unwrap();
    }
    drop(child.stdin.take());
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    let rs: Vec<Json> = stdout.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(rs.len(), 3, "{stdout}");
    // the failed open answers cleanly, keeps the current session, and
    // the loop keeps serving
    assert_eq!(rs[0].get("ok").unwrap().as_bool(), Some(false), "{}", rs[0]);
    assert!(
        rs[0].get("error").unwrap().as_str().unwrap().contains("snapshot"),
        "{}",
        rs[0]
    );
    assert_eq!(rs[1].get("ok").unwrap().as_bool(), Some(true), "{}", rs[1]);
    assert_eq!(rs[2].get("shutdown").unwrap().as_bool(), Some(true), "{}", rs[2]);
}

#[test]
fn serve_listen_accepts_concurrent_clients_and_survives_bad_ones() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::time::Duration;
    use stiknn::util::json::Json;

    let mut child = Command::new(bin())
        .args([
            "serve", "--dataset", "moon", "--n-train", "30", "--k", "3",
            "--listen", "127.0.0.1:0",
        ])
        .stdin(std::process::Stdio::null())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn stiknn serve --listen");

    // the chosen port (of 127.0.0.1:0) is reported on stderr
    let mut stderr = BufReader::new(child.stderr.take().unwrap());
    let addr = loop {
        let mut line = String::new();
        if stderr.read_line(&mut line).unwrap() == 0 {
            let _ = child.kill();
            panic!("serve exited before reporting a listen address");
        }
        if let Some(rest) = line.split("listening on ").nth(1) {
            break rest.split_whitespace().next().unwrap().to_string();
        }
    };

    struct Client {
        reader: BufReader<TcpStream>,
        writer: TcpStream,
    }
    impl Client {
        fn connect(addr: &str) -> Client {
            let writer = TcpStream::connect(addr).expect("connect");
            writer
                .set_read_timeout(Some(Duration::from_secs(30)))
                .unwrap();
            let reader = BufReader::new(writer.try_clone().unwrap());
            Client { reader, writer }
        }
        fn send(&mut self, line: &str) -> Json {
            writeln!(self.writer, "{line}").unwrap();
            let mut resp = String::new();
            self.reader.read_line(&mut resp).unwrap();
            Json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response {resp:?}: {e}"))
        }
    }

    let mut a = Client::connect(&addr);
    let r = a.send(r#"{"cmd":"ping"}"#);
    assert_eq!(r.get("n").unwrap().as_usize(), Some(30), "{r}");
    let r = a.send(r#"{"cmd":"ingest","x":[0.1,0.2,1.0,-0.3,0.5,0.5],"y":[0,1,0]}"#);
    assert_eq!(r.get("ingested").unwrap().as_usize(), Some(3), "{r}");

    // a half-closed client (partial line, no newline, then gone) and a
    // garbage client must not take the server down
    {
        let mut bad = TcpStream::connect(&addr).unwrap();
        bad.write_all(b"{\"cmd\":\"pi").unwrap();
        drop(bad);
        let mut garbage = Client::connect(&addr);
        let r = garbage.send("\u{fffd}not json at all");
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
    }

    // a second client sees the same default session (shared registry) …
    let mut b = Client::connect(&addr);
    let r = b.send(r#"{"cmd":"stats"}"#);
    assert_eq!(r.get("tests").unwrap().as_usize(), Some(3), "{r}");
    // … opens a second session without disturbing the first …
    let r = b.send(r#"{"cmd":"open","name":"scratch"}"#);
    assert_eq!(r.get("created").unwrap().as_bool(), Some(true), "{r}");
    let r = b.send(r#"{"cmd":"ingest","x":[0.4,0.4],"y":[1]}"#);
    assert_eq!(r.get("tests").unwrap().as_usize(), Some(1), "{r}");
    let r = b.send(r#"{"cmd":"list"}"#);
    assert_eq!(
        r.get("sessions").unwrap().as_arr().unwrap().len(),
        2,
        "{r}"
    );
    // … while client A (still on the default session) is unaffected
    let r = a.send(r#"{"cmd":"stats"}"#);
    assert_eq!(r.get("tests").unwrap().as_usize(), Some(3), "{r}");

    // shutdown ends ONE connection; the server keeps serving others
    let r = a.send(r#"{"cmd":"shutdown"}"#);
    assert_eq!(r.get("shutdown").unwrap().as_bool(), Some(true), "{r}");
    drop(a);
    let r = b.send(r#"{"cmd":"ping"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");

    child.kill().expect("kill serve");
    let _ = child.wait();
}

#[test]
fn serve_stdio_metrics_verb_snapshot_lookup_and_disabled_answers() {
    use std::io::Write;
    use stiknn::util::json::Json;

    // obs on (the default) with every command slow-logged
    let mut child = Command::new(bin())
        .args([
            "serve", "--dataset", "moon", "--n-train", "30", "--k", "3",
            "--slow-ms", "0",
        ])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn stiknn serve --slow-ms 0");
    {
        let stdin = child.stdin.as_mut().unwrap();
        writeln!(stdin, r#"{{"cmd":"ingest","x":[0.1,0.2,1.0,-0.3],"y":[0,1]}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"metrics"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"metrics","metric":"session.ingest_points"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"metrics","metric":"no.such.metric"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"shutdown"}}"#).unwrap();
    }
    drop(child.stdin.take());
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    let rs: Vec<Json> = stdout.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(rs.len(), 5, "{stdout}");
    // full session-scope snapshot: enabled, with the ingest counted
    assert_eq!(rs[1].get("ok").unwrap().as_bool(), Some(true), "{}", rs[1]);
    assert_eq!(rs[1].get("scope").unwrap().as_str(), Some("session"), "{}", rs[1]);
    assert_eq!(rs[1].get("enabled").unwrap().as_bool(), Some(true), "{}", rs[1]);
    let counters = rs[1].get("metrics").unwrap().get("counters").unwrap();
    assert_eq!(
        counters.get("session.ingest_batches").unwrap().as_usize(),
        Some(1),
        "{}",
        rs[1]
    );
    // single-metric lookup answers with just that value
    assert_eq!(rs[2].get("ok").unwrap().as_bool(), Some(true), "{}", rs[2]);
    assert_eq!(rs[2].get("value").unwrap().as_usize(), Some(2), "{}", rs[2]);
    // unknown names answer cleanly
    assert_eq!(rs[3].get("ok").unwrap().as_bool(), Some(false), "{}", rs[3]);
    assert!(
        rs[3].get("error").unwrap().as_str().unwrap().contains("unknown metric"),
        "{}",
        rs[3]
    );
    // --slow-ms 0 slow-logged the traffic on stderr
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("slow-query cmd=ingest"), "{stderr}");
    assert!(stderr.contains("session=default"), "{stderr}");

    // --obs off: snapshot answers with enabled=false, lookups explain
    let mut child = Command::new(bin())
        .args([
            "serve", "--dataset", "moon", "--n-train", "30", "--k", "3",
            "--obs", "off",
        ])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn stiknn serve --obs off");
    {
        let stdin = child.stdin.as_mut().unwrap();
        writeln!(stdin, r#"{{"cmd":"metrics"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"metrics","metric":"session.ingest_points"}}"#).unwrap();
        writeln!(stdin, r#"{{"cmd":"shutdown"}}"#).unwrap();
    }
    drop(child.stdin.take());
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    let rs: Vec<Json> = stdout.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(rs.len(), 3, "{stdout}");
    assert_eq!(rs[0].get("ok").unwrap().as_bool(), Some(true), "{}", rs[0]);
    assert_eq!(rs[0].get("enabled").unwrap().as_bool(), Some(false), "{}", rs[0]);
    assert!(matches!(rs[0].get("metrics"), Some(Json::Null)), "{}", rs[0]);
    assert_eq!(rs[1].get("ok").unwrap().as_bool(), Some(false), "{}", rs[1]);
    assert!(
        rs[1].get("error").unwrap().as_str().unwrap().contains("disabled"),
        "{}",
        rs[1]
    );
}

#[test]
fn serve_listen_metrics_process_scope_and_metrics_cli() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::time::Duration;
    use stiknn::util::json::Json;

    let mut child = Command::new(bin())
        .args([
            "serve", "--dataset", "moon", "--n-train", "30", "--k", "3",
            "--listen", "127.0.0.1:0", "--slow-ms", "0",
        ])
        .stdin(std::process::Stdio::null())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn stiknn serve --listen");
    let mut stderr = BufReader::new(child.stderr.take().unwrap());
    let addr = loop {
        let mut line = String::new();
        if stderr.read_line(&mut line).unwrap() == 0 {
            let _ = child.kill();
            panic!("serve exited before reporting a listen address");
        }
        if let Some(rest) = line.split("listening on ").nth(1) {
            break rest.split_whitespace().next().unwrap().to_string();
        }
    };

    // raw protocol: process-wide scope over TCP
    let writer = TcpStream::connect(&addr).expect("connect");
    writer.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(writer.try_clone().unwrap());
    let mut writer = writer;
    let mut send = |line: &str| -> Json {
        writeln!(writer, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response {resp:?}: {e}"))
    };
    let r = send(r#"{"cmd":"ingest","x":[0.1,0.2,1.0,-0.3],"y":[0,1]}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    let r = send(r#"{"cmd":"metrics","scope":"process"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    assert_eq!(r.get("scope").unwrap().as_str(), Some("process"), "{r}");
    assert_eq!(r.get("enabled").unwrap().as_bool(), Some(true), "{r}");
    assert!(!r.get("sessions").unwrap().as_arr().unwrap().is_empty(), "{r}");
    let commands = r
        .get("metrics").unwrap()
        .get("counters").unwrap()
        .get("server.commands").unwrap()
        .as_usize()
        .unwrap();
    assert!(commands >= 2, "{r}");

    // the `stiknn metrics` CLI scrapes the same server as Prometheus text …
    let (prom, stderr_cli, ok) = run(&["metrics", "--connect", &addr]);
    assert!(ok, "stderr: {stderr_cli}");
    assert!(prom.contains("# TYPE stiknn_server_commands counter"), "{prom}");
    assert!(prom.contains("stiknn_server_cmd_ingest_ns_count"), "{prom}");
    // … or as the raw JSON snapshot …
    let (json_out, _, ok) = run(&["metrics", "--connect", &addr, "--json"]);
    assert!(ok);
    let snap = Json::parse(json_out.trim()).expect("valid snapshot json");
    assert!(snap.get("counters").is_some(), "{json_out}");
    // … or a single metric, session-scoped
    let (one, _, ok) = run(&[
        "metrics", "--connect", &addr, "--session", "default",
        "--metric", "session.ingest_points",
    ]);
    assert!(ok);
    assert_eq!(one.trim(), "2", "{one}");
    // unknown names fail with the server's explanation
    let (_, stderr_cli, ok) = run(&["metrics", "--connect", &addr, "--metric", "no.such"]);
    assert!(!ok);
    assert!(stderr_cli.contains("unknown metric"), "{stderr_cli}");

    child.kill().expect("kill serve");
    let _ = child.wait();
}

#[test]
fn session_inspector_rejects_garbage_files() {
    let bogus = std::env::temp_dir().join(format!("stiknn_cli_bogus_{}.snap", std::process::id()));
    std::fs::write(&bogus, b"definitely not a snapshot").unwrap();
    let (_, stderr, ok) = run(&["session", "--file", bogus.to_str().unwrap()]);
    assert!(!ok);
    assert!(
        stderr.contains("snapshot") || stderr.contains("checksum"),
        "unhelpful error: {stderr}"
    );
    let _ = std::fs::remove_file(&bogus);
}

#[test]
fn artifacts_subcommand_lists_manifest_when_present() {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json");
    if !manifest.exists() {
        eprintln!("SKIP: no artifacts built");
        return;
    }
    let (stdout, _, ok) = run(&["artifacts"]);
    assert!(ok);
    assert!(stdout.contains("sti_n600_d2_b32_k5"));
}
