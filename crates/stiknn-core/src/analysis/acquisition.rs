//! Data acquisition — the third use case the paper's introduction
//! motivates ("expanding the training set"): given a small seed training
//! set and a pool of candidate points, acquire candidates in value order
//! and track the accuracy trajectory vs random acquisition.
//!
//! Candidate value is estimated with KNN-Shapley computed over
//! seed ∪ pool (values transfer to the acquisition decision because KNN
//! value is rank/label-local), which is the paper-ecosystem's standard
//! acquisition proxy (Ghorbani & Zou 2019).

use crate::data::Dataset;
use crate::knn::KnnClassifier;
use crate::shapley::knn_shapley::knn_shapley;
use crate::shapley::values::{sti_point_values, Engine};
use crate::shapley::StiParams;
use crate::util::rng::Rng;

/// Accuracy trajectory of acquiring `step` pool points at a time.
/// Returns (acquired_count, accuracy) pairs, starting from the seed set.
pub fn acquisition_curve(
    ds: &Dataset,
    seed_size: usize,
    order: &[usize],
    step: usize,
    k: usize,
) -> Vec<(usize, f64)> {
    assert!(seed_size >= k && seed_size <= ds.n_train());
    assert!(order.iter().all(|&i| i >= seed_size && i < ds.n_train()),
            "acquisition order must index pool points (>= seed_size)");
    let mut keep: Vec<usize> = (0..seed_size).collect();
    let mut out = Vec::new();
    let mut cursor = 0usize;
    loop {
        let sub = ds.retain_train(&keep);
        let acc = KnnClassifier::new(&sub.train_x, &sub.train_y, sub.d, k)
            .accuracy(&ds.test_x, &ds.test_y);
        out.push((keep.len() - seed_size, acc));
        if cursor >= order.len() {
            break;
        }
        let take = step.min(order.len() - cursor);
        keep.extend_from_slice(&order[cursor..cursor + take]);
        cursor += take;
    }
    out
}

/// Value-greedy acquisition order over the pool (descending KNN-Shapley,
/// computed on the full seed ∪ pool set).
pub fn value_order(ds: &Dataset, seed_size: usize, k: usize) -> Vec<usize> {
    let values = knn_shapley(&ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, k);
    let mut pool: Vec<usize> = (seed_size..ds.n_train()).collect();
    // total order per the session::top_k_of convention — a NaN value
    // must reorder deterministically, never panic the acquisition loop
    pool.sort_by(|&a, &b| values[b].total_cmp(&values[a]).then(a.cmp(&b)));
    pool
}

/// Value-greedy acquisition order from STI per-point values (total
/// rowsum, descending — acquire high main-effect-plus-synergy points
/// first). `params` carries k AND the metric (so orders reproduce any
/// session config's values); routes through the value engine:
/// `Engine::Implicit` computes the order in O(t·n log n) with O(n)
/// state, which is what makes valuation-guided acquisition viable on
/// pools the dense matrix cannot even be allocated for; `Engine::Dense`
/// is the materializing cross-check.
pub fn sti_value_order(
    ds: &Dataset,
    seed_size: usize,
    params: &StiParams,
    engine: Engine,
) -> Vec<usize> {
    let pv = sti_point_values(
        &ds.train_x,
        &ds.train_y,
        ds.d,
        &ds.test_x,
        &ds.test_y,
        params,
        engine,
    );
    let mut pool: Vec<usize> = (seed_size..ds.n_train()).collect();
    pool.sort_by(|&a, &b| pv.rowsum[b].total_cmp(&pv.rowsum[a]).then(a.cmp(&b)));
    pool
}

/// Random acquisition order (baseline).
pub fn random_order(ds: &Dataset, seed_size: usize, seed: u64) -> Vec<usize> {
    let mut pool: Vec<usize> = (seed_size..ds.n_train()).collect();
    Rng::new(seed).shuffle(&mut pool);
    pool
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::removal::curve_area;
    use crate::data::{corrupt, load_dataset};

    #[test]
    fn value_order_defers_mislabeled_pool_points() {
        // pool contains 20% flipped labels: the value signal should push
        // them toward the END of the acquisition order (low value). We
        // assert on the ordering signal itself — accuracy trajectories on
        // a noise-robust learner like KNN-5 are too flat to discriminate.
        let mut ds = load_dataset("circle", 300, 80, 3).unwrap();
        let seed_size = 30;
        let flipped: std::collections::HashSet<usize> =
            corrupt::flip_labels(&mut ds, 0.2, 7).into_iter().collect();
        let k = 5;
        let order = value_order(&ds, seed_size, k);
        let half = order.len() / 2;
        let front = order[..half].iter().filter(|i| flipped.contains(i)).count();
        let back = order[half..].iter().filter(|i| flipped.contains(i)).count();
        assert!(
            back > 2 * front,
            "flipped points should sink to the back: front={front} back={back}"
        );
    }

    #[test]
    fn greedy_curve_dominates_random_early() {
        // acquire only a few points from a pool that is mostly noise:
        // greedy picks the informative ones first
        let mut ds = load_dataset("circle", 200, 60, 9).unwrap();
        let seed_size = 20;
        corrupt::flip_labels(&mut ds, 0.4, 3);
        // restore the seed to clean labels
        let clean = load_dataset("circle", 200, 60, 9).unwrap();
        ds.train_y[..seed_size].copy_from_slice(&clean.train_y[..seed_size]);
        let k = 5;
        let greedy_order = value_order(&ds, seed_size, k);
        let rand_order = random_order(&ds, seed_size, 11);
        // acquire the first 40 points in steps of 10, compare areas
        let greedy = acquisition_curve(&ds, seed_size, &greedy_order[..40], 10, k);
        let random = acquisition_curve(&ds, seed_size, &rand_order[..40], 10, k);
        let (ag, ar) = (curve_area(&greedy), curve_area(&random));
        assert!(ag >= ar, "greedy {ag} should not lose to random {ar}");
    }

    #[test]
    fn sti_value_order_defers_mislabeled_pool_points_without_a_matrix() {
        // Same property as the KNN-Shapley order, via the implicit STI
        // engine: flipped pool points sink toward the back of the order.
        let mut ds = load_dataset("circle", 300, 80, 3).unwrap();
        let seed_size = 30;
        let flipped: std::collections::HashSet<usize> =
            corrupt::flip_labels(&mut ds, 0.2, 7).into_iter().collect();
        let order = sti_value_order(&ds, seed_size, &StiParams::new(5), Engine::Implicit);
        assert_eq!(order.len(), ds.n_train() - seed_size);
        let half = order.len() / 2;
        let front = order[..half].iter().filter(|i| flipped.contains(i)).count();
        let back = order[half..].iter().filter(|i| flipped.contains(i)).count();
        assert!(
            back > front,
            "flipped points should sink to the back: front={front} back={back}"
        );
        // both engines produce value-equivalent orders
        let dense = sti_value_order(&ds, seed_size, &StiParams::new(5), Engine::Dense);
        let pv = sti_point_values(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(5), Engine::Implicit,
        );
        for (a, b) in order.iter().zip(&dense) {
            assert!((pv.rowsum[*a] - pv.rowsum[*b]).abs() < 1e-9);
        }
    }

    #[test]
    fn curve_starts_at_seed_accuracy_and_counts_acquisitions() {
        let ds = load_dataset("moon", 100, 25, 5).unwrap();
        let pool = ds.n_train() - 20;
        let order = random_order(&ds, 20, 3);
        assert_eq!(order.len(), pool);
        let curve = acquisition_curve(&ds, 20, &order[..40], 20, 3);
        assert_eq!(curve[0].0, 0);
        assert_eq!(curve.last().unwrap().0, 40);
        assert_eq!(curve.len(), 3); // 0, 20, 40
    }

    #[test]
    #[should_panic(expected = "pool points")]
    fn rejects_orders_into_the_seed() {
        let ds = load_dataset("moon", 50, 10, 5).unwrap();
        acquisition_curve(&ds, 20, &[5], 1, 3);
    }
}
