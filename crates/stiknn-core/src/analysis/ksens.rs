//! k-sensitivity analysis (§3.2, Appendix B, Figs. 7–10): the paper's
//! claim that the KNN parameter k has negligible effect on the *shape* of
//! the interaction matrix — Pearson correlation between flattened STI
//! matrices > 0.99 for all 3 ≤ k₁, k₂ ≤ 20 — while the *scale* changes
//! (Corollary 1: std inversely related to k).

use crate::data::Dataset;
use crate::shapley::sti_knn::{sti_knn, StiParams};
use crate::util::matrix::Matrix;
use crate::util::stats;

/// Pairwise correlation report over a k-grid for one dataset.
#[derive(Clone, Debug)]
pub struct KSensReport {
    pub dataset: String,
    pub ks: Vec<usize>,
    /// Pearson r between FULL flattened matrices (incl. diagonal — the
    /// paper's Appendix-B methodology: "the correlation between matrices
    /// (flattened)"), pairwise over `ks` × `ks`.
    pub correlations: Matrix,
    /// Pearson r between strict-upper-triangle entries only — the
    /// stricter variant that excludes the main terms (which are
    /// proportional across k and inflate the full-matrix correlation);
    /// reported alongside in EXPERIMENTS.md.
    pub correlations_offdiag: Matrix,
    /// std of strict-upper-triangle entries per k (Corollary 1).
    pub stds: Vec<f64>,
    /// min over pairs, full-matrix (paper methodology).
    pub min_correlation: f64,
    /// min over pairs, off-diagonal only.
    pub min_correlation_offdiag: f64,
}

/// Compute STI matrices for each k and correlate them pairwise.
pub fn k_sensitivity(ds: &Dataset, ks: &[usize]) -> KSensReport {
    assert!(!ks.is_empty());
    let mut flats: Vec<Vec<f64>> = Vec::with_capacity(ks.len());
    let mut uppers: Vec<Vec<f64>> = Vec::with_capacity(ks.len());
    for &k in ks {
        let m = sti_knn(
            &ds.train_x,
            &ds.train_y,
            ds.d,
            &ds.test_x,
            &ds.test_y,
            &StiParams::new(k),
        );
        flats.push(m.data().to_vec());
        uppers.push(m.upper_triangle_entries());
    }
    let stds: Vec<f64> = uppers.iter().map(|m| stats::std(m)).collect();
    let correlate = |sets: &[Vec<f64>]| -> (Matrix, f64) {
        let mut corr = Matrix::zeros(ks.len(), ks.len());
        let mut min_r = f64::INFINITY;
        for i in 0..ks.len() {
            for j in 0..ks.len() {
                let r = if i == j {
                    1.0
                } else {
                    stats::pearson(&sets[i], &sets[j])
                };
                corr.set(i, j, r);
                if i != j && r < min_r {
                    min_r = r;
                }
            }
        }
        if ks.len() == 1 {
            min_r = 1.0;
        }
        (corr, min_r)
    };
    let (correlations, min_correlation) = correlate(&flats);
    let (correlations_offdiag, min_correlation_offdiag) = correlate(&uppers);
    KSensReport {
        dataset: ds.name.clone(),
        ks: ks.to_vec(),
        correlations,
        correlations_offdiag,
        stds,
        min_correlation,
        min_correlation_offdiag,
    }
}

impl KSensReport {
    /// The paper's acceptance criterion.
    pub fn passes_paper_threshold(&self) -> bool {
        self.min_correlation > 0.99
    }

    /// Corollary-1 check: stds non-increasing as k grows (ks must be
    /// passed in ascending order).
    pub fn stds_decreasing(&self) -> bool {
        self.stds.windows(2).all(|w| w[1] <= w[0] * 1.05)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;

    #[test]
    fn circle_correlations_above_paper_threshold() {
        // paper methodology (full flattened matrices, Appendix B) at a
        // reduced scale; the full n=600 sweep lives in examples/k_sensitivity
        let ds = load_dataset("circle", 150, 40, 3).unwrap();
        let rep = k_sensitivity(&ds, &[3, 5, 9, 15, 20]);
        assert!(
            rep.passes_paper_threshold(),
            "min corr {} ≤ 0.99",
            rep.min_correlation
        );
        // the stricter off-diagonal variant is lower but still high at
        // paper scale (~0.98 at n=600, see EXPERIMENTS.md); here we only
        // pin that it is meaningfully positive
        assert!(rep.min_correlation_offdiag > 0.5,
                "offdiag corr {}", rep.min_correlation_offdiag);
    }

    #[test]
    fn corollary1_std_decreases_with_k() {
        let ds = load_dataset("circle", 150, 40, 3).unwrap();
        let rep = k_sensitivity(&ds, &[3, 6, 12, 20]);
        assert!(rep.stds_decreasing(), "stds {:?}", rep.stds);
        assert!(rep.stds[0] > rep.stds[3], "stds {:?}", rep.stds);
    }

    #[test]
    fn correlation_matrix_is_symmetric_with_unit_diag() {
        let ds = load_dataset("moon", 80, 20, 5).unwrap();
        let rep = k_sensitivity(&ds, &[3, 7]);
        assert_eq!(rep.correlations.get(0, 0), 1.0);
        assert!(
            (rep.correlations.get(0, 1) - rep.correlations.get(1, 0)).abs() < 1e-12
        );
        // full-matrix correlation dominates the off-diagonal one (the
        // proportional main terms can only raise it)
        assert!(rep.min_correlation >= rep.min_correlation_offdiag - 1e-9);
    }
}
