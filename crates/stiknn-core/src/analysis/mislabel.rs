//! Mislabel detection from interaction patterns (§4, Fig. 5): "Mislabeled
//! points behave like the opposite class ... their pattern corresponds
//! more to the opposite class."
//!
//! Operationalization: for each training point i, compare its interaction
//! row φ_{i,·} against the mean interaction row of each class (templates
//! built excluding i). A point whose row correlates better with another
//! class's template than its own class's is flagged. Scores are
//! margin-based so the caller can sweep thresholds / compute AUC.
//!
//! Two score paths (the engine switch, DESIGN.md §10):
//!
//! * [`mislabel_scores`] — the dense/original detector above; needs the
//!   materialized matrix (O(n²) memory).
//! * [`mislabel_scores_values`] — the implicit path: per-point
//!   CLASS-SPLIT interaction means from
//!   `shapley::values::class_interaction_sums` (O(t·n·classes) time,
//!   O(n·classes) state, no matrix). Same signal read coarser: in-class
//!   interaction mass is strongly negative for correctly-labeled points
//!   (Fig. 3's diagonal blocks), while a mislabeled point interacts with
//!   its *labeled* class like a foreign point — so its labeled-class
//!   mean sits ABOVE some other class's mean and the margin flips sign.

use crate::shapley::values::class_interaction_sums;
use crate::shapley::StiParams;
use crate::util::matrix::Matrix;
use crate::util::stats;

/// Per-point suspicion report.
#[derive(Clone, Debug)]
pub struct MislabelReport {
    /// suspicion margin per train point: corr(best other class) −
    /// corr(own class); > 0 means the point patterns with another class.
    pub margins: Vec<f64>,
    /// indices flagged (margin > 0), sorted by decreasing margin.
    pub flagged: Vec<usize>,
}

/// Compute suspicion margins from an averaged interaction matrix and the
/// (possibly corrupted) train labels.
pub fn mislabel_scores(phi: &Matrix, train_y: &[i32], classes: usize) -> MislabelReport {
    let n = train_y.len();
    assert_eq!(phi.rows(), n);
    // class templates: mean row per class, EXCLUDING diagonal entries —
    // the main terms φ_jj are orders of magnitude larger than the
    // interactions and would otherwise dominate every correlation
    let mut templates = vec![vec![0.0f64; n]; classes];
    let mut tcounts = vec![vec![0usize; n]; classes];
    let mut counts = vec![0usize; classes];
    for i in 0..n {
        let c = train_y[i] as usize;
        counts[c] += 1;
        for j in 0..n {
            if j == i {
                continue;
            }
            templates[c][j] += phi.get(i, j);
            tcounts[c][j] += 1;
        }
    }
    for (t, tc) in templates.iter_mut().zip(&tcounts) {
        for (v, &cnt) in t.iter_mut().zip(tc) {
            if cnt > 0 {
                *v /= cnt as f64;
            }
        }
    }
    // margins: best-other-class correlation minus own-class correlation.
    // The diagonal and the point's own column are excluded (main terms are
    // label-dependent and would leak).
    let mut margins = vec![0.0f64; n];
    for i in 0..n {
        let row: Vec<f64> = (0..n)
            .filter(|&j| j != i)
            .map(|j| phi.get(i, j))
            .collect();
        let mut own = f64::NAN;
        let mut best_other = f64::NEG_INFINITY;
        for (c, t) in templates.iter().enumerate() {
            if counts[c] == 0 {
                continue;
            }
            let tv: Vec<f64> = (0..n).filter(|&j| j != i).map(|j| t[j]).collect();
            let r = stats::pearson(&row, &tv);
            let r = if r.is_nan() { 0.0 } else { r };
            if c == train_y[i] as usize {
                own = r;
            } else if r > best_other {
                best_other = r;
            }
        }
        margins[i] = best_other - own;
    }
    // total_cmp, not partial_cmp().unwrap(): a NaN margin (degenerate
    // correlation input) must not panic the detector mid-report
    let mut flagged: Vec<usize> = (0..n).filter(|&i| margins[i] > 0.0).collect();
    flagged.sort_by(|&a, &b| margins[b].total_cmp(&margins[a]).then(a.cmp(&b)));
    MislabelReport { margins, flagged }
}

/// Mislabel suspicion from class-split interaction MEANS, computed via
/// the implicit engine — no n×n matrix anywhere (O(t·n·classes) total).
///
/// For each point i and class c, let mean_c(i) be i's average pairwise
/// interaction with class-c points (excluding i). Correctly-labeled
/// points have strongly negative own-class means (in-class redundancy,
/// Fig. 3/4); a mislabeled point's own-LABEL mean looks cross-class
/// (weak) while some other class's mean carries the in-class signature.
/// Margin: `own_mean − min_other_mean` — positive ⇒ the point interacts
/// more "in-class-ly" with a class it is not labeled as ⇒ suspicious.
/// Same [`MislabelReport`] contract as [`mislabel_scores`].
pub fn mislabel_scores_values(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    params: &StiParams,
    classes: usize,
) -> MislabelReport {
    let n = train_y.len();
    let sums = class_interaction_sums(train_x, train_y, d, test_x, test_y, params, classes);
    let mut counts = vec![0usize; classes];
    for &y in train_y {
        counts[y as usize] += 1;
    }
    let mut margins = vec![0.0f64; n];
    for i in 0..n {
        let own_class = train_y[i] as usize;
        let mut own = f64::NAN;
        let mut min_other = f64::INFINITY;
        for c in 0..classes {
            // pair partners in class c, excluding i itself
            let partners = counts[c] - usize::from(c == own_class);
            if partners == 0 {
                continue;
            }
            let mean = sums.get(i, c) / partners as f64;
            if c == own_class {
                own = mean;
            } else if mean < min_other {
                min_other = mean;
            }
        }
        margins[i] = if own.is_nan() || min_other.is_infinite() {
            0.0
        } else {
            own - min_other
        };
    }
    let mut flagged: Vec<usize> = (0..n).filter(|&i| margins[i] > 0.0).collect();
    flagged.sort_by(|&a, &b| margins[b].total_cmp(&margins[a]).then(a.cmp(&b)));
    MislabelReport { margins, flagged }
}

/// Precision/recall of a flag set against ground-truth flipped indices.
pub fn precision_recall(flagged: &[usize], truth: &[usize]) -> (f64, f64) {
    if flagged.is_empty() {
        return (f64::NAN, 0.0);
    }
    let truth_set: std::collections::HashSet<_> = truth.iter().collect();
    let tp = flagged.iter().filter(|i| truth_set.contains(i)).count() as f64;
    (
        tp / flagged.len() as f64,
        if truth.is_empty() {
            f64::NAN
        } else {
            tp / truth.len() as f64
        },
    )
}

/// Recall within the top-m ranked margins, m = |truth| ("precision@k" with
/// k = prevalence — the detection metric valuation papers report when the
/// contamination rate is known).
pub fn top_prevalence_recall(margins: &[f64], truth: &[usize]) -> f64 {
    if truth.is_empty() {
        return f64::NAN;
    }
    let mut idx: Vec<usize> = (0..margins.len()).collect();
    idx.sort_by(|&a, &b| margins[b].total_cmp(&margins[a]).then(a.cmp(&b)));
    let top: std::collections::HashSet<usize> = idx.into_iter().take(truth.len()).collect();
    truth.iter().filter(|i| top.contains(i)).count() as f64 / truth.len() as f64
}

/// ROC AUC of margin scores against ground truth (probability a flipped
/// point outranks a clean one).
pub fn auc(margins: &[f64], truth: &[usize]) -> f64 {
    let truth_set: std::collections::HashSet<_> = truth.iter().copied().collect();
    let mut pos = Vec::new();
    let mut neg = Vec::new();
    for (i, &m) in margins.iter().enumerate() {
        if truth_set.contains(&i) {
            pos.push(m);
        } else {
            neg.push(m);
        }
    }
    if pos.is_empty() || neg.is_empty() {
        return f64::NAN;
    }
    let mut wins = 0.0;
    for &p in &pos {
        for &q in &neg {
            if p > q {
                wins += 1.0;
            } else if p == q {
                wins += 0.5;
            }
        }
    }
    wins / (pos.len() * neg.len()) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{corrupt, load_dataset};
    use crate::shapley::sti_knn::{sti_knn, StiParams};

    #[test]
    fn detects_flipped_circle_points() {
        let mut ds = load_dataset("circle", 160, 60, 7).unwrap();
        let truth = corrupt::flip_labels(&mut ds, 0.05, 13);
        let phi = sti_knn(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(5),
        );
        let rep = mislabel_scores(&phi, &ds.train_y, ds.classes);
        let a = auc(&rep.margins, &truth);
        assert!(a > 0.9, "mislabel AUC too low: {a}");
        let r = top_prevalence_recall(&rep.margins, &truth);
        assert!(r > 0.5, "top-prevalence recall too low: {r}");
    }

    #[test]
    fn clean_dataset_flags_little() {
        let ds = load_dataset("circle", 160, 60, 7).unwrap();
        let phi = sti_knn(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(5),
        );
        let rep = mislabel_scores(&phi, &ds.train_y, ds.classes);
        assert!(
            rep.flagged.len() < ds.n_train() / 10,
            "flagged {} of {} clean points",
            rep.flagged.len(),
            ds.n_train()
        );
    }

    #[test]
    fn value_based_detector_finds_flips_without_a_matrix() {
        let mut ds = load_dataset("circle", 160, 60, 7).unwrap();
        let truth = corrupt::flip_labels(&mut ds, 0.05, 13);
        let rep = mislabel_scores_values(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(5), ds.classes,
        );
        assert_eq!(rep.margins.len(), ds.n_train());
        let a = auc(&rep.margins, &truth);
        assert!(a > 0.8, "value-based mislabel AUC too low: {a}");
    }

    #[test]
    fn value_based_detector_is_quiet_on_clean_data() {
        let ds = load_dataset("circle", 160, 60, 7).unwrap();
        let rep = mislabel_scores_values(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(5), ds.classes,
        );
        assert!(
            rep.flagged.len() < ds.n_train() / 5,
            "flagged {} of {} clean points",
            rep.flagged.len(),
            ds.n_train()
        );
    }

    #[test]
    fn precision_recall_arithmetic() {
        let (p, r) = precision_recall(&[1, 2, 3, 4], &[2, 4, 9]);
        assert!((p - 0.5).abs() < 1e-12);
        assert!((r - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn auc_of_perfect_separation_is_one() {
        let margins = vec![0.9, -0.5, 0.8, -0.3];
        assert_eq!(auc(&margins, &[0, 2]), 1.0);
        assert_eq!(auc(&margins, &[1, 3]), 0.0);
    }

    #[test]
    fn top_prevalence_recall_with_nan_margins_is_deterministic() {
        // a NaN margin outranks everything under the total order; the
        // ranking must neither panic nor depend on input permutation
        let margins = vec![0.1, f64::NAN, 0.9, 0.2];
        let r = top_prevalence_recall(&margins, &[1, 2]);
        assert!((r - 1.0).abs() < 1e-12, "{r}");
        let r = top_prevalence_recall(&margins, &[0, 3]);
        assert_eq!(r, 0.0);
    }
}
