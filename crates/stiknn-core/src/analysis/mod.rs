//! Analysis suite over computed interaction values — the paper's §3.2
//! and §4 experiments as reusable components. Point-value consumers
//! (removal / acquisition orders, the class-split mislabel detector)
//! route through the implicit value engine (`shapley::values`,
//! DESIGN.md §10) so they scale past matrix-materializable n; the
//! matrix-based paths stay available behind the engine switch.

pub mod acquisition;
pub mod ksens;
pub mod mislabel;
pub mod redundancy;
pub mod removal;
pub mod structure;

pub use ksens::{k_sensitivity, KSensReport};
pub use mislabel::{mislabel_scores, mislabel_scores_values, MislabelReport};
pub use removal::sti_removal_order;
pub use structure::block_structure;
