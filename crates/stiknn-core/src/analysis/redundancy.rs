//! Redundancy analysis (§4, Fig. 4): "Redundancy decreases in-class
//! interaction" — subsampling one class (fewer, less redundant points)
//! must *increase* the per-pair in-class interaction magnitude, because
//! the efficiency budget (≈ a_test) is split across fewer pairs.

use crate::util::matrix::Matrix;
use crate::util::stats;

/// Mean |interaction| split by pair type.
#[derive(Clone, Debug, PartialEq)]
pub struct InteractionBreakdown {
    /// mean |φ_ij| over pairs with equal labels (i < j)
    pub in_class: f64,
    /// mean |φ_ij| over pairs with different labels (i < j)
    pub out_class: f64,
    pub n_in: usize,
    pub n_out: usize,
}

/// Decompose the strict upper triangle by pair label equality.
pub fn interaction_breakdown(phi: &Matrix, train_y: &[i32]) -> InteractionBreakdown {
    let n = train_y.len();
    assert_eq!(phi.rows(), n);
    let mut in_vals = Vec::new();
    let mut out_vals = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            let v = phi.get(i, j).abs();
            if train_y[i] == train_y[j] {
                in_vals.push(v);
            } else {
                out_vals.push(v);
            }
        }
    }
    InteractionBreakdown {
        in_class: stats::mean(&in_vals),
        out_class: stats::mean(&out_vals),
        n_in: in_vals.len(),
        n_out: out_vals.len(),
    }
}

/// Mean |φ| within one class's block.
pub fn class_block_mean_abs(phi: &Matrix, train_y: &[i32], class: i32) -> f64 {
    let idx: Vec<usize> = (0..train_y.len())
        .filter(|&i| train_y[i] == class)
        .collect();
    let mut vals = Vec::new();
    for (a, &i) in idx.iter().enumerate() {
        for &j in &idx[a + 1..] {
            vals.push(phi.get(i, j).abs());
        }
    }
    stats::mean(&vals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{corrupt, load_dataset};
    use crate::shapley::sti_knn::{sti_knn, StiParams};

    #[test]
    fn in_class_dominates_out_class_on_circle() {
        // §4 Fig. 3: same-class points interact heavily (negatively),
        // cross-class pairs interact less. Measured at paper scale
        // (n=600): in/out ≈ 1.9× (EXPERIMENTS.md FIG3 — the paper's
        // "almost do not interact" is qualitative; the cluster structure
        // is what reproduces).
        let ds = load_dataset("circle", 600, 150, 3).unwrap();
        let phi = sti_knn(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(5),
        );
        let b = interaction_breakdown(&phi, &ds.train_y);
        assert!(
            b.in_class > 1.5 * b.out_class,
            "in {} vs out {}",
            b.in_class,
            b.out_class
        );
    }

    #[test]
    fn subsampling_raises_per_pair_interaction() {
        // §4 Fig. 4: fewer (less redundant) blue points -> larger per-pair
        // in-class interaction magnitude for that class
        let ds = load_dataset("circle", 300, 80, 9).unwrap();
        let k = 5;
        let phi_full = sti_knn(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(k),
        );
        let full_blue = class_block_mean_abs(&phi_full, &ds.train_y, 0);

        let sub = corrupt::subsample_class(&ds, 0, 30, 3);
        let phi_sub = sti_knn(
            &sub.train_x, &sub.train_y, sub.d, &sub.test_x, &sub.test_y,
            &StiParams::new(k),
        );
        let sub_blue = class_block_mean_abs(&phi_sub, &sub.train_y, 0);
        assert!(
            sub_blue > 1.5 * full_blue,
            "subsampled {} vs full {}",
            sub_blue,
            full_blue
        );
    }

    #[test]
    fn breakdown_counts_pairs() {
        let phi = Matrix::zeros(4, 4);
        let b = interaction_breakdown(&phi, &[0, 0, 1, 1]);
        assert_eq!(b.n_in, 2); // (0,1), (2,3)
        assert_eq!(b.n_out, 4);
    }
}
