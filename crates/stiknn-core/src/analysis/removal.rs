//! Point-removal experiments — the data-valuation use cases the paper's
//! introduction motivates (training-set summarization / cleaning):
//! remove points in value order and track test accuracy.
//!
//! Point-value consumption routes through the implicit value engine by
//! default ([`sti_removal_order`], `shapley::values` / DESIGN.md §10):
//! removal curves only need per-point aggregates, so materializing the
//! n×n matrix is pure waste — the dense path stays available behind the
//! engine switch for cross-checks.
//!
//! Two removal orders exist:
//!
//! * [`sti_removal_order`] — ONE static ranking of the full train set
//!   (values computed once, points removed in that fixed order). Cheap,
//!   but an approximation: values shift as points leave the set.
//! * `sti_iterative_removal_order` — the EXACT greedy order via the
//!   delta subsystem (DESIGN.md §11): remove the current lowest-value
//!   point, repair the live session in O(t·n), re-rank, repeat. It
//!   drives a live mutable session, so it lives in `stiknn-session`
//!   (`removal` module there); the `stiknn` facade re-exports it at this
//!   module's pre-split path.

use crate::data::Dataset;
use crate::knn::KnnClassifier;
use crate::shapley::values::{sti_point_values, Engine};
use crate::shapley::StiParams;

/// Accuracy curve from removing train points in the given order.
/// Returns accuracy after removing 0, step, 2·step, ... points
/// (keeping at least `min_keep`).
pub fn removal_curve(
    ds: &Dataset,
    removal_order: &[usize],
    step: usize,
    min_keep: usize,
    k: usize,
) -> Vec<(usize, f64)> {
    assert_eq!(removal_order.len(), ds.n_train());
    assert!(step >= 1);
    let mut removed: std::collections::HashSet<usize> = Default::default();
    let mut out = Vec::new();
    let mut cursor = 0usize;
    loop {
        let keep: Vec<usize> = (0..ds.n_train()).filter(|i| !removed.contains(i)).collect();
        if keep.len() < min_keep.max(k) {
            break;
        }
        let sub = ds.retain_train(&keep);
        let acc = KnnClassifier::new(&sub.train_x, &sub.train_y, sub.d, k)
            .accuracy(&ds.test_x, &ds.test_y);
        out.push((removed.len(), acc));
        // remove the next `step`
        let mut added = 0;
        while added < step && cursor < removal_order.len() {
            removed.insert(removal_order[cursor]);
            cursor += 1;
            added += 1;
        }
        if added == 0 {
            break;
        }
    }
    out
}

/// Removal order from STI per-point values (total rowsum — main effect
/// plus synergies), lowest value first. `params` carries k AND the
/// metric, so orders reproduce values served by any session config;
/// `engine` picks how the values are computed: `Engine::Implicit`
/// (default choice for every caller that only needs the ORDER) runs in
/// O(t·n log n)/O(n) via the rank-space suffix-sum identity;
/// `Engine::Dense` materializes the matrix first. Both orders agree up
/// to value ties (values agree to ≤ 1e-12 —
/// `tests/values_equivalence.rs`).
pub fn sti_removal_order(ds: &Dataset, params: &StiParams, engine: Engine) -> Vec<usize> {
    let pv = sti_point_values(
        &ds.train_x,
        &ds.train_y,
        ds.d,
        &ds.test_x,
        &ds.test_y,
        params,
        engine,
    );
    order_by_value_asc(&pv.rowsum)
}

/// Index of the minimum value (total order, ties → lowest index) — the
/// greedy-removal step shared by this module and `stiknn mutate
/// --drop-lowest`; keeping one copy keeps their orders identical.
pub fn argmin_by_value(values: &[f64]) -> usize {
    values
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.total_cmp(b.1).then(a.0.cmp(&b.0)))
        .expect("non-empty value vector")
        .0
}

/// Order train indices by a value vector, ascending (lowest value first —
/// "remove harmful/useless points first"). Total order + index tiebreak
/// (the `session::top_k_of` convention): `partial_cmp().unwrap()` here
/// would PANIC the analysis on the first NaN value a degenerate dataset
/// produces, and these orders drive removal curves where a panic aborts
/// the whole experiment.
pub fn order_by_value_asc(values: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| values[a].total_cmp(&values[b]).then(a.cmp(&b)));
    idx
}

/// Order descending (highest value first — adversarial removal). Sorted
/// directly (not `asc` reversed) so ties still break by LOWEST index.
pub fn order_by_value_desc(values: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| values[b].total_cmp(&values[a]).then(a.cmp(&b)));
    idx
}

/// Area under the removal curve (higher = accuracy retained longer).
pub fn curve_area(curve: &[(usize, f64)]) -> f64 {
    if curve.len() < 2 {
        return f64::NAN;
    }
    let mut area = 0.0;
    for w in curve.windows(2) {
        let dx = (w[1].0 - w[0].0) as f64;
        area += dx * (w[0].1 + w[1].1) / 2.0;
    }
    area / (curve.last().unwrap().0 - curve[0].0) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{corrupt, load_dataset};
    use crate::shapley::knn_shapley::knn_shapley;

    #[test]
    fn removing_low_value_first_beats_high_value_first() {
        // the classic data-valuation sanity check (Ghorbani & Zou 2019):
        // dropping low-Shapley points preserves accuracy; dropping
        // high-Shapley points destroys it
        let mut ds = load_dataset("circle", 120, 50, 3).unwrap();
        corrupt::flip_labels(&mut ds, 0.1, 5); // give low-value points to find
        let k = 5;
        let vals = knn_shapley(&ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, k);
        let low_first = removal_curve(&ds, &order_by_value_asc(&vals), 10, 30, k);
        let high_first = removal_curve(&ds, &order_by_value_desc(&vals), 10, 30, k);
        let a_low = curve_area(&low_first);
        let a_high = curve_area(&high_first);
        assert!(
            a_low > a_high + 0.05,
            "low-first area {a_low} vs high-first {a_high}"
        );
    }

    #[test]
    fn curve_starts_at_full_accuracy_and_tracks_removals() {
        let ds = load_dataset("moon", 60, 30, 1).unwrap();
        let vals = vec![0.0; 60];
        let curve = removal_curve(&ds, &order_by_value_asc(&vals), 15, 10, 3);
        assert_eq!(curve[0].0, 0);
        for w in curve.windows(2) {
            assert_eq!(w[1].0 - w[0].0, 15);
        }
    }

    #[test]
    fn order_helpers() {
        let v = [0.3, -1.0, 2.0];
        assert_eq!(order_by_value_asc(&v), vec![1, 0, 2]);
        assert_eq!(order_by_value_desc(&v), vec![2, 0, 1]);
    }

    #[test]
    fn implicit_and_dense_removal_orders_agree() {
        let mut ds = load_dataset("circle", 90, 30, 11).unwrap();
        corrupt::flip_labels(&mut ds, 0.1, 4);
        let params = crate::shapley::StiParams::new(5);
        let implicit = sti_removal_order(&ds, &params, crate::shapley::values::Engine::Implicit);
        let dense = sti_removal_order(&ds, &params, crate::shapley::values::Engine::Dense);
        // the engines agree to ≤ 1e-12 per value, so the orders can only
        // differ across (near-)ties — assert positionwise value equality,
        // which is what the removal curve actually consumes
        let pv = crate::shapley::values::sti_point_values(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &crate::shapley::StiParams::new(5),
            crate::shapley::values::Engine::Implicit,
        );
        assert_eq!(implicit.len(), dense.len());
        for (a, b) in implicit.iter().zip(&dense) {
            assert!(
                (pv.rowsum[*a] - pv.rowsum[*b]).abs() < 1e-9,
                "orders diverged beyond tie tolerance at {a} vs {b}"
            );
        }
    }

    #[test]
    fn implicit_removal_order_beats_adversarial_order() {
        let mut ds = load_dataset("circle", 120, 50, 3).unwrap();
        corrupt::flip_labels(&mut ds, 0.1, 5);
        let k = 5;
        let order = sti_removal_order(
            &ds,
            &crate::shapley::StiParams::new(k),
            crate::shapley::values::Engine::Implicit,
        );
        let low_first = removal_curve(&ds, &order, 10, 30, k);
        let mut rev = order.clone();
        rev.reverse();
        let high_first = removal_curve(&ds, &rev, 10, 30, k);
        assert!(
            curve_area(&low_first) > curve_area(&high_first),
            "low-value-first should retain accuracy longer"
        );
    }

    #[test]
    fn value_orders_survive_nan_without_panicking_or_reordering_finite_points() {
        // NaN values land deterministically at the TOP of the total order
        // (past +∞): last in asc, first in desc — never a panic, and the
        // finite points keep their relative order
        let vals = [0.5, f64::NAN, -1.0, 0.5];
        assert_eq!(order_by_value_asc(&vals), vec![2, 0, 3, 1]);
        assert_eq!(order_by_value_desc(&vals), vec![1, 0, 3, 2]);
        assert_eq!(argmin_by_value(&vals), 2);
        // an all-NaN vector is still a deterministic permutation
        assert_eq!(order_by_value_asc(&[f64::NAN, f64::NAN]), vec![0, 1]);
    }
}
