//! Block-structure summary of an interaction matrix under the paper's
//! display ordering (§4: class, then features): per-class-pair block
//! means, which is what Figs. 3–5 visualize as dark/light blocks.

use crate::util::matrix::Matrix;

/// Mean interaction per (class_a, class_b) block (classes × classes,
/// symmetric; diagonal blocks exclude the matrix diagonal).
pub fn block_structure(phi: &Matrix, train_y: &[i32], classes: usize) -> Matrix {
    let n = train_y.len();
    assert_eq!(phi.rows(), n);
    let mut sums = Matrix::zeros(classes, classes);
    let mut counts = Matrix::zeros(classes, classes);
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let (a, b) = (train_y[i] as usize, train_y[j] as usize);
            sums.add_at(a, b, phi.get(i, j));
            counts.add_at(a, b, 1.0);
        }
    }
    let mut out = Matrix::zeros(classes, classes);
    for a in 0..classes {
        for b in 0..classes {
            let c = counts.get(a, b);
            out.set(a, b, if c > 0.0 { sums.get(a, b) / c } else { f64::NAN });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;
    use crate::shapley::sti_knn::{sti_knn, StiParams};

    #[test]
    fn circle_diagonal_blocks_are_negative_and_stronger_than_cross() {
        // Fig. 3's visual claim, quantified at paper scale: in-class
        // blocks strongly negative and visibly darker than the
        // cross-class block (measured b00 ≈ 2× b01; see EXPERIMENTS.md
        // FIG3 for paper-vs-measured discussion).
        let ds = load_dataset("circle", 600, 150, 3).unwrap();
        let phi = sti_knn(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(5),
        );
        let blocks = block_structure(&phi, &ds.train_y, 2);
        assert!(blocks.get(0, 0) < 0.0, "in-class block 0: {}", blocks.get(0, 0));
        assert!(blocks.get(1, 1) < 0.0, "in-class block 1: {}", blocks.get(1, 1));
        assert!(
            blocks.get(0, 1).abs() < blocks.get(0, 0).abs() / 1.5,
            "cross-class {} vs in-class {}",
            blocks.get(0, 1),
            blocks.get(0, 0)
        );
    }

    #[test]
    fn block_matrix_symmetric_for_symmetric_input() {
        let ds = load_dataset("moon", 80, 20, 1).unwrap();
        let phi = sti_knn(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(3),
        );
        let blocks = block_structure(&phi, &ds.train_y, 2);
        assert!((blocks.get(0, 1) - blocks.get(1, 0)).abs() < 1e-12);
    }

    #[test]
    fn empty_class_pair_is_nan() {
        let phi = Matrix::zeros(2, 2);
        let blocks = block_structure(&phi, &[0, 0], 2);
        assert!(blocks.get(1, 1).is_nan());
        assert!(blocks.get(0, 0).is_finite());
    }
}
