//! Micro/bench harness (no criterion in the offline image): warmup,
//! adaptive iteration count, mean/median/p99 and throughput reporting.
//! Used by every target under `crates/stiknn-cli/benches/`
//! (`harness = false`).

use crate::report::table::Table;
use crate::util::json::Json;
use crate::util::timer::fmt_duration;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Workspace root resolved from a crate manifest directory: the first
/// ancestor containing `ROADMAP.md` (the repo's root marker). Falls back
/// to the starting directory itself when no marker is found (a vendored
/// or exported crate tree), so callers always get a usable path.
///
/// The runtime `CARGO_MANIFEST_DIR` (set by `cargo bench`/`run`/`test`)
/// takes precedence over the compile-time path the caller bakes in with
/// `env!` — artifacts land in the CURRENT checkout even when the binary
/// was built from another one.
pub fn workspace_root_from(manifest_dir: &Path) -> PathBuf {
    let start = std::env::var_os("CARGO_MANIFEST_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| manifest_dir.to_path_buf());
    for dir in start.ancestors() {
        if dir.join("ROADMAP.md").is_file() {
            return dir.to_path_buf();
        }
    }
    start
}

/// Where a bench artifact (`BENCH_*.json`) belongs: at the WORKSPACE
/// ROOT, never relative to the invoking crate or the current directory —
/// `cargo bench -p stiknn-cli` from any subdirectory and the CI artifact
/// step must agree on one location. Call with the bench's own
/// `env!("CARGO_MANIFEST_DIR")`.
pub fn artifact_path(manifest_dir: &str, file_name: &str) -> PathBuf {
    workspace_root_from(Path::new(manifest_dir)).join(file_name)
}

/// One measured benchmark result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// Machine-readable form, for bench artifacts (e.g. BENCH_scaling.json
    /// — the perf-trajectory record CI uploads per commit).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_secs", Json::num(self.mean.as_secs_f64())),
            ("median_secs", Json::num(self.median.as_secs_f64())),
            ("p99_secs", Json::num(self.p99.as_secs_f64())),
            ("min_secs", Json::num(self.min.as_secs_f64())),
        ])
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Minimum total measurement time per benchmark.
    pub min_time: Duration,
    /// Hard cap on iterations.
    pub max_iters: usize,
    pub warmup_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            min_time: Duration::from_millis(300),
            max_iters: 1000,
            warmup_iters: 2,
        }
    }
}

/// Quick config for slow end-to-end benches.
pub fn quick() -> BenchConfig {
    BenchConfig {
        min_time: Duration::from_millis(100),
        max_iters: 20,
        warmup_iters: 1,
    }
}

/// A suite collects measurements and renders a table at the end.
pub struct Suite {
    pub title: String,
    config: BenchConfig,
    results: Vec<Measurement>,
}

impl Suite {
    pub fn new(title: &str) -> Self {
        Suite {
            title: title.to_string(),
            config: BenchConfig::default(),
            results: Vec::new(),
        }
    }

    pub fn with_config(mut self, config: BenchConfig) -> Self {
        self.config = config;
        self
    }

    /// Measure a closure. The closure's return value is black-boxed to
    /// keep the optimizer honest.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.config.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples: Vec<Duration> = Vec::new();
        let start = crate::obs::now();
        while start.elapsed() < self.config.min_time && samples.len() < self.config.max_iters {
            let t0 = crate::obs::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            let t0 = crate::obs::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let total: Duration = samples.iter().sum();
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len(),
            mean: total / samples.len() as u32,
            median: samples[samples.len() / 2],
            p99: samples[(samples.len() * 99) / 100],
            min: samples[0],
        };
        // lint: allow(bare-eprintln) — bench progress is operator
        // console output by design, not an operational event.
        eprintln!(
            "  {name}: mean {} (median {}, p99 {}, {} iters)",
            fmt_duration(m.mean),
            fmt_duration(m.median),
            fmt_duration(m.p99),
            m.iters
        );
        self.results.push(m.clone());
        m
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// The suite's measurements as a JSON object (title + results array).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            (
                "results",
                Json::arr(self.results.iter().map(|m| m.to_json())),
            ),
        ])
    }

    /// Render the suite as an aligned table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["benchmark", "mean", "median", "p99", "min", "iters"]);
        for m in &self.results {
            t.row(&[
                m.name.clone(),
                fmt_duration(m.mean),
                fmt_duration(m.median),
                fmt_duration(m.p99),
                fmt_duration(m.min),
                m.iters.to_string(),
            ]);
        }
        format!("\n== {} ==\n{}", self.title, t.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut s = Suite::new("test").with_config(BenchConfig {
            min_time: Duration::from_millis(5),
            max_iters: 50,
            warmup_iters: 1,
        });
        let m = s.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(m.iters >= 1);
        assert!(m.mean.as_nanos() > 0);
        assert!(m.min <= m.median && m.median <= m.p99);
        let table = s.render();
        assert!(table.contains("spin"));
    }

    #[test]
    fn json_export_roundtrips() {
        let mut s = Suite::new("json").with_config(BenchConfig {
            min_time: Duration::from_millis(1),
            max_iters: 2,
            warmup_iters: 0,
        });
        s.bench("noop", || 1);
        let j = s.to_json();
        assert_eq!(j.get("title").unwrap().as_str(), Some("json"));
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("noop"));
        assert!(results[0].get("mean_secs").unwrap().as_f64().unwrap() >= 0.0);
        // serializes to parseable JSON text
        let text = j.to_string();
        assert!(Json::parse(&text).is_ok(), "{text}");
    }

    #[test]
    fn artifact_paths_resolve_to_the_workspace_root() {
        let root = workspace_root_from(Path::new(env!("CARGO_MANIFEST_DIR")));
        // The root is the directory with ROADMAP.md — NOT this crate's
        // own directory (crates/stiknn-core) or the crates/ folder.
        assert!(
            root.join("ROADMAP.md").is_file(),
            "no ROADMAP.md at {}",
            root.display()
        );
        assert!(!root.ends_with("stiknn-core") && !root.ends_with("crates"));
        let out = artifact_path(env!("CARGO_MANIFEST_DIR"), "BENCH_smoke.json");
        assert_eq!(out.parent(), Some(root.as_path()));
        assert_eq!(out.file_name().unwrap(), "BENCH_smoke.json");
    }

    #[test]
    fn respects_max_iters() {
        let mut s = Suite::new("cap").with_config(BenchConfig {
            min_time: Duration::from_secs(10),
            max_iters: 3,
            warmup_iters: 0,
        });
        s.bench("noop", || 1);
        assert_eq!(s.results()[0].iters, 3);
    }
}
