//! Valuation job and result types, plus the sharding/banding plans.

use crate::data::Dataset;
use crate::knn::distance::Metric;
use crate::runtime::Engine;
use crate::util::matrix::Matrix;
use std::time::Duration;

/// How the Rust engine parallelizes the Phase-2 assembly sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Assembly {
    /// Legacy path: each worker runs `sti_knn_partial` on its test shard
    /// and holds a PRIVATE n×n accumulator; the merger sums the partial
    /// matrices in shard order. Peak memory O(W·n²) for W workers, merge
    /// cost O(shards·n²).
    TestSharded,
    /// Banded path (default): ONE shared n×n accumulator, partitioned into
    /// disjoint row bands `[r_lo, r_hi)`; prep workers parallelize Phase 1
    /// over test blocks, band workers sweep Phase 2 concurrently into
    /// their own rows. Peak memory O(n²) independent of worker count, and
    /// results are bit-identical to the single-threaded engine (band
    /// boundaries cannot reorder any cell's `row[j] += v` sequence).
    /// `band_rows = 0` picks triangle-area-balanced bands, one per worker.
    RowBanded { band_rows: usize },
}

/// A complete valuation request against one dataset.
#[derive(Clone, Debug)]
pub struct ValuationJob {
    pub k: usize,
    pub engine: Engine,
    /// Test points per shard (block). For the XLA engine this is clamped
    /// to the artifact's baked block size.
    pub block_size: usize,
    pub workers: usize,
    pub metric: Metric,
    /// Bounded-queue capacity as a multiple of `workers` (backpressure).
    pub queue_factor: usize,
    /// Phase-2 parallelization strategy for the Rust engine.
    pub assembly: Assembly,
}

impl ValuationJob {
    pub fn new(k: usize) -> Self {
        ValuationJob {
            k,
            engine: Engine::Rust,
            block_size: 32,
            workers: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4),
            metric: Metric::SqEuclidean,
            queue_factor: 2,
            assembly: Assembly::RowBanded { band_rows: 0 },
        }
    }

    pub fn with_engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn with_block_size(mut self, block: usize) -> Self {
        self.block_size = block.max(1);
        self
    }

    pub fn with_assembly(mut self, assembly: Assembly) -> Self {
        self.assembly = assembly;
        self
    }

    /// Shorthand for `with_assembly(Assembly::RowBanded { band_rows })`.
    pub fn with_band_rows(mut self, band_rows: usize) -> Self {
        self.assembly = Assembly::RowBanded { band_rows };
        self
    }

    /// Shard the test set into [lo, hi) block ranges.
    pub fn plan_shards(&self, n_test: usize) -> Vec<(usize, usize)> {
        assert!(n_test > 0, "empty test set");
        let b = self.block_size.max(1);
        (0..n_test.div_ceil(b))
            .map(|i| (i * b, ((i + 1) * b).min(n_test)))
            .collect()
    }

    /// Partition the n accumulator rows into bands for the banded
    /// assembly. With explicit `band_rows` > 0 the bands are uniform in
    /// height (the last may be short when `band_rows` does not divide n);
    /// with `band_rows == 0` the boundaries are placed so each band gets
    /// an (approximately) equal share of the upper-triangle sweep work
    /// Σ_i (n − i) — equal HEIGHTS would leave the first band with most of
    /// the triangle — with one band per worker.
    ///
    /// Each band costs one sweep thread and one queue, so `band_rows` is
    /// treated as a LOWER bound on band height: the planner widens bands
    /// as needed to keep the band count within ~4× the worker count
    /// (`--band-rows 1` on a million-row train set must not try to spawn
    /// a million threads). The result never depends on which rows land in
    /// which band — any partition is bit-identical (DESIGN.md §7).
    pub fn plan_bands(&self, n_train: usize) -> Vec<(usize, usize)> {
        assert!(n_train > 0, "empty train set");
        match self.assembly {
            Assembly::RowBanded { band_rows } if band_rows > 0 => {
                let max_bands = (self.workers.max(1) * 4).max(8);
                let b = band_rows
                    .clamp(1, n_train)
                    .max(n_train.div_ceil(max_bands));
                (0..n_train.div_ceil(b))
                    .map(|i| (i * b, ((i + 1) * b).min(n_train)))
                    .collect()
            }
            _ => plan_balanced_bands(n_train, self.workers),
        }
    }
}

/// Triangle-area-balanced band boundaries: row i costs (n − i) sweep
/// cells (its upper-triangle run plus the diagonal), so bands are closed
/// greedily as cumulative cost crosses each 1/nb quantile. Every band is
/// non-empty and the bands partition [0, n).
pub fn plan_balanced_bands(n: usize, nbands: usize) -> Vec<(usize, usize)> {
    assert!(n > 0);
    let nb = nbands.clamp(1, n);
    let total = (n * (n + 1) / 2) as f64;
    let mut out = Vec::with_capacity(nb);
    let mut lo = 0usize;
    let mut acc = 0.0f64;
    for i in 0..n {
        acc += (n - i) as f64;
        let closed = out.len();
        let remaining_rows = n - i - 1;
        let remaining_bands = nb - closed - 1;
        if closed + 1 < nb
            && (acc >= total * (closed + 1) as f64 / nb as f64
                || remaining_rows == remaining_bands)
        {
            out.push((lo, i + 1));
            lo = i + 1;
        }
    }
    out.push((lo, n));
    out
}

/// The outcome of a valuation job.
#[derive(Clone, Debug)]
pub struct ValuationResult {
    /// Averaged interaction matrix (Eq. 9), diagonal = main terms.
    pub phi: Matrix,
    /// Number of test points contributing.
    pub weight: f64,
    /// Blocks processed.
    pub blocks: usize,
    pub elapsed: Duration,
    /// Test points per second.
    pub throughput: f64,
    pub engine: Engine,
}

impl ValuationResult {
    /// Average interaction of the strict upper triangle (summary stat the
    /// examples print).
    pub fn mean_offdiag(&self) -> f64 {
        let ut = self.phi.upper_triangle_entries();
        crate::util::stats::mean(&ut)
    }
}

/// The outcome of a per-point value job (the implicit engine,
/// `shapley::values` / DESIGN.md §10): averaged value vectors instead of
/// an n×n matrix — O(n) result memory at any n.
#[derive(Clone, Debug)]
pub struct ValuesResult {
    /// Averaged main terms φ_ii (Eq. 4/5, Eq. 9).
    pub main: Vec<f64>,
    /// Averaged total row sums φ_ii + Σ_{j≠i} φ_ij.
    pub rowsum: Vec<f64>,
    /// Number of test points contributing.
    pub weight: f64,
    /// Blocks processed.
    pub blocks: usize,
    pub elapsed: Duration,
    /// Test points per second.
    pub throughput: f64,
}

/// A unit of work: one test-block range of the dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    pub index: usize,
    pub lo: usize,
    pub hi: usize,
}

/// The partial result a worker produces for one shard (test-sharded path).
pub struct PartialResult {
    pub index: usize,
    pub phi_sum: Matrix,
    pub weight: f64,
}

/// Helper: the shard list for a dataset under this job.
pub fn shards_for(job: &ValuationJob, ds: &Dataset) -> Vec<Shard> {
    shards_for_len(job, ds.n_test())
}

/// Shard list for a raw test-set length — the streaming-ingest paths
/// (`pipeline::ingest_banded`) have no `Dataset`, only slices.
pub fn shards_for_len(job: &ValuationJob, n_test: usize) -> Vec<Shard> {
    job.plan_shards(n_test)
        .into_iter()
        .enumerate()
        .map(|(index, (lo, hi))| Shard { index, lo, hi })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_test_set_without_overlap() {
        let job = ValuationJob::new(3).with_block_size(8);
        for n_test in [1usize, 7, 8, 9, 64, 65] {
            let shards = job.plan_shards(n_test);
            assert_eq!(shards[0].0, 0);
            assert_eq!(shards.last().unwrap().1, n_test);
            for w in shards.windows(2) {
                assert_eq!(w[0].1, w[1].0, "gap/overlap at {w:?}");
            }
            assert!(shards.iter().all(|&(lo, hi)| hi - lo <= 8 && hi > lo));
        }
    }

    #[test]
    fn builder_clamps() {
        let job = ValuationJob::new(5).with_workers(0).with_block_size(0);
        assert_eq!(job.workers, 1);
        assert_eq!(job.block_size, 1);
    }

    #[test]
    #[should_panic(expected = "empty test set")]
    fn empty_test_set_panics() {
        ValuationJob::new(3).plan_shards(0);
    }

    #[test]
    fn uniform_bands_cover_rows_even_when_height_does_not_divide_n() {
        let job = ValuationJob::new(3).with_band_rows(7);
        for n in [1usize, 6, 7, 8, 20, 23] {
            let bands = job.plan_bands(n);
            assert_eq!(bands[0].0, 0);
            assert_eq!(bands.last().unwrap().1, n);
            for w in bands.windows(2) {
                assert_eq!(w[0].1, w[1].0, "gap/overlap at {w:?}");
            }
            assert!(bands.iter().all(|&(lo, hi)| hi > lo && hi - lo <= 7));
        }
    }

    #[test]
    fn balanced_bands_partition_and_balance_triangle_area() {
        for (n, nb) in [(600usize, 4usize), (601, 7), (10, 3), (5, 8), (1, 1)] {
            let bands = plan_balanced_bands(n, nb);
            assert_eq!(bands.len(), nb.min(n));
            assert_eq!(bands[0].0, 0);
            assert_eq!(bands.last().unwrap().1, n);
            for w in bands.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            assert!(bands.iter().all(|&(lo, hi)| hi > lo));
            if n >= 100 && nb > 1 {
                // area balance: no band more than 2x the ideal share
                let ideal = (n * (n + 1) / 2) as f64 / bands.len() as f64;
                for &(lo, hi) in &bands {
                    let area: usize = (lo..hi).map(|i| n - i).sum();
                    assert!(
                        (area as f64) < 2.0 * ideal,
                        "band ({lo},{hi}) area {area} vs ideal {ideal}"
                    );
                }
                // equal-height split would give the first band far more
                // area than the last; balanced bands must not
                let first: usize = (bands[0].0..bands[0].1).map(|i| n - i).sum();
                let last_band = bands[bands.len() - 1];
                let last: usize = (last_band.0..last_band.1).map(|i| n - i).sum();
                assert!(
                    (first as f64) < 1.6 * last as f64,
                    "unbalanced: first {first} last {last}"
                );
            }
        }
    }

    #[test]
    fn auto_bands_track_worker_count() {
        let job = ValuationJob::new(3).with_workers(5);
        assert_eq!(job.plan_bands(100).len(), 5);
        let sharded = job.with_assembly(Assembly::TestSharded);
        // plan_bands is still meaningful (the banded runner owns the call)
        assert_eq!(sharded.plan_bands(100).len(), 5);
    }

    #[test]
    fn default_assembly_is_banded_auto() {
        assert_eq!(
            ValuationJob::new(2).assembly,
            Assembly::RowBanded { band_rows: 0 }
        );
    }
}
