//! Deterministic merge of per-shard partial results.
//!
//! Eq. (9) is linear over the test set, so the global matrix is
//! Σ_blocks phi_sum / Σ_blocks weight.
//!
//! Two mergers, one per assembly mode (DESIGN.md §7):
//!
//! * [`Merger`] — the test-sharded path's matrix merger. Floating-point
//!   addition is not associative, so to make results bit-identical
//!   regardless of worker count and completion order it buffers the
//!   partial MATRICES and reduces them in block-index order: O(shards·n²)
//!   merge work on top of the O(W·n²) worker accumulators.
//! * [`WeightMerger`] — the row-banded path's bookkeeping. Band workers
//!   write the shared accumulator directly, so nothing matrix-shaped ever
//!   reaches the merger: it only tracks per-block weights (integer counts
//!   of test points — exactly associative) and completeness. This is what
//!   makes the banded coordinator's peak memory O(n²) BY CONSTRUCTION:
//!   the one accumulator in `run_rust` is the only n×n allocation.

use super::job::PartialResult;
use crate::util::matrix::Matrix;

/// Accumulates partial results and produces the final averaged matrix.
pub struct Merger {
    expected: usize,
    slots: Vec<Option<PartialResult>>,
}

impl Merger {
    pub fn new(expected_blocks: usize) -> Self {
        Merger {
            expected: expected_blocks,
            slots: (0..expected_blocks).map(|_| None).collect(),
        }
    }

    /// Deposit one shard's partial result. Panics on duplicate or
    /// out-of-range indices (pipeline invariant violations).
    pub fn push(&mut self, partial: PartialResult) {
        let idx = partial.index;
        assert!(idx < self.expected, "shard index {idx} out of range");
        assert!(
            self.slots[idx].is_none(),
            "shard {idx} delivered twice — pipeline bug"
        );
        self.slots[idx] = Some(partial);
    }

    pub fn received(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_complete(&self) -> bool {
        self.received() == self.expected
    }

    /// Reduce in block-index order → (averaged matrix, total weight).
    /// Panics if any shard is missing.
    pub fn finalize(self) -> (Matrix, f64) {
        assert!(self.expected > 0, "no shards");
        let mut acc: Option<Matrix> = None;
        let mut weight = 0.0f64;
        for (i, slot) in self.slots.into_iter().enumerate() {
            let p = slot.unwrap_or_else(|| panic!("shard {i} missing at finalize"));
            weight += p.weight;
            match &mut acc {
                None => acc = Some(p.phi_sum),
                Some(m) => m.add_assign(&p.phi_sum),
            }
        }
        let mut m = acc.unwrap();
        assert!(weight > 0.0, "zero total weight");
        m.scale(1.0 / weight);
        (m, weight)
    }
}

/// Weight bookkeeping for the banded assembly: tracks which test blocks
/// have been prepared and their total weight. No matrices pass through —
/// the shared accumulator is written in place by the band workers.
pub struct WeightMerger {
    seen: Vec<bool>,
    weight: f64,
}

impl WeightMerger {
    pub fn new(expected_blocks: usize) -> Self {
        WeightMerger {
            seen: vec![false; expected_blocks],
            weight: 0.0,
        }
    }

    /// Record one block's weight. Panics on duplicate or out-of-range
    /// indices (pipeline invariant violations).
    pub fn push(&mut self, index: usize, weight: f64) {
        assert!(
            index < self.seen.len(),
            "block index {index} out of range"
        );
        assert!(
            !self.seen[index],
            "block {index} delivered twice — pipeline bug"
        );
        self.seen[index] = true;
        self.weight += weight;
    }

    pub fn received(&self) -> usize {
        self.seen.iter().filter(|&&s| s).count()
    }

    pub fn is_complete(&self) -> bool {
        self.seen.iter().all(|&s| s)
    }

    /// Total weight across all blocks. Panics if any block is missing or
    /// the total is not positive.
    pub fn finalize(self) -> f64 {
        assert!(!self.seen.is_empty(), "no blocks");
        if let Some(missing) = self.seen.iter().position(|&s| !s) {
            panic!("block {missing} missing at finalize");
        }
        assert!(self.weight > 0.0, "zero total weight");
        self.weight
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn partial(index: usize, v: f64, w: f64) -> PartialResult {
        PartialResult {
            index,
            phi_sum: Matrix::from_vec(2, 2, vec![v, 0.0, 0.0, v]),
            weight: w,
        }
    }

    #[test]
    fn merge_is_weighted_average() {
        let mut m = Merger::new(2);
        m.push(partial(0, 2.0, 2.0));
        m.push(partial(1, 4.0, 2.0));
        let (phi, w) = m.finalize();
        assert_eq!(w, 4.0);
        assert_eq!(phi.get(0, 0), 1.5); // (2+4)/4
    }

    #[test]
    fn merge_order_independent_bitwise() {
        // adversarial magnitudes where naive arrival-order summation differs
        let vals = [1e16, 1.0, -1e16, 3.0, 1e-8, 7.0];
        let build = |order: &[usize]| {
            let mut m = Merger::new(vals.len());
            for &i in order {
                m.push(partial(i, vals[i], 1.0));
            }
            m.finalize().0.get(0, 0).to_bits()
        };
        let a = build(&[0, 1, 2, 3, 4, 5]);
        let b = build(&[5, 3, 1, 0, 2, 4]);
        let c = build(&[2, 4, 0, 5, 1, 3]);
        assert_eq!(a, b);
        assert_eq!(b, c);
    }

    #[test]
    #[should_panic(expected = "delivered twice")]
    fn duplicate_shard_detected() {
        let mut m = Merger::new(2);
        m.push(partial(0, 1.0, 1.0));
        m.push(partial(0, 1.0, 1.0));
    }

    #[test]
    #[should_panic(expected = "missing at finalize")]
    fn missing_shard_detected() {
        let mut m = Merger::new(2);
        m.push(partial(1, 1.0, 1.0));
        let _ = m.finalize();
    }

    #[test]
    fn completeness_tracking() {
        let mut m = Merger::new(3);
        assert!(!m.is_complete());
        m.push(partial(1, 1.0, 1.0));
        assert_eq!(m.received(), 1);
        m.push(partial(0, 1.0, 1.0));
        m.push(partial(2, 1.0, 1.0));
        assert!(m.is_complete());
    }

    #[test]
    fn weight_merger_sums_and_tracks_completeness() {
        let mut m = WeightMerger::new(3);
        assert!(!m.is_complete());
        m.push(2, 7.0);
        m.push(0, 32.0);
        assert_eq!(m.received(), 2);
        m.push(1, 32.0);
        assert!(m.is_complete());
        assert_eq!(m.finalize(), 71.0);
    }

    #[test]
    #[should_panic(expected = "delivered twice")]
    fn weight_merger_rejects_duplicates() {
        let mut m = WeightMerger::new(2);
        m.push(0, 1.0);
        m.push(0, 1.0);
    }

    #[test]
    #[should_panic(expected = "missing at finalize")]
    fn weight_merger_detects_missing_blocks() {
        let mut m = WeightMerger::new(2);
        m.push(1, 4.0);
        let _ = m.finalize();
    }
}
