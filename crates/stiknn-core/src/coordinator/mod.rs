//! Layer-3 coordinator: the streaming data-valuation pipeline.
//!
//! A valuation job shards the test set into blocks, feeds them through a
//! bounded work queue (backpressure) to a pool of workers, and combines
//! the per-block work deterministically. Two assembly strategies exist
//! for the Rust engine (see [`Assembly`]):
//!
//! * **Row-banded** (default): prep workers run the O(n log n) Phase 1
//!   per test block; band workers sweep prepared blocks — in block order —
//!   into disjoint row bands of ONE shared n×n accumulator. Peak memory
//!   is O(n²) independent of worker count, the merger reduces to weight
//!   bookkeeping, and results are bit-identical to the single-threaded
//!   engine for any worker count, block size, or band layout.
//! * **Test-sharded** (legacy): each worker accumulates a private n×n
//!   partial matrix; the merger sums them in block-index order — results
//!   are bit-identical across worker counts for a fixed block size, at
//!   O(W·n²) peak memory.
//!
//! A third, **value-sharded** path serves the implicit per-point value
//! engine (`shapley::values`, DESIGN.md §10): the same prep pool and
//! in-order publication, but Phase 2 collapses to a single O(len·n)
//! `sweep_values` consumer folding into an O(n) `ValueVector` — no n×n
//! accumulator exists at all, and results are bit-identical to the
//! single-threaded implicit engine for any worker count or block size
//! ([`run_values_job`] one-shot, [`ingest_values`] streaming).
//!
//! A fourth, **repair** fan-out serves the delta subsystem
//! (`shapley::delta`, DESIGN.md §11): a training-set edit's per-test row
//! repairs are embarrassingly parallel (each test's repair reads only
//! its own retained row), so [`repair_rows`] just splits the tests into
//! contiguous chunks across workers — no queue, no merge, bit-identical
//! to single-threaded for any worker count.
//!
//! * [`pool`]    — thread pool + bounded channel substrate
//! * [`job`]     — job/result types, sharding and band plans
//! * [`merge`]   — deterministic partial reduction / weight bookkeeping
//! * [`pipeline`] — the orchestrator wiring it all together
//! * [`progress`] — per-job counters over the [`crate::obs`] primitives
//!   (prep/sweep phase split, utilization), rolling up into a metrics
//!   registry when one is attached (DESIGN.md §14)
//! * [`repair`]  — delta-repair chunk fan-out

pub mod job;
pub mod merge;
pub mod pipeline;
pub mod pool;
pub mod progress;
pub mod repair;

pub use job::{Assembly, ValuationJob, ValuationResult, ValuesResult};
pub use pipeline::{
    ingest_banded, ingest_banded_with, ingest_values, ingest_values_with, run_job,
    run_job_with_engine, run_values_job,
};
pub use repair::{repair_rows, RepairedRows};
