//! The orchestrator: shard → bounded queue → worker pool → deterministic
//! merge.
//!
//! Engine dispatch:
//! * `Engine::Rust` — two assembly strategies (see [`Assembly`]):
//!   - `RowBanded` (default): Phase 1 (`prepare_batch_cached` over the
//!     SIMD distance kernels with one shared per-job norm cache,
//!     O(n log n) per test point) is parallelized over test blocks by a
//!     prep pool; each
//!     prepared block is published IN BLOCK ORDER to every band worker,
//!     which sweeps it (`sweep_band`, O(block·band·n)) into its own
//!     disjoint row band of ONE shared n×n accumulator. Peak memory is
//!     O(n²) + O(in-flight blocks · block · n) regardless of worker
//!     count, there is no matrix merge at all, and results are
//!     bit-identical to single-threaded `sti_knn` for any worker count
//!     or band layout (per-cell addition order never changes).
//!   - `TestSharded` (legacy): each worker runs the pure-Rust Algorithm 1
//!     on its shard with a private accumulator; the merger sums partial
//!     matrices in shard order. O(W·n²) memory, kept for comparison
//!     benches and as the shape of the XLA path.
//! * `Engine::Xla`  — each worker owns a [`StiExecutor`] compiled from the
//!   matching AOT artifact (one PJRT client per worker; the CPU plugin
//!   serializes execution per client, so per-worker clients are what
//!   gives real parallelism).

use super::job::{
    shards_for, shards_for_len, Assembly, PartialResult, Shard, ValuationJob, ValuationResult,
    ValuesResult,
};
use super::merge::{Merger, WeightMerger};
use super::pool::{run_workers, Bounded};

use super::progress::{Progress, ThroughputMeter};
use crate::data::Dataset;
use crate::knn::kernel::NormCache;
use crate::runtime::{executor_for, Engine, Manifest, StiExecutor};
use crate::shapley::sti_knn::{
    prepare_batch_cached, sti_knn_partial, sweep_band, PrepScratch, PreparedBatch, StiParams,
};
use crate::shapley::values::{sweep_values, ValueVector, ValuesScratch};
use crate::util::matrix::Matrix;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Condvar, Mutex};

/// Run a valuation job with the pure-Rust engine (no artifacts needed).
pub fn run_job(ds: &Dataset, job: &ValuationJob) -> Result<ValuationResult> {
    anyhow::ensure!(job.engine == Engine::Rust, "use run_job_with_engine for XLA");
    run_rust(ds, job)
}

/// Run a valuation job with either engine; `artifacts_dir` is only read
/// for `Engine::Xla`.
pub fn run_job_with_engine(
    ds: &Dataset,
    job: &ValuationJob,
    artifacts_dir: &Path,
) -> Result<ValuationResult> {
    match job.engine {
        Engine::Rust => run_rust(ds, job),
        Engine::Xla => run_xla(ds, job, artifacts_dir),
    }
}

fn run_rust(ds: &Dataset, job: &ValuationJob) -> Result<ValuationResult> {
    match job.assembly {
        Assembly::RowBanded { .. } => run_rust_banded(ds, job),
        Assembly::TestSharded => run_rust_test_sharded(ds, job),
    }
}

/// In-order publication buffer: prep workers finish blocks in any order;
/// band workers must receive them in block order (so every accumulator
/// row sees the same addition sequence as a single-threaded run).
/// Occupancy is bounded by the publication window (prep workers wait on
/// the paired condvar when they run too far ahead of the oldest
/// unpublished block), so one straggling block cannot balloon memory.
struct Reorder {
    next: usize,
    aborted: bool,
    pending: BTreeMap<usize, Arc<PreparedBatch>>,
}

/// Panic containment for the banded pipeline (INV-3): if any worker
/// unwinds — a prepare/sweep assert, a poisoned lock — this guard closes
/// every queue and wakes every waiter on its way out, so peers drain and
/// exit, `thread::scope` joins them, and the panic propagates to the
/// caller instead of deadlocking the run.
struct AbortOnPanic<'a> {
    prep_queue: &'a Bounded<Shard>,
    band_queues: &'a [Bounded<Arc<PreparedBatch>>],
    reorder: &'a Mutex<Reorder>,
    reorder_cv: &'a Condvar,
}

impl Drop for AbortOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.prep_queue.close();
            for q in self.band_queues {
                q.close();
            }
            let mut rb = match self.reorder.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            rb.aborted = true;
            drop(rb);
            self.reorder_cv.notify_all();
        }
    }
}

/// One prep worker's loop: Phase 1 over test blocks with reorder-window
/// backpressure and in-block-order publication to every consumer queue,
/// closing the consumer queues once the last block is published. Shared
/// by the banded matrix path and the value-sharded path — their only
/// difference is the Phase-2 consumer, so the delicate
/// window/publication/close logic lives exactly once.
#[allow(clippy::too_many_arguments)]
fn prep_worker_loop(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    params: &StiParams,
    norms: &NormCache,
    prep_queue: &Bounded<Shard>,
    band_queues: &[Bounded<Arc<PreparedBatch>>],
    reorder: &Mutex<Reorder>,
    reorder_cv: &Condvar,
    merger: &Mutex<WeightMerger>,
    progress: &Progress,
    window: usize,
    n_blocks: usize,
) {
    let _abort = AbortOnPanic {
        prep_queue,
        band_queues,
        reorder,
        reorder_cv,
    };
    let mut scratch = PrepScratch::new();
    'blocks: while let Some(shard) = prep_queue.recv() {
        // Reorder-buffer backpressure: don't prepare (and allocate) a
        // block far ahead of the oldest unpublished one.
        {
            let mut rb = reorder.lock().unwrap();
            while !rb.aborted && shard.index >= rb.next + window {
                rb = reorder_cv.wait(rb).unwrap();
            }
            if rb.aborted {
                break 'blocks;
            }
        }
        let t0 = crate::obs::now();
        let (tx, ty) = (
            &test_x[shard.lo * d..shard.hi * d],
            &test_y[shard.lo..shard.hi],
        );
        let batch = Arc::new(prepare_batch_cached(
            train_x, train_y, d, tx, ty, params, norms, &mut scratch,
        ));
        progress.record_block(shard.hi - shard.lo, t0.elapsed().as_nanos() as u64);
        progress.record_kernel(batch.kernel_ns());
        merger.lock().unwrap().push(shard.index, batch.weight());
        // Publish every newly in-order block to all consumers; the
        // reorder lock serializes publication, keeping each queue in
        // strict block order.
        let mut rb = reorder.lock().unwrap();
        rb.pending.insert(shard.index, batch);
        loop {
            let key = rb.next;
            let Some(ready) = rb.pending.remove(&key) else {
                break;
            };
            rb.next += 1;
            for q in band_queues {
                let _ = q.send(ready.clone());
            }
        }
        let all_published = rb.next == n_blocks;
        drop(rb);
        reorder_cv.notify_all();
        if all_published {
            for q in band_queues {
                q.close();
            }
        }
    }
}

/// Row-banded assembly: ONE n×n accumulator for the whole job — the only
/// matrix this function allocates, independent of `job.workers`.
fn run_rust_banded(ds: &Dataset, job: &ValuationJob) -> Result<ValuationResult> {
    let meter = ThroughputMeter::new();
    let progress = Progress::new();
    let n = ds.n_train();
    let mut acc = Matrix::zeros(n, n);
    let (weight, blocks) = banded_accumulate(
        &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, job, &mut acc, &progress,
    )?;
    acc.mirror_upper_to_lower();
    acc.scale(1.0 / weight);
    let elapsed = meter.elapsed();
    Ok(ValuationResult {
        phi: acc,
        weight,
        blocks,
        elapsed,
        throughput: meter.rate(progress.points()),
        engine: Engine::Rust,
    })
}

/// Streaming batch-ingest entry point for the session layer
/// (`stiknn-session`): accumulate the UNNORMALIZED contribution of one
/// test batch into an existing n×n accumulator through the banded
/// parallel pipeline (prep pool → in-order publication → per-band sweep
/// workers), returning the batch's merge weight (its test count, Eq. 9).
///
/// The accumulator is written exactly as `sweep_band` writes it — upper
/// triangle + diagonal, additions appended in test order — so repeated
/// calls over a contiguous partition of a test stream are bit-identical
/// to a one-shot run, no matter how `job.workers`/`block_size`/band
/// layout slice the work (DESIGN.md §7/§9). The caller owns
/// normalization (mirror + scale by the accumulated weight).
#[allow(clippy::too_many_arguments)]
pub fn ingest_banded(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    job: &ValuationJob,
    acc: &mut Matrix,
) -> Result<f64> {
    ingest_banded_with(
        train_x,
        train_y,
        d,
        test_x,
        test_y,
        job,
        acc,
        &Progress::new(),
    )
}

/// [`ingest_banded`] with a caller-owned [`Progress`] — the session
/// layer passes `Progress::with_obs(...)` here so batch ingests roll up
/// into its metrics registry (DESIGN.md §14) without changing a single
/// accumulated bit.
#[allow(clippy::too_many_arguments)]
pub fn ingest_banded_with(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    job: &ValuationJob,
    acc: &mut Matrix,
    progress: &Progress,
) -> Result<f64> {
    let n = train_y.len();
    anyhow::ensure!(
        acc.rows() == n && acc.cols() == n,
        "accumulator is {}x{} but train set has n={n}",
        acc.rows(),
        acc.cols()
    );
    anyhow::ensure!(!test_y.is_empty(), "empty ingest batch");
    // Shape errors must surface as Err here, not as a panic inside a
    // worker thread slicing out of bounds (matching sti_knn_accumulate's
    // contract on the single-threaded path).
    anyhow::ensure!(
        train_x.len() == n * d,
        "train shape mismatch: {} features for {n} points (d={d})",
        train_x.len()
    );
    anyhow::ensure!(
        test_x.len() == test_y.len() * d,
        "test batch shape mismatch: {} features for {} labels (d={d})",
        test_x.len(),
        test_y.len()
    );
    let (weight, _blocks) =
        banded_accumulate(train_x, train_y, d, test_x, test_y, job, acc, progress)?;
    Ok(weight)
}

/// The banded pipeline core shared by [`run_rust_banded`] (one-shot jobs)
/// and [`ingest_banded`] (streaming sessions): sweeps `test_x`/`test_y`
/// into `acc` (unnormalized, upper triangle + diagonal) and returns
/// (total weight, number of test blocks).
#[allow(clippy::too_many_arguments)]
fn banded_accumulate(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    job: &ValuationJob,
    acc: &mut Matrix,
    progress: &Progress,
) -> Result<(f64, usize)> {
    let wall = crate::obs::now();
    let params = StiParams {
        k: job.k,
        metric: job.metric,
    };
    // One norm cache per job, shared read-only by every prep worker.
    let norms = NormCache::build(train_x, d, params.metric);
    let n = train_y.len();
    let shards = shards_for_len(job, test_y.len());
    let n_blocks = shards.len();
    let bands = job.plan_bands(n);
    let merger = Mutex::new(WeightMerger::new(n_blocks));
    let prep_queue: Bounded<Shard> = Bounded::new(job.workers * job.queue_factor.max(1));
    let band_queues: Vec<Bounded<Arc<PreparedBatch>>> = bands
        .iter()
        .map(|_| Bounded::new(2 * job.queue_factor.max(1)))
        .collect();
    let reorder = Mutex::new(Reorder {
        next: 0,
        aborted: false,
        pending: BTreeMap::new(),
    });
    let reorder_cv = Condvar::new();
    // Publication window: a prep worker whose block index is this far
    // ahead of the oldest unpublished block waits instead of preparing,
    // bounding the reorder buffer to O(window · block · n) memory even
    // when one block straggles (the FIFO shard queue guarantees the
    // oldest unpublished block is always already with a worker, so the
    // window can never wedge).
    let window = job.workers + 2 * job.queue_factor.max(1);

    // Split the accumulator into per-band row slices; each band worker
    // owns its slice exclusively, so no synchronization guards the sweep.
    let mut band_slices: Vec<(usize, usize, &mut [f64])> = Vec::with_capacity(bands.len());
    let mut rest: &mut [f64] = acc.data_mut();
    for &(r_lo, r_hi) in &bands {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut((r_hi - r_lo) * n);
        band_slices.push((r_lo, r_hi, head));
        rest = tail;
    }

    std::thread::scope(|s| {
        // Feeder: test-block shards in order (prep may still finish them
        // out of order; the reorder buffer restores order at publication).
        s.spawn(|| {
            for shard in &shards {
                if prep_queue.send(*shard).is_err() {
                    break;
                }
            }
            prep_queue.close();
        });

        // Prep pool: Phase 1 over test blocks (shared worker loop).
        for _w in 0..job.workers {
            s.spawn(|| {
                prep_worker_loop(
                    train_x, train_y, d, test_x, test_y, &params, &norms, &prep_queue,
                    &band_queues, &reorder, &reorder_cv, &merger, progress, window, n_blocks,
                );
            });
        }

        // Band pool: Phase 2, one worker per disjoint row band.
        for (band_idx, (r_lo, r_hi, slice)) in band_slices.into_iter().enumerate() {
            let q = &band_queues[band_idx];
            let prep_queue = &prep_queue;
            let band_queues = &band_queues;
            let reorder = &reorder;
            let reorder_cv = &reorder_cv;
            s.spawn(move || {
                let _abort = AbortOnPanic {
                    prep_queue,
                    band_queues,
                    reorder,
                    reorder_cv,
                };
                let rows = slice;
                while let Some(batch) = q.recv() {
                    let t0 = crate::obs::now();
                    sweep_band(&batch, train_y, r_lo, r_hi, rows);
                    progress.record_sweep(t0.elapsed().as_nanos() as u64);
                }
            });
        }
    });
    progress.record_wall(job.workers, wall.elapsed().as_nanos() as u64);

    let weight = merger.into_inner().unwrap().finalize();
    Ok((weight, n_blocks))
}

/// Streaming value-sharded ingest for the implicit engine
/// (`shapley::values`, DESIGN.md §10): accumulate one test batch's
/// UNNORMALIZED per-point values into an existing [`ValueVector`]
/// through the prep pool, returning the batch's merge weight (its test
/// count, Eq. 9 — values are linear in test points exactly like the
/// matrix).
///
/// Topology: the same prep pool + in-order publication as the banded
/// matrix path, but Phase 2 collapses to a SINGLE value sweeper — the
/// O(len·n) `sweep_values` fold is ~n× cheaper than the O(len·n²) matrix
/// sweep, so prep (O(n log n) per test) dominates and parallelizing the
/// fold would buy nothing. Because blocks are published in block order
/// and every vector element takes exactly one addition per test point,
/// the result is **bit-identical** to single-threaded
/// `values_accumulate` for any worker count or block size.
#[allow(clippy::too_many_arguments)]
pub fn ingest_values(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    job: &ValuationJob,
    vv: &mut ValueVector,
) -> Result<f64> {
    ingest_values_with(
        train_x,
        train_y,
        d,
        test_x,
        test_y,
        job,
        vv,
        &Progress::new(),
    )
}

/// [`ingest_values`] with a caller-owned [`Progress`] — the obs twin of
/// [`ingest_banded_with`] for the implicit engine.
#[allow(clippy::too_many_arguments)]
pub fn ingest_values_with(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    job: &ValuationJob,
    vv: &mut ValueVector,
    progress: &Progress,
) -> Result<f64> {
    let n = train_y.len();
    anyhow::ensure!(
        vv.n() == n,
        "value vector is length {} but train set has n={n}",
        vv.n()
    );
    anyhow::ensure!(!test_y.is_empty(), "empty ingest batch");
    anyhow::ensure!(
        train_x.len() == n * d,
        "train shape mismatch: {} features for {n} points (d={d})",
        train_x.len()
    );
    anyhow::ensure!(
        test_x.len() == test_y.len() * d,
        "test batch shape mismatch: {} features for {} labels (d={d})",
        test_x.len(),
        test_y.len()
    );
    let (weight, _blocks) =
        values_pipeline(train_x, train_y, d, test_x, test_y, job, vv, progress)?;
    Ok(weight)
}

/// The value-sharded pipeline core: prep pool → in-order publication →
/// one `sweep_values` consumer. Returns (total weight, block count).
#[allow(clippy::too_many_arguments)]
fn values_pipeline(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    job: &ValuationJob,
    vv: &mut ValueVector,
    progress: &Progress,
) -> Result<(f64, usize)> {
    let wall = crate::obs::now();
    let params = StiParams {
        k: job.k,
        metric: job.metric,
    };
    let norms = NormCache::build(train_x, d, params.metric);
    let shards = shards_for_len(job, test_y.len());
    let n_blocks = shards.len();
    let merger = Mutex::new(WeightMerger::new(n_blocks));
    let prep_queue: Bounded<Shard> = Bounded::new(job.workers * job.queue_factor.max(1));
    // One consumer queue, but kept as a Vec so the AbortOnPanic guard and
    // the publication loop are shared verbatim with the banded path.
    let band_queues: Vec<Bounded<Arc<PreparedBatch>>> =
        vec![Bounded::new(2 * job.queue_factor.max(1))];
    let reorder = Mutex::new(Reorder {
        next: 0,
        aborted: false,
        pending: BTreeMap::new(),
    });
    let reorder_cv = Condvar::new();
    let window = job.workers + 2 * job.queue_factor.max(1);
    let sweeper_vv = &mut *vv;

    std::thread::scope(|s| {
        s.spawn(|| {
            for shard in &shards {
                if prep_queue.send(*shard).is_err() {
                    break;
                }
            }
            prep_queue.close();
        });

        for _w in 0..job.workers {
            s.spawn(|| {
                prep_worker_loop(
                    train_x, train_y, d, test_x, test_y, &params, &norms, &prep_queue,
                    &band_queues, &reorder, &reorder_cv, &merger, progress, window, n_blocks,
                );
            });
        }

        // The single value sweeper: folds published blocks in block order.
        {
            let q = &band_queues[0];
            let prep_queue = &prep_queue;
            let band_queues = &band_queues;
            let reorder = &reorder;
            let reorder_cv = &reorder_cv;
            s.spawn(move || {
                let _abort = AbortOnPanic {
                    prep_queue,
                    band_queues,
                    reorder,
                    reorder_cv,
                };
                let mut scratch = ValuesScratch::new();
                while let Some(batch) = q.recv() {
                    let t0 = crate::obs::now();
                    sweep_values(&batch, train_y, sweeper_vv, &mut scratch);
                    progress.record_sweep(t0.elapsed().as_nanos() as u64);
                }
            });
        }
    });
    progress.record_wall(job.workers, wall.elapsed().as_nanos() as u64);

    let weight = merger.into_inner().unwrap().finalize();
    Ok((weight, n_blocks))
}

/// Run a per-point value job with the implicit engine (DESIGN.md §10):
/// the value-sharded twin of [`run_job`]. Never allocates the n×n
/// matrix; the result carries the averaged main + rowsum vectors.
pub fn run_values_job(ds: &Dataset, job: &ValuationJob) -> Result<ValuesResult> {
    anyhow::ensure!(
        job.engine == Engine::Rust,
        "the implicit value engine is Rust-only (the XLA artifacts compute matrices)"
    );
    // Err, not the plan_shards assert: parity with ingest_values.
    anyhow::ensure!(!ds.test_y.is_empty(), "empty test set");
    let meter = ThroughputMeter::new();
    let progress = Progress::new();
    let n = ds.n_train();
    let mut vv = ValueVector::zeros(n);
    let (weight, blocks) = values_pipeline(
        &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, job, &mut vv, &progress,
    )?;
    let inv_w = 1.0 / weight;
    let elapsed = meter.elapsed();
    Ok(ValuesResult {
        main: vv.main_values(inv_w),
        rowsum: vv.rowsum_values(inv_w),
        weight,
        blocks,
        elapsed,
        throughput: meter.rate(progress.points()),
    })
}

/// Legacy test-sharded assembly: each worker's `sti_knn_partial` call
/// allocates a private n×n accumulator (O(W·n²) peak), merged in shard
/// order. Kept selectable for the memory/scaling comparison benches.
fn run_rust_test_sharded(ds: &Dataset, job: &ValuationJob) -> Result<ValuationResult> {
    let params = StiParams {
        k: job.k,
        metric: job.metric,
    };
    let meter = ThroughputMeter::new();
    let progress = Progress::new();
    let shards = shards_for(job, ds);
    let merger = Mutex::new(Merger::new(shards.len()));
    let queue: Bounded<Shard> = Bounded::new(job.workers * job.queue_factor.max(1));

    std::thread::scope(|s| {
        s.spawn(|| {
            for shard in &shards {
                if queue.send(*shard).is_err() {
                    break;
                }
            }
            queue.close();
        });
        run_workers(&queue, job.workers, |_w, shard: Shard| {
            let t0 = crate::obs::now();
            let (tx, ty) = ds.test_slice(shard.lo, shard.hi);
            let (phi_sum, weight) =
                sti_knn_partial(&ds.train_x, &ds.train_y, ds.d, tx, ty, &params);
            progress.record_block(shard.hi - shard.lo, t0.elapsed().as_nanos() as u64);
            merger.lock().unwrap().push(PartialResult {
                index: shard.index,
                phi_sum,
                weight,
            });
        });
    });

    let (phi, weight) = merger.into_inner().unwrap().finalize();
    let elapsed = meter.elapsed();
    Ok(ValuationResult {
        phi,
        weight,
        blocks: shards.len(),
        elapsed,
        throughput: meter.rate(progress.points()),
        engine: Engine::Rust,
    })
}

fn run_xla(ds: &Dataset, job: &ValuationJob, artifacts_dir: &Path) -> Result<ValuationResult> {
    let manifest = Manifest::load(artifacts_dir)?;
    // Bind the job to the artifact's baked block size.
    let spec = manifest
        .find("sti", ds.n_train(), ds.d, job.k)
        .with_context(|| {
            format!(
                "no sti artifact for (n={}, d={}, k={}); run `make artifacts` \
                 with this shape in DEFAULT_GRID or use --engine rust",
                ds.n_train(),
                ds.d,
                job.k
            )
        })?;
    let block = spec.b;
    let job = job.clone().with_block_size(block);

    let meter = ThroughputMeter::new();
    let progress = Progress::new();
    let shards = shards_for(&job, ds);
    let merger = Mutex::new(Merger::new(shards.len()));
    let queue: Bounded<Shard> = Bounded::new(job.workers * job.queue_factor.max(1));

    let errors: Mutex<Vec<anyhow::Error>> = Mutex::new(Vec::new());

    // The xla crate's PJRT handles are !Send (Rc internally), so each
    // worker thread constructs — and keeps — its own client + compiled
    // executable; only Shards and PartialResults cross thread boundaries.
    std::thread::scope(|s| {
        s.spawn(|| {
            for shard in &shards {
                if queue.send(*shard).is_err() {
                    break;
                }
            }
            queue.close();
        });
        for _w in 0..job.workers {
            let queue = &queue;
            let manifest = &manifest;
            let merger = &merger;
            let errors = &errors;
            let progress = &progress;
            let job = &job;
            s.spawn(move || {
                let exec: StiExecutor =
                    match executor_for(manifest, "sti", ds.n_train(), ds.d, job.k) {
                        Ok(e) => e,
                        Err(e) => {
                            errors.lock().unwrap().push(e);
                            queue.close();
                            return;
                        }
                    };
                while let Some(shard) = queue.recv() {
                    let t0 = crate::obs::now();
                    let (tx, ty) = ds.test_slice(shard.lo, shard.hi);
                    match exec.run_block(&ds.train_x, &ds.train_y, tx, ty) {
                        Ok((phi_sum, weight)) => {
                            progress.record_block(
                                shard.hi - shard.lo,
                                t0.elapsed().as_nanos() as u64,
                            );
                            merger.lock().unwrap().push(PartialResult {
                                index: shard.index,
                                phi_sum,
                                weight,
                            });
                        }
                        Err(e) => {
                            errors.lock().unwrap().push(e.context(format!(
                                "shard {} [{}, {})",
                                shard.index, shard.lo, shard.hi
                            )));
                            queue.close(); // fail fast: stop feeding workers
                        }
                    }
                }
            });
        }
    });

    let errs = errors.into_inner().unwrap();
    if let Some(e) = errs.into_iter().next() {
        return Err(e);
    }
    let (phi, weight) = merger.into_inner().unwrap().finalize();
    let elapsed = meter.elapsed();
    Ok(ValuationResult {
        phi,
        weight,
        blocks: shards.len(),
        elapsed,
        throughput: meter.rate(progress.points()),
        engine: Engine::Xla,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;
    use crate::shapley::sti_knn::sti_knn;

    #[test]
    fn pipeline_equals_single_threaded_reference() {
        let ds = load_dataset("moon", 60, 23, 5).unwrap();
        let reference = sti_knn(
            &ds.train_x,
            &ds.train_y,
            ds.d,
            &ds.test_x,
            &ds.test_y,
            &StiParams::new(5),
        );
        for assembly in [
            Assembly::RowBanded { band_rows: 0 },
            Assembly::RowBanded { band_rows: 13 }, // does not divide n=60
            Assembly::TestSharded,
        ] {
            for workers in [1usize, 2, 4] {
                for block in [1usize, 7, 16, 64] {
                    let job = ValuationJob::new(5)
                        .with_workers(workers)
                        .with_block_size(block)
                        .with_assembly(assembly);
                    let res = run_job(&ds, &job).unwrap();
                    assert_eq!(res.weight, 23.0);
                    assert!(
                        res.phi.max_abs_diff(&reference) < 1e-12,
                        "assembly={assembly:?} workers={workers} block={block}"
                    );
                }
            }
        }
    }

    #[test]
    fn pipeline_bit_deterministic_across_worker_counts() {
        let ds = load_dataset("click", 80, 17, 9).unwrap();
        let run = |workers| {
            let job = ValuationJob::new(3).with_workers(workers).with_block_size(4);
            run_job(&ds, &job).unwrap().phi
        };
        let a = run(1);
        let b = run(3);
        let c = run(8);
        // bitwise equality, not approximate
        assert_eq!(a.data().len(), b.data().len());
        for i in 0..a.data().len() {
            assert_eq!(a.data()[i].to_bits(), b.data()[i].to_bits());
            assert_eq!(b.data()[i].to_bits(), c.data()[i].to_bits());
        }
    }

    #[test]
    fn banded_is_bit_identical_to_single_threaded_engine() {
        // Stronger than the test-sharded guarantee (which only promises
        // determinism for a FIXED block size): the banded path's per-cell
        // addition order is exactly the single-threaded engine's, so the
        // bits match sti_knn itself for any block size and band layout.
        let ds = load_dataset("phoneme", 70, 21, 4).unwrap();
        let reference = sti_knn(
            &ds.train_x,
            &ds.train_y,
            ds.d,
            &ds.test_x,
            &ds.test_y,
            &StiParams::new(3),
        );
        for (workers, block, band_rows) in [(2usize, 5usize, 9usize), (7, 64, 0), (3, 1, 70)] {
            let job = ValuationJob::new(3)
                .with_workers(workers)
                .with_block_size(block)
                .with_band_rows(band_rows);
            let res = run_job(&ds, &job).unwrap();
            for (a, b) in reference.data().iter().zip(res.phi.data()) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "workers={workers} block={block} band_rows={band_rows}"
                );
            }
        }
    }

    #[test]
    fn ingest_banded_streaming_matches_one_shot_bits() {
        // The session-layer contract: two ingest_banded calls over a
        // contiguous split of the test set, into one shared accumulator,
        // produce (after mirror + scale) the same BITS as one-shot
        // sti_knn — the parallel pipeline never reorders any cell's
        // additions, and neither do ingest boundaries.
        let ds = load_dataset("moon", 40, 16, 11).unwrap();
        let reference = sti_knn(
            &ds.train_x,
            &ds.train_y,
            ds.d,
            &ds.test_x,
            &ds.test_y,
            &StiParams::new(4),
        );
        let job = ValuationJob::new(4).with_workers(3).with_block_size(3);
        let mut acc = Matrix::zeros(40, 40);
        let mut weight = 0.0;
        for (lo, hi) in [(0usize, 7usize), (7, 16)] {
            let (tx, ty) = ds.test_slice(lo, hi);
            weight +=
                ingest_banded(&ds.train_x, &ds.train_y, ds.d, tx, ty, &job, &mut acc).unwrap();
        }
        assert_eq!(weight, 16.0);
        acc.mirror_upper_to_lower();
        let s = 1.0 / weight;
        acc.scale(s);
        for (a, b) in reference.data().iter().zip(acc.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn ingest_banded_rejects_bad_shapes() {
        let ds = load_dataset("moon", 20, 6, 3).unwrap();
        let job = ValuationJob::new(3);
        let mut wrong = Matrix::zeros(19, 19);
        let (tx, ty) = ds.test_slice(0, 6);
        assert!(
            ingest_banded(&ds.train_x, &ds.train_y, ds.d, tx, ty, &job, &mut wrong).is_err()
        );
        let mut acc = Matrix::zeros(20, 20);
        assert!(
            ingest_banded(&ds.train_x, &ds.train_y, ds.d, &[], &[], &job, &mut acc).is_err()
        );
    }

    #[test]
    fn values_pipeline_is_bit_identical_to_single_threaded() {
        // The value-sharded path's contract: in-order publication + one
        // sweeper means every vector element takes its per-test additions
        // in stream order — same BITS as values_accumulate, any workers /
        // block size.
        use crate::shapley::values::{values_accumulate, ValueVector};
        let ds = load_dataset("moon", 45, 18, 6).unwrap();
        let params = StiParams::new(4);
        let mut reference = ValueVector::zeros(45);
        values_accumulate(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, &params, &mut reference,
        );
        for (workers, block) in [(1usize, 5usize), (3, 1), (7, 64)] {
            let job = ValuationJob::new(4).with_workers(workers).with_block_size(block);
            let mut vv = ValueVector::zeros(45);
            let w = ingest_values(
                &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, &job, &mut vv,
            )
            .unwrap();
            assert_eq!(w, 18.0);
            for i in 0..45 {
                assert_eq!(
                    reference.main_raw()[i].to_bits(),
                    vv.main_raw()[i].to_bits(),
                    "main[{i}] workers={workers} block={block}"
                );
                assert_eq!(
                    reference.inter_raw()[i].to_bits(),
                    vv.inter_raw()[i].to_bits(),
                    "inter[{i}] workers={workers} block={block}"
                );
            }
        }
    }

    #[test]
    fn run_values_job_matches_dense_job_rowsums() {
        let ds = load_dataset("click", 60, 21, 3).unwrap();
        let job = ValuationJob::new(5).with_workers(3).with_block_size(4);
        let vres = run_values_job(&ds, &job).unwrap();
        assert_eq!(vres.weight, 21.0);
        assert_eq!(vres.blocks, 6); // ceil(21/4)
        assert!(vres.throughput > 0.0);
        let dres = run_job(&ds, &job).unwrap();
        for i in 0..60 {
            assert!((vres.main[i] - dres.phi.get(i, i)).abs() < 1e-12, "main[{i}]");
            let direct: f64 = dres.phi.row(i).iter().sum();
            assert!((vres.rowsum[i] - direct).abs() < 1e-12, "rowsum[{i}]");
        }
    }

    #[test]
    fn values_streaming_ingest_matches_one_shot_bits() {
        use crate::shapley::values::ValueVector;
        let ds = load_dataset("moon", 30, 12, 9).unwrap();
        let job = ValuationJob::new(3).with_workers(2).with_block_size(3);
        let mut one = ValueVector::zeros(30);
        ingest_values(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, &job, &mut one,
        )
        .unwrap();
        let mut parts = ValueVector::zeros(30);
        let mut weight = 0.0;
        for (lo, hi) in [(0usize, 5usize), (5, 12)] {
            let (tx, ty) = ds.test_slice(lo, hi);
            weight +=
                ingest_values(&ds.train_x, &ds.train_y, ds.d, tx, ty, &job, &mut parts).unwrap();
        }
        assert_eq!(weight, 12.0);
        for i in 0..30 {
            assert_eq!(one.main_raw()[i].to_bits(), parts.main_raw()[i].to_bits());
            assert_eq!(one.inter_raw()[i].to_bits(), parts.inter_raw()[i].to_bits());
        }
    }

    #[test]
    fn ingest_values_rejects_bad_shapes() {
        use crate::shapley::values::ValueVector;
        let ds = load_dataset("moon", 20, 6, 3).unwrap();
        let job = ValuationJob::new(3);
        let mut wrong = ValueVector::zeros(19);
        let (tx, ty) = ds.test_slice(0, 6);
        assert!(
            ingest_values(&ds.train_x, &ds.train_y, ds.d, tx, ty, &job, &mut wrong).is_err()
        );
        let mut vv = ValueVector::zeros(20);
        assert!(
            ingest_values(&ds.train_x, &ds.train_y, ds.d, &[], &[], &job, &mut vv).is_err()
        );
    }

    #[test]
    fn throughput_and_blocks_reported() {
        let ds = load_dataset("cpu", 50, 10, 2).unwrap();
        let job = ValuationJob::new(3).with_workers(2).with_block_size(3);
        let res = run_job(&ds, &job).unwrap();
        assert_eq!(res.blocks, 4); // ceil(10/3)
        assert!(res.throughput > 0.0);
        assert!(res.elapsed.as_nanos() > 0);
    }
}
