//! Concurrency substrate: a bounded MPMC channel and a scoped worker pool
//! (no tokio/rayon in the offline image).
//!
//! The bounded channel provides the pipeline's backpressure: producers
//! block once `capacity` items are in flight, so a slow engine (e.g. the
//! XLA executor) throttles shard production instead of ballooning memory.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// A bounded multi-producer multi-consumer queue. `None` from `recv`
/// means the channel is closed and drained.
pub struct Bounded<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner<T> {
    queue: VecDeque<T>,
    closed: bool,
    /// high-water mark, for the backpressure invariant tests
    peak: usize,
}

impl<T> Bounded<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        Bounded {
            inner: Mutex::new(Inner {
                queue: VecDeque::with_capacity(capacity),
                closed: false,
                peak: 0,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking send. Returns Err(item) if the channel is closed.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        while g.queue.len() >= self.capacity && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return Err(item);
        }
        g.queue.push_back(item);
        let len = g.queue.len();
        if len > g.peak {
            g.peak = len;
        }
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; `None` once closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.queue.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Close the channel: senders fail, receivers drain then get `None`.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Highest queue occupancy observed (backpressure invariant: ≤ capacity).
    pub fn peak(&self) -> usize {
        self.inner.lock().unwrap().peak
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

/// Run `worker` on `threads` scoped threads, each pulling from `queue`
/// until it drains. The closure receives (worker_index, item).
pub fn run_workers<T: Send, F>(queue: &Bounded<T>, threads: usize, worker: F)
where
    F: Fn(usize, T) + Sync,
{
    assert!(threads >= 1);
    std::thread::scope(|s| {
        for w in 0..threads {
            let worker = &worker;
            s.spawn(move || {
                while let Some(item) = queue.recv() {
                    worker(w, item);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn fifo_single_thread() {
        let q = Bounded::new(4);
        q.send(1).unwrap();
        q.send(2).unwrap();
        q.close();
        assert_eq!(q.recv(), Some(1));
        assert_eq!(q.recv(), Some(2));
        assert_eq!(q.recv(), None);
    }

    #[test]
    fn send_after_close_fails() {
        let q: Bounded<u32> = Bounded::new(1);
        q.close();
        assert_eq!(q.send(9), Err(9));
    }

    #[test]
    fn backpressure_bounds_occupancy() {
        let q = std::sync::Arc::new(Bounded::new(2));
        let total = 100;
        let consumed = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let qp = q.clone();
            s.spawn(move || {
                for i in 0..total {
                    qp.send(i).unwrap();
                }
                qp.close();
            });
            while q.recv().is_some() {
                consumed.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(consumed.load(Ordering::Relaxed), total);
        assert!(q.peak() <= 2, "peak {} exceeded capacity", q.peak());
    }

    #[test]
    fn workers_process_everything_exactly_once() {
        let q = Bounded::new(8);
        let seen = Mutex::new(vec![0usize; 200]);
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..200 {
                    q.send(i).unwrap();
                }
                q.close();
            });
            s.spawn(|| {
                run_workers(&q, 4, |_w, i: usize| {
                    seen.lock().unwrap()[i] += 1;
                });
            });
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }

    #[test]
    fn multiple_consumers_drain() {
        let q = std::sync::Arc::new(Bounded::new(3));
        let count = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..3 {
                let q = q.clone();
                let count = &count;
                s.spawn(move || {
                    while q.recv().is_some() {
                        count.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
            for i in 0..50 {
                q.send(i).unwrap();
            }
            q.close();
        });
        assert_eq!(count.load(Ordering::Relaxed), 50);
    }
}
