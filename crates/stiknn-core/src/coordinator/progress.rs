//! Pipeline progress: per-job counters built on the obs primitives.
//!
//! Since DESIGN.md §14 there is ONE atomic-counter vocabulary in the
//! workspace — [`crate::obs`] — and this module is a thin per-job view
//! over it: the fields ARE [`obs::Counter`]s, and a `Progress` built
//! with [`Progress::with_obs`] additionally rolls every record up into
//! the attached registry under the `coord.*` names (blocks, points,
//! busy/wall nanoseconds, and the prep-vs-sweep phase histograms).
//! Workers only ever touch pre-resolved handles, so the hot path stays
//! relaxed atomic adds whether or not a registry is attached.
//!
//! [`obs::Counter`]: crate::obs::Counter

use crate::knn::kernel::Kernel;
use crate::obs::{Counter, Histogram, ObsHandle};
use std::sync::Arc;
use std::time::Instant;

/// Global roll-up handles, resolved once at job start so workers never
/// touch the registry's name maps.
struct Sinks {
    blocks: Arc<Counter>,
    points: Arc<Counter>,
    busy_ns: Arc<Counter>,
    wall_ns: Arc<Counter>,
    worker_ns: Arc<Counter>,
    prep_ns: Arc<Histogram>,
    sweep_ns: Arc<Histogram>,
    kernel_ns: Arc<Histogram>,
}

/// Shared progress state between workers and the orchestrator: Phase-1
/// (prep) blocks/points/busy time, Phase-2 (sweep) busy time, and —
/// when a registry is attached — the `coord.*` global metrics.
#[derive(Default)]
pub struct Progress {
    blocks_done: Counter,
    points_done: Counter,
    prep_ns: Counter,
    sweep_ns: Counter,
    kernel_ns: Counter,
    wall_ns: Counter,
    worker_ns: Counter,
    sinks: Option<Sinks>,
}

impl Progress {
    /// Job-local progress with no global roll-up (the default for
    /// one-shot jobs and for sessions with observability disabled).
    pub fn new() -> Self {
        Self::default()
    }

    /// Progress that also rolls up into `obs`'s registry under the
    /// `coord.*` metric names. A disabled handle behaves like
    /// [`Progress::new`].
    pub fn with_obs(obs: &ObsHandle) -> Self {
        let sinks = obs.registry().map(|reg| {
            // Snapshot readers see which distance kernel served this
            // process's prep path (DESIGN.md §15).
            reg.set_label("kernel", Kernel::active().name());
            Sinks {
                blocks: reg.counter("coord.blocks"),
                points: reg.counter("coord.points"),
                busy_ns: reg.counter("coord.busy_ns"),
                wall_ns: reg.counter("coord.wall_ns"),
                worker_ns: reg.counter("coord.worker_ns"),
                prep_ns: reg.histogram("coord.prep_ns"),
                sweep_ns: reg.histogram("coord.sweep_ns"),
                kernel_ns: reg.histogram("coord.prep.kernel_ns"),
            }
        });
        Progress {
            sinks,
            ..Self::default()
        }
    }

    /// Record one finished Phase-1 block of `points` test points that
    /// took `ns` busy-nanoseconds.
    pub fn record_block(&self, points: usize, ns: u64) {
        self.blocks_done.inc();
        self.points_done.add(points as u64);
        self.prep_ns.add(ns);
        if let Some(s) = &self.sinks {
            s.blocks.inc();
            s.points.add(points as u64);
            s.busy_ns.add(ns);
            s.prep_ns.record_ns(ns);
        }
    }

    /// Record the distance-kernel slice of one finished Phase-1 block:
    /// `ns` nanoseconds spent inside `distances_block`. This is a
    /// sub-slice of the time already counted by [`Progress::record_block`],
    /// so it does NOT feed busy time — only the kernel counter and the
    /// `coord.prep.kernel_ns` histogram.
    pub fn record_kernel(&self, ns: u64) {
        self.kernel_ns.add(ns);
        if let Some(s) = &self.sinks {
            s.kernel_ns.record_ns(ns);
        }
    }

    /// Record one Phase-2 sweep (a matrix band or a value fold) of `ns`
    /// busy-nanoseconds.
    pub fn record_sweep(&self, ns: u64) {
        self.sweep_ns.add(ns);
        if let Some(s) = &self.sinks {
            s.busy_ns.add(ns);
            s.sweep_ns.record_ns(ns);
        }
    }

    /// Record the job's wall time once, at orchestrator exit: `ns` of
    /// wall clock with `workers` prep workers configured. Worker-time
    /// (`wall × workers`) is what busy time divides by for utilization.
    pub fn record_wall(&self, workers: usize, ns: u64) {
        self.wall_ns.add(ns);
        self.worker_ns.add(ns * workers as u64);
        if let Some(s) = &self.sinks {
            s.wall_ns.add(ns);
            s.worker_ns.add(ns * workers as u64);
        }
    }

    pub fn blocks(&self) -> usize {
        self.blocks_done.get() as usize
    }

    pub fn points(&self) -> usize {
        self.points_done.get() as usize
    }

    /// Cumulative Phase-1 busy time across workers, nanoseconds.
    pub fn prep_ns(&self) -> u64 {
        self.prep_ns.get()
    }

    /// Cumulative Phase-2 busy time across workers, nanoseconds.
    pub fn sweep_ns(&self) -> u64 {
        self.sweep_ns.get()
    }

    /// Cumulative time inside the distance kernel across workers,
    /// nanoseconds (a sub-slice of [`Progress::prep_ns`]).
    pub fn kernel_ns(&self) -> u64 {
        self.kernel_ns.get()
    }

    /// Total busy time across both phases, nanoseconds.
    pub fn busy_ns(&self) -> u64 {
        self.prep_ns() + self.sweep_ns()
    }

    /// Mean busy time per test point in nanoseconds (0 if none yet).
    pub fn ns_per_point(&self) -> f64 {
        let pts = self.points();
        if pts == 0 {
            return 0.0;
        }
        self.busy_ns() as f64 / pts as f64
    }

    /// Busy time over configured worker time: ~1.0 means the prep pool
    /// never starved, ~0 means workers mostly idled. 0 before
    /// [`Progress::record_wall`].
    pub fn utilization(&self) -> f64 {
        let denom = self.worker_ns.get();
        if denom == 0 {
            return 0.0;
        }
        self.busy_ns() as f64 / denom as f64
    }
}

/// Wall-clock throughput helper for the orchestrator.
pub struct ThroughputMeter {
    start: Instant,
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> Self {
        ThroughputMeter {
            start: crate::obs::now(),
        }
    }

    pub fn elapsed(&self) -> std::time::Duration {
        self.start.elapsed()
    }

    /// Points per second at this instant.
    pub fn rate(&self, points: usize) -> f64 {
        let secs = self.elapsed().as_secs_f64();
        if secs == 0.0 {
            return 0.0;
        }
        points as f64 / secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_across_threads() {
        let p = Progress::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..25 {
                        p.record_block(8, 1000);
                    }
                });
            }
        });
        assert_eq!(p.blocks(), 100);
        assert_eq!(p.points(), 800);
        assert!((p.ns_per_point() - 125.0).abs() < 1e-9);
    }

    #[test]
    fn empty_progress_is_zero() {
        let p = Progress::new();
        assert_eq!(p.ns_per_point(), 0.0);
        assert_eq!(p.points(), 0);
        assert_eq!(p.utilization(), 0.0);
    }

    #[test]
    fn sweep_and_wall_fold_into_busy_and_utilization() {
        let p = Progress::new();
        p.record_block(4, 600);
        p.record_sweep(400);
        assert_eq!(p.prep_ns(), 600);
        assert_eq!(p.sweep_ns(), 400);
        assert_eq!(p.busy_ns(), 1000);
        p.record_wall(2, 1000); // 2 workers × 1000ns wall = 2000ns capacity
        assert!((p.utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn with_obs_rolls_up_into_the_registry() {
        let obs = ObsHandle::enabled("coord-test");
        let p = Progress::with_obs(&obs);
        p.record_block(8, 1_500);
        p.record_kernel(900);
        p.record_sweep(2_500);
        p.record_wall(3, 10_000);
        let reg = obs.registry().unwrap();
        assert_eq!(reg.counter("coord.blocks").get(), 1);
        assert_eq!(reg.counter("coord.points").get(), 8);
        assert_eq!(reg.counter("coord.busy_ns").get(), 4_000);
        assert_eq!(reg.counter("coord.wall_ns").get(), 10_000);
        assert_eq!(reg.counter("coord.worker_ns").get(), 30_000);
        assert_eq!(reg.histogram("coord.prep_ns").count(), 1);
        assert_eq!(reg.histogram("coord.sweep_ns").count(), 1);
        assert_eq!(reg.histogram("coord.prep.kernel_ns").count(), 1);
        // The job-local view is unaffected by the roll-up; kernel time
        // stays out of busy time (it is a sub-slice of prep time).
        assert_eq!(p.blocks(), 1);
        assert_eq!(p.kernel_ns(), 900);
        assert_eq!(p.busy_ns(), 4_000);
    }

    #[test]
    fn disabled_obs_behaves_like_plain_progress() {
        let p = Progress::with_obs(&ObsHandle::disabled());
        p.record_block(2, 100);
        assert_eq!(p.blocks(), 1);
        assert_eq!(p.points(), 2);
    }

    #[test]
    fn meter_rate_positive() {
        let m = ThroughputMeter::new();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(m.rate(100) > 0.0);
    }
}
