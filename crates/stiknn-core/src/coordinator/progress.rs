//! Pipeline metrics: atomic counters sampled by the orchestrator, giving
//! throughput (test points/s) and per-phase accounting without locks on
//! the hot path.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

/// Shared progress state between workers and the orchestrator.
#[derive(Default)]
pub struct Progress {
    blocks_done: AtomicUsize,
    points_done: AtomicUsize,
    /// Cumulative busy time across workers, nanoseconds.
    busy_ns: AtomicU64,
}

impl Progress {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one finished block of `points` test points that took `ns`
    /// busy-nanoseconds.
    pub fn record_block(&self, points: usize, ns: u64) {
        self.blocks_done.fetch_add(1, Ordering::Relaxed);
        self.points_done.fetch_add(points, Ordering::Relaxed);
        self.busy_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn blocks(&self) -> usize {
        self.blocks_done.load(Ordering::Relaxed)
    }

    pub fn points(&self) -> usize {
        self.points_done.load(Ordering::Relaxed)
    }

    /// Mean busy time per test point in nanoseconds (0 if none yet).
    pub fn ns_per_point(&self) -> f64 {
        let pts = self.points();
        if pts == 0 {
            return 0.0;
        }
        self.busy_ns.load(Ordering::Relaxed) as f64 / pts as f64
    }
}

/// Wall-clock throughput helper for the orchestrator.
pub struct ThroughputMeter {
    start: Instant,
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> Self {
        ThroughputMeter {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> std::time::Duration {
        self.start.elapsed()
    }

    /// Points per second at this instant.
    pub fn rate(&self, points: usize) -> f64 {
        let secs = self.elapsed().as_secs_f64();
        if secs == 0.0 {
            return 0.0;
        }
        points as f64 / secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_across_threads() {
        let p = Progress::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..25 {
                        p.record_block(8, 1000);
                    }
                });
            }
        });
        assert_eq!(p.blocks(), 100);
        assert_eq!(p.points(), 800);
        assert!((p.ns_per_point() - 125.0).abs() < 1e-9);
    }

    #[test]
    fn empty_progress_is_zero() {
        let p = Progress::new();
        assert_eq!(p.ns_per_point(), 0.0);
        assert_eq!(p.points(), 0);
    }

    #[test]
    fn meter_rate_positive() {
        let m = ThroughputMeter::new();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(m.rate(100) > 0.0);
    }
}
