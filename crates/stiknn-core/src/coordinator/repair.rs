//! Parallel fan-out for delta repairs (DESIGN.md §11): split a mutable
//! session's retained test rows into contiguous chunks and run
//! [`repair_chunk`] on each from its own worker.
//!
//! Unlike the ingest pipelines, repairs need NO reorder buffer, queue,
//! or merger: every test's repair is a pure function of its own old row
//! plus the shared edit context, and each worker writes a disjoint slice
//! of the new row storage. Any chunking therefore produces identical
//! rows — bit-identical to the single-threaded repair by construction
//! (asserted across worker counts in `tests/delta_equivalence.rs`). The
//! value-vector refold that follows a repair stays single-threaded in
//! the session (it is the bit-reproducibility anchor; see
//! `shapley::delta::refold_values`).

use crate::shapley::delta::{repair_chunk, Edit, RepairCtx, RepairScratch};

/// Freshly repaired row storage for one edit, `tests` rows of
/// `ctx.new_n` each, in the same layouts the session retains: (dist,
/// pos) in rank order, (rank, colval) in train order.
pub struct RepairedRows {
    pub dist: Vec<f64>,
    pub pos: Vec<u32>,
    pub rank: Vec<u32>,
    pub colval: Vec<f64>,
}

/// Repair all `tests` retained rows for one edit, fanning the per-test
/// work out over up to `workers` threads (contiguous chunks; `workers
/// <= 1` or a single chunk runs inline with no thread spawn — the
/// iterative-removal loop in `analysis::removal` leans on that).
pub fn repair_rows(
    ctx: &RepairCtx<'_>,
    edit: &Edit<'_>,
    tests: usize,
    old_dist: &[f64],
    old_pos: &[u32],
    workers: usize,
) -> RepairedRows {
    let new_n = ctx.new_n;
    assert_eq!(old_dist.len(), tests * ctx.old_n, "old dist shape");
    assert_eq!(old_pos.len(), tests * ctx.old_n, "old pos shape");
    let mut out = RepairedRows {
        dist: vec![0.0; tests * new_n],
        pos: vec![0; tests * new_n],
        rank: vec![0; tests * new_n],
        colval: vec![0.0; tests * new_n],
    };
    if tests == 0 {
        return out;
    }
    let workers = workers.clamp(1, tests);
    if workers == 1 {
        let mut scratch = RepairScratch::new();
        repair_chunk(
            ctx,
            edit,
            0,
            old_dist,
            old_pos,
            &mut out.dist,
            &mut out.pos,
            &mut out.rank,
            &mut out.colval,
            &mut scratch,
        );
        return out;
    }

    // Contiguous chunk per worker; the last chunk absorbs the remainder.
    let per = tests.div_ceil(workers);
    std::thread::scope(|s| {
        let mut rest_dist: &mut [f64] = &mut out.dist;
        let mut rest_pos: &mut [u32] = &mut out.pos;
        let mut rest_rank: &mut [u32] = &mut out.rank;
        let mut rest_colval: &mut [f64] = &mut out.colval;
        let mut lo = 0usize;
        while lo < tests {
            let hi = (lo + per).min(tests);
            let len = hi - lo;
            let (nd, rd) = std::mem::take(&mut rest_dist).split_at_mut(len * new_n);
            let (np, rp) = std::mem::take(&mut rest_pos).split_at_mut(len * new_n);
            let (nr, rr) = std::mem::take(&mut rest_rank).split_at_mut(len * new_n);
            let (nc, rc) = std::mem::take(&mut rest_colval).split_at_mut(len * new_n);
            rest_dist = rd;
            rest_pos = rp;
            rest_rank = rr;
            rest_colval = rc;
            let od = &old_dist[lo * ctx.old_n..hi * ctx.old_n];
            let op = &old_pos[lo * ctx.old_n..hi * ctx.old_n];
            s.spawn(move || {
                let mut scratch = RepairScratch::new();
                repair_chunk(ctx, edit, lo, od, op, nd, np, nr, nc, &mut scratch);
            });
            lo = hi;
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knn::distance::Metric;
    use crate::knn::kernel::NormCache;
    use crate::shapley::delta::{ingest_rows, MutableRows, RetainedRows};
    use crate::shapley::values::ValueVector;
    use crate::shapley::StiParams;
    use crate::util::rng::Rng;

    #[test]
    fn fan_out_is_bit_identical_across_worker_counts() {
        let mut rng = Rng::new(5);
        let (n, d, t, k) = (21usize, 3usize, 13usize, 4usize);
        let tx: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let ty: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let qx: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let qy: Vec<i32> = (0..t).map(|_| rng.below(2) as i32).collect();
        let mut rows = RetainedRows::new(n);
        let mut mrows = MutableRows::new(n, d);
        let mut vv = ValueVector::zeros(n);
        let params = StiParams::new(k);
        let norms = NormCache::build(&tx, d, params.metric);
        ingest_rows(
            &tx, &ty, d, &qx, &qy, &params, &norms, &mut rows, &mut mrows, &mut vv,
        );
        let new_x: Vec<f32> = tx[0..d].to_vec();
        let mut new_ty = ty.clone();
        new_ty.push(1);
        let ctx = RepairCtx {
            k,
            metric: Metric::SqEuclidean,
            d,
            old_n: n,
            new_n: n + 1,
            train_y: &new_ty,
            test_x: &qx,
            test_y: &qy,
        };
        let edit = Edit::Add { x: &new_x, y: 1 };
        let reference = repair_rows(&ctx, &edit, t, &mrows.dist, &mrows.pos, 1);
        for workers in [2usize, 3, 5, 16] {
            let got = repair_rows(&ctx, &edit, t, &mrows.dist, &mrows.pos, workers);
            assert_eq!(got.pos, reference.pos, "workers={workers}");
            assert_eq!(got.rank, reference.rank, "workers={workers}");
            for i in 0..t * (n + 1) {
                assert_eq!(
                    got.dist[i].to_bits(),
                    reference.dist[i].to_bits(),
                    "dist[{i}] workers={workers}"
                );
                assert_eq!(
                    got.colval[i].to_bits(),
                    reference.colval[i].to_bits(),
                    "colval[{i}] workers={workers}"
                );
            }
        }
    }

    #[test]
    fn zero_tests_is_a_noop() {
        let ctx = RepairCtx {
            k: 1,
            metric: Metric::SqEuclidean,
            d: 2,
            old_n: 3,
            new_n: 2,
            train_y: &[0, 1],
            test_x: &[],
            test_y: &[],
        };
        let out = repair_rows(&ctx, &Edit::Remove { index: 0 }, 0, &[], &[], 4);
        assert!(out.dist.is_empty());
        assert!(out.pos.is_empty());
    }
}
