//! Dataset corruption tools for the paper's §4 experiments:
//! label flips (Fig. 5, "mislabeled points behave like the opposite
//! class"), class subsampling (Fig. 4, redundancy/unbalance), and
//! duplicate injection (the symmetry-axiom redundancy discussion).

use super::dataset::Dataset;
use crate::util::rng::Rng;

/// Flip the labels of `fraction` of the training points (uniformly chosen)
/// to a different uniformly-chosen class. Returns the flipped indices —
/// the ground truth the mislabel-detection experiment scores against.
pub fn flip_labels(ds: &mut Dataset, fraction: f64, seed: u64) -> Vec<usize> {
    assert!((0.0..=1.0).contains(&fraction));
    let n = ds.n_train();
    let n_flip = ((n as f64 * fraction).round() as usize).min(n);
    let mut rng = Rng::new(seed);
    let mut flipped = rng.sample_indices(n, n_flip);
    flipped.sort_unstable();
    for &i in &flipped {
        let old = ds.train_y[i];
        let mut new = rng.below(ds.classes) as i32;
        while new == old && ds.classes > 1 {
            new = rng.below(ds.classes) as i32;
        }
        ds.train_y[i] = new;
    }
    flipped
}

/// Subsample one class of the training set down to `keep` points (Fig. 4's
/// unbalanced-circle construction). Returns the retained dataset.
pub fn subsample_class(ds: &Dataset, class: i32, keep: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let class_idx: Vec<usize> = (0..ds.n_train())
        .filter(|&i| ds.train_y[i] == class)
        .collect();
    assert!(keep <= class_idx.len(), "cannot keep {keep} of {}", class_idx.len());
    let kept: std::collections::HashSet<usize> = rng
        .sample_indices(class_idx.len(), keep)
        .into_iter()
        .map(|p| class_idx[p])
        .collect();
    let keep_all: Vec<usize> = (0..ds.n_train())
        .filter(|i| ds.train_y[*i] != class || kept.contains(i))
        .collect();
    ds.retain_train(&keep_all)
}

/// Append `copies` near-duplicates of training point `idx` (feature jitter
/// `eps`), for the redundancy experiment: "Redundancy decreases in-class
/// interaction" (§4).
pub fn duplicate_point(ds: &mut Dataset, idx: usize, copies: usize, eps: f64, seed: u64) {
    let mut rng = Rng::new(seed);
    let row: Vec<f32> = ds.train_row(idx).to_vec();
    let label = ds.train_y[idx];
    for _ in 0..copies {
        for &v in &row {
            ds.train_x.push(v + (eps * rng.normal()) as f32);
        }
        ds.train_y.push(label);
    }
    ds.validate();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn base() -> Dataset {
        synth::dataset_from_points("c", synth::circle(60, 0.05, 0.5, 3), 20, 2, 3)
    }

    #[test]
    fn flip_labels_flips_exactly_fraction() {
        let mut ds = base();
        let orig = ds.train_y.clone();
        let flipped = flip_labels(&mut ds, 0.1, 9);
        assert_eq!(flipped.len(), (ds.n_train() as f64 * 0.1).round() as usize);
        for (i, (&a, &b)) in orig.iter().zip(&ds.train_y).enumerate() {
            if flipped.contains(&i) {
                assert_ne!(a, b, "index {i} reported flipped but unchanged");
            } else {
                assert_eq!(a, b, "index {i} changed but not reported");
            }
        }
        ds.validate();
    }

    #[test]
    fn flip_zero_fraction_is_noop() {
        let mut ds = base();
        let orig = ds.train_y.clone();
        assert!(flip_labels(&mut ds, 0.0, 1).is_empty());
        assert_eq!(ds.train_y, orig);
    }

    #[test]
    fn subsample_class_keeps_exact_count() {
        let ds = base();
        let before = ds.train_class_counts();
        let sub = subsample_class(&ds, 0, 10, 5);
        let after = sub.train_class_counts();
        assert_eq!(after[0], 10);
        assert_eq!(after[1], before[1]);
        sub.validate();
    }

    #[test]
    fn duplicate_point_appends_jittered_copies() {
        let mut ds = base();
        let n0 = ds.n_train();
        duplicate_point(&mut ds, 3, 5, 1e-3, 7);
        assert_eq!(ds.n_train(), n0 + 5);
        let orig = ds.train_row(3).to_vec();
        for c in 0..5 {
            let row = ds.train_row(n0 + c);
            let dist: f32 = row
                .iter()
                .zip(&orig)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            assert!(dist < 1e-3, "copy {c} too far: {dist}");
            assert_eq!(ds.train_y[n0 + c], ds.train_y[3]);
        }
    }
}
