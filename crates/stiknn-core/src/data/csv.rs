//! Minimal CSV I/O: export interaction matrices / value vectors for
//! external plotting, and load labeled feature tables (numeric features,
//! last column = integer class label).

use crate::util::matrix::Matrix;
use std::io::{BufRead, Write};
use std::path::Path;

/// Write a matrix as CSV (no header).
pub fn write_matrix(path: &Path, m: &Matrix) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for i in 0..m.rows() {
        let row: Vec<String> = m.row(i).iter().map(|v| format!("{v:.9e}")).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Write (index, value) rows with a header.
pub fn write_values(path: &Path, header: &str, values: &[f64]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "index,{header}")?;
    for (i, v) in values.iter().enumerate() {
        writeln!(f, "{i},{v:.9e}")?;
    }
    Ok(())
}

/// Read a numeric CSV with the last column as integer label.
/// Returns (features row-major, labels, d).
///
/// Header handling: the FIRST line is a header iff its LAST field (the
/// label column) fails to parse as a number. Keying on the label column
/// rather than the first field means a genuine header whose first
/// column name is numeric (`1,x2,label`) is not mis-eaten as a data
/// row, while a data row with a typo in a feature field (`1.0,2.O,0`)
/// still fails loudly with its line number instead of being silently
/// swallowed as a "header". An all-numeric header (`1,2,3`) is
/// indistinguishable from data and must be removed by hand.
///
/// Labels are INTEGERS: `2` and `2.0` are accepted, `2.7` is rejected
/// as non-integral and values outside i32 range as out-of-range — a
/// `parse::<f32>() as i32` would silently truncate the former and
/// saturate the latter, corrupting every class-dependent value the
/// pipeline computes from the file. Features must be finite f32s (an
/// over-range `1e39` parses to ∞ and would poison every distance).
/// Every rejection carries the 1-based line number.
pub fn read_labeled(path: &Path) -> std::io::Result<(Vec<f32>, Vec<i32>, usize)> {
    let f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut xs: Vec<f32> = Vec::new();
    let mut ys: Vec<i32> = Vec::new();
    let mut d = 0usize;
    for (lineno, line) in f.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        let last = fields.last().expect("split yields at least one field");
        if lineno == 0 && last.trim().parse::<f64>().is_err() {
            continue; // header
        }
        if fields.len() < 2 {
            return Err(bad(lineno, "need at least one feature and a label"));
        }
        let row_d = fields.len() - 1;
        if d == 0 {
            d = row_d;
        } else if row_d != d {
            return Err(bad(
                lineno,
                &format!("inconsistent column count ({} vs {} before)", row_d + 1, d + 1),
            ));
        }
        for v in &fields[..row_d] {
            let x = v
                .trim()
                .parse::<f32>()
                .map_err(|e| bad(lineno, &format!("feature: {e}")))?;
            if !x.is_finite() {
                return Err(bad(
                    lineno,
                    &format!("feature '{}' is not a finite f32", v.trim()),
                ));
            }
            xs.push(x);
        }
        ys.push(parse_label(fields[row_d], lineno)?);
    }
    Ok((xs, ys, d))
}

/// One class label: an integer, possibly written as `2.0`, in i32 range.
fn parse_label(field: &str, lineno: usize) -> std::io::Result<i32> {
    let t = field.trim();
    if let Ok(v) = t.parse::<i32>() {
        return Ok(v);
    }
    match t.parse::<f64>() {
        Ok(f) if f.fract() == 0.0 && (i32::MIN as f64..=i32::MAX as f64).contains(&f) => {
            Ok(f as i32)
        }
        Ok(f) if f.is_finite() && f.fract() != 0.0 => Err(bad(
            lineno,
            &format!("label '{t}' is not an integer (class labels must be integral)"),
        )),
        Ok(_) => Err(bad(lineno, &format!("label '{t}' is out of i32 range"))),
        Err(e) => Err(bad(lineno, &format!("label: {e}"))),
    }
}

fn bad(lineno: usize, msg: &str) -> std::io::Error {
    std::io::Error::new(
        std::io::ErrorKind::InvalidData,
        format!("csv line {}: {msg}", lineno + 1),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("stiknn_csv_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn matrix_roundtrips_via_read_labeled_shape() {
        let p = tmp("m.csv");
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        write_matrix(&p, &m).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.starts_with("1.000000000e0,"));
    }

    #[test]
    fn values_file_has_header() {
        let p = tmp("v.csv");
        write_values(&p, "shapley", &[0.5, -0.25]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next(), Some("index,shapley"));
        assert_eq!(lines.count(), 2);
    }

    #[test]
    fn read_labeled_with_header_and_without() {
        let p = tmp("d.csv");
        std::fs::write(&p, "x1,x2,label\n1.0,2.0,0\n3.0,4.0,1\n").unwrap();
        let (xs, ys, d) = read_labeled(&p).unwrap();
        assert_eq!((xs, ys, d), (vec![1.0, 2.0, 3.0, 4.0], vec![0, 1], 2));

        std::fs::write(&p, "1.5,0\n2.5,1\n").unwrap();
        let (xs, ys, d) = read_labeled(&p).unwrap();
        assert_eq!((xs, ys, d), (vec![1.5, 2.5], vec![0, 1], 1));
    }

    #[test]
    fn read_labeled_rejects_ragged_rows() {
        let p = tmp("bad.csv");
        std::fs::write(&p, "1.0,2.0,0\n3.0,1\n").unwrap();
        let err = read_labeled(&p).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("column count"), "{err}");
    }

    #[test]
    fn header_with_numeric_first_field_is_not_eaten_as_data() {
        // `1,x2,label` is a header (its label column is not a number)
        // even though its first field parses — the old first-field-only
        // heuristic read it as a data row and failed on 'x2'.
        let p = tmp("numhdr.csv");
        std::fs::write(&p, "1,x2,label\n1.0,2.0,0\n3.0,4.0,1\n").unwrap();
        let (xs, ys, d) = read_labeled(&p).unwrap();
        assert_eq!((xs, ys, d), (vec![1.0, 2.0, 3.0, 4.0], vec![0, 1], 2));
    }

    #[test]
    fn corrupt_first_data_row_errors_instead_of_passing_as_header() {
        // a feature typo on line 1 of a headerless file must be a
        // line-numbered error, not a silently swallowed "header" (the
        // label column is numeric, so this cannot be a header)
        let p = tmp("typo1.csv");
        std::fs::write(&p, "1.0,2.O,0\n3.0,4.0,1\n").unwrap();
        let err = read_labeled(&p).unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        assert!(err.contains("feature"), "{err}");
    }

    #[test]
    fn non_integral_label_is_rejected_with_line_number() {
        let p = tmp("fraclabel.csv");
        std::fs::write(&p, "x,label\n1.0,0\n2.0,2.7\n").unwrap();
        let err = read_labeled(&p).unwrap_err().to_string();
        assert!(err.contains("line 3"), "{err}");
        assert!(err.contains("2.7"), "{err}");
        assert!(err.contains("not an integer"), "{err}");
    }

    #[test]
    fn out_of_range_label_is_rejected_not_saturated() {
        let p = tmp("hugelabel.csv");
        std::fs::write(&p, "1.0,3000000000\n").unwrap();
        let err = read_labeled(&p).unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        assert!(err.contains("out of i32 range"), "{err}");
    }

    #[test]
    fn float_written_integral_labels_are_accepted() {
        let p = tmp("floatint.csv");
        std::fs::write(&p, "1.0,0.0\n2.0,1.0\n").unwrap();
        let (_, ys, _) = read_labeled(&p).unwrap();
        assert_eq!(ys, vec![0, 1]);
    }

    #[test]
    fn non_finite_features_are_rejected() {
        let p = tmp("inffeat.csv");
        // 1e39 overflows f32 to ∞ on parse; nan parses "successfully" too
        for body in ["1e39,0\n", "nan,0\n"] {
            std::fs::write(&p, body).unwrap();
            let err = read_labeled(&p).unwrap_err().to_string();
            assert!(err.contains("line 1"), "{body}: {err}");
            assert!(err.contains("finite"), "{body}: {err}");
        }
    }
}
