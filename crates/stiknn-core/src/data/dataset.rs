//! The dataset container shared by every engine and the coordinator.

use anyhow::{ensure, Context, Result};
use std::path::Path;

/// A labeled dataset split into train and test parts. Features are
/// row-major f32 (the dtype of the XLA artifacts); labels are i32 class
/// ids 0..classes.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub d: usize,
    pub classes: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<i32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
}

impl Dataset {
    /// Build a dataset from a labeled CSV file
    /// ([`crate::data::csv::read_labeled`]: numeric features, last
    /// column an integer class label, optional header). The LAST
    /// `n_test` rows become the test split (0 = one fifth, at least 1);
    /// `n_train` rows immediately before it train (0 = everything
    /// else). Labels must be non-negative class ids; `classes` is
    /// max label + 1. All malformed-file failures carry the CSV line
    /// number from the reader.
    pub fn from_labeled_csv(path: &Path, n_train: usize, n_test: usize) -> Result<Dataset> {
        let (xs, ys, d) = crate::data::csv::read_labeled(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rows = ys.len();
        ensure!(
            rows >= 2 && d >= 1,
            "{}: need at least 2 data rows with at least 1 feature column",
            path.display()
        );
        let min_label = *ys.iter().min().expect("rows >= 2");
        ensure!(
            min_label >= 0,
            "{}: labels must be non-negative class ids (found {min_label})",
            path.display()
        );
        let max_label = *ys.iter().max().expect("rows >= 2");
        let classes = (max_label as usize + 1).max(2);
        let n_test = if n_test == 0 { (rows / 5).max(1) } else { n_test };
        ensure!(
            n_test < rows,
            "{}: test split ({n_test}) must leave training rows (file has {rows})",
            path.display()
        );
        let test_lo = rows - n_test;
        let n_train = if n_train == 0 { test_lo } else { n_train };
        ensure!(
            n_train <= test_lo,
            "{}: n_train + n_test = {} exceeds the {rows} data rows",
            path.display(),
            n_train + n_test
        );
        let train_lo = test_lo - n_train;
        let ds = Dataset {
            name: format!("csv:{}", path.display()),
            d,
            classes,
            train_x: xs[train_lo * d..test_lo * d].to_vec(),
            train_y: ys[train_lo..test_lo].to_vec(),
            test_x: xs[test_lo * d..].to_vec(),
            test_y: ys[test_lo..].to_vec(),
        };
        // Every validate() invariant is already guaranteed above (label
        // range, finite features via the reader, shapes by slicing), so
        // this cannot panic on user input — it guards this constructor.
        ds.validate();
        Ok(ds)
    }

    pub fn n_train(&self) -> usize {
        self.train_y.len()
    }

    pub fn n_test(&self) -> usize {
        self.test_y.len()
    }

    /// Panics if any internal invariant is broken (shape mismatches,
    /// out-of-range labels). Called by generators and loaders.
    pub fn validate(&self) {
        assert_eq!(
            self.train_x.len(),
            self.train_y.len() * self.d,
            "{}: train shape",
            self.name
        );
        assert_eq!(
            self.test_x.len(),
            self.test_y.len() * self.d,
            "{}: test shape",
            self.name
        );
        assert!(self.classes >= 2, "{}: needs >= 2 classes", self.name);
        for &y in self.train_y.iter().chain(&self.test_y) {
            assert!(
                (0..self.classes as i32).contains(&y),
                "{}: label {y} out of range",
                self.name
            );
        }
        assert!(
            self.train_x.iter().chain(&self.test_x).all(|v| v.is_finite()),
            "{}: non-finite feature",
            self.name
        );
    }

    /// The i-th training feature row.
    pub fn train_row(&self, i: usize) -> &[f32] {
        &self.train_x[i * self.d..(i + 1) * self.d]
    }

    /// The p-th test feature row.
    pub fn test_row(&self, p: usize) -> &[f32] {
        &self.test_x[p * self.d..(p + 1) * self.d]
    }

    /// Per-class counts over the training labels.
    pub fn train_class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.classes];
        for &y in &self.train_y {
            counts[y as usize] += 1;
        }
        counts
    }

    /// A copy restricted to `test_range` of the test set (coordinator
    /// sharding helper; train part is shared by clone).
    pub fn test_slice(&self, lo: usize, hi: usize) -> (&[f32], &[i32]) {
        (&self.test_x[lo * self.d..hi * self.d], &self.test_y[lo..hi])
    }

    /// Keep only the selected training indices (used by the
    /// summarization/removal experiments). Preserves order.
    pub fn retain_train(&self, keep: &[usize]) -> Dataset {
        let mut out = self.clone();
        out.train_x = Vec::with_capacity(keep.len() * self.d);
        out.train_y = Vec::with_capacity(keep.len());
        for &i in keep {
            out.train_x.extend_from_slice(self.train_row(i));
            out.train_y.push(self.train_y[i]);
        }
        out.name = format!("{}[{} kept]", self.name, keep.len());
        out
    }

    /// Paper's matrix ordering (§4): indices sorted by class, then by
    /// feature 0, then feature 1... Returns the permutation to apply to
    /// train indices before rendering interaction heatmaps.
    pub fn paper_display_order(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.n_train()).collect();
        idx.sort_by(|&a, &b| {
            self.train_y[a].cmp(&self.train_y[b]).then_with(|| {
                let ra = self.train_row(a);
                let rb = self.train_row(b);
                for (x, y) in ra.iter().zip(rb) {
                    // total_cmp (repo convention, clippy.toml): a NaN
                    // feature sorts deterministically instead of
                    // silently comparing "equal" as partial_cmp's None
                    // arm used to.
                    match x.total_cmp(y) {
                        std::cmp::Ordering::Equal => continue,
                        o => return o,
                    }
                }
                std::cmp::Ordering::Equal
            })
        });
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            name: "tiny".into(),
            d: 2,
            classes: 2,
            train_x: vec![0.0, 0.0, 1.0, 0.0, 0.5, 1.0],
            train_y: vec![0, 1, 0],
            test_x: vec![0.1, 0.1],
            test_y: vec![0],
        }
    }

    #[test]
    fn validate_accepts_consistent() {
        tiny().validate();
    }

    #[test]
    #[should_panic(expected = "label")]
    fn validate_rejects_bad_label() {
        let mut ds = tiny();
        ds.train_y[0] = 7;
        ds.validate();
    }

    #[test]
    fn rows_and_counts() {
        let ds = tiny();
        assert_eq!(ds.train_row(1), &[1.0, 0.0]);
        assert_eq!(ds.test_row(0), &[0.1, 0.1]);
        assert_eq!(ds.train_class_counts(), vec![2, 1]);
    }

    #[test]
    fn from_labeled_csv_splits_tail_as_test() {
        let p = std::env::temp_dir().join(format!(
            "stiknn_dataset_csv_{}.csv",
            std::process::id()
        ));
        let mut body = String::from("x1,x2,label\n");
        for i in 0..10 {
            body.push_str(&format!("{}.0,{}.5,{}\n", i, i, i % 2));
        }
        std::fs::write(&p, body).unwrap();
        // explicit split
        let ds = Dataset::from_labeled_csv(&p, 6, 3).unwrap();
        assert_eq!((ds.n_train(), ds.n_test(), ds.d, ds.classes), (6, 3, 2, 2));
        // the tail rows are the test split
        assert_eq!(ds.test_y, vec![1, 0, 1]);
        ds.validate();
        // default split: 1/5 test, rest train
        let ds = Dataset::from_labeled_csv(&p, 0, 0).unwrap();
        assert_eq!((ds.n_train(), ds.n_test()), (8, 2));
        // oversized splits are clean errors
        let err = Dataset::from_labeled_csv(&p, 9, 3).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
        let err = Dataset::from_labeled_csv(&p, 0, 10).unwrap_err().to_string();
        assert!(err.contains("leave training rows"), "{err}");
        // negative labels cannot be class ids
        std::fs::write(&p, "1.0,-1\n2.0,0\n").unwrap();
        let err = Dataset::from_labeled_csv(&p, 0, 0).unwrap_err().to_string();
        assert!(err.contains("non-negative"), "{err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn retain_train_keeps_selection_in_order() {
        let ds = tiny();
        let sub = ds.retain_train(&[2, 0]);
        assert_eq!(sub.train_y, vec![0, 0]);
        assert_eq!(sub.train_row(0), &[0.5, 1.0]);
        sub.validate();
    }

    #[test]
    fn paper_display_order_sorts_class_then_features() {
        let ds = Dataset {
            name: "o".into(),
            d: 1,
            classes: 2,
            train_x: vec![5.0, 1.0, 3.0, 2.0],
            train_y: vec![1, 0, 0, 1],
            test_x: vec![],
            test_y: vec![],
        };
        // class 0: indices 1 (x=1), 2 (x=3); class 1: 3 (x=2), 0 (x=5)
        assert_eq!(ds.paper_display_order(), vec![1, 2, 3, 0]);
    }

    #[test]
    fn test_slice_views() {
        let ds = Dataset {
            name: "s".into(),
            d: 2,
            classes: 2,
            train_x: vec![0.0; 4],
            train_y: vec![0, 1],
            test_x: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            test_y: vec![0, 1, 0],
        };
        let (x, y) = ds.test_slice(1, 3);
        assert_eq!(x, &[3.0, 4.0, 5.0, 6.0]);
        assert_eq!(y, &[1, 0]);
    }
}
