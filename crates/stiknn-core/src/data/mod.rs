//! Dataset substrate: containers, synthetic generators (including twins of
//! every dataset in the paper's Table 1 — see DESIGN.md §5 for the
//! substitution rationale), splits, corruption (mislabeling/redundancy for
//! Figs. 4–5), and CSV I/O.

pub mod corrupt;
pub mod csv;
pub mod dataset;
pub mod registry;
pub mod split;
pub mod synth;

pub use dataset::Dataset;
pub use registry::{load_dataset, registry_names, DatasetSpec};

/// Load a registry dataset by name, or — with the `csv:PATH` scheme — a
/// labeled CSV file ([`Dataset::from_labeled_csv`]). This is what the
/// CLI routes `--dataset` through, so every subcommand accepts user
/// data files; malformed CSVs fail with the offending line number
/// instead of a panic. `seed` only applies to registry generators.
pub fn load_dataset_any(
    name: &str,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> anyhow::Result<Dataset> {
    if let Some(path) = name.strip_prefix("csv:") {
        return Dataset::from_labeled_csv(std::path::Path::new(path), n_train, n_test);
    }
    load_dataset(name, n_train, n_test, seed).ok_or_else(|| {
        anyhow::anyhow!("unknown dataset '{name}' — try `stiknn datasets`, or csv:PATH")
    })
}
