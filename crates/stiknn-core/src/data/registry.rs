//! The evaluation-dataset registry: deterministic synthetic *twins* of
//! every dataset in the paper's Table 1.
//!
//! The image has no network access, so OpenML downloads are replaced by
//! generators that match each dataset's published schema (features,
//! classes, class balance) and a plausible cluster structure — STI-KNN
//! consumes only (distance ranks, labels), so any dataset with comparable
//! geometry exercises the identical code path (DESIGN.md §5). Circle and
//! Moon are generated from the same parametric families scikit-learn uses
//! (the paper's own source for them). FashionMNIST is represented by
//! 32-dim "feature extractor output" clusters, matching the paper's
//! pretrained-extractor setup.

use super::dataset::Dataset;
use super::synth;

/// Twin specification: the real dataset's schema plus generator knobs.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// OpenML id or citation in the paper's Table 1 ("-" for sklearn).
    pub source: &'static str,
    pub d: usize,
    pub classes: usize,
    /// Default train size used by the experiments.
    pub n_train: usize,
    pub n_test: usize,
    /// Class weights (imbalance), cluster count, separation, noise, flip.
    pub class_weights: &'static [f64],
    pub clusters_per_class: usize,
    pub sep: f64,
    pub noise: f64,
    pub flip: f64,
}

/// All 16 Table-1 datasets.
pub const REGISTRY: &[DatasetSpec] = &[
    DatasetSpec { name: "apsfailure",  source: "openml.org/d/41138", d: 20, classes: 2, n_train: 600, n_test: 150, class_weights: &[0.98, 0.02], clusters_per_class: 2, sep: 4.0, noise: 1.0, flip: 0.02 },
    DatasetSpec { name: "cpu",          source: "openml.org/d/761",  d: 8,  classes: 2, n_train: 600, n_test: 150, class_weights: &[0.5, 0.5],   clusters_per_class: 1, sep: 3.0, noise: 1.0, flip: 0.05 },
    DatasetSpec { name: "circle",       source: "sklearn make_circles", d: 2, classes: 2, n_train: 600, n_test: 150, class_weights: &[0.5, 0.5], clusters_per_class: 1, sep: 0.0, noise: 0.05, flip: 0.0 },
    DatasetSpec { name: "click",        source: "openml.org/d/1218", d: 9,  classes: 2, n_train: 600, n_test: 150, class_weights: &[0.83, 0.17], clusters_per_class: 3, sep: 2.0, noise: 1.0, flip: 0.15 },
    DatasetSpec { name: "creditcard",   source: "openml.org/d/31",   d: 20, classes: 2, n_train: 600, n_test: 150, class_weights: &[0.7, 0.3],  clusters_per_class: 2, sep: 2.5, noise: 1.0, flip: 0.1 },
    DatasetSpec { name: "fashionmnist", source: "Xiao et al. 2017 (extractor features)", d: 32, classes: 10, n_train: 600, n_test: 150, class_weights: &[0.1; 10], clusters_per_class: 1, sep: 6.0, noise: 1.0, flip: 0.02 },
    DatasetSpec { name: "flower",       source: "openml.org/d/43839", d: 16, classes: 5, n_train: 600, n_test: 150, class_weights: &[0.2; 5], clusters_per_class: 1, sep: 5.0, noise: 1.0, flip: 0.03 },
    DatasetSpec { name: "monksv2",      source: "openml.org/d/334",  d: 6,  classes: 2, n_train: 400, n_test: 100, class_weights: &[0.66, 0.34], clusters_per_class: 4, sep: 2.0, noise: 0.8, flip: 0.1 },
    DatasetSpec { name: "moon",         source: "sklearn make_moons", d: 2, classes: 2, n_train: 600, n_test: 150, class_weights: &[0.5, 0.5], clusters_per_class: 1, sep: 0.0, noise: 0.08, flip: 0.0 },
    DatasetSpec { name: "phoneme",      source: "openml.org/d/1489", d: 5,  classes: 2, n_train: 600, n_test: 150, class_weights: &[0.71, 0.29], clusters_per_class: 2, sep: 2.5, noise: 1.0, flip: 0.08 },
    DatasetSpec { name: "planes2d",     source: "openml.org/d/727",  d: 10, classes: 2, n_train: 600, n_test: 150, class_weights: &[0.5, 0.5], clusters_per_class: 1, sep: 2.0, noise: 1.0, flip: 0.1 },
    DatasetSpec { name: "pol",          source: "openml.org/d/722",  d: 26, classes: 2, n_train: 600, n_test: 150, class_weights: &[0.5, 0.5], clusters_per_class: 2, sep: 3.5, noise: 1.0, flip: 0.05 },
    DatasetSpec { name: "steelplates",  source: "openml.org/d/40982", d: 27, classes: 7, n_train: 600, n_test: 150, class_weights: &[0.35, 0.1, 0.2, 0.04, 0.03, 0.2, 0.08], clusters_per_class: 1, sep: 4.5, noise: 1.0, flip: 0.05 },
    DatasetSpec { name: "tictactoe",    source: "openml.org/d/50",   d: 9,  classes: 2, n_train: 600, n_test: 150, class_weights: &[0.65, 0.35], clusters_per_class: 4, sep: 2.0, noise: 0.8, flip: 0.05 },
    DatasetSpec { name: "transfusion",  source: "openml.org/d/1464", d: 4,  classes: 2, n_train: 500, n_test: 125, class_weights: &[0.76, 0.24], clusters_per_class: 1, sep: 2.0, noise: 1.0, flip: 0.12 },
    DatasetSpec { name: "wind",         source: "openml.org/d/847",  d: 14, classes: 2, n_train: 600, n_test: 150, class_weights: &[0.53, 0.47], clusters_per_class: 1, sep: 2.5, noise: 1.0, flip: 0.08 },
];

/// Names of all registered datasets (Table-1 order).
pub fn registry_names() -> Vec<&'static str> {
    REGISTRY.iter().map(|s| s.name).collect()
}

pub fn spec(name: &str) -> Option<&'static DatasetSpec> {
    REGISTRY.iter().find(|s| s.name == name)
}

/// Instantiate a registered dataset (deterministic per (name, seed)).
/// `n_train`/`n_test` of 0 use the spec defaults.
pub fn load_dataset(name: &str, n_train: usize, n_test: usize, seed: u64) -> Option<Dataset> {
    let s = spec(name)?;
    let n_train = if n_train == 0 { s.n_train } else { n_train };
    let n_test = if n_test == 0 { s.n_test } else { n_test };
    let ds = match s.name {
        "circle" => {
            let total = n_train + n_test;
            let pts = synth::circle(total.div_ceil(2), s.noise, 0.5, seed);
            synth::dataset_from_points("circle", pts, n_test, 2, seed)
        }
        "moon" => {
            let total = n_train + n_test;
            let pts = synth::moon(total.div_ceil(2), s.noise, seed);
            synth::dataset_from_points("moon", pts, n_test, 2, seed)
        }
        _ => {
            let (xs, ys) = synth::gaussian_classes(
                n_train + n_test,
                s.d,
                s.classes,
                s.clusters_per_class,
                s.sep,
                s.noise,
                s.flip,
                s.class_weights,
                seed,
            );
            let mut ds = Dataset {
                name: s.name.to_string(),
                d: s.d,
                classes: s.classes,
                train_x: xs[n_test * s.d..].to_vec(),
                train_y: ys[n_test..].to_vec(),
                test_x: xs[..n_test * s.d].to_vec(),
                test_y: ys[..n_test].to_vec(),
            };
            // Guarantee every class appears in train (tiny-split edge case).
            for c in 0..s.classes as i32 {
                if !ds.train_y.contains(&c) {
                    ds.train_y[0] = c;
                }
            }
            ds.validate();
            ds
        }
    };
    Some(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_all_16_table1_datasets() {
        assert_eq!(REGISTRY.len(), 16);
        for name in [
            "apsfailure", "cpu", "circle", "click", "creditcard", "fashionmnist",
            "flower", "monksv2", "moon", "phoneme", "planes2d", "pol",
            "steelplates", "tictactoe", "transfusion", "wind",
        ] {
            assert!(spec(name).is_some(), "{name} missing");
        }
    }

    #[test]
    fn load_all_datasets_validates() {
        for s in REGISTRY {
            let ds = load_dataset(s.name, 120, 30, 7).unwrap();
            ds.validate();
            assert_eq!(ds.d, s.d, "{}", s.name);
            assert_eq!(ds.classes, s.classes, "{}", s.name);
            assert_eq!(ds.n_test(), 30);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = load_dataset("click", 100, 20, 3).unwrap();
        let b = load_dataset("click", 100, 20, 3).unwrap();
        let c = load_dataset("click", 100, 20, 4).unwrap();
        assert_eq!(a.train_x, b.train_x);
        assert_ne!(a.train_x, c.train_x);
    }

    #[test]
    fn imbalanced_specs_produce_imbalance() {
        let ds = load_dataset("apsfailure", 500, 100, 11).unwrap();
        let counts = ds.train_class_counts();
        assert!(
            counts[0] > counts[1] * 5,
            "apsfailure should be heavily imbalanced: {counts:?}"
        );
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(load_dataset("nope", 10, 5, 1).is_none());
    }

    #[test]
    fn default_sizes_from_spec() {
        let ds = load_dataset("transfusion", 0, 0, 1).unwrap();
        assert_eq!(ds.n_train(), 500);
        assert_eq!(ds.n_test(), 125);
    }
}
