//! Train/test split utilities, incl. the paper's 80/20 recommendation
//! (§3.2 cites Gholamy et al. for it when discussing the effect of t).

use super::dataset::Dataset;
use crate::util::rng::Rng;

/// Re-split a dataset's pooled points into a new (train, test) partition
/// with the given test fraction, shuffled deterministically.
pub fn resplit(ds: &Dataset, test_fraction: f64, seed: u64) -> Dataset {
    assert!((0.0..1.0).contains(&test_fraction) && test_fraction > 0.0);
    let d = ds.d;
    let total = ds.n_train() + ds.n_test();
    let mut xs: Vec<f32> = Vec::with_capacity(total * d);
    let mut ys: Vec<i32> = Vec::with_capacity(total);
    xs.extend_from_slice(&ds.train_x);
    xs.extend_from_slice(&ds.test_x);
    ys.extend_from_slice(&ds.train_y);
    ys.extend_from_slice(&ds.test_y);

    let mut rng = Rng::new(seed);
    let idx = rng.permutation(total);
    let n_test = ((total as f64 * test_fraction).round() as usize).clamp(1, total - 1);
    let mut out = Dataset {
        name: format!("{}[{}% test]", ds.name, (test_fraction * 100.0) as u32),
        d,
        classes: ds.classes,
        train_x: Vec::with_capacity((total - n_test) * d),
        train_y: Vec::with_capacity(total - n_test),
        test_x: Vec::with_capacity(n_test * d),
        test_y: Vec::with_capacity(n_test),
    };
    for (pos, &i) in idx.iter().enumerate() {
        let row = &xs[i * d..(i + 1) * d];
        if pos < n_test {
            out.test_x.extend_from_slice(row);
            out.test_y.push(ys[i]);
        } else {
            out.train_x.extend_from_slice(row);
            out.train_y.push(ys[i]);
        }
    }
    out.validate();
    out
}

/// Stratified K-fold indices over `labels`: each fold has (approximately)
/// the full class distribution. Returns `folds` vectors of indices.
pub fn stratified_folds(labels: &[i32], folds: usize, seed: u64) -> Vec<Vec<usize>> {
    assert!(folds >= 2);
    let mut rng = Rng::new(seed);
    let mut by_class: std::collections::BTreeMap<i32, Vec<usize>> = Default::default();
    for (i, &y) in labels.iter().enumerate() {
        by_class.entry(y).or_default().push(i);
    }
    let mut out = vec![Vec::new(); folds];
    for (_, mut idx) in by_class {
        rng.shuffle(&mut idx);
        for (pos, i) in idx.into_iter().enumerate() {
            out[pos % folds].push(i);
        }
    }
    for fold in &mut out {
        fold.sort_unstable();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn resplit_8020_sizes() {
        let ds = synth::dataset_from_points("c", synth::circle(100, 0.05, 0.5, 1), 40, 2, 1);
        let re = resplit(&ds, 0.2, 5);
        assert_eq!(re.n_test(), 40); // 20% of 200
        assert_eq!(re.n_train(), 160);
        re.validate();
    }

    #[test]
    fn resplit_preserves_point_multiset() {
        let ds = synth::dataset_from_points("c", synth::circle(30, 0.05, 0.5, 2), 10, 2, 2);
        let re = resplit(&ds, 0.5, 9);
        let mut a: Vec<i32> = ds.train_y.iter().chain(&ds.test_y).copied().collect();
        let mut b: Vec<i32> = re.train_y.iter().chain(&re.test_y).copied().collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn stratified_folds_cover_everything_once() {
        let labels = vec![0, 0, 0, 0, 1, 1, 1, 1, 1, 1];
        let folds = stratified_folds(&labels, 3, 7);
        let mut all: Vec<usize> = folds.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        // each fold has both classes
        for f in &folds {
            assert!(f.iter().any(|&i| labels[i] == 0));
            assert!(f.iter().any(|&i| labels[i] == 1));
        }
    }
}
