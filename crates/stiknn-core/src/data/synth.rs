//! Synthetic dataset generators.
//!
//! `circle` and `moon` reproduce scikit-learn's `make_circles` /
//! `make_moons` parametric forms (the paper's §4 and Appendix B datasets);
//! `blobs`, `xor` and `spiral` provide additional geometry; and
//! `tabular_twin` generates class-clustered tabular data with a given
//! schema — the substitution substrate for the paper's OpenML datasets
//! (DESIGN.md §5).

use super::dataset::Dataset;
use crate::util::rng::Rng;
use std::f64::consts::PI;

/// Two concentric circles (binary). `factor` is the inner/outer radius
/// ratio, `noise` the gaussian feature noise — scikit-learn defaults
/// mirrored (factor 0.5, noise 0.08 in the paper's plots' visual range).
pub fn circle(n_per_class: usize, noise: f64, factor: f64, seed: u64) -> Vec<(f32, f32, i32)> {
    assert!((0.0..1.0).contains(&factor));
    let mut rng = Rng::new(seed);
    let mut pts = Vec::with_capacity(2 * n_per_class);
    for i in 0..n_per_class {
        let theta = 2.0 * PI * i as f64 / n_per_class as f64;
        // outer circle = class 0
        pts.push((
            (theta.cos() + noise * rng.normal()) as f32,
            (theta.sin() + noise * rng.normal()) as f32,
            0,
        ));
        // inner circle = class 1
        pts.push((
            (factor * theta.cos() + noise * rng.normal()) as f32,
            (factor * theta.sin() + noise * rng.normal()) as f32,
            1,
        ));
    }
    pts
}

/// Two interleaving half-moons (binary), scikit-learn `make_moons` form.
pub fn moon(n_per_class: usize, noise: f64, seed: u64) -> Vec<(f32, f32, i32)> {
    let mut rng = Rng::new(seed);
    let mut pts = Vec::with_capacity(2 * n_per_class);
    for i in 0..n_per_class {
        let t = PI * i as f64 / (n_per_class.max(2) - 1) as f64;
        pts.push((
            (t.cos() + noise * rng.normal()) as f32,
            (t.sin() + noise * rng.normal()) as f32,
            0,
        ));
        pts.push((
            (1.0 - t.cos() + noise * rng.normal()) as f32,
            (0.5 - t.sin() + noise * rng.normal()) as f32,
            1,
        ));
    }
    pts
}

/// Two-armed XOR checkerboard (binary), 2-D.
pub fn xor(n_per_class: usize, seed: u64) -> Vec<(f32, f32, i32)> {
    let mut rng = Rng::new(seed);
    let mut pts = Vec::with_capacity(2 * n_per_class);
    for _ in 0..n_per_class {
        // class 0: quadrants (+,+) and (−,−); class 1: the others
        let (sx, sy) = if rng.bool(0.5) { (1.0, 1.0) } else { (-1.0, -1.0) };
        pts.push((
            (sx * (0.3 + rng.f64())) as f32,
            (sy * (0.3 + rng.f64())) as f32,
            0,
        ));
        let (sx, sy) = if rng.bool(0.5) { (1.0, -1.0) } else { (-1.0, 1.0) };
        pts.push((
            (sx * (0.3 + rng.f64())) as f32,
            (sy * (0.3 + rng.f64())) as f32,
            1,
        ));
    }
    pts
}

/// Two interleaved spirals (binary), 2-D.
pub fn spiral(n_per_class: usize, noise: f64, seed: u64) -> Vec<(f32, f32, i32)> {
    let mut rng = Rng::new(seed);
    let mut pts = Vec::with_capacity(2 * n_per_class);
    for i in 0..n_per_class {
        let r = i as f64 / n_per_class as f64 * 3.0;
        let t = 1.75 * r * 2.0 * PI / 3.0;
        for (cls, phase) in [(0i32, 0.0f64), (1, PI)] {
            pts.push((
                (r * (t + phase).cos() + noise * rng.normal()) as f32,
                (r * (t + phase).sin() + noise * rng.normal()) as f32,
                cls,
            ));
        }
    }
    pts
}

/// Gaussian class clusters in `d` dimensions — the tabular/embedding twin
/// generator (DESIGN.md §5). Each class gets `clusters_per_class` centers
/// drawn on a sphere of radius `sep`; points are normal around a random
/// center. `flip` fraction of labels is randomized to set the Bayes floor
/// (real tabular sets are not separable; Click/CreditCard etc. have
/// substantial class overlap).
#[allow(clippy::too_many_arguments)]
pub fn gaussian_classes(
    n: usize,
    d: usize,
    classes: usize,
    clusters_per_class: usize,
    sep: f64,
    noise: f64,
    flip: f64,
    class_weights: &[f64],
    seed: u64,
) -> (Vec<f32>, Vec<i32>) {
    assert!(classes >= 2 && d >= 1 && clusters_per_class >= 1);
    assert_eq!(class_weights.len(), classes);
    let mut rng = Rng::new(seed);
    // class centers
    let mut centers = Vec::with_capacity(classes * clusters_per_class);
    for _ in 0..classes * clusters_per_class {
        let mut c: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let norm = c.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-9);
        for v in &mut c {
            *v *= sep / norm;
        }
        centers.push(c);
    }
    // cumulative weights for class sampling
    let total: f64 = class_weights.iter().sum();
    let mut cum = Vec::with_capacity(classes);
    let mut acc = 0.0;
    for w in class_weights {
        acc += w / total;
        cum.push(acc);
    }
    let mut xs = Vec::with_capacity(n * d);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let r = rng.f64();
        let mut cls = cum.iter().position(|&c| r < c).unwrap_or(classes - 1);
        let center = &centers[cls * clusters_per_class + rng.below(clusters_per_class)];
        for v in center {
            xs.push((v + noise * rng.normal()) as f32);
        }
        if flip > 0.0 && rng.bool(flip) {
            cls = rng.below(classes);
        }
        ys.push(cls as i32);
    }
    (xs, ys)
}

/// Assemble a [`Dataset`] from 2-D labeled points with a deterministic
/// shuffled train/test split.
pub fn dataset_from_points(
    name: &str,
    pts: Vec<(f32, f32, i32)>,
    n_test: usize,
    classes: usize,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xD15E_A5E5);
    let mut idx: Vec<usize> = (0..pts.len()).collect();
    rng.shuffle(&mut idx);
    assert!(n_test < pts.len(), "test split larger than dataset");
    let (test_idx, train_idx) = idx.split_at(n_test);
    let mut ds = Dataset {
        name: name.to_string(),
        d: 2,
        classes,
        train_x: Vec::with_capacity(train_idx.len() * 2),
        train_y: Vec::with_capacity(train_idx.len()),
        test_x: Vec::with_capacity(n_test * 2),
        test_y: Vec::with_capacity(n_test),
    };
    for &i in train_idx {
        ds.train_x.extend_from_slice(&[pts[i].0, pts[i].1]);
        ds.train_y.push(pts[i].2);
    }
    for &i in test_idx {
        ds.test_x.extend_from_slice(&[pts[i].0, pts[i].1]);
        ds.test_y.push(pts[i].2);
    }
    ds.validate();
    ds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knn::KnnClassifier;

    #[test]
    fn circle_radii_separate_classes() {
        let pts = circle(100, 0.02, 0.5, 1);
        for (x, y, c) in &pts {
            let r = ((x * x + y * y) as f64).sqrt();
            if *c == 0 {
                assert!(r > 0.8, "outer point at r={r}");
            } else {
                assert!(r < 0.7, "inner point at r={r}");
            }
        }
    }

    #[test]
    fn circle_is_deterministic_per_seed() {
        assert_eq!(circle(10, 0.1, 0.5, 7), circle(10, 0.1, 0.5, 7));
        assert_ne!(circle(10, 0.1, 0.5, 7), circle(10, 0.1, 0.5, 8));
    }

    #[test]
    fn moon_classes_balanced() {
        let pts = moon(50, 0.05, 3);
        let c1 = pts.iter().filter(|p| p.2 == 1).count();
        assert_eq!(c1, 50);
        assert_eq!(pts.len(), 100);
    }

    #[test]
    fn knn_separates_low_noise_circle() {
        let ds = dataset_from_points("circle", circle(120, 0.05, 0.5, 5), 40, 2, 5);
        let knn = KnnClassifier::new(&ds.train_x, &ds.train_y, 2, 5);
        let acc = knn.accuracy(&ds.test_x, &ds.test_y);
        assert!(acc > 0.95, "accuracy {acc}");
    }

    #[test]
    fn knn_separates_moons_and_spiral() {
        for (name, pts) in [
            ("moon", moon(120, 0.05, 9)),
            ("spiral", spiral(150, 0.02, 9)),
        ] {
            let ds = dataset_from_points(name, pts, 50, 2, 9);
            let knn = KnnClassifier::new(&ds.train_x, &ds.train_y, 2, 5);
            let acc = knn.accuracy(&ds.test_x, &ds.test_y);
            assert!(acc > 0.9, "{name} accuracy {acc}");
        }
    }

    #[test]
    fn xor_requires_nonlinear_boundary_but_knn_handles_it() {
        let ds = dataset_from_points("xor", xor(150, 11), 60, 2, 11);
        let knn = KnnClassifier::new(&ds.train_x, &ds.train_y, 2, 5);
        assert!(knn.accuracy(&ds.test_x, &ds.test_y) > 0.9);
    }

    #[test]
    fn gaussian_classes_respect_weights_and_shapes() {
        let (xs, ys) = gaussian_classes(1000, 8, 3, 2, 4.0, 1.0, 0.0, &[0.6, 0.3, 0.1], 13);
        assert_eq!(xs.len(), 8000);
        assert_eq!(ys.len(), 1000);
        let c0 = ys.iter().filter(|&&y| y == 0).count();
        let c2 = ys.iter().filter(|&&y| y == 2).count();
        assert!(c0 > 500 && c0 < 700, "class 0 count {c0}");
        assert!(c2 < 180, "class 2 count {c2}");
    }

    #[test]
    fn label_flips_lower_separability() {
        let mk = |flip: f64| {
            let (xs, ys) = gaussian_classes(400, 4, 2, 1, 5.0, 1.0, flip, &[0.5, 0.5], 21);
            let (tx, ty) = (xs[..600].to_vec(), ys[..150].to_vec());
            let (sx, sy) = (xs[600..800].to_vec(), ys[150..200].to_vec());
            KnnClassifier::new(&tx, &ty, 4, 5).accuracy(&sx, &sy)
        };
        assert!(mk(0.0) > mk(0.4) + 0.1, "flipping should cost accuracy");
    }

    #[test]
    fn dataset_split_sizes() {
        let ds = dataset_from_points("c", circle(50, 0.1, 0.5, 1), 30, 2, 1);
        assert_eq!(ds.n_test(), 30);
        assert_eq!(ds.n_train(), 70);
    }
}
