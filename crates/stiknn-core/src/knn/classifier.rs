//! The KNN classifier itself: majority-vote prediction and test metrics.
//!
//! Used by (a) the efficiency-axiom checks (a_test in §3.2), (b) the data
//! summarization example (accuracy after pruning), and (c) the mislabel
//! experiments.

use super::distance::{argsort_by_distance, distances, Metric};

/// A K-nearest-neighbor classifier over borrowed training data.
pub struct KnnClassifier<'a> {
    train_x: &'a [f32],
    train_y: &'a [i32],
    d: usize,
    k: usize,
    metric: Metric,
}

impl<'a> KnnClassifier<'a> {
    pub fn new(train_x: &'a [f32], train_y: &'a [i32], d: usize, k: usize) -> Self {
        assert_eq!(train_x.len(), train_y.len() * d, "train shape mismatch");
        assert!(k >= 1, "k must be >= 1");
        KnnClassifier {
            train_x,
            train_y,
            d,
            k,
            metric: Metric::SqEuclidean,
        }
    }

    pub fn with_metric(mut self, metric: Metric) -> Self {
        self.metric = metric;
        self
    }

    pub fn n_train(&self) -> usize {
        self.train_y.len()
    }

    /// Majority vote among the k nearest; ties break toward the smaller
    /// class id (deterministic).
    pub fn predict(&self, query: &[f32]) -> i32 {
        let dists = distances(query, self.train_x, self.d, self.metric);
        let order = argsort_by_distance(&dists);
        let take = order.len().min(self.k);
        let mut counts: std::collections::BTreeMap<i32, usize> = Default::default();
        for &idx in &order[..take] {
            *counts.entry(self.train_y[idx]).or_insert(0) += 1;
        }
        counts
            .into_iter()
            .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0)))
            .map(|(label, _)| label)
            .expect("empty training set")
    }

    /// Classification accuracy over a test set (t×d row-major).
    pub fn accuracy(&self, test_x: &[f32], test_y: &[i32]) -> f64 {
        assert_eq!(test_x.len(), test_y.len() * self.d);
        if test_y.is_empty() {
            return f64::NAN;
        }
        let hits = test_x
            .chunks_exact(self.d)
            .zip(test_y)
            .filter(|(q, &y)| self.predict(q) == y)
            .count();
        hits as f64 / test_y.len() as f64
    }

    /// The paper's likelihood test score (Eqs. 1–2): mean over test points
    /// of (#label-matching neighbors among the k nearest)/k. This is the
    /// a_test that the STI efficiency axiom constrains.
    pub fn likelihood(&self, test_x: &[f32], test_y: &[i32]) -> f64 {
        assert_eq!(test_x.len(), test_y.len() * self.d);
        if test_y.is_empty() {
            return f64::NAN;
        }
        let mut acc = 0.0;
        for (q, &y) in test_x.chunks_exact(self.d).zip(test_y) {
            let dists = distances(q, self.train_x, self.d, self.metric);
            let order = argsort_by_distance(&dists);
            let take = order.len().min(self.k);
            let hits = order[..take]
                .iter()
                .filter(|&&i| self.train_y[i] == y)
                .count();
            acc += hits as f64 / self.k as f64;
        }
        acc / test_y.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (Vec<f32>, Vec<i32>) {
        // two tight clusters: class 0 near origin, class 1 near (10, 10)
        let x = vec![
            0.0, 0.0, 0.5, 0.0, 0.0, 0.5, // class 0
            10.0, 10.0, 10.5, 10.0, 10.0, 10.5, // class 1
        ];
        let y = vec![0, 0, 0, 1, 1, 1];
        (x, y)
    }

    #[test]
    fn predicts_nearest_cluster() {
        let (x, y) = toy();
        let knn = KnnClassifier::new(&x, &y, 2, 3);
        assert_eq!(knn.predict(&[0.1, 0.1]), 0);
        assert_eq!(knn.predict(&[9.9, 10.1]), 1);
    }

    #[test]
    fn perfect_accuracy_on_separated_clusters() {
        let (x, y) = toy();
        let knn = KnnClassifier::new(&x, &y, 2, 3);
        let test_x = vec![0.2, 0.2, 10.2, 10.2];
        let test_y = vec![0, 1];
        assert_eq!(knn.accuracy(&test_x, &test_y), 1.0);
        assert_eq!(knn.likelihood(&test_x, &test_y), 1.0);
    }

    #[test]
    fn likelihood_counts_fractional_votes() {
        // train: 2 points of class 0, 1 of class 1, all equidistant-ish
        let x = vec![1.0, 0.0, -1.0, 0.0, 0.0, 1.0];
        let y = vec![0, 0, 1];
        let knn = KnnClassifier::new(&x, &y, 2, 3);
        // test at origin with label 0: 2 of 3 neighbors match -> 2/3
        assert!((knn.likelihood(&[0.0, 0.0], &[0]) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn k_larger_than_train_set_is_clamped_in_voting() {
        let x = vec![0.0, 0.0, 1.0, 1.0];
        let y = vec![0, 1];
        let knn = KnnClassifier::new(&x, &y, 2, 5);
        // votes: one 0, one 1 -> tie breaks to smaller class id
        assert_eq!(knn.predict(&[0.4, 0.4]), 0);
        // likelihood: 1 matching of k=5 -> 1/5
        assert!((knn.likelihood(&[0.0, 0.0], &[0]) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn tie_break_is_deterministic() {
        let x = vec![0.0, 0.0, 2.0, 0.0];
        let y = vec![1, 0];
        let knn = KnnClassifier::new(&x, &y, 2, 2);
        // equidistant from (1, 0): counts equal; smaller class id wins
        assert_eq!(knn.predict(&[1.0, 0.0]), 0);
    }

    #[test]
    #[should_panic(expected = "train shape mismatch")]
    fn shape_validation() {
        let x = vec![0.0f32; 5];
        let y = vec![0, 1];
        KnnClassifier::new(&x, &y, 2, 1);
    }
}
