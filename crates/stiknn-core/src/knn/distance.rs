//! Distance metrics and neighbor ordering.
//!
//! The paper's pipeline only ever consumes the *ranking* of train points
//! by distance to a test point (KNN is rank-based), so everything
//! downstream is metric-agnostic; squared euclidean is the default and
//! matches the L1 Pallas kernel.

/// Supported distance metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Squared euclidean (monotone in euclidean — identical ranking).
    SqEuclidean,
    /// L1 / cityblock.
    Manhattan,
    /// 1 − cosine similarity (undefined for zero vectors; returns 1).
    Cosine,
}

impl Metric {
    pub fn parse(s: &str) -> Option<Metric> {
        match s {
            "euclidean" | "sqeuclidean" | "l2" => Some(Metric::SqEuclidean),
            "manhattan" | "l1" => Some(Metric::Manhattan),
            "cosine" => Some(Metric::Cosine),
            _ => None,
        }
    }

    /// Distance between two feature slices of equal length.
    #[inline]
    pub fn dist(&self, a: &[f32], b: &[f32]) -> f64 {
        match self {
            Metric::SqEuclidean => {
                let mut acc = 0.0f64;
                for (x, y) in a.iter().zip(b) {
                    let d = (*x - *y) as f64;
                    acc += d * d;
                }
                acc
            }
            Metric::Manhattan => {
                let mut acc = 0.0f64;
                for (x, y) in a.iter().zip(b) {
                    acc += ((*x - *y) as f64).abs();
                }
                acc
            }
            Metric::Cosine => {
                let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
                for (x, y) in a.iter().zip(b) {
                    dot += (*x as f64) * (*y as f64);
                    na += (*x as f64) * (*x as f64);
                    nb += (*y as f64) * (*y as f64);
                }
                if na == 0.0 || nb == 0.0 {
                    1.0
                } else {
                    // Clamp the −2e-16-ish negatives FP can produce for
                    // near-parallel vectors (cos similarity > 1 by an
                    // ulp): every metric promises non-negative distances
                    // — the packed-key argsort's domain. NaN (garbage
                    // input) deliberately survives the comparison and
                    // propagates instead of being masked.
                    let d = 1.0 - dot / (na.sqrt() * nb.sqrt());
                    if d < 0.0 {
                        0.0
                    } else {
                        d
                    }
                }
            }
        }
    }
}

/// Distances from `query` (length d) to all rows of `points` (n×d,
/// row-major). Output length n.
pub fn distances(query: &[f32], points: &[f32], d: usize, metric: Metric) -> Vec<f64> {
    assert!(d > 0, "distances: d must be positive");
    assert_eq!(query.len(), d);
    assert_eq!(points.len() % d, 0, "points not a multiple of d");
    points
        .chunks_exact(d)
        .map(|row| metric.dist(query, row))
        .collect()
}

/// Distances from `query` into a caller-provided buffer (hot-path variant
/// that avoids per-test allocation).
pub fn distances_into(
    query: &[f32],
    points: &[f32],
    d: usize,
    metric: Metric,
    out: &mut [f64],
) {
    // d == 0 would make the row-count assert below pass vacuously for
    // ANY out length and leave `out` untouched — reject it loudly.
    assert!(d > 0, "distances_into: d must be positive");
    assert_eq!(query.len(), d);
    assert_eq!(out.len() * d, points.len());
    for (o, row) in out.iter_mut().zip(points.chunks_exact(d)) {
        *o = metric.dist(query, row);
    }
}

/// Stable argsort of train points by ascending distance: `order[a]` is the
/// original index of the a-th nearest point. Ties break by original index
/// (stability), matching `np.argsort(kind="stable")` on the python side —
/// required for bit-identical cross-engine results.
pub fn argsort_by_distance(dists: &[f64]) -> Vec<usize> {
    let mut order = vec![0usize; dists.len()];
    argsort_by_distance_into(dists, &mut order);
    order
}

/// [`argsort_by_distance`] into a caller-provided buffer (hot-path
/// variant: the prep loop sorts one order per TEST POINT, so a fresh
/// `Vec<usize>` per call is a measurable allocation cost on
/// small-n/large-t streams). Same stable ordering contract.
///
/// Ordering is `total_cmp` + index tiebreak — the repo-wide NaN
/// convention: NaN sorts as a definite value (positive NaN after
/// +inf) instead of `partial_cmp().unwrap_or(Equal)`'s silent
/// "incomparable means equal", which made the final order depend on
/// the sort algorithm's visit pattern whenever a NaN was present.
pub fn argsort_by_distance_into(dists: &[f64], order: &mut [usize]) {
    assert_eq!(order.len(), dists.len(), "order buffer length mismatch");
    for (pos, slot) in order.iter_mut().enumerate() {
        *slot = pos;
    }
    order.sort_by(|&a, &b| dists[a].total_cmp(&dists[b]).then(a.cmp(&b)));
}

/// Packed-key argsort — the prep hot loop's fast path. For NON-NEGATIVE
/// distances the raw IEEE-754 bit pattern is monotone in the value, so
/// `(dist_bits << 32) | index` keys sorted as plain u128 integers
/// reproduce EXACTLY the stable distance-then-index order of
/// [`argsort_by_distance`] — one cache-friendly unstable sort of packed
/// keys instead of an indirect comparator sort (every comparison of
/// which is two dependent loads).
///
/// Every built-in [`Metric`] returns non-negative distances (cosine
/// clamps its FP-noise negatives), so a NaN or negative distance here
/// means corrupted upstream state — in debug builds that FAILS LOUDLY
/// (`debug_assert`) instead of quietly taking a different code path;
/// release builds (and the legitimate n ≥ 2³² case) fall back to the
/// comparator sort, so the ordering contract stays total either way.
///
/// `keys` is caller-owned scratch (cleared and refilled; capacity
/// persists across calls — zero allocations in steady state).
pub fn argsort_by_distance_keyed(dists: &[f64], keys: &mut Vec<u128>, order: &mut [usize]) {
    assert_eq!(order.len(), dists.len(), "order buffer length mismatch");
    let n = dists.len();
    let fast = n <= u32::MAX as usize
        && dists.iter().all(|d| !d.is_nan() && d.to_bits() >> 63 == 0);
    if !fast {
        debug_assert!(
            n > u32::MAX as usize,
            "argsort_by_distance_keyed fed a NaN or negative distance — every \
             metric promises non-negative finite distances, so upstream state is \
             corrupt (the packed-key order would silently mis-sort such inputs)"
        );
        argsort_by_distance_into(dists, order);
        return;
    }
    keys.clear();
    keys.extend(
        dists
            .iter()
            .enumerate()
            .map(|(i, d)| ((d.to_bits() as u128) << 32) | i as u128),
    );
    keys.sort_unstable();
    for (slot, &key) in order.iter_mut().zip(keys.iter()) {
        *slot = (key & 0xFFFF_FFFF) as usize;
    }
}

/// Inverse permutation: `ranks[original] = sorted position`.
pub fn invert_permutation(order: &[usize]) -> Vec<usize> {
    let mut ranks = vec![0usize; order.len()];
    for (pos, &orig) in order.iter().enumerate() {
        ranks[orig] = pos;
    }
    ranks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sqeuclidean_known() {
        assert_eq!(Metric::SqEuclidean.dist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn manhattan_known() {
        assert_eq!(Metric::Manhattan.dist(&[1.0, -1.0], &[-2.0, 3.0]), 7.0);
    }

    #[test]
    fn cosine_orthogonal_and_parallel() {
        assert!((Metric::Cosine.dist(&[1.0, 0.0], &[0.0, 2.0]) - 1.0).abs() < 1e-12);
        assert!(Metric::Cosine.dist(&[1.0, 1.0], &[2.0, 2.0]).abs() < 1e-12);
        assert_eq!(Metric::Cosine.dist(&[0.0, 0.0], &[1.0, 0.0]), 1.0);
    }

    #[test]
    fn distances_rowwise() {
        let pts = [0.0f32, 0.0, 1.0, 0.0, 0.0, 2.0];
        let d = distances(&[0.0, 0.0], &pts, 2, Metric::SqEuclidean);
        assert_eq!(d, vec![0.0, 1.0, 4.0]);
    }

    #[test]
    fn distances_into_matches() {
        let pts = [0.0f32, 0.0, 1.0, 0.0, 0.0, 2.0];
        let mut buf = vec![0.0; 3];
        distances_into(&[0.0, 0.0], &pts, 2, Metric::SqEuclidean, &mut buf);
        assert_eq!(buf, distances(&[0.0, 0.0], &pts, 2, Metric::SqEuclidean));
    }

    #[test]
    fn argsort_stable_on_ties() {
        let order = argsort_by_distance(&[2.0, 1.0, 1.0, 0.5]);
        assert_eq!(order, vec![3, 1, 2, 0]);
    }

    #[test]
    fn keyed_argsort_matches_comparator_sort_including_ties() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        let mut keys = Vec::new();
        for n in [1usize, 2, 7, 64, 301] {
            // random distances with deliberate duplicates (ties)
            let dists: Vec<f64> = (0..n)
                .map(|_| (rng.below(n / 2 + 1) as f64) * 0.125)
                .collect();
            let reference = argsort_by_distance(&dists);
            let mut keyed = vec![0usize; n];
            argsort_by_distance_keyed(&dists, &mut keys, &mut keyed);
            assert_eq!(keyed, reference, "n={n} dists={dists:?}");
        }
    }

    // NaN / negative distances mean corrupted upstream state (every
    // metric promises non-negative; cosine clamps its FP-noise
    // negatives): the keyed argsort must FAIL LOUDLY in debug builds
    // instead of silently taking a different path than production.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "NaN or negative distance")]
    fn keyed_argsort_panics_on_nan_in_debug() {
        let weird = [0.5, f64::NAN, 0.25];
        let mut keys = Vec::new();
        let mut keyed = vec![0usize; weird.len()];
        argsort_by_distance_keyed(&weird, &mut keys, &mut keyed);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "NaN or negative distance")]
    fn keyed_argsort_panics_on_negative_in_debug() {
        let weird = [0.5, -1.0, 0.25];
        let mut keys = Vec::new();
        let mut keyed = vec![0usize; weird.len()];
        argsort_by_distance_keyed(&weird, &mut keys, &mut keyed);
    }

    // ... while release builds stay total via the comparator fallback
    // (a corrupted production serve keeps a correct ordering rather
    // than crashing mid-query).
    #[cfg(not(debug_assertions))]
    #[test]
    fn keyed_argsort_falls_back_on_bad_input_in_release() {
        let weird = [0.5, -1.0, f64::NAN, 0.25, -1.0];
        let mut keys = Vec::new();
        let mut keyed = vec![0usize; weird.len()];
        argsort_by_distance_keyed(&weird, &mut keys, &mut keyed);
        assert_eq!(keyed, argsort_by_distance(&weird));
    }

    #[test]
    fn cosine_near_parallel_vectors_clamp_to_zero_not_negative() {
        // three mutually near-parallel vectors whose pairwise cosine
        // similarity can exceed 1 by an ulp — the distance must clamp to
        // exactly 0.0 (non-negative domain), never go negative
        let a = [0.1f32, 0.2, 0.3];
        let b = [0.2f32, 0.4, 0.6];
        let c = [0.3f32, 0.6, 0.9];
        for (x, y) in [(&a, &b), (&a, &c), (&b, &c), (&a, &a)] {
            let d = Metric::Cosine.dist(x, y);
            assert!(d >= 0.0, "cosine distance went negative: {d:e}");
            assert!(d < 1e-12, "parallel vectors should be ~0: {d:e}");
        }
        // and NaN inputs still propagate (not masked to 0 by the clamp)
        let d = Metric::Cosine.dist(&[f32::NAN, 1.0], &[1.0, 1.0]);
        assert!(d.is_nan(), "NaN must propagate, got {d}");
    }

    // The comparator fallback's NaN order is PINNED: total_cmp sorts
    // positive NaN after +inf, ties (including NaN==NaN) break by
    // index. This is the order the keyed path's release fallback
    // takes after its debug-assert contract rejects such input in
    // debug builds — never `unwrap_or(Equal)`'s visit-pattern roulette.
    #[test]
    fn argsort_nan_order_is_total_and_deterministic() {
        let dists = [f64::NAN, 1.0, f64::NAN, 0.5];
        let order = argsort_by_distance(&dists);
        assert_eq!(order, vec![3, 1, 0, 2]);
        // idempotent: a second sort over the same buffer agrees
        let mut again = vec![7usize; 4];
        argsort_by_distance_into(&dists, &mut again);
        assert_eq!(again, order);
        // negative infinities and negatives order below all finites
        let order = argsort_by_distance(&[0.0, f64::NEG_INFINITY, -3.0, f64::INFINITY]);
        assert_eq!(order, vec![1, 2, 0, 3]);
    }

    #[test]
    #[should_panic(expected = "distances_into: d must be positive")]
    fn distances_into_rejects_zero_dimension() {
        // with d == 0 the out.len()*d == points.len() assert passes
        // VACUOUSLY for any out length and the buffer stays unwritten
        let mut out = vec![0.0f64; 2];
        distances_into(&[], &[], 0, Metric::SqEuclidean, &mut out);
    }

    #[test]
    #[should_panic(expected = "distances: d must be positive")]
    fn distances_rejects_zero_dimension() {
        distances(&[], &[], 0, Metric::SqEuclidean);
    }

    #[test]
    fn argsort_into_matches_and_reuses_dirty_buffers() {
        let dists = [2.0, 1.0, 1.0, 0.5];
        // deliberately stale contents: the buffer must be fully rewritten
        let mut order = vec![9usize; 4];
        argsort_by_distance_into(&dists, &mut order);
        assert_eq!(order, argsort_by_distance(&dists));
        // second use with different distances
        argsort_by_distance_into(&[0.1, 0.4, 0.2, 0.3], &mut order);
        assert_eq!(order, vec![0, 2, 3, 1]);
    }

    #[test]
    fn invert_permutation_roundtrip() {
        let order = vec![2, 0, 3, 1];
        let ranks = invert_permutation(&order);
        assert_eq!(ranks, vec![1, 3, 0, 2]);
        for (pos, &orig) in order.iter().enumerate() {
            assert_eq!(ranks[orig], pos);
        }
    }

    #[test]
    fn metric_parse() {
        assert_eq!(Metric::parse("l2"), Some(Metric::SqEuclidean));
        assert_eq!(Metric::parse("cosine"), Some(Metric::Cosine));
        assert_eq!(Metric::parse("nope"), None);
    }
}
