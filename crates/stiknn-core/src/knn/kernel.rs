//! Runtime-dispatched SIMD distance kernels with norm caching and
//! cache-blocked batched prep (DESIGN.md §15).
//!
//! Every engine pays O(n·d) per test point in the distance loop before
//! the O(n log n) argsort even starts; at realistic d that loop
//! dominates prep wall time. This module replaces the scalar
//! [`Metric::dist`] left-fold on the prep hot path with three pieces:
//!
//! * **SIMD kernels** — AVX2+FMA when the host has them (checked once
//!   via `is_x86_feature_detected!`), with a portable-scalar fallback
//!   that computes the SAME fixed 8-lane accumulation tree: element `i`
//!   lands in lane `i % 8` (the portable path uses `f64::mul_add`,
//!   which is the correctly-rounded FMA the hardware executes), lanes
//!   reduce pairwise in one fixed order. SIMD and fallback are therefore
//!   **bit-identical** — property-tested, not assumed — so a resultset
//!   never depends on which machine computed it.
//! * **Norm caching** — [`NormCache`] holds per-train-row ‖x‖² (one
//!   fused dot per row, computed once per session and repaired on
//!   `add_train`/`remove_train`), turning squared euclidean into
//!   dot-product form `‖q‖² − 2⟨q,x⟩ + ‖x‖²` and cosine into a single
//!   fused dot per pair. The cache stores values of the same shared
//!   `⟨x,x⟩` kernel the per-pair path computes, so caching never
//!   changes a bit.
//! * **Blocked batched prep** — [`distances_block`] computes a B×n
//!   distance tile by walking train rows in L1-sized tiles and
//!   revisiting each tile for all B queries, so one train-row load from
//!   memory is amortized over B dot products.
//!
//! The lane-tree reduction order differs from the scalar left-fold, so
//! kernel distances are not bit-equal to [`Metric::dist`] — they agree
//! to ≤ 1e-12 relative, and (the property the pipeline actually
//! consumes) produce IDENTICAL rankings under the stable argsort, ties
//! included. Since every downstream value depends on distances only
//! through the ranking, values are unchanged wherever rankings are.
//! [`Kernel::Reference`] keeps the old scalar loop selectable
//! (`STIKNN_KERNEL=reference`) for A/B against the seed path.
//!
//! Squared-euclidean dot form can go negative by an ulp when `q ≈ x`
//! (catastrophic cancellation); like cosine, it clamps to exactly 0.0
//! because every metric promises the non-negative domain the packed-key
//! argsort sorts in. NaN survives the clamp comparison and propagates.

use std::sync::OnceLock;

use crate::knn::distance::{distances_into, Metric};

/// Which distance kernel implementation to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Pick the fastest implementation the host supports (the default).
    Auto,
    /// AVX2+FMA lanes (x86-64 hosts that pass feature detection).
    Avx2,
    /// Scalar twin of the SIMD path — same 8-lane tree, bit-identical.
    Portable,
    /// The seed scalar loop ([`Metric::dist`] left-fold), kept
    /// selectable for A/B; prep and delta-repair stay in lockstep
    /// under it because every distance routes through this module.
    Reference,
}

impl Kernel {
    /// Parse a kernel name (case-insensitive); `None` for unknown.
    pub fn parse(s: &str) -> Option<Kernel> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(Kernel::Auto),
            "avx2" => Some(Kernel::Avx2),
            "portable" => Some(Kernel::Portable),
            "reference" => Some(Kernel::Reference),
            _ => None,
        }
    }

    /// Stable lowercase name (the `kernel` label in metrics snapshots).
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Auto => "auto",
            Kernel::Avx2 => "avx2",
            Kernel::Portable => "portable",
            Kernel::Reference => "reference",
        }
    }

    /// The kernel this process runs: `STIKNN_KERNEL` (unknown values
    /// fall back to `auto`) resolved against host capabilities, cached
    /// for the process lifetime. Never returns [`Kernel::Auto`].
    pub fn active() -> Kernel {
        static ACTIVE: OnceLock<Kernel> = OnceLock::new();
        *ACTIVE.get_or_init(|| {
            let requested = std::env::var("STIKNN_KERNEL")
                .ok()
                .and_then(|v| Kernel::parse(&v))
                .unwrap_or(Kernel::Auto);
            resolve(requested)
        })
    }
}

/// Resolve a requested kernel against what the host can actually run.
fn resolve(requested: Kernel) -> Kernel {
    match requested {
        Kernel::Portable | Kernel::Reference => requested,
        Kernel::Auto | Kernel::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                if std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
                {
                    return Kernel::Avx2;
                }
            }
            Kernel::Portable
        }
    }
}

// ---------------------------------------------------------------------
// The 8-lane accumulation tree.
//
// Contract shared by the AVX2 and portable paths: element i accumulates
// into lane (i % 8) in increasing-i order with a fused multiply-add
// (dot) or an add of |a−b| (manhattan); after the main loop the tail
// (from the largest multiple of 8) runs the SAME scalar loop in both
// paths; the 8 lanes reduce as ((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7)).
// Every operation is correctly rounded and executed in the same order,
// which is what makes the two paths bit-identical.
// ---------------------------------------------------------------------

/// Fixed final reduction of the 8 accumulator lanes.
#[inline]
fn reduce8(lanes: &[f64; 8]) -> f64 {
    let s0 = lanes[0] + lanes[4];
    let s1 = lanes[1] + lanes[5];
    let s2 = lanes[2] + lanes[6];
    let s3 = lanes[3] + lanes[7];
    (s0 + s2) + (s1 + s3)
}

/// Scalar tail of the dot lane tree, from `start` (a multiple of 8 —
/// the AVX2 path hands over here so element i still maps to lane i%8).
#[inline]
fn dot_tail(a: &[f32], b: &[f32], start: usize, lanes: &mut [f64; 8]) {
    for i in start..a.len() {
        lanes[i % 8] = (a[i] as f64).mul_add(b[i] as f64, lanes[i % 8]);
    }
}

/// Scalar tail of the manhattan lane tree (same start contract).
#[inline]
fn manhattan_tail(a: &[f32], b: &[f32], start: usize, lanes: &mut [f64; 8]) {
    for i in start..a.len() {
        lanes[i % 8] += ((a[i] as f64) - (b[i] as f64)).abs();
    }
}

/// Portable ⟨a,b⟩: the full lane tree run in scalar code.
/// `f64::mul_add` is the correctly-rounded FMA, so each lane's value is
/// bit-identical to the AVX2 `vfmadd` sequence.
fn dot_portable(a: &[f32], b: &[f32]) -> f64 {
    let mut lanes = [0.0f64; 8];
    dot_tail(a, b, 0, &mut lanes);
    reduce8(&lanes)
}

/// Portable Σ|a−b| with the same lane tree.
fn manhattan_portable(a: &[f32], b: &[f32]) -> f64 {
    let mut lanes = [0.0f64; 8];
    manhattan_tail(a, b, 0, &mut lanes);
    reduce8(&lanes)
}

/// AVX2+FMA ⟨a,b⟩. 8 f32 per iteration, widened to two f64×4 vectors;
/// `acc0` holds lanes 0–3, `acc1` lanes 4–7, so lane j accumulates
/// exactly the elements with i % 8 == j — the portable tree.
///
/// # Safety
/// Caller must have verified `avx2` and `fma` via feature detection.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f64 {
    use std::arch::x86_64::*;
    let chunks = a.len() / 8;
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    for c in 0..chunks {
        let pa = _mm256_loadu_ps(a.as_ptr().add(c * 8));
        let pb = _mm256_loadu_ps(b.as_ptr().add(c * 8));
        let a_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(pa));
        let a_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(pa));
        let b_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(pb));
        let b_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(pb));
        acc0 = _mm256_fmadd_pd(a_lo, b_lo, acc0);
        acc1 = _mm256_fmadd_pd(a_hi, b_hi, acc1);
    }
    let mut lanes = [0.0f64; 8];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc0);
    _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc1);
    dot_tail(a, b, chunks * 8, &mut lanes);
    reduce8(&lanes)
}

/// AVX2 Σ|a−b|; abs is the sign-bit mask, identical to `f64::abs`.
///
/// # Safety
/// Caller must have verified `avx2` and `fma` via feature detection.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn manhattan_avx2(a: &[f32], b: &[f32]) -> f64 {
    use std::arch::x86_64::*;
    let chunks = a.len() / 8;
    let abs_mask = _mm256_castsi256_pd(_mm256_set1_epi64x(i64::MAX));
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    for c in 0..chunks {
        let pa = _mm256_loadu_ps(a.as_ptr().add(c * 8));
        let pb = _mm256_loadu_ps(b.as_ptr().add(c * 8));
        let a_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(pa));
        let a_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(pa));
        let b_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(pb));
        let b_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(pb));
        acc0 = _mm256_add_pd(acc0, _mm256_and_pd(_mm256_sub_pd(a_lo, b_lo), abs_mask));
        acc1 = _mm256_add_pd(acc1, _mm256_and_pd(_mm256_sub_pd(a_hi, b_hi), abs_mask));
    }
    let mut lanes = [0.0f64; 8];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc0);
    _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc1);
    manhattan_tail(a, b, chunks * 8, &mut lanes);
    reduce8(&lanes)
}

/// Dispatch ⟨a,b⟩ on an already-resolved kernel.
#[inline]
fn dot_with(kernel: Kernel, a: &[f32], b: &[f32]) -> f64 {
    match kernel {
        // SAFETY: `Kernel::Avx2` is only produced by `resolve` after
        // feature detection confirmed avx2+fma (tests gate likewise).
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { dot_avx2(a, b) },
        _ => dot_portable(a, b),
    }
}

/// Dispatch Σ|a−b| on an already-resolved kernel.
#[inline]
fn manhattan_with(kernel: Kernel, a: &[f32], b: &[f32]) -> f64 {
    match kernel {
        // SAFETY: as for `dot_with`.
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { manhattan_avx2(a, b) },
        _ => manhattan_portable(a, b),
    }
}

/// Clamp FP-noise negatives to exactly 0.0 (the packed-key argsort's
/// non-negative domain); NaN fails the comparison and propagates.
#[inline]
fn clamp_non_negative(v: f64) -> f64 {
    if v < 0.0 {
        0.0
    } else {
        v
    }
}

/// True when the metric consumes cached squared norms.
#[inline]
fn uses_norms(metric: Metric) -> bool {
    matches!(metric, Metric::SqEuclidean | Metric::Cosine)
}

/// Norm-form distance for the metrics that have one. `nq`/`nx` MUST be
/// the shared `dot_with(kernel, v, v)` of the two operands — the cache
/// stores exactly that, which is why caching never changes a bit.
#[inline]
fn norm_form(kernel: Kernel, metric: Metric, q: &[f32], x: &[f32], nq: f64, nx: f64) -> f64 {
    match metric {
        Metric::SqEuclidean => {
            let dot = dot_with(kernel, q, x);
            clamp_non_negative((nq - 2.0 * dot) + nx)
        }
        Metric::Cosine => {
            // Zero-vector convention matches `Metric::dist`: distance 1.
            // NaN norms fail both == tests and propagate through the dot.
            if nq == 0.0 || nx == 0.0 {
                1.0
            } else {
                let dot = dot_with(kernel, q, x);
                clamp_non_negative(1.0 - dot / (nq.sqrt() * nx.sqrt()))
            }
        }
        Metric::Manhattan => unreachable!("manhattan has no norm form"),
    }
}

// ---------------------------------------------------------------------
// Norm cache
// ---------------------------------------------------------------------

/// Per-train-row squared norms ‖x‖², computed once and kept in sync
/// with live train-set edits. A pure performance cache: it stores the
/// same `⟨x,x⟩` the per-pair path would compute, so results are
/// bit-identical with or without it. Manhattan needs no norms; its
/// cache is an empty vector that only tracks the row count.
#[derive(Clone, Debug)]
pub struct NormCache {
    d: usize,
    rows: usize,
    metric: Metric,
    sq: Vec<f64>,
}

impl NormCache {
    /// Build the cache for `points` (n×d row-major).
    pub fn build(points: &[f32], d: usize, metric: Metric) -> NormCache {
        assert!(d > 0, "NormCache::build: d must be positive");
        assert_eq!(
            points.len() % d,
            0,
            "NormCache::build: points not a multiple of d"
        );
        let rows = points.len() / d;
        let kernel = Kernel::active();
        let sq = if uses_norms(metric) {
            points
                .chunks_exact(d)
                .map(|row| dot_with(kernel, row, row))
                .collect()
        } else {
            Vec::new()
        };
        NormCache { d, rows, metric, sq }
    }

    /// Append one row's norm (mirrors `train_x.extend_from_slice(row)`).
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.d, "NormCache::push_row: wrong dimension");
        if uses_norms(self.metric) {
            self.sq.push(dot_with(Kernel::active(), row, row));
        }
        self.rows += 1;
    }

    /// Drop one row's norm, shifting the tail down (mirrors
    /// `train_x.drain(index*d..(index+1)*d)`).
    pub fn remove_row(&mut self, index: usize) {
        assert!(index < self.rows, "NormCache::remove_row: out of range");
        if uses_norms(self.metric) {
            self.sq.remove(index);
        }
        self.rows -= 1;
    }

    /// Number of cached rows.
    pub fn len(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }

    /// Assert the cache matches the train set a caller is about to use
    /// it against — a stale cache is corrupted state, fail loudly.
    fn check(&self, d: usize, metric: Metric, rows: usize) {
        assert_eq!(self.d, d, "NormCache: dimension mismatch");
        assert_eq!(self.metric, metric, "NormCache: metric mismatch");
        assert_eq!(self.rows, rows, "NormCache: row-count mismatch");
    }
}

// ---------------------------------------------------------------------
// Public distance entry points
// ---------------------------------------------------------------------

/// Single-pair distance under the active kernel (norms computed on the
/// fly). The delta-repair path uses this for its O(d) edit distance,
/// which keeps repaired rows bit-identical to from-scratch prep: both
/// evaluate the same norm-form expression on the same operands.
pub fn pair_dist(metric: Metric, q: &[f32], x: &[f32]) -> f64 {
    pair_dist_with(Kernel::active(), metric, q, x)
}

fn pair_dist_with(kernel: Kernel, metric: Metric, q: &[f32], x: &[f32]) -> f64 {
    assert_eq!(q.len(), x.len(), "pair_dist: dimension mismatch");
    assert!(!q.is_empty(), "pair_dist: d must be positive");
    if kernel == Kernel::Reference {
        return metric.dist(q, x);
    }
    match metric {
        Metric::Manhattan => manhattan_with(kernel, q, x),
        Metric::SqEuclidean | Metric::Cosine => {
            let nq = dot_with(kernel, q, q);
            let nx = dot_with(kernel, x, x);
            norm_form(kernel, metric, q, x, nq, nx)
        }
    }
}

/// Kernel twin of [`distances_into`]: distances from `query` to every
/// row of `points`, reading per-row norms from the cache.
pub fn distances_into_kernel(
    query: &[f32],
    points: &[f32],
    d: usize,
    metric: Metric,
    norms: &NormCache,
    out: &mut [f64],
) {
    distances_into_with(Kernel::active(), query, points, d, metric, norms, out)
}

#[allow(clippy::too_many_arguments)]
fn distances_into_with(
    kernel: Kernel,
    query: &[f32],
    points: &[f32],
    d: usize,
    metric: Metric,
    norms: &NormCache,
    out: &mut [f64],
) {
    assert!(d > 0, "distances_into_kernel: d must be positive");
    assert_eq!(query.len(), d, "distances_into_kernel: query length");
    assert_eq!(
        out.len() * d,
        points.len(),
        "distances_into_kernel: out/points mismatch"
    );
    norms.check(d, metric, out.len());
    if kernel == Kernel::Reference {
        distances_into(query, points, d, metric, out);
        return;
    }
    match metric {
        Metric::Manhattan => {
            for (o, row) in out.iter_mut().zip(points.chunks_exact(d)) {
                *o = manhattan_with(kernel, query, row);
            }
        }
        Metric::SqEuclidean | Metric::Cosine => {
            let nq = dot_with(kernel, query, query);
            for ((o, row), &nx) in out
                .iter_mut()
                .zip(points.chunks_exact(d))
                .zip(norms.sq.iter())
            {
                *o = norm_form(kernel, metric, query, row, nq, nx);
            }
        }
    }
}

/// L1 row-tile budget for [`distances_block`]: a tile of train rows
/// that stays resident while all B queries revisit it.
const TILE_BYTES: usize = 32 * 1024;

#[inline]
fn tile_rows(d: usize) -> usize {
    (TILE_BYTES / (4 * d)).clamp(8, 1024)
}

/// Cache-blocked batched prep: distances from B queries (`queries`,
/// B×d row-major) to all n rows of `points`, written to `out` (B×n
/// row-major, `out[qi*n + i]`). Train rows are walked once per L1-sized
/// tile and revisited for every query, amortizing each row load over B
/// dot products. Tiling only reorders WHICH (query, row) pair is
/// computed when — each pair's arithmetic is untouched — so the output
/// is bitwise identical to B calls of [`distances_into_kernel`].
pub fn distances_block(
    queries: &[f32],
    points: &[f32],
    d: usize,
    metric: Metric,
    norms: &NormCache,
    out: &mut [f64],
) {
    distances_block_with(Kernel::active(), queries, points, d, metric, norms, out)
}

#[allow(clippy::too_many_arguments)]
fn distances_block_with(
    kernel: Kernel,
    queries: &[f32],
    points: &[f32],
    d: usize,
    metric: Metric,
    norms: &NormCache,
    out: &mut [f64],
) {
    assert!(d > 0, "distances_block: d must be positive");
    assert_eq!(
        queries.len() % d,
        0,
        "distances_block: queries not a multiple of d"
    );
    let b = queries.len() / d;
    assert_eq!(
        points.len() % d,
        0,
        "distances_block: points not a multiple of d"
    );
    let n = points.len() / d;
    assert_eq!(out.len(), b * n, "distances_block: out length mismatch");
    norms.check(d, metric, n);
    if kernel == Kernel::Reference {
        for (q, orow) in queries.chunks_exact(d).zip(out.chunks_exact_mut(n)) {
            distances_into(q, points, d, metric, orow);
        }
        return;
    }
    // Per-query norms once per block (empty for manhattan).
    let nq: Vec<f64> = if uses_norms(metric) {
        queries
            .chunks_exact(d)
            .map(|q| dot_with(kernel, q, q))
            .collect()
    } else {
        Vec::new()
    };
    let tile = tile_rows(d);
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + tile).min(n);
        let rows = &points[lo * d..hi * d];
        for (qi, q) in queries.chunks_exact(d).enumerate() {
            let orow = &mut out[qi * n + lo..qi * n + hi];
            match metric {
                Metric::Manhattan => {
                    for (o, row) in orow.iter_mut().zip(rows.chunks_exact(d)) {
                        *o = manhattan_with(kernel, q, row);
                    }
                }
                Metric::SqEuclidean | Metric::Cosine => {
                    let qn = nq[qi];
                    for ((o, row), &nx) in orow
                        .iter_mut()
                        .zip(rows.chunks_exact(d))
                        .zip(norms.sq[lo..hi].iter())
                    {
                        *o = norm_form(kernel, metric, q, row, qn, nx);
                    }
                }
            }
        }
        lo = hi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knn::distance::{argsort_by_distance, distances};
    use crate::util::rng::Rng;

    /// Odd tails exercise the remainder loop; 8/16 the pure-SIMD path.
    const DIMS: [usize; 7] = [1, 3, 7, 8, 16, 100, 301];
    const METRICS: [Metric; 3] = [Metric::SqEuclidean, Metric::Manhattan, Metric::Cosine];

    #[cfg(target_arch = "x86_64")]
    fn avx2_available() -> bool {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }

    #[cfg(not(target_arch = "x86_64"))]
    fn avx2_available() -> bool {
        false
    }

    fn random_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn simd_and_portable_primitives_are_bit_identical() {
        if !avx2_available() {
            return; // host cannot run the SIMD side of the comparison
        }
        let mut rng = Rng::new(41);
        for d in DIMS {
            for _ in 0..8 {
                let a = random_vec(&mut rng, d);
                let b = random_vec(&mut rng, d);
                let sp = dot_with(Kernel::Portable, &a, &b);
                let sv = dot_with(Kernel::Avx2, &a, &b);
                assert_eq!(sp.to_bits(), sv.to_bits(), "dot d={d}");
                let mp = manhattan_with(Kernel::Portable, &a, &b);
                let mv = manhattan_with(Kernel::Avx2, &a, &b);
                assert_eq!(mp.to_bits(), mv.to_bits(), "manhattan d={d}");
            }
        }
    }

    #[test]
    fn simd_and_portable_distances_are_bit_identical() {
        if !avx2_available() {
            return;
        }
        let mut rng = Rng::new(42);
        let n = 37;
        for d in DIMS {
            let points = random_vec(&mut rng, n * d);
            let q = random_vec(&mut rng, d);
            for metric in METRICS {
                let norms = NormCache::build(&points, d, metric);
                let mut a = vec![0.0f64; n];
                let mut b = vec![0.0f64; n];
                distances_into_with(Kernel::Portable, &q, &points, d, metric, &norms, &mut a);
                distances_into_with(Kernel::Avx2, &q, &points, d, metric, &norms, &mut b);
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "d={d} metric={metric:?}");
                }
                let dist = pair_dist_with(Kernel::Portable, metric, &q, &points[..d]);
                let dist_v = pair_dist_with(Kernel::Avx2, metric, &q, &points[..d]);
                assert_eq!(dist.to_bits(), dist_v.to_bits());
            }
        }
    }

    #[test]
    fn blocked_matches_unblocked_bitwise() {
        let mut rng = Rng::new(43);
        // d=301 shrinks the tile below n, exercising the tile seams.
        for (n, d) in [(1usize, 3usize), (17, 8), (100, 301)] {
            let points = random_vec(&mut rng, n * d);
            for b in [1usize, 3, 8] {
                let queries = random_vec(&mut rng, b * d);
                for metric in METRICS {
                    let norms = NormCache::build(&points, d, metric);
                    let mut blocked = vec![0.0f64; b * n];
                    distances_block(&queries, &points, d, metric, &norms, &mut blocked);
                    let mut single = vec![0.0f64; n];
                    for qi in 0..b {
                        let q = &queries[qi * d..(qi + 1) * d];
                        distances_into_kernel(q, &points, d, metric, &norms, &mut single);
                        for (x, y) in blocked[qi * n..(qi + 1) * n].iter().zip(&single) {
                            assert_eq!(x.to_bits(), y.to_bits(), "n={n} d={d} b={b}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn norm_cache_edits_match_rebuild_bitwise() {
        let mut rng = Rng::new(44);
        let (n, d) = (23usize, 16usize);
        let points = random_vec(&mut rng, n * d);
        let extra = random_vec(&mut rng, d);
        for metric in METRICS {
            let mut cache = NormCache::build(&points, d, metric);
            // push == rebuild over the extended set
            let mut extended = points.clone();
            extended.extend_from_slice(&extra);
            cache.push_row(&extra);
            let rebuilt = NormCache::build(&extended, d, metric);
            assert_eq!(cache.len(), rebuilt.len());
            assert_eq!(cache.sq.len(), rebuilt.sq.len());
            for (a, b) in cache.sq.iter().zip(&rebuilt.sq) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            // remove == rebuild over the drained set
            cache.remove_row(5);
            let mut drained = extended.clone();
            drained.drain(5 * d..6 * d);
            let rebuilt = NormCache::build(&drained, d, metric);
            assert_eq!(cache.len(), rebuilt.len());
            for (a, b) in cache.sq.iter().zip(&rebuilt.sq) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    // The lane tree reorders the reduction, so kernel distances differ
    // from the scalar left-fold only by accumulated rounding: ≤ 1e-12
    // relative on well-scaled data — the documented envelope.
    #[test]
    fn kernel_distances_match_scalar_within_envelope() {
        let mut rng = Rng::new(45);
        let n = 41;
        for d in DIMS {
            let points = random_vec(&mut rng, n * d);
            let q = random_vec(&mut rng, d);
            for metric in METRICS {
                let norms = NormCache::build(&points, d, metric);
                let mut got = vec![0.0f64; n];
                distances_into_kernel(&q, &points, d, metric, &norms, &mut got);
                let want = distances(&q, &points, d, metric);
                for (g, w) in got.iter().zip(&want) {
                    let tol = 1e-12 * w.abs().max(1.0);
                    assert!(
                        (g - w).abs() <= tol,
                        "d={d} metric={metric:?} got={g} want={w}"
                    );
                }
            }
        }
    }

    // What the pipeline actually consumes is the RANKING. Deliberate
    // exact ties (duplicated train rows) must keep their stable
    // index order, and untied points must not cross.
    #[test]
    fn rankings_match_scalar_path_under_deliberate_ties() {
        let mut rng = Rng::new(46);
        let d = 8;
        let base: Vec<Vec<f32>> = (0..20).map(|_| random_vec(&mut rng, d)).collect();
        // each base row appears 3x => 3-way exact distance ties
        let mut points = Vec::new();
        for _rep in 0..3 {
            for row in &base {
                points.extend_from_slice(row);
            }
        }
        let n = 60;
        let q = random_vec(&mut rng, d);
        for metric in METRICS {
            let norms = NormCache::build(&points, d, metric);
            let mut kd = vec![0.0f64; n];
            distances_into_kernel(&q, &points, d, metric, &norms, &mut kd);
            let sd = distances(&q, &points, d, metric);
            let k_order = argsort_by_distance(&kd);
            let s_order = argsort_by_distance(&sd);
            assert_eq!(k_order, s_order, "metric={metric:?}");
            // the three copies of each base row rank adjacently in
            // ascending index order (stability preserved)
            for w in k_order.chunks_exact(3) {
                assert_eq!(w[0] % 20, w[1] % 20);
                assert_eq!(w[1] % 20, w[2] % 20);
                assert!(w[0] < w[1] && w[1] < w[2], "tie order broken: {w:?}");
            }
        }
    }

    // Every kernel distance on finite input must live in the packed-key
    // argsort's domain: non-NaN, sign bit clear (clamp guarantees it
    // even when the dot form cancels below zero).
    #[test]
    fn kernel_distances_stay_in_keyed_argsort_domain() {
        let mut rng = Rng::new(47);
        for d in DIMS {
            let n = 29;
            let mut points = random_vec(&mut rng, n * d);
            // adversarial rows for cancellation: the query itself,
            // a scaled copy (cosine-parallel), and an all-zero row
            let q = random_vec(&mut rng, d);
            points[..d].copy_from_slice(&q);
            for (i, v) in q.iter().enumerate() {
                points[d + i] = v * 2.0;
            }
            for v in &mut points[2 * d..3 * d] {
                *v = 0.0;
            }
            for metric in METRICS {
                let norms = NormCache::build(&points, d, metric);
                let mut dists = vec![0.0f64; n];
                distances_into_kernel(&q, &points, d, metric, &norms, &mut dists);
                for (i, dist) in dists.iter().enumerate() {
                    assert!(!dist.is_nan(), "row {i} metric={metric:?}");
                    assert_eq!(dist.to_bits() >> 63, 0, "negative bits: row {i} {dist:e}");
                }
                // the self-row is an exact or clamped zero under sqeuclidean
                if metric == Metric::SqEuclidean {
                    assert_eq!(dists[0], 0.0);
                }
            }
        }
    }

    #[test]
    fn nan_propagates_like_the_scalar_path() {
        let mut rng = Rng::new(48);
        let (n, d) = (9usize, 13usize);
        let mut points = random_vec(&mut rng, n * d);
        points[4 * d + 2] = f32::NAN; // poison row 4
        let mut q = random_vec(&mut rng, d);
        for metric in METRICS {
            let norms = NormCache::build(&points, d, metric);
            let mut dists = vec![0.0f64; n];
            distances_into_kernel(&q, &points, d, metric, &norms, &mut dists);
            for (i, dist) in dists.iter().enumerate() {
                assert_eq!(dist.is_nan(), i == 4, "metric={metric:?} row {i}");
            }
        }
        // poisoned QUERY propagates to every row
        q[0] = f32::NAN;
        for metric in METRICS {
            let norms = NormCache::build(&points, d, metric);
            let mut dists = vec![0.0f64; n];
            distances_into_kernel(&q, &points, d, metric, &norms, &mut dists);
            assert!(dists.iter().all(|v| v.is_nan()), "metric={metric:?}");
        }
    }

    #[test]
    fn cosine_zero_vector_and_clamp_match_convention() {
        let mut rng = Rng::new(49);
        let d = 7;
        let q = random_vec(&mut rng, d);
        // train rows: zero vector, 2q (parallel), −q (antiparallel)
        let mut points = vec![0.0f32; d];
        points.extend(q.iter().map(|v| v * 2.0));
        points.extend(q.iter().map(|v| -v));
        let norms = NormCache::build(&points, d, Metric::Cosine);
        let mut dists = vec![0.0f64; 3];
        distances_into_kernel(&q, &points, d, Metric::Cosine, &norms, &mut dists);
        assert_eq!(dists[0], 1.0, "zero train row => distance exactly 1");
        assert!(dists[1] >= 0.0 && dists[1] < 1e-12, "parallel: {:e}", dists[1]);
        assert!((dists[2] - 2.0).abs() < 1e-12, "antiparallel: {}", dists[2]);
        // zero QUERY: every distance is exactly 1
        let zq = vec![0.0f32; d];
        distances_into_kernel(&zq, &points, d, Metric::Cosine, &norms, &mut dists);
        assert!(dists.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn reference_kernel_reproduces_the_seed_loop_bitwise() {
        let mut rng = Rng::new(50);
        let (n, d) = (19usize, 11usize);
        let points = random_vec(&mut rng, n * d);
        let queries = random_vec(&mut rng, 3 * d);
        let q = &queries[..d];
        for metric in METRICS {
            let norms = NormCache::build(&points, d, metric);
            let want = distances(q, &points, d, metric);
            let mut got = vec![0.0f64; n];
            distances_into_with(Kernel::Reference, q, &points, d, metric, &norms, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits());
            }
            let pd = pair_dist_with(Kernel::Reference, metric, q, &points[..d]);
            assert_eq!(pd.to_bits(), metric.dist(q, &points[..d]).to_bits());
            let mut blocked = vec![0.0f64; 3 * n];
            distances_block_with(
                Kernel::Reference,
                &queries,
                &points,
                d,
                metric,
                &norms,
                &mut blocked,
            );
            for qi in 0..3 {
                let want = distances(&queries[qi * d..(qi + 1) * d], &points, d, metric);
                for (g, w) in blocked[qi * n..(qi + 1) * n].iter().zip(&want) {
                    assert_eq!(g.to_bits(), w.to_bits());
                }
            }
        }
    }

    #[test]
    fn kernel_parse_and_active_resolution() {
        assert_eq!(Kernel::parse("avx2"), Some(Kernel::Avx2));
        assert_eq!(Kernel::parse("AVX2"), Some(Kernel::Avx2));
        assert_eq!(Kernel::parse("portable"), Some(Kernel::Portable));
        assert_eq!(Kernel::parse("reference"), Some(Kernel::Reference));
        assert_eq!(Kernel::parse("auto"), Some(Kernel::Auto));
        assert_eq!(Kernel::parse("nope"), None);
        for k in [Kernel::Auto, Kernel::Avx2, Kernel::Portable, Kernel::Reference] {
            assert_eq!(Kernel::parse(k.name()), Some(k));
        }
        // the resolved kernel is never Auto and never an unsupported Avx2
        let active = Kernel::active();
        assert_ne!(active, Kernel::Auto);
        if active == Kernel::Avx2 {
            assert!(avx2_available());
        }
        // resolution honors explicit fallbacks and host capabilities
        assert_eq!(resolve(Kernel::Portable), Kernel::Portable);
        assert_eq!(resolve(Kernel::Reference), Kernel::Reference);
        let auto = resolve(Kernel::Auto);
        assert_eq!(auto == Kernel::Avx2, avx2_available());
    }

    #[test]
    #[should_panic(expected = "d must be positive")]
    fn norm_cache_rejects_zero_dimension() {
        NormCache::build(&[], 0, Metric::SqEuclidean);
    }

    #[test]
    #[should_panic(expected = "d must be positive")]
    fn pair_dist_rejects_empty_vectors() {
        pair_dist(Metric::SqEuclidean, &[], &[]);
    }

    #[test]
    #[should_panic(expected = "row-count mismatch")]
    fn stale_norm_cache_fails_loudly() {
        let points = [1.0f32, 2.0, 3.0, 4.0];
        let norms = NormCache::build(&points, 2, Metric::SqEuclidean);
        let bigger = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out = vec![0.0f64; 3];
        distances_into_kernel(&[0.0, 0.0], &bigger, 2, Metric::SqEuclidean, &norms, &mut out);
    }
}
