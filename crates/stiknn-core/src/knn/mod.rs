//! KNN model substrate: distance metrics, neighbor ordering, the
//! SIMD distance kernels with norm caching, the classifier, and the
//! paper's valuation function (Eqs. 1–2).

pub mod classifier;
pub mod distance;
pub mod kernel;
pub mod valuation;

pub use classifier::KnnClassifier;
pub use distance::{argsort_by_distance, distances, Metric};
pub use kernel::{distances_block, distances_into_kernel, pair_dist, Kernel, NormCache};
pub use valuation::{likelihood_score, u_single, u_subset};
