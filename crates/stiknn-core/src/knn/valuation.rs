//! The paper's valuation function (Eqs. 1–2): the test score of a KNN
//! model trained on a subset S is the likelihood of the right label,
//!
//!   v(S)          = (1/t) Σ_{y_test}  u_{y_test}(S)                (Eq. 1)
//!   u_{y_test}(S) = (1/k) Σ_{i=1..min(k,|S|)} 1[y_i = y_test]      (Eq. 2)
//!
//! where members of S vote in order of distance to the test point.
//! These are the primitives the brute-force Eq. (3) oracle and the
//! Monte-Carlo estimator train/test "the model" with — for KNN, training
//! is free and scoring is rank counting, which is what makes exhaustive
//! subset enumeration feasible at small n.

/// u_{y_test}(S) for S given as sorted-order member ranks (ascending).
///
/// `match_sorted[r]` = 1 iff the train point at rank r has the test label.
/// `members` must be sorted ascending (nearest member first).
pub fn u_subset(match_sorted: &[bool], members: &[usize], k: usize) -> f64 {
    debug_assert!(members.windows(2).all(|w| w[0] < w[1]), "members not sorted");
    let take = members.len().min(k);
    let hits = members[..take]
        .iter()
        .filter(|&&r| match_sorted[r])
        .count();
    hits as f64 / k as f64
}

/// u_{y_test}(S) for S given as a bitmask over ranks (bit r = rank r
/// present). Fast path for the exhaustive Eq. (3) enumeration, n ≤ 64.
pub fn u_subset_mask(match_bits: u64, subset: u64, k: usize) -> f64 {
    let mut remaining = subset;
    let mut hits = 0usize;
    let mut taken = 0usize;
    while remaining != 0 && taken < k {
        let r = remaining.trailing_zeros() as u64;
        if (match_bits >> r) & 1 == 1 {
            hits += 1;
        }
        remaining &= remaining - 1;
        taken += 1;
    }
    hits as f64 / k as f64
}

/// u_{y_test}({i}) for a singleton (Eq. 5): 1[y_i = y_test]/k.
#[inline]
pub fn u_single(label_matches: bool, k: usize) -> f64 {
    if label_matches {
        1.0 / k as f64
    } else {
        0.0
    }
}

/// v(N) over a full train set for one test point: fraction of the k
/// nearest whose label matches, divided by k (Eq. 2 with S = N).
pub fn u_full(match_sorted: &[bool], k: usize) -> f64 {
    let take = match_sorted.len().min(k);
    match_sorted[..take].iter().filter(|&&m| m).count() as f64 / k as f64
}

/// Eq. (1): the likelihood test score of the full train set, averaged over
/// test points. `match_sorted_per_test[p]` is the match vector for test
/// point p in ITS distance order.
///
/// Panics on an empty test set — Eq. (1) is undefined there, and the
/// valuation engines (`shapley::sti_knn`) already reject it loudly;
/// returning NaN here let the same condition flow silently into axiom
/// checks and reports.
pub fn likelihood_score(match_sorted_per_test: &[Vec<bool>], k: usize) -> f64 {
    assert!(
        !match_sorted_per_test.is_empty(),
        "empty test set: Eq. (1) is undefined for t = 0"
    );
    match_sorted_per_test
        .iter()
        .map(|m| u_full(m, k))
        .sum::<f64>()
        / match_sorted_per_test.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §2.1 worked example: k=3, labels (by distance) match/­miss/match/match.
    /// (u({1,3,4}) = 3/3 in the paper forces points 1, 3, 4 to match.)
    const FIG1: [bool; 4] = [true, false, true, true];

    #[test]
    fn fig1_full_train_set() {
        assert!((u_full(&FIG1, 3) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn fig1_singletons() {
        assert!((u_subset(&FIG1, &[0], 3) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(u_subset(&FIG1, &[1], 3), 0.0);
    }

    #[test]
    fn fig1_triple() {
        // {1,3,4} 1-based = ranks {0,2,3}
        assert!((u_subset(&FIG1, &[0, 2, 3], 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn only_k_nearest_members_vote() {
        let m = [true, true, true, true];
        assert!((u_subset(&m, &[0, 1, 2, 3], 2) - 1.0).abs() < 1e-12);
        // farther members are ignored once k are taken
        let m2 = [false, false, true, true];
        assert_eq!(u_subset(&m2, &[0, 1, 2, 3], 2), 0.0);
    }

    #[test]
    fn mask_and_list_agree() {
        let match_sorted = [true, false, true, true, false, true];
        let match_bits = match_sorted
            .iter()
            .enumerate()
            .fold(0u64, |acc, (i, &m)| acc | ((m as u64) << i));
        for subset in 0u64..(1 << 6) {
            let members: Vec<usize> = (0..6).filter(|&r| (subset >> r) & 1 == 1).collect();
            for k in 1..=6 {
                assert_eq!(
                    u_subset(&match_sorted, &members, k),
                    u_subset_mask(match_bits, subset, k),
                    "subset={subset:b} k={k}"
                );
            }
        }
    }

    #[test]
    fn u_single_matches_eq5() {
        assert_eq!(u_single(true, 4), 0.25);
        assert_eq!(u_single(false, 4), 0.0);
    }

    #[test]
    fn likelihood_score_averages() {
        let per_test = vec![vec![true, true], vec![false, false]];
        assert!((likelihood_score(&per_test, 2) - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "empty test set")]
    fn likelihood_score_rejects_empty_test_set() {
        // regression: this used to return NaN while sti_knn panicked on
        // the same condition — the two entry points now agree
        likelihood_score(&[], 3);
    }

    #[test]
    fn empty_subset_scores_zero() {
        assert_eq!(u_subset(&FIG1, &[], 3), 0.0);
        assert_eq!(u_subset_mask(0b1011, 0, 3), 0.0);
    }
}
