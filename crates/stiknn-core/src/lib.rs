//! # stiknn-core — the pure algorithm layer of the STI-KNN workspace
//!
//! Everything in this crate is a deterministic function of its inputs:
//! the STI-KNN valuation engines ([`shapley`], DESIGN.md §4/§10), the
//! exact delta repairs ([`shapley::delta`], §11), KNN primitives
//! ([`knn`]), dataset generators and loaders ([`data`]), the analysis
//! suite ([`analysis`], §3.2/§4), the in-process parallel coordinator
//! ([`coordinator`], §7), the AOT artifact runtime ([`runtime`], behind
//! the `xla` feature), the unified observability layer ([`obs`], §14:
//! atomic counters/gauges, fixed-bucket latency histograms, and a
//! bounded event ring behind a no-op-when-disabled `ObsHandle`), and
//! the report/bench utilities shared by every layer above.
//!
//! **Layering contract (CI-enforced per crate):** `stiknn-core` depends
//! on NO other workspace crate. The session layer (`stiknn-session`),
//! the server (`stiknn-server`) and the CLI (`stiknn-cli`) all build on
//! top of it; the `stiknn` facade crate re-exports the whole stack under
//! the original monolith's module paths. See DESIGN.md §13 for the crate
//! dependency DAG.
//!
//! The one function that needs a live session — the exact iterative
//! removal curve — lives in `stiknn-session` (re-exported by the facade
//! at its old `analysis::removal` path); everything else in [`analysis`]
//! is matrix/value-vector pure and stays here.

// Every `unsafe` block in this crate (they all live in `knn::kernel`)
// must carry a `// SAFETY:` comment; `cargo xtask lint` enforces the
// same contract textually across the whole workspace (DESIGN.md §17).
#![deny(clippy::undocumented_unsafe_blocks)]

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod knn;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod shapley;
pub mod util;

pub use shapley::delta;
