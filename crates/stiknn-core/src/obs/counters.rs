//! The lock-free metric primitives: [`Counter`], [`Gauge`],
//! [`Histogram`].
//!
//! Extracted from `obs/mod.rs` so the loom harness (`verify/loom`, see
//! [`super::sync`]) can include this file verbatim and model-check every
//! interleaving of concurrent writers. Everything here must stay
//! dependency-free (std + the sync shim only) and free of `#[cfg(test)]`
//! modules — unit tests live in `obs/mod.rs`, loom models in
//! `verify/loom/tests/models.rs`.

use super::sync::{fetch_max_relaxed, AtomicI64, AtomicU64, Ordering::Relaxed};
use std::time::Duration;

/// A monotone event count. All operations are relaxed: counters are
/// statistics, never synchronization.
pub struct Counter(AtomicU64);

impl Default for Counter {
    // Manual impl: loom's atomics do not implement `Default`, and this
    // file compiles against both arms of the sync shim.
    fn default() -> Self {
        Counter(AtomicU64::new(0))
    }
}

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// A signed instantaneous level (e.g. active connections).
pub struct Gauge(AtomicI64);

impl Default for Gauge {
    fn default() -> Self {
        Gauge(AtomicI64::new(0))
    }
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Relaxed);
    }

    pub fn add(&self, delta: i64) {
        self.0.fetch_add(delta, Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Relaxed)
    }
}

/// Number of finite histogram buckets; one implicit overflow bucket
/// follows. Bucket `i` counts samples with `ns <= 1000 << i`, so the
/// finite range spans 1µs .. ~8.4s in exact powers of two — wide enough
/// for a lock acquisition and a full-session recompute to land in the
/// same vocabulary.
pub const HIST_BUCKETS: usize = 24;

/// Upper bound (inclusive, nanoseconds) of finite bucket `i`.
pub fn bucket_bound_ns(i: usize) -> u64 {
    1_000u64 << i
}

/// A fixed-bucket latency histogram over nanoseconds. Recording is a
/// handful of relaxed atomic adds — no locks, no allocation — so it is
/// safe on every hot path. Quantiles are bucket-resolution estimates
/// (reported as the bucket's upper bound), which is all a powers-of-two
/// layout can promise and all operators need.
///
/// The five fields update independently (no lock couples them), so a
/// concurrent reader can observe e.g. a bucket increment before the
/// matching `count` — every read-side consumer tolerates that, which is
/// exactly what the loom model asserts.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    overflow: AtomicU64,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            overflow: AtomicU64::new(0),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn record_ns(&self, ns: u64) {
        match Self::bucket_of(ns) {
            Some(i) => self.buckets[i].fetch_add(1, Relaxed),
            None => self.overflow.fetch_add(1, Relaxed),
        };
        self.count.fetch_add(1, Relaxed);
        self.sum_ns.fetch_add(ns, Relaxed);
        fetch_max_relaxed(&self.max_ns, ns);
    }

    /// Index of the finite bucket for `ns`, or `None` for overflow.
    pub(crate) fn bucket_of(ns: u64) -> Option<usize> {
        if ns <= 1_000 {
            return Some(0);
        }
        // Smallest i with 1000 << i >= ns, i.e. ceil(log2(ns / 1000)).
        let i = 64 - ns.div_ceil(1_000).leading_zeros() as usize
            - usize::from(ns.div_ceil(1_000).is_power_of_two());
        (i < HIST_BUCKETS).then_some(i)
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Relaxed)
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_ns() as f64 / c as f64
    }

    /// Bucket-resolution quantile estimate: the upper bound of the first
    /// bucket whose cumulative count reaches `q·count` (the observed max
    /// for the overflow bucket). 0 when empty.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Relaxed);
            if cum >= target {
                return bucket_bound_ns(i);
            }
        }
        self.max_ns()
    }

    /// Per-bucket counts: the `HIST_BUCKETS` finite buckets followed by
    /// the overflow bucket.
    pub fn bucket_counts(&self) -> Vec<u64> {
        let mut out: Vec<u64> = self.buckets.iter().map(|b| b.load(Relaxed)).collect();
        out.push(self.overflow.load(Relaxed));
        out
    }
}
