//! `stiknn::obs` — unified runtime telemetry (DESIGN.md §14).
//!
//! One vocabulary for every layer's metrics: lock-free atomic
//! [`Counter`]s and [`Gauge`]s, fixed-bucket latency [`Histogram`]s, and
//! a bounded structured [`Event`] ring, all owned by a named
//! [`MetricsRegistry`]. Layers never hold the registry directly — they
//! hold an [`ObsHandle`], a cheap clone that degrades to a no-op when
//! observability is disabled:
//!
//! * **disabled** (the default everywhere): every hook is a branch on
//!   `None` — no clock read, no allocation, no atomic traffic. This is
//!   the zero-overhead argument: the instrumented binary with obs off
//!   executes the same loads/stores as an uninstrumented one, so
//!   results are bit-identical by construction (`tests/obs_invariants.rs`
//!   property-tests this end to end).
//! * **enabled**: hot-path hooks are relaxed atomic adds; the only
//!   locks live on the cold paths (metric registration — amortized by
//!   cached `Arc` handles — event append, and snapshotting).
//!
//! Snapshots serialize deterministically (`BTreeMap` ordering) to the
//! repo's own [`Json`], which is what the `metrics` protocol verb ships
//! over NDJSON; [`prometheus_text`] renders any snapshot — local or
//! fetched over the wire — as Prometheus-style text exposition for the
//! `stiknn metrics` CLI.
//!
//! Request-scoped *tracing* lives next door in [`trace`] (DESIGN.md
//! §16): the same disabled-by-default handle discipline
//! ([`trace::TraceHandle`]), but recording spans — trace/span/parent
//! ids, durations, fields — into a bounded span store, with context
//! propagation over the NDJSON protocol so one sharded request renders
//! as one tree.
//!
//! ## Verified concurrency core (DESIGN.md §17)
//!
//! The lock-free/lossy structures underneath this module — the metric
//! primitives ([`counters`](self), re-exported here), the event ring
//! ([`ring::EventRing`]) and the span slot ring ([`slots::SlotRing`]) —
//! live in self-contained files that import their sync primitives
//! through the [`sync`](self) shim. The `verify/loom` harness (a
//! CI-only crate excluded from the workspace) `#[path]`-includes those
//! files verbatim and model-checks every interleaving with
//! [loom](https://docs.rs/loom); nothing in the main workspace ever
//! compiles the loom arm.

mod counters;
mod prometheus;
pub mod ring;
pub mod slots;
pub(crate) mod sync;
pub mod trace;

pub use counters::{bucket_bound_ns, Counter, Gauge, Histogram, HIST_BUCKETS};
pub use prometheus::prometheus_text;
pub use trace::{Span, SpanCtx, SpanRecord, TraceHandle, TraceMode};

use crate::util::json::Json;
use ring::EventRing;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// The observability clock: the one sanctioned way to read monotonic
/// time outside this module. Library code calls `obs::now()` instead of
/// `Instant::now()` directly (enforced by `cargo xtask lint`, rule
/// `raw-clock`) so there is a single seam for every timestamp the
/// system takes — one place to audit, and one place to hook if a future
/// PR wants a virtual clock for deterministic tests.
#[inline]
pub fn now() -> Instant {
    Instant::now()
}

impl Histogram {
    /// JSON rendering lives here (not in `counters.rs`) so the extracted
    /// core stays dependency-free for the loom harness.
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count() as f64)),
            ("sum_ns", Json::num(self.sum_ns() as f64)),
            ("mean_ns", Json::num(self.mean_ns())),
            ("p50_ns", Json::num(self.quantile_ns(0.50) as f64)),
            ("p99_ns", Json::num(self.quantile_ns(0.99) as f64)),
            ("max_ns", Json::num(self.max_ns() as f64)),
            (
                "buckets",
                Json::arr(self.bucket_counts().into_iter().map(|c| Json::num(c as f64))),
            ),
        ])
    }
}

/// Default capacity of the structured event ring: old events are
/// dropped (and counted) once this many are pending, so a flapping
/// error can never grow memory or a snapshot without bound. Configure
/// per registry with [`MetricsRegistry::with_event_cap`] (CLI:
/// `serve --event-ring N`).
pub const EVENT_RING_CAP: usize = 256;

/// One structured trace event: a kind, key/value context fields, and
/// when it happened relative to registry creation.
#[derive(Clone, Debug)]
pub struct Event {
    pub seq: u64,
    pub elapsed_ms: u64,
    pub kind: String,
    pub fields: Vec<(String, String)>,
}

impl Event {
    fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("seq", Json::num(self.seq as f64)),
            ("elapsed_ms", Json::num(self.elapsed_ms as f64)),
            ("kind", Json::str(self.kind.clone())),
        ];
        let ctx: BTreeMap<String, Json> = self
            .fields
            .iter()
            .map(|(k, v)| (k.clone(), Json::str(v.clone())))
            .collect();
        fields.push(("fields", Json::Obj(ctx)));
        Json::obj(fields)
    }
}

/// A named family of metrics. Registration (name → metric) takes a
/// short-lived lock; the returned `Arc` handles are meant to be cached
/// by hot loops so steady-state recording never touches the maps.
pub struct MetricsRegistry {
    name: String,
    start: Instant,
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
    labels: Mutex<BTreeMap<String, String>>,
    ring: EventRing<Event>,
}

impl MetricsRegistry {
    pub fn new(name: &str) -> Arc<Self> {
        Self::with_event_cap(name, EVENT_RING_CAP)
    }

    /// A registry whose event ring retains at most `cap` events
    /// (`serve --event-ring N`; [`EVENT_RING_CAP`] is the default).
    pub fn with_event_cap(name: &str, cap: usize) -> Arc<Self> {
        Arc::new(MetricsRegistry {
            name: name.to_string(),
            start: now(),
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            histograms: Mutex::new(BTreeMap::new()),
            labels: Mutex::new(BTreeMap::new()),
            ring: EventRing::new(cap),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Set a static string label on the registry (e.g. which distance
    /// kernel serves this process). Labels are cold-path metadata —
    /// written at setup, carried verbatim in every snapshot — not
    /// metrics; setting one again overwrites it.
    pub fn set_label(&self, name: &str, value: &str) {
        self.labels
            .lock()
            .unwrap()
            .insert(name.to_string(), value.to_string());
    }

    /// Get-or-create a counter by name.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.gauges.lock().unwrap();
        map.entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = self.histograms.lock().unwrap();
        map.entry(name.to_string()).or_default().clone()
    }

    /// Append a structured event, evicting the oldest past the ring's
    /// configured capacity.
    pub fn event(&self, kind: &str, fields: &[(&str, String)]) {
        let elapsed_ms = self.start.elapsed().as_millis().min(u64::MAX as u128) as u64;
        self.ring.push_with(|seq| Event {
            seq,
            elapsed_ms,
            kind: kind.to_string(),
            fields: fields
                .iter()
                .map(|(k, v)| (k.to_string(), v.clone()))
                .collect(),
        });
    }

    /// The buffered events, oldest first.
    pub fn events(&self) -> Vec<Event> {
        self.ring.items()
    }

    /// Events evicted from the ring so far (the exit report surfaces
    /// this so silent truncation is visible).
    pub fn events_dropped(&self) -> u64 {
        self.ring.dropped()
    }

    /// A single metric's current value by name, if it exists (counters,
    /// then gauges, then histograms — names are expected to be unique
    /// across kinds by convention).
    pub fn lookup(&self, name: &str) -> Option<Json> {
        if let Some(c) = self.counters.lock().unwrap().get(name) {
            return Some(Json::num(c.get() as f64));
        }
        if let Some(g) = self.gauges.lock().unwrap().get(name) {
            return Some(Json::num(g.get() as f64));
        }
        if let Some(h) = self.histograms.lock().unwrap().get(name) {
            return Some(h.to_json());
        }
        None
    }

    /// The full registry state as deterministic JSON — the payload of
    /// the `metrics` protocol verb and the input to [`prometheus_text`].
    pub fn snapshot(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(v.get() as f64)))
            .collect();
        let gauges: BTreeMap<String, Json> = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(v.get() as f64)))
            .collect();
        let histograms: BTreeMap<String, Json> = self
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.to_json()))
            .collect();
        let labels: BTreeMap<String, Json> = self
            .labels
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Json::str(v.clone())))
            .collect();
        let (events, dropped) = self.ring.snapshot();
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            (
                "uptime_ms",
                Json::num(self.start.elapsed().as_millis() as f64),
            ),
            ("labels", Json::Obj(labels)),
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(histograms)),
            ("events", Json::arr(events.iter().map(|e| e.to_json()))),
            ("events_dropped", Json::num(dropped as f64)),
        ])
    }
}

/// The handle every layer holds: either a live registry or nothing.
/// Cloning is a pointer copy. Every recording method is a no-op when
/// disabled — no clock reads, no allocation, no atomics — which is what
/// makes default-off instrumentation free.
#[derive(Clone, Default)]
pub struct ObsHandle {
    reg: Option<Arc<MetricsRegistry>>,
}

impl ObsHandle {
    /// The no-op handle (also `Default`).
    pub fn disabled() -> Self {
        ObsHandle { reg: None }
    }

    /// A handle over a fresh registry with the given name.
    pub fn enabled(name: &str) -> Self {
        ObsHandle {
            reg: Some(MetricsRegistry::new(name)),
        }
    }

    /// [`Self::enabled`] with an explicit event-ring capacity
    /// (`serve --event-ring N`).
    pub fn enabled_with_cap(name: &str, event_cap: usize) -> Self {
        ObsHandle {
            reg: Some(MetricsRegistry::with_event_cap(name, event_cap)),
        }
    }

    /// Events evicted across the registry's ring (0 when disabled).
    pub fn events_dropped(&self) -> u64 {
        self.reg.as_ref().map_or(0, |r| r.events_dropped())
    }

    /// A handle sharing an existing registry.
    pub fn with_registry(reg: Arc<MetricsRegistry>) -> Self {
        ObsHandle { reg: Some(reg) }
    }

    pub fn is_enabled(&self) -> bool {
        self.reg.is_some()
    }

    pub fn registry(&self) -> Option<&Arc<MetricsRegistry>> {
        self.reg.as_ref()
    }

    pub fn inc(&self, name: &str) {
        if let Some(reg) = &self.reg {
            reg.counter(name).inc();
        }
    }

    pub fn add(&self, name: &str, n: u64) {
        if let Some(reg) = &self.reg {
            reg.counter(name).add(n);
        }
    }

    pub fn gauge_add(&self, name: &str, delta: i64) {
        if let Some(reg) = &self.reg {
            reg.gauge(name).add(delta);
        }
    }

    pub fn observe_ns(&self, name: &str, ns: u64) {
        if let Some(reg) = &self.reg {
            reg.histogram(name).record_ns(ns);
        }
    }

    pub fn event(&self, kind: &str, fields: &[(&str, String)]) {
        if let Some(reg) = &self.reg {
            reg.event(kind, fields);
        }
    }

    /// Record a structured event AND mirror it to stderr as
    /// `"{prefix}: event={kind} k1=v1 k2=v2"`. This is the one
    /// sanctioned operational logger for library crates (`cargo xtask
    /// lint` rejects bare `eprintln!` elsewhere): the stderr line is
    /// unconditional — operators watching a console still see failures
    /// when obs is disabled — while the structured copy lands in the
    /// event ring whenever a registry is attached.
    pub fn event_logged(&self, prefix: &str, kind: &str, fields: &[(&str, String)]) {
        self.event(kind, fields);
        let mut line = format!("{prefix}: event={kind}");
        for (k, v) in fields {
            line.push(' ');
            line.push_str(k);
            line.push('=');
            line.push_str(v);
        }
        // lint: allow(bare-eprintln) — the sanctioned printer itself.
        eprintln!("{line}");
    }

    /// Cached-handle accessors for hot loops: resolve once, record many.
    pub fn counter(&self, name: &str) -> Option<Arc<Counter>> {
        self.reg.as_ref().map(|r| r.counter(name))
    }

    pub fn histogram(&self, name: &str) -> Option<Arc<Histogram>> {
        self.reg.as_ref().map(|r| r.histogram(name))
    }

    /// Start timing toward histogram `name`. Disabled handles return an
    /// inert timer without reading the clock.
    pub fn timer(&self, name: &str) -> ObsTimer {
        ObsTimer {
            inner: self
                .reg
                .as_ref()
                .map(|r| (now(), name.to_string(), r.clone())),
        }
    }

    /// The registry snapshot, or `Json::Null` when disabled.
    pub fn snapshot_json(&self) -> Json {
        match &self.reg {
            Some(reg) => reg.snapshot(),
            None => Json::Null,
        }
    }
}

/// A scope timer from [`ObsHandle::timer`]: records the elapsed time
/// into its histogram when dropped (or explicitly via [`ObsTimer::stop`],
/// which also reports the measured nanoseconds).
pub struct ObsTimer {
    inner: Option<(Instant, String, Arc<MetricsRegistry>)>,
}

impl ObsTimer {
    /// Record now and return the elapsed nanoseconds (0 when disabled).
    pub fn stop(mut self) -> u64 {
        self.finish()
    }

    /// Abandon the measurement without recording anything.
    pub fn discard(mut self) {
        self.inner = None;
    }

    fn finish(&mut self) -> u64 {
        match self.inner.take() {
            Some((t0, name, reg)) => {
                let ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
                reg.histogram(&name).record_ns(ns);
                ns
            }
            None => 0,
        }
    }
}

impl Drop for ObsTimer {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_accumulate_across_threads() {
        let reg = MetricsRegistry::new("test");
        std::thread::scope(|s| {
            for _ in 0..4 {
                let reg = &reg;
                s.spawn(move || {
                    let c = reg.counter("hits");
                    let g = reg.gauge("level");
                    for _ in 0..1000 {
                        c.inc();
                        g.add(1);
                    }
                    g.add(-1000);
                });
            }
        });
        assert_eq!(reg.counter("hits").get(), 4000);
        assert_eq!(reg.gauge("level").get(), 0);
    }

    #[test]
    fn histogram_buckets_are_powers_of_two_microseconds() {
        let h = Histogram::new();
        h.record_ns(1); // bucket 0 (<= 1µs)
        h.record_ns(1_000); // bucket 0 boundary
        h.record_ns(1_001); // bucket 1
        h.record_ns(2_000); // bucket 1 boundary
        h.record_ns(2_001); // bucket 2
        h.record_ns(u64::MAX); // overflow
        let counts = h.bucket_counts();
        assert_eq!(counts.len(), HIST_BUCKETS + 1);
        assert_eq!(counts[0], 2);
        assert_eq!(counts[1], 2);
        assert_eq!(counts[2], 1);
        assert_eq!(counts[HIST_BUCKETS], 1);
        assert_eq!(h.count(), 6);
        assert_eq!(h.max_ns(), u64::MAX);
    }

    #[test]
    fn histogram_bucket_of_matches_bounds_exhaustively() {
        for i in 0..HIST_BUCKETS {
            let bound = bucket_bound_ns(i);
            assert_eq!(Histogram::bucket_of(bound), Some(i), "at bound {bound}");
            if i + 1 < HIST_BUCKETS {
                assert_eq!(Histogram::bucket_of(bound + 1), Some(i + 1));
            }
        }
        assert_eq!(Histogram::bucket_of(bucket_bound_ns(HIST_BUCKETS - 1) + 1), None);
    }

    #[test]
    fn quantiles_are_bucket_resolution() {
        let h = Histogram::new();
        for _ in 0..99 {
            h.record_ns(500); // bucket 0, bound 1µs
        }
        h.record_ns(1_000_000); // ~1ms
        assert_eq!(h.quantile_ns(0.5), 1_000);
        assert!(h.quantile_ns(1.0) >= 1_000_000);
        let empty = Histogram::new();
        assert_eq!(empty.quantile_ns(0.99), 0);
    }

    #[test]
    fn quantile_at_exact_bucket_boundaries() {
        // One sample per finite bucket, recorded AT each bucket's upper
        // bound: quantile q must return the bound of the ceil(q·24)-th
        // occupied bucket exactly.
        let h = Histogram::new();
        for i in 0..HIST_BUCKETS {
            h.record_ns(bucket_bound_ns(i));
        }
        assert_eq!(h.count(), HIST_BUCKETS as u64);
        // A target of exactly k samples resolves to bucket k-1's bound
        // (q placed mid-step so f64 rounding cannot tip ceil() over).
        for k in 1..=HIST_BUCKETS {
            let q = (k as f64 - 0.5) / HIST_BUCKETS as f64;
            assert_eq!(h.quantile_ns(q), bucket_bound_ns(k - 1), "q={q}");
        }
        // And the absolute edge: q=1.0 is the last occupied bucket.
        assert_eq!(h.quantile_ns(1.0), bucket_bound_ns(HIST_BUCKETS - 1));
    }

    #[test]
    fn quantile_overflow_bucket_reports_observed_max() {
        let h = Histogram::new();
        let beyond = bucket_bound_ns(HIST_BUCKETS - 1) + 1; // > ~8.4s
        h.record_ns(500);
        h.record_ns(beyond);
        h.record_ns(beyond + 7);
        // p50 target = 2 of 3 → still... cumulative finite count is 1,
        // so any q putting the target past the finite buckets falls
        // through to max_ns.
        assert_eq!(h.quantile_ns(0.5), beyond + 7);
        assert_eq!(h.quantile_ns(1.0), beyond + 7);
        assert_eq!(h.max_ns(), beyond + 7);
        // A quantile small enough to stay finite still resolves a bound.
        assert_eq!(h.quantile_ns(0.1), 1_000);
    }

    #[test]
    fn quantile_q0_and_q1_edges() {
        let h = Histogram::new();
        h.record_ns(1_500); // bucket 1 (bound 2µs)
        h.record_ns(3_000); // bucket 2 (bound 4µs)
        // q=0 clamps to a target of 1 sample — the first occupied bucket.
        assert_eq!(h.quantile_ns(0.0), 2_000);
        assert_eq!(h.quantile_ns(-3.0), 2_000); // clamped below
        // q=1 is the last occupied finite bucket's bound.
        assert_eq!(h.quantile_ns(1.0), 4_000);
        assert_eq!(h.quantile_ns(7.5), 4_000); // clamped above
        // Empty histogram: every quantile is 0.
        let empty = Histogram::new();
        assert_eq!(empty.quantile_ns(0.0), 0);
        assert_eq!(empty.quantile_ns(1.0), 0);
    }

    #[test]
    fn event_ring_capacity_is_configurable() {
        let reg = MetricsRegistry::with_event_cap("smallring", 3);
        for i in 0..5 {
            reg.event("tick", &[("i", i.to_string())]);
        }
        let events = reg.events();
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].seq, 2);
        assert_eq!(reg.events_dropped(), 2);
        // Degenerate cap clamps to 1 instead of panicking.
        let one = MetricsRegistry::with_event_cap("one", 0);
        one.event("a", &[]);
        one.event("b", &[]);
        assert_eq!(one.events().len(), 1);
        assert_eq!(one.events_dropped(), 1);
        // Handle-level accessor mirrors the registry (and is 0 disabled).
        let h = ObsHandle::enabled_with_cap("h", 2);
        h.event("x", &[]);
        assert_eq!(h.events_dropped(), 0);
        assert_eq!(ObsHandle::disabled().events_dropped(), 0);
    }

    #[test]
    fn event_ring_is_bounded_and_counts_drops() {
        let reg = MetricsRegistry::new("ring");
        for i in 0..(EVENT_RING_CAP + 10) {
            reg.event("tick", &[("i", i.to_string())]);
        }
        let events = reg.events();
        assert_eq!(events.len(), EVENT_RING_CAP);
        // Oldest 10 evicted: the ring starts at seq 10.
        assert_eq!(events[0].seq, 10);
        assert_eq!(events.last().unwrap().seq, (EVENT_RING_CAP + 9) as u64);
        let snap = reg.snapshot();
        assert_eq!(
            snap.get("events_dropped").unwrap().as_usize(),
            Some(10)
        );
    }

    #[test]
    fn generic_event_ring_snapshot_is_consistent() {
        let ring: EventRing<u64> = EventRing::new(4);
        for _ in 0..11 {
            ring.push_with(|seq| seq * 10);
        }
        let (items, dropped) = ring.snapshot();
        assert_eq!(dropped, 7);
        assert_eq!(items, vec![70, 80, 90, 100]);
        assert_eq!(ring.pushed(), 11);
        assert_eq!(ring.seqs(), vec![7, 8, 9, 10]);
        assert_eq!(ring.pushed(), ring.dropped() + ring.items().len() as u64);
    }

    #[test]
    fn generic_slot_ring_retains_last_cap_items() {
        use slots::SlotRing;
        let ring: SlotRing<u64> = SlotRing::new(4);
        assert_eq!(ring.capacity(), 4);
        for _ in 0..11 {
            ring.push_with(|seq| seq * 10);
        }
        assert_eq!(ring.pushed(), 11);
        assert_eq!(ring.dropped(), 7);
        // Exactly the last 4 survive, in seq order.
        assert_eq!(ring.collect(|_| true), vec![70, 80, 90, 100]);
        // Filtered collect preserves order.
        assert_eq!(ring.collect(|v| v % 20 == 0), vec![80, 100]);
        // Degenerate cap clamps to 1.
        let one: SlotRing<u8> = SlotRing::new(0);
        one.push_with(|_| 1);
        one.push_with(|_| 2);
        assert_eq!(one.collect(|_| true), vec![2]);
        assert_eq!(one.dropped(), 1);
    }

    #[test]
    fn event_logged_mirrors_into_the_ring() {
        let obs = ObsHandle::enabled("logged");
        obs.event_logged("test", "conn_ended", &[("peer", "p1".to_string())]);
        let events = obs.registry().unwrap().events();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind, "conn_ended");
        assert_eq!(events[0].fields[0], ("peer".to_string(), "p1".to_string()));
        // Disabled: prints (untestable here) but records nothing, and
        // must not panic.
        ObsHandle::disabled().event_logged("test", "x", &[]);
    }

    #[test]
    fn snapshot_is_deterministic_json() {
        let reg = MetricsRegistry::new("snap");
        reg.counter("b").add(2);
        reg.counter("a").inc();
        reg.histogram("lat_ns").record_ns(5_000);
        reg.gauge("active").set(3);
        reg.event("boom", &[("why", "test".to_string())]);
        let snap = reg.snapshot();
        assert_eq!(snap.get("name").unwrap().as_str(), Some("snap"));
        let counters = snap.get("counters").unwrap();
        assert_eq!(counters.get("a").unwrap().as_usize(), Some(1));
        assert_eq!(counters.get("b").unwrap().as_usize(), Some(2));
        let hist = snap.get("histograms").unwrap().get("lat_ns").unwrap();
        assert_eq!(hist.get("count").unwrap().as_usize(), Some(1));
        let text = snap.to_string();
        // Round-trips through the parser, and map order is stable.
        assert_eq!(Json::parse(&text).unwrap().to_string(), text);
    }

    #[test]
    fn labels_are_carried_in_snapshots_and_overwrite() {
        let reg = MetricsRegistry::new("labels");
        reg.set_label("kernel", "portable");
        reg.set_label("kernel", "avx2");
        reg.set_label("host", "ci");
        let labels = reg.snapshot().get("labels").unwrap().clone();
        assert_eq!(labels.get("kernel").unwrap().as_str(), Some("avx2"));
        assert_eq!(labels.get("host").unwrap().as_str(), Some("ci"));
        // A fresh registry snapshots an empty (but present) label map.
        let empty = MetricsRegistry::new("bare").snapshot();
        assert!(empty.get("labels").is_some());
    }

    #[test]
    fn lookup_finds_each_kind_and_misses_cleanly() {
        let reg = MetricsRegistry::new("lookup");
        reg.counter("c").inc();
        reg.gauge("g").set(-2);
        reg.histogram("h").record_ns(10);
        assert_eq!(reg.lookup("c").unwrap().as_usize(), Some(1));
        assert_eq!(reg.lookup("g").unwrap().as_f64(), Some(-2.0));
        assert!(reg.lookup("h").unwrap().get("count").is_some());
        assert!(reg.lookup("nope").is_none());
    }

    #[test]
    fn disabled_handle_is_inert() {
        let obs = ObsHandle::disabled();
        assert!(!obs.is_enabled());
        obs.inc("x");
        obs.observe_ns("y", 123);
        obs.event("z", &[]);
        let t = obs.timer("t");
        assert_eq!(t.stop(), 0);
        assert!(obs.counter("x").is_none());
        assert!(matches!(obs.snapshot_json(), Json::Null));
    }

    #[test]
    fn timer_records_on_stop_and_drop() {
        let obs = ObsHandle::enabled("timers");
        let ns = obs.timer("op_ns").stop();
        assert!(ns > 0);
        {
            let _t = obs.timer("op_ns"); // records on drop
        }
        let h = obs.histogram("op_ns").unwrap();
        assert_eq!(h.count(), 2);
        let t = obs.timer("op_ns");
        t.discard();
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn handles_share_one_registry() {
        let obs = ObsHandle::enabled("shared");
        let clone = obs.clone();
        clone.inc("n");
        obs.inc("n");
        assert_eq!(obs.registry().unwrap().counter("n").get(), 2);
    }
}
