//! Prometheus-style text exposition for obs snapshots.
//!
//! Renders the JSON produced by [`MetricsRegistry::snapshot`] — whether
//! taken locally or fetched over the NDJSON `metrics` verb — so the
//! `stiknn metrics` CLI can scrape a running server without the server
//! speaking HTTP. Names are prefixed `stiknn_` and sanitized to the
//! Prometheus charset; histogram buckets keep their nanosecond `le`
//! bounds (every histogram here is named `*_ns`, so the unit is in the
//! name, as the exposition format expects).
//!
//! [`MetricsRegistry::snapshot`]: super::MetricsRegistry::snapshot

use super::{bucket_bound_ns, HIST_BUCKETS};
use crate::util::json::Json;

/// Metric name → exposition name: `stiknn_` prefix, every character
/// outside `[a-zA-Z0-9_]` folded to `_`.
fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 7);
    out.push_str("stiknn_");
    for ch in name.chars() {
        out.push(if ch.is_ascii_alphanumeric() || ch == '_' {
            ch
        } else {
            '_'
        });
    }
    out
}

fn num(j: &Json) -> String {
    // Json renders integral values without a decimal point already.
    j.to_string()
}

/// Render a snapshot (see module docs). `Json::Null` — a disabled
/// handle's snapshot — renders as a single explanatory comment.
pub fn prometheus_text(snapshot: &Json) -> String {
    let mut out = String::new();
    let Some(obj) = snapshot.as_obj() else {
        out.push_str("# observability disabled (no metrics registry)\n");
        return out;
    };
    if let Some(name) = obj.get("name").and_then(|j| j.as_str()) {
        out.push_str(&format!("# stiknn metrics registry: {name}\n"));
    }
    if let Some(up) = obj.get("uptime_ms") {
        out.push_str(&format!("# uptime_ms: {}\n", num(up)));
    }
    if let Some(counters) = obj.get("counters").and_then(|j| j.as_obj()) {
        for (k, v) in counters {
            let name = sanitize(k);
            out.push_str(&format!("# TYPE {name} counter\n{name} {}\n", num(v)));
        }
    }
    if let Some(gauges) = obj.get("gauges").and_then(|j| j.as_obj()) {
        for (k, v) in gauges {
            let name = sanitize(k);
            out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", num(v)));
        }
    }
    if let Some(hists) = obj.get("histograms").and_then(|j| j.as_obj()) {
        for (k, h) in hists {
            let name = sanitize(k);
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let counts: Vec<u64> = h
                .get("buckets")
                .and_then(|b| b.as_arr())
                .map(|arr| {
                    arr.iter()
                        .map(|c| c.as_f64().unwrap_or(0.0) as u64)
                        .collect()
                })
                .unwrap_or_default();
            let mut cum = 0u64;
            for (i, c) in counts.iter().enumerate() {
                cum += c;
                if i < HIST_BUCKETS {
                    out.push_str(&format!(
                        "{name}_bucket{{le=\"{}\"}} {cum}\n",
                        bucket_bound_ns(i)
                    ));
                } else {
                    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
                }
            }
            if let Some(sum) = h.get("sum_ns") {
                out.push_str(&format!("{name}_sum {}\n", num(sum)));
            }
            if let Some(count) = h.get("count") {
                out.push_str(&format!("{name}_count {}\n", num(count)));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::MetricsRegistry;
    use super::*;

    #[test]
    fn renders_counters_gauges_and_histograms() {
        let reg = MetricsRegistry::new("prom");
        reg.counter("server.commands").add(7);
        reg.gauge("server.connections_active").set(2);
        reg.histogram("cmd.query_ns").record_ns(1_500);
        let text = prometheus_text(&reg.snapshot());
        assert!(text.contains("# TYPE stiknn_server_commands counter"));
        assert!(text.contains("stiknn_server_commands 7"));
        assert!(text.contains("stiknn_server_connections_active 2"));
        assert!(text.contains("# TYPE stiknn_cmd_query_ns histogram"));
        // 1500ns lands in the 2µs bucket; cumulative counts reach 1.
        assert!(text.contains("stiknn_cmd_query_ns_bucket{le=\"2000\"} 1"));
        assert!(text.contains("stiknn_cmd_query_ns_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("stiknn_cmd_query_ns_sum 1500"));
        assert!(text.contains("stiknn_cmd_query_ns_count 1"));
    }

    #[test]
    fn null_snapshot_renders_disabled_comment() {
        let text = prometheus_text(&Json::Null);
        assert!(text.contains("disabled"));
    }

    #[test]
    fn sanitizes_metric_names() {
        assert_eq!(sanitize("a.b-c d"), "stiknn_a_b_c_d");
    }
}
