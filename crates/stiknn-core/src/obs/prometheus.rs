//! Prometheus-style text exposition for obs snapshots.
//!
//! Renders the JSON produced by [`MetricsRegistry::snapshot`] — whether
//! taken locally or fetched over the NDJSON `metrics` verb — so the
//! `stiknn metrics` CLI can scrape a running server without the server
//! speaking HTTP. Names are prefixed `stiknn_` and sanitized to the
//! Prometheus charset; histogram buckets keep their nanosecond `le`
//! bounds (every histogram here is named `*_ns`, so the unit is in the
//! name, as the exposition format expects).
//!
//! [`MetricsRegistry::snapshot`]: super::MetricsRegistry::snapshot

use super::{bucket_bound_ns, HIST_BUCKETS};
use crate::util::json::Json;

/// Metric name → exposition name: `stiknn_` prefix, every character
/// outside `[a-zA-Z0-9_]` folded to `_`.
fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 7);
    out.push_str("stiknn_");
    out.push_str(&label_name(name));
    out
}

/// Label NAME sanitizer (no prefix): the exposition charset for label
/// names is the same `[a-zA-Z0-9_]` fold, but values keep their text and
/// are escaped instead ([`escape_label_value`]).
fn label_name(name: &str) -> String {
    name.chars()
        .map(|ch| {
            if ch.is_ascii_alphanumeric() || ch == '_' {
                ch
            } else {
                '_'
            }
        })
        .collect()
}

fn num(j: &Json) -> String {
    // Json renders integral values without a decimal point already.
    j.to_string()
}

/// Label VALUE escaping per the exposition format: backslash, double
/// quote, and line feed are the three characters with escape sequences
/// (`\\`, `\"`, `\n`); everything else passes through verbatim.
fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(ch),
        }
    }
    out
}

/// Render a snapshot (see module docs). `Json::Null` — a disabled
/// handle's snapshot — renders as a single explanatory comment.
pub fn prometheus_text(snapshot: &Json) -> String {
    let mut out = String::new();
    let Some(obj) = snapshot.as_obj() else {
        out.push_str("# observability disabled (no metrics registry)\n");
        return out;
    };
    if let Some(name) = obj.get("name").and_then(|j| j.as_str()) {
        out.push_str(&format!("# stiknn metrics registry: {name}\n"));
    }
    if let Some(up) = obj.get("uptime_ms") {
        out.push_str(&format!("# uptime_ms: {}\n", num(up)));
    }
    // Registry labels render as a Prometheus info-style metric (constant
    // 1 with one label pair per registry label), with label VALUES
    // escaped per the exposition format — a kernel name or hostname
    // containing `"`, `\` or a newline must not corrupt the scrape.
    if let Some(labels) = obj.get("labels").and_then(|j| j.as_obj()) {
        if !labels.is_empty() {
            let pairs: Vec<String> = labels
                .iter()
                .filter_map(|(k, v)| {
                    let v = v.as_str()?;
                    Some(format!("{}=\"{}\"", label_name(k), escape_label_value(v)))
                })
                .collect();
            out.push_str("# HELP stiknn_registry_info static registry labels\n");
            out.push_str("# TYPE stiknn_registry_info gauge\n");
            out.push_str(&format!("stiknn_registry_info{{{}}} 1\n", pairs.join(",")));
        }
    }
    if let Some(counters) = obj.get("counters").and_then(|j| j.as_obj()) {
        for (k, v) in counters {
            let name = sanitize(k);
            out.push_str(&format!("# HELP {name} counter {k}\n"));
            out.push_str(&format!("# TYPE {name} counter\n{name} {}\n", num(v)));
        }
    }
    if let Some(gauges) = obj.get("gauges").and_then(|j| j.as_obj()) {
        for (k, v) in gauges {
            let name = sanitize(k);
            out.push_str(&format!("# HELP {name} gauge {k}\n"));
            out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", num(v)));
        }
    }
    if let Some(hists) = obj.get("histograms").and_then(|j| j.as_obj()) {
        for (k, h) in hists {
            let name = sanitize(k);
            out.push_str(&format!("# HELP {name} latency histogram {k} (ns)\n"));
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let counts: Vec<u64> = h
                .get("buckets")
                .and_then(|b| b.as_arr())
                .map(|arr| {
                    arr.iter()
                        .map(|c| c.as_f64().unwrap_or(0.0) as u64)
                        .collect()
                })
                .unwrap_or_default();
            let mut cum = 0u64;
            for (i, c) in counts.iter().enumerate() {
                cum += c;
                if i < HIST_BUCKETS {
                    out.push_str(&format!(
                        "{name}_bucket{{le=\"{}\"}} {cum}\n",
                        bucket_bound_ns(i)
                    ));
                } else {
                    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
                }
            }
            if let Some(sum) = h.get("sum_ns") {
                out.push_str(&format!("{name}_sum {}\n", num(sum)));
            }
            if let Some(count) = h.get("count") {
                out.push_str(&format!("{name}_count {}\n", num(count)));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::MetricsRegistry;
    use super::*;

    #[test]
    fn renders_counters_gauges_and_histograms() {
        let reg = MetricsRegistry::new("prom");
        reg.counter("server.commands").add(7);
        reg.gauge("server.connections_active").set(2);
        reg.histogram("cmd.query_ns").record_ns(1_500);
        let text = prometheus_text(&reg.snapshot());
        assert!(text.contains("# TYPE stiknn_server_commands counter"));
        assert!(text.contains("stiknn_server_commands 7"));
        assert!(text.contains("stiknn_server_connections_active 2"));
        assert!(text.contains("# TYPE stiknn_cmd_query_ns histogram"));
        // 1500ns lands in the 2µs bucket; cumulative counts reach 1.
        assert!(text.contains("stiknn_cmd_query_ns_bucket{le=\"2000\"} 1"));
        assert!(text.contains("stiknn_cmd_query_ns_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("stiknn_cmd_query_ns_sum 1500"));
        assert!(text.contains("stiknn_cmd_query_ns_count 1"));
    }

    #[test]
    fn null_snapshot_renders_disabled_comment() {
        let text = prometheus_text(&Json::Null);
        assert!(text.contains("disabled"));
    }

    #[test]
    fn sanitizes_metric_names() {
        assert_eq!(sanitize("a.b-c d"), "stiknn_a_b_c_d");
    }

    #[test]
    fn help_and_type_lines_precede_every_metric() {
        let reg = MetricsRegistry::new("prom");
        reg.counter("server.commands").add(1);
        reg.gauge("lvl").set(0);
        reg.histogram("cmd.query_ns").record_ns(1);
        let text = prometheus_text(&reg.snapshot());
        for metric in [
            "stiknn_server_commands",
            "stiknn_lvl",
            "stiknn_cmd_query_ns",
        ] {
            let help = text.lines().position(|l| l.starts_with(&format!("# HELP {metric} ")));
            let typ = text.lines().position(|l| l.starts_with(&format!("# TYPE {metric} ")));
            assert!(help.is_some(), "no HELP for {metric}");
            assert!(typ.is_some(), "no TYPE for {metric}");
            assert!(help < typ, "HELP must precede TYPE for {metric}");
        }
        assert!(text.contains("# TYPE stiknn_cmd_query_ns histogram"));
    }

    #[test]
    fn label_values_with_quotes_backslashes_newlines_are_escaped() {
        // Regression: a label value containing `"` (set via set_label)
        // used to be impossible to render safely — labels were silently
        // dropped from the exposition. Now they ship escaped.
        let reg = MetricsRegistry::new("esc");
        reg.set_label("kernel", "avx2 \"fma\"");
        reg.set_label("path", "C:\\bin");
        reg.set_label("note", "two\nlines");
        let text = prometheus_text(&reg.snapshot());
        assert!(text.contains("# TYPE stiknn_registry_info gauge"));
        assert!(text.contains("kernel=\"avx2 \\\"fma\\\"\""));
        assert!(text.contains("path=\"C:\\\\bin\""));
        assert!(text.contains("note=\"two\\nlines\""));
        // The info line stays a single line: the raw newline never leaks.
        let info = text
            .lines()
            .find(|l| l.starts_with("stiknn_registry_info{"))
            .unwrap();
        assert!(info.ends_with("} 1"));
    }

    #[test]
    fn escape_label_value_is_exhaustive_over_the_three_escapes() {
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(escape_label_value("a\"b"), "a\\\"b");
        assert_eq!(escape_label_value("a\\b"), "a\\\\b");
        assert_eq!(escape_label_value("a\nb"), "a\\nb");
        assert_eq!(escape_label_value("\\\"\n"), "\\\\\\\"\\n");
    }

    #[test]
    fn registries_without_labels_emit_no_info_metric() {
        let reg = MetricsRegistry::new("bare");
        reg.counter("c").inc();
        assert!(!prometheus_text(&reg.snapshot()).contains("registry_info"));
    }
}
