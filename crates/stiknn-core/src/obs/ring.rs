//! A bounded, sequence-stamped event ring behind one mutex.
//!
//! The generic core of the [`MetricsRegistry`](super::MetricsRegistry)
//! structured-event buffer, extracted so the loom harness
//! (`verify/loom`, see [`super::sync`]) can include this file verbatim
//! and model-check concurrent push vs. eviction vs. snapshot. Must stay
//! dependency-free (std + the sync shim only) and `#[cfg(test)]`-free —
//! unit tests live in `obs/mod.rs`, loom models in `verify/loom`.

use super::sync::Mutex;
use std::collections::VecDeque;

/// Invariants (loom-checked in `verify/loom/tests/models.rs`):
///
/// * every push gets a unique, strictly increasing sequence number;
/// * at most `cap` items are retained — the oldest is evicted and
///   counted, so `pushed == dropped + len` at every observable point;
/// * a snapshot is internally consistent (items + drop count are read
///   under one lock acquisition).
pub struct EventRing<T> {
    inner: Mutex<RingState<T>>,
}

struct RingState<T> {
    cap: usize,
    next_seq: u64,
    dropped: u64,
    buf: VecDeque<(u64, T)>,
}

impl<T: Clone> EventRing<T> {
    /// A ring retaining at most `cap` items (a degenerate cap of 0
    /// clamps to 1 instead of panicking).
    pub fn new(cap: usize) -> Self {
        EventRing {
            inner: Mutex::new(RingState {
                cap: cap.max(1),
                next_seq: 0,
                dropped: 0,
                buf: VecDeque::new(),
            }),
        }
    }

    /// Claim the next sequence number and append `make(seq)`, evicting
    /// (and counting) the oldest item past capacity. Returns the seq.
    pub fn push_with<F: FnOnce(u64) -> T>(&self, make: F) -> u64 {
        let mut st = self.inner.lock().unwrap();
        let seq = st.next_seq;
        st.next_seq += 1;
        if st.buf.len() == st.cap {
            st.buf.pop_front();
            st.dropped += 1;
        }
        let item = make(seq);
        st.buf.push_back((seq, item));
        seq
    }

    /// The retained items, oldest first.
    pub fn items(&self) -> Vec<T> {
        self.snapshot().0
    }

    /// Items ever evicted.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Items ever pushed (the next sequence number).
    pub fn pushed(&self) -> u64 {
        self.inner.lock().unwrap().next_seq
    }

    /// Retained items (oldest first) and the drop count, read under ONE
    /// lock acquisition so the pair is consistent.
    pub fn snapshot(&self) -> (Vec<T>, u64) {
        let st = self.inner.lock().unwrap();
        (st.buf.iter().map(|(_, e)| e.clone()).collect(), st.dropped)
    }

    /// Sequence numbers of the retained items, oldest first (the loom
    /// models assert these stay strictly increasing mid-eviction).
    pub fn seqs(&self) -> Vec<u64> {
        self.inner.lock().unwrap().buf.iter().map(|(s, _)| *s).collect()
    }
}
