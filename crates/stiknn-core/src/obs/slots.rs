//! A fixed-capacity, lossy slot ring: lock-free sequence claim, per-slot
//! mutexes, wrap-around overwrite.
//!
//! The generic core of the tracer's span store ([`super::trace`]),
//! extracted so the loom harness (`verify/loom`, see [`super::sync`]) can
//! include this file verbatim and model-check concurrent record vs.
//! eviction vs. snapshot. Must stay dependency-free (std + the sync shim
//! only) and `#[cfg(test)]`-free — unit tests live in `obs/trace.rs`,
//! loom models in `verify/loom/tests/models.rs`.

use super::sync::{AtomicU64, Mutex, Ordering::Relaxed};

/// Writers claim a globally unique sequence number with one relaxed
/// `fetch_add`, then write `(seq, item)` into slot `seq % capacity` under
/// that slot's mutex. Old items are overwritten, never blocked on — a
/// busy ring loses history, not throughput.
///
/// Invariants (loom-checked in `verify/loom/tests/models.rs`):
///
/// * sequence numbers are unique and dense (0, 1, 2, …);
/// * a slot always holds an internally consistent `(seq, item)` pair —
///   never a torn mix of two writers;
/// * at most `capacity` items are retained and every retained pair was
///   genuinely pushed. (Two writers racing the SAME slot may land in
///   either order — the ring is lossy by design, so a slow writer can
///   overwrite a newer seq; what can never happen is a torn pair.)
/// * a concurrent `collect` sees only whole pairs, in seq order.
pub struct SlotRing<T> {
    slots: Box<[Mutex<Option<(u64, T)>>]>,
    cursor: AtomicU64,
}

impl<T: Clone> SlotRing<T> {
    /// A ring with `cap` slots (a degenerate cap of 0 clamps to 1
    /// instead of panicking).
    pub fn new(cap: usize) -> Self {
        SlotRing {
            slots: (0..cap.max(1)).map(|_| Mutex::new(None)).collect(),
            cursor: AtomicU64::new(0),
        }
    }

    /// Claim the next sequence number and store `make(seq)` in its slot,
    /// overwriting whatever was there. Returns the seq.
    pub fn push_with<F: FnOnce(u64) -> T>(&self, make: F) -> u64 {
        let seq = self.cursor.fetch_add(1, Relaxed);
        let slot = (seq % self.slots.len() as u64) as usize;
        let item = make(seq);
        *self.slots[slot].lock().unwrap() = Some((seq, item));
        seq
    }

    /// Items ever pushed (the next sequence number).
    pub fn pushed(&self) -> u64 {
        self.cursor.load(Relaxed)
    }

    /// Items pushed beyond capacity, i.e. overwritten at least once.
    pub fn dropped(&self) -> u64 {
        self.pushed().saturating_sub(self.slots.len() as u64)
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Clone every retained `(seq, item)` pair, sorted by sequence
    /// number (oldest first). Slots are locked one at a time, so a
    /// concurrent writer can slip between slots — each pair is still
    /// whole, which is the contract callers (and the loom models,
    /// which assert item-against-seq consistency) rely on.
    pub fn pairs(&self) -> Vec<(u64, T)> {
        let mut pairs: Vec<(u64, T)> = self
            .slots
            .iter()
            .filter_map(|s| s.lock().unwrap().clone())
            .collect();
        pairs.sort_by_key(|(seq, _)| *seq);
        pairs
    }

    /// Retained items passing `keep`, in seq order.
    pub fn collect<F: Fn(&T) -> bool>(&self, keep: F) -> Vec<T> {
        self.pairs()
            .into_iter()
            .filter(|(_, item)| keep(item))
            .map(|(_, item)| item)
            .collect()
    }
}
