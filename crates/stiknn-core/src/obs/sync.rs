//! Synchronization-primitive shim for the obs concurrency core.
//!
//! The event ring ([`super::ring`]), the span slot ring
//! ([`super::slots`]) and the atomic metric primitives
//! ([`super::counters`]) import every atomic/lock through this module
//! instead of naming `std::sync` directly. Under a normal build the shim
//! is a zero-cost re-export of `std`; under `--cfg loom` it re-exports
//! [loom](https://docs.rs/loom)'s model-checked doubles, which is what
//! lets `verify/loom` (a CI-only harness crate, excluded from the
//! workspace so the offline tier-1 build never resolves the loom
//! dependency) include these files verbatim via `#[path]` and explore
//! every interleaving of their lock-free cores exhaustively.
//!
//! The `loom` arm is never compiled inside `stiknn-core` itself: nothing
//! in the main workspace passes `--cfg loom`, so the crate keeps its
//! zero-dependency layering contract.
//!
//! Keep this module (and the three modules above) dependency-free — no
//! `crate::` imports — or the `#[path]` inclusion breaks.

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
#[cfg(not(loom))]
pub use std::sync::Mutex;

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicI64, AtomicU64, Ordering};
#[cfg(loom)]
pub use loom::sync::Mutex;

/// `fetch_max` with relaxed ordering, spelled as a named helper so the
/// one call site (histogram max tracking) reads its ordering contract in
/// the function name. Loom models RMW ops through `compare_exchange`, so
/// the loom arm is the CAS loop the native instruction means anyway.
#[cfg(not(loom))]
pub fn fetch_max_relaxed(a: &AtomicU64, val: u64) -> u64 {
    a.fetch_max(val, Ordering::Relaxed)
}

#[cfg(loom)]
pub fn fetch_max_relaxed(a: &AtomicU64, val: u64) -> u64 {
    let mut cur = a.load(Ordering::Relaxed);
    loop {
        if cur >= val {
            return cur;
        }
        match a.compare_exchange_weak(cur, val, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(prev) => return prev,
            Err(next) => cur = next,
        }
    }
}
