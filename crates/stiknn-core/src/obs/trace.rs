//! Request-scoped distributed tracing (DESIGN.md §16).
//!
//! A [`Span`] is one timed unit of work: a trace id shared by every span
//! of one request, its own span id, an optional parent span id, a
//! monotonic start offset and duration, and free-form string fields
//! (engine, command, session, …). Finished spans land in a bounded span
//! store inside the process's [`TraceHandle`] — a fixed slot ring
//! indexed by an atomic cursor, so recording a span is one relaxed
//! `fetch_add` plus an uncontended per-slot lock (writers only meet on a
//! slot after the ring wraps a full capacity, and never block each
//! other's cursor).
//!
//! Distribution works by value, not by collector: trace context travels
//! as an optional `"trace": {"id","parent"}` object on NDJSON request
//! frames, the remote side runs its spans under the caller's ids, and
//! echoes the finished spans back on the response (`"spans": [...]`) so
//! a `ShardedSession` fan-out re-imports every member's subtree into the
//! coordinator's own store — ONE tree under the coordinator's root span,
//! assembled without any shared backend.
//!
//! # Sampling
//!
//! [`TraceMode::Sampled(n)`] admits every n-th ROOT span; the decision
//! is made once where the trace starts. Child and adopted (propagated)
//! spans always record — by the time context reaches a member, the root
//! already paid for the trace.
//!
//! # Zero overhead when off
//!
//! The same contract as [`ObsHandle`](super::ObsHandle): a disabled
//! handle is `None` inside, every operation is a branch on that option —
//! no clock reads, no id allocation, and the span store is never even
//! constructed. `tests/obs_invariants.rs` proves results are
//! bit-identical with tracing off, on, and sampled.

use super::slots::SlotRing;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Default bounded span-store capacity (spans retained per process).
pub const SPAN_STORE_CAP: usize = 2048;

/// Span ids must be unique across every process that contributes to one
/// tree, without coordination: low 24 bits of the pid in the high half,
/// a process-wide counter in the low half.
///
/// Stays a `std` atomic (not the [`super::sync`] shim): loom atomics
/// have no `const fn new`, and this global id well is trivially a single
/// `fetch_add` — the loom models cover the span *store* ([`SlotRing`]),
/// which is where the interesting interleavings live.
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

fn fresh_id() -> u64 {
    let pid = (std::process::id() as u64) & 0xFF_FFFF;
    (pid << 40) | (NEXT_ID.fetch_add(1, Ordering::Relaxed) & 0xFF_FFFF_FFFF)
}

/// Render a span/trace id the way the protocol and logs spell it.
pub fn hex_id(id: u64) -> String {
    format!("{id:016x}")
}

/// Parse a [`hex_id`]-formatted id.
pub fn parse_hex_id(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

/// The coordinates a span hands to its children (and to remote members
/// via the `"trace"` request field).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanCtx {
    pub trace_id: u64,
    pub span_id: u64,
}

/// One finished span as stored and shipped.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Store-local arrival order (NOT shipped; reassigned on import).
    pub seq: u64,
    pub trace_id: u64,
    pub span_id: u64,
    pub parent_id: Option<u64>,
    pub name: String,
    /// Microseconds since the recording store's epoch — comparable
    /// within one process, ordering-only across processes.
    pub start_us: u64,
    pub dur_ns: u64,
    pub fields: Vec<(String, String)>,
}

impl SpanRecord {
    /// Wire form (ids as 16-hex strings: u64 does not survive f64 JSON).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("trace", Json::str(hex_id(self.trace_id))),
            ("span", Json::str(hex_id(self.span_id))),
            ("name", Json::str(self.name.clone())),
            ("start_us", Json::num(self.start_us as f64)),
            ("dur_ns", Json::num(self.dur_ns as f64)),
        ];
        if let Some(p) = self.parent_id {
            pairs.push(("parent", Json::str(hex_id(p))));
        }
        if !self.fields.is_empty() {
            pairs.push((
                "fields",
                Json::obj(
                    self.fields
                        .iter()
                        .map(|(k, v)| (k.as_str(), Json::str(v.clone())))
                        .collect(),
                ),
            ));
        }
        Json::obj(pairs)
    }

    /// Inverse of [`Self::to_json`]; `seq` comes back 0 (the importing
    /// store assigns its own arrival order).
    pub fn from_json(v: &Json) -> Option<SpanRecord> {
        let trace_id = parse_hex_id(v.get("trace")?.as_str()?)?;
        let span_id = parse_hex_id(v.get("span")?.as_str()?)?;
        let parent_id = match v.get("parent") {
            Some(p) => Some(parse_hex_id(p.as_str()?)?),
            None => None,
        };
        let name = v.get("name")?.as_str()?.to_string();
        let start_us = v.get("start_us")?.as_f64()? as u64;
        let dur_ns = v.get("dur_ns")?.as_f64()? as u64;
        let mut fields = Vec::new();
        if let Some(obj) = v.get("fields").and_then(Json::as_obj) {
            for (k, val) in obj {
                fields.push((k.clone(), val.as_str()?.to_string()));
            }
        }
        Some(SpanRecord {
            seq: 0,
            trace_id,
            span_id,
            parent_id,
            name,
            start_us,
            dur_ns,
            fields,
        })
    }
}

/// Whether (and how often) root spans are admitted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceMode {
    Off,
    On,
    /// Admit every n-th root span (n ≥ 1; 1 behaves like [`TraceMode::On`]).
    Sampled(u64),
}

impl TraceMode {
    /// Parse the `serve --trace` / protocol spelling: `on`, `off`, or
    /// `sampled:N`.
    pub fn parse(s: &str) -> Result<TraceMode, String> {
        match s {
            "on" => Ok(TraceMode::On),
            "off" => Ok(TraceMode::Off),
            _ => match s.strip_prefix("sampled:") {
                Some(n) => match n.parse::<u64>() {
                    Ok(n) if n >= 1 => Ok(TraceMode::Sampled(n)),
                    _ => Err(format!("sampled:N needs an integer N >= 1 (got '{n}')")),
                },
                None => Err(format!("expected on|off|sampled:N (got '{s}')")),
            },
        }
    }

    pub fn label(&self) -> String {
        match self {
            TraceMode::Off => "off".to_string(),
            TraceMode::On => "on".to_string(),
            TraceMode::Sampled(n) => format!("sampled:{n}"),
        }
    }
}

/// The per-process recording state behind an enabled handle.
struct Tracer {
    epoch: Instant,
    mode: TraceMode,
    /// The loom-modeled slot ring ([`super::slots`]): an atomic cursor
    /// claims a seq, slot `seq % cap` holds the record, so the newest
    /// `cap` spans survive.
    store: SlotRing<SpanRecord>,
    /// Root-span attempts, for the every-n-th sampling decision.
    roots_seen: AtomicU64,
}

impl Tracer {
    fn push(&self, mut rec: SpanRecord) {
        self.store.push_with(|seq| {
            rec.seq = seq;
            rec
        });
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn collect<F: Fn(&SpanRecord) -> bool>(&self, keep: F) -> Vec<SpanRecord> {
        self.store.collect(keep)
    }
}

/// Cloneable tracing handle: `None` inside when disabled (the
/// zero-overhead default), a shared [`Tracer`] when enabled. Clones
/// share the same span store, which is how the server registry, every
/// session, and the shard coordinator all record into one tree.
#[derive(Clone, Default)]
pub struct TraceHandle {
    tracer: Option<Arc<Tracer>>,
}

impl TraceHandle {
    /// The no-op handle: never reads a clock, never touches a store.
    pub fn disabled() -> TraceHandle {
        TraceHandle { tracer: None }
    }

    /// Record every root span, default store capacity.
    pub fn enabled() -> TraceHandle {
        Self::with_mode(TraceMode::On)
    }

    /// Handle for a parsed `--trace` mode ([`TraceMode::Off`] yields the
    /// disabled handle).
    pub fn with_mode(mode: TraceMode) -> TraceHandle {
        Self::with_mode_and_cap(mode, SPAN_STORE_CAP)
    }

    /// [`Self::with_mode`] with an explicit span-store capacity.
    pub fn with_mode_and_cap(mode: TraceMode, cap: usize) -> TraceHandle {
        if mode == TraceMode::Off {
            return Self::disabled();
        }
        TraceHandle {
            tracer: Some(Arc::new(Tracer {
                epoch: super::now(),
                mode,
                store: SlotRing::new(cap),
                roots_seen: AtomicU64::new(0),
            })),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.tracer.is_some()
    }

    /// The configured mode ([`TraceMode::Off`] when disabled).
    pub fn mode(&self) -> TraceMode {
        self.tracer.as_ref().map_or(TraceMode::Off, |t| t.mode)
    }

    /// Start a new trace: a root span, subject to the sampling mode.
    pub fn root(&self, name: &str) -> Span {
        let Some(t) = &self.tracer else {
            return Span { inner: None };
        };
        let k = t.roots_seen.fetch_add(1, Ordering::Relaxed);
        if let TraceMode::Sampled(n) = t.mode {
            if k % n != 0 {
                return Span { inner: None };
            }
        }
        self.start(t.clone(), fresh_id(), None, name)
    }

    /// Start a span under `parent` when the caller is inside a sampled
    /// trace, or a fresh (sampling-gated) root when it is not — the
    /// one-liner for layers that run both standalone and per-request.
    pub fn span_under(&self, parent: Option<SpanCtx>, name: &str) -> Span {
        match parent {
            Some(p) => self.child(p, name),
            None => self.root(name),
        }
    }

    /// Start a child span. Always records (the sampling decision was
    /// made at the root that produced `parent`).
    pub fn child(&self, parent: SpanCtx, name: &str) -> Span {
        let Some(t) = &self.tracer else {
            return Span { inner: None };
        };
        self.start_ids(t.clone(), parent.trace_id, Some(parent.span_id), name)
    }

    /// Join a trace that arrived over the wire: run `name` under the
    /// remote caller's trace and parent-span ids. Always records.
    pub fn adopt(&self, trace_id: u64, parent_id: u64, name: &str) -> Span {
        let Some(t) = &self.tracer else {
            return Span { inner: None };
        };
        self.start_ids(t.clone(), trace_id, Some(parent_id), name)
    }

    fn start(&self, t: Arc<Tracer>, trace_id: u64, parent_id: Option<u64>, name: &str) -> Span {
        Span {
            inner: Some(SpanInner {
                start_us: t.now_us(),
                tracer: t,
                trace_id,
                span_id: trace_id,
                parent_id,
                name: name.to_string(),
                start: super::now(),
                fields: Vec::new(),
            }),
        }
    }

    fn start_ids(&self, t: Arc<Tracer>, trace_id: u64, parent_id: Option<u64>, name: &str) -> Span {
        Span {
            inner: Some(SpanInner {
                start_us: t.now_us(),
                tracer: t,
                trace_id,
                span_id: fresh_id(),
                parent_id,
                name: name.to_string(),
                start: super::now(),
                fields: Vec::new(),
            }),
        }
    }

    /// Record a pre-measured span (the coordinator pipeline's phase
    /// spans carry cumulative cross-worker busy time measured by
    /// [`Progress`](crate::coordinator::progress::Progress), not a live
    /// clock window). Returns the new span's id so callers can nest
    /// further synthetic children (`coord.prep.kernel` under
    /// `coord.prep`); 0 when disabled.
    pub fn record_synth(
        &self,
        trace_id: u64,
        parent_id: u64,
        name: &str,
        dur_ns: u64,
        fields: &[(&str, String)],
    ) -> u64 {
        let Some(t) = &self.tracer else { return 0 };
        let span_id = fresh_id();
        let now = t.now_us();
        t.push(SpanRecord {
            seq: 0,
            trace_id,
            span_id,
            parent_id: Some(parent_id),
            name: name.to_string(),
            start_us: now.saturating_sub(dur_ns / 1_000),
            dur_ns,
            fields: fields
                .iter()
                .map(|(k, v)| (k.to_string(), v.clone()))
                .collect(),
        });
        span_id
    }

    /// Import a span that finished in ANOTHER process (a member's echo):
    /// ids are preserved — that is what stitches the tree — while the
    /// arrival order is local.
    pub fn import(&self, rec: SpanRecord) {
        if let Some(t) = &self.tracer {
            t.push(rec);
        }
    }

    /// Store watermark: records pushed so far. `spans_since(id, mark)`
    /// with a mark taken before a command isolates that command's spans.
    pub fn seq(&self) -> u64 {
        self.tracer.as_ref().map_or(0, |t| t.store.pushed())
    }

    /// Spans recorded past the ring's capacity (oldest-evicted count).
    pub fn dropped(&self) -> u64 {
        self.tracer.as_ref().map_or(0, |t| t.store.dropped())
    }

    /// Every retained span of one trace, in arrival order.
    pub fn spans_of(&self, trace_id: u64) -> Vec<SpanRecord> {
        self.tracer
            .as_ref()
            .map_or(Vec::new(), |t| t.collect(|r| r.trace_id == trace_id))
    }

    /// [`Self::spans_of`] restricted to records pushed at or after a
    /// [`Self::seq`] watermark.
    pub fn spans_since(&self, trace_id: u64, mark: u64) -> Vec<SpanRecord> {
        self.tracer.as_ref().map_or(Vec::new(), |t| {
            t.collect(|r| r.trace_id == trace_id && r.seq >= mark)
        })
    }

    /// The newest retained root spans (no parent), newest first, at most
    /// `limit`.
    pub fn recent_roots(&self, limit: usize) -> Vec<SpanRecord> {
        let Some(t) = &self.tracer else {
            return Vec::new();
        };
        let mut roots = t.collect(|r| r.parent_id.is_none());
        roots.reverse();
        roots.truncate(limit);
        roots
    }
}

struct SpanInner {
    tracer: Arc<Tracer>,
    trace_id: u64,
    span_id: u64,
    parent_id: Option<u64>,
    name: String,
    start: Instant,
    start_us: u64,
    fields: Vec<(String, String)>,
}

/// A live span: records itself into the store when finished or dropped.
/// A span from a disabled handle (or a sampled-out root) is inert —
/// every method is a no-op and nothing is ever recorded.
pub struct Span {
    inner: Option<SpanInner>,
}

impl Span {
    /// An inert span that records nothing — for call sites that need a
    /// span variable on paths where no parent context exists (a child
    /// position must never fall back to starting a fresh root).
    pub fn noop() -> Span {
        Span { inner: None }
    }

    /// Is this span actually recording (enabled handle, sampled in)?
    pub fn is_recording(&self) -> bool {
        self.inner.is_some()
    }

    /// The coordinates children and remote members record under.
    pub fn ctx(&self) -> Option<SpanCtx> {
        self.inner.as_ref().map(|i| SpanCtx {
            trace_id: i.trace_id,
            span_id: i.span_id,
        })
    }

    /// Attach a string field (engine, command, session, …). No-op on an
    /// inert span.
    pub fn field(&mut self, key: &str, value: impl Into<String>) {
        if let Some(i) = &mut self.inner {
            i.fields.push((key.to_string(), value.into()));
        }
    }

    /// Finish now (Drop does the same; this spells out intent at the
    /// end of a measured window).
    pub fn finish(self) {}
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(i) = self.inner.take() {
            let dur_ns = i.start.elapsed().as_nanos() as u64;
            i.tracer.push(SpanRecord {
                seq: 0,
                trace_id: i.trace_id,
                span_id: i.span_id,
                parent_id: i.parent_id,
                name: i.name,
                start_us: i.start_us,
                dur_ns,
                fields: i.fields,
            });
        }
    }
}

fn fmt_dur(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.3}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1}us", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

/// Render spans as an indented tree with per-span self-time (duration
/// minus DIRECT children, clamped at zero — coordinator phase spans
/// carry cumulative busy time across workers, which can exceed the
/// parent's wall clock). Spans whose parent is not in the set (e.g. a
/// member store queried for a trace rooted elsewhere) print at top
/// level.
pub fn render_tree(spans: &[SpanRecord]) -> String {
    let present: std::collections::BTreeSet<u64> = spans.iter().map(|r| r.span_id).collect();
    let mut children: std::collections::BTreeMap<u64, Vec<&SpanRecord>> =
        std::collections::BTreeMap::new();
    let mut tops: Vec<&SpanRecord> = Vec::new();
    for r in spans {
        match r.parent_id {
            Some(p) if present.contains(&p) && p != r.span_id => {
                children.entry(p).or_default().push(r)
            }
            _ => tops.push(r),
        }
    }
    let order = |v: &mut Vec<&SpanRecord>| v.sort_by_key(|r| (r.start_us, r.seq, r.span_id));
    tops.sort_by_key(|r| (r.start_us, r.seq, r.span_id));
    for v in children.values_mut() {
        order(v);
    }
    let mut out = String::new();
    fn walk(
        r: &SpanRecord,
        depth: usize,
        children: &std::collections::BTreeMap<u64, Vec<&SpanRecord>>,
        out: &mut String,
    ) {
        let kids = children.get(&r.span_id);
        let child_ns: u64 = kids
            .map(|v| v.iter().map(|c| c.dur_ns).sum())
            .unwrap_or(0);
        let self_ns = r.dur_ns.saturating_sub(child_ns);
        let mut line = format!(
            "{}{}  {}  self={}",
            "  ".repeat(depth),
            r.name,
            fmt_dur(r.dur_ns),
            fmt_dur(self_ns)
        );
        if depth == 0 {
            line.push_str(&format!("  trace={}", hex_id(r.trace_id)));
        }
        for (k, v) in &r.fields {
            line.push_str(&format!("  {k}={v}"));
        }
        line.push('\n');
        out.push_str(&line);
        if let Some(kids) = kids {
            for c in kids {
                walk(c, depth + 1, children, out);
            }
        }
    }
    for r in &tops {
        walk(r, 0, &children, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handle_records_nothing() {
        let t = TraceHandle::disabled();
        assert!(!t.is_enabled());
        assert_eq!(t.mode(), TraceMode::Off);
        let mut s = t.root("anything");
        assert!(!s.is_recording());
        assert!(s.ctx().is_none());
        s.field("k", "v");
        s.finish();
        assert_eq!(t.seq(), 0);
        assert!(t.recent_roots(10).is_empty());
    }

    #[test]
    fn off_mode_is_the_disabled_handle() {
        assert!(!TraceHandle::with_mode(TraceMode::Off).is_enabled());
    }

    #[test]
    fn root_child_share_a_trace_and_nest() {
        let t = TraceHandle::enabled();
        let root = t.root("req");
        let rc = root.ctx().unwrap();
        let child = t.child(rc, "work");
        let cc = child.ctx().unwrap();
        assert_eq!(cc.trace_id, rc.trace_id);
        assert_ne!(cc.span_id, rc.span_id);
        child.finish();
        root.finish();
        let spans = t.spans_of(rc.trace_id);
        assert_eq!(spans.len(), 2);
        // Child finished first, so it arrives first.
        assert_eq!(spans[0].name, "work");
        assert_eq!(spans[0].parent_id, Some(rc.span_id));
        assert_eq!(spans[1].name, "req");
        assert_eq!(spans[1].parent_id, None);
    }

    #[test]
    fn sampled_admits_every_nth_root_but_every_child() {
        let t = TraceHandle::with_mode(TraceMode::Sampled(3));
        let recorded: Vec<bool> = (0..9).map(|_| t.root("r").is_recording()).collect();
        assert_eq!(
            recorded,
            [true, false, false, true, false, false, true, false, false]
        );
        // Adopted spans ignore sampling: the root already decided.
        assert!(t.adopt(7, 9, "member").is_recording());
    }

    #[test]
    fn span_store_is_bounded_and_counts_drops() {
        let t = TraceHandle::with_mode_and_cap(TraceMode::On, 4);
        let root = t.root("keeper");
        let ctx = root.ctx().unwrap();
        root.finish();
        for i in 0..10 {
            t.root(&format!("r{i}")).finish();
        }
        assert_eq!(t.seq(), 11);
        assert_eq!(t.dropped(), 7);
        // The keeper was evicted; only the newest 4 remain.
        assert!(t.spans_of(ctx.trace_id).is_empty());
        let roots = t.recent_roots(100);
        assert_eq!(roots.len(), 4);
        assert_eq!(roots[0].name, "r9"); // newest first
    }

    #[test]
    fn record_json_roundtrip() {
        let t = TraceHandle::enabled();
        let mut s = t.root("req");
        s.field("session", "plain");
        s.field("engine", "dense");
        let ctx = s.ctx().unwrap();
        s.finish();
        let rec = &t.spans_of(ctx.trace_id)[0];
        let back = SpanRecord::from_json(&rec.to_json()).unwrap();
        assert_eq!(back.trace_id, rec.trace_id);
        assert_eq!(back.span_id, rec.span_id);
        assert_eq!(back.parent_id, rec.parent_id);
        assert_eq!(back.name, rec.name);
        assert_eq!(back.start_us, rec.start_us);
        assert_eq!(back.dur_ns, rec.dur_ns);
        // The wire form is a sorted map, so compare fields order-free.
        let (mut a, mut b) = (back.fields.clone(), rec.fields.clone());
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn import_preserves_ids_and_spans_since_isolates() {
        let t = TraceHandle::enabled();
        let root = t.root("req");
        let ctx = root.ctx().unwrap();
        let mark = t.seq();
        t.import(SpanRecord {
            seq: 999, // overwritten by the importing store
            trace_id: ctx.trace_id,
            span_id: 0xabc,
            parent_id: Some(ctx.span_id),
            name: "remote".into(),
            start_us: 5,
            dur_ns: 1_000,
            fields: vec![("member".into(), "1".into())],
        });
        root.finish();
        let since = t.spans_since(ctx.trace_id, mark);
        assert_eq!(since.len(), 2);
        assert_eq!(since[0].span_id, 0xabc);
        assert_eq!(since[0].parent_id, Some(ctx.span_id));
    }

    #[test]
    fn synth_spans_nest_under_their_parent() {
        let t = TraceHandle::enabled();
        let root = t.root("ingest");
        let ctx = root.ctx().unwrap();
        let prep = t.record_synth(ctx.trace_id, ctx.span_id, "coord.prep", 5_000, &[]);
        assert_ne!(prep, 0);
        t.record_synth(ctx.trace_id, prep, "coord.prep.kernel", 2_000, &[]);
        root.finish();
        let spans = t.spans_of(ctx.trace_id);
        assert_eq!(spans.len(), 3);
        let kernel = spans.iter().find(|s| s.name == "coord.prep.kernel").unwrap();
        assert_eq!(kernel.parent_id, Some(prep));
        assert_eq!(kernel.dur_ns, 2_000);
    }

    #[test]
    fn mode_parse_roundtrip() {
        assert_eq!(TraceMode::parse("on").unwrap(), TraceMode::On);
        assert_eq!(TraceMode::parse("off").unwrap(), TraceMode::Off);
        assert_eq!(TraceMode::parse("sampled:5").unwrap(), TraceMode::Sampled(5));
        assert!(TraceMode::parse("sampled:0").is_err());
        assert!(TraceMode::parse("sampled:x").is_err());
        assert!(TraceMode::parse("maybe").is_err());
        assert_eq!(TraceMode::Sampled(5).label(), "sampled:5");
    }

    #[test]
    fn render_tree_indents_and_reports_self_time() {
        let spans = vec![
            SpanRecord {
                seq: 0,
                trace_id: 1,
                span_id: 10,
                parent_id: None,
                name: "root".into(),
                start_us: 0,
                dur_ns: 10_000,
                fields: vec![("cmd".into(), "values".into())],
            },
            SpanRecord {
                seq: 1,
                trace_id: 1,
                span_id: 11,
                parent_id: Some(10),
                name: "kid".into(),
                start_us: 1,
                dur_ns: 4_000,
                fields: vec![],
            },
        ];
        let out = render_tree(&spans);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("root  10.0us  self=6.0us"));
        assert!(lines[0].contains("trace=0000000000000001"));
        assert!(lines[0].contains("cmd=values"));
        assert!(lines[1].starts_with("  kid  4.0us  self=4.0us"));
    }

    #[test]
    fn hex_ids_roundtrip() {
        let id = fresh_id();
        assert_eq!(parse_hex_id(&hex_id(id)), Some(id));
        assert!(parse_hex_id("zz").is_none());
    }
}
