//! ASCII heatmap renderer for interaction matrices — the terminal
//! equivalent of the paper's Figs. 3–5 and 7–10. Downsamples the matrix
//! to a character grid and maps values through a symmetric diverging
//! ramp (negative → '#', zero → ' ', positive → '+' side), with the scale
//! printed so figures are comparable across k (Corollary 1's effect is
//! visible as a scale change, not a pattern change).

use crate::util::matrix::Matrix;

const NEG_RAMP: [char; 5] = ['·', '-', '=', '%', '#'];
const POS_RAMP: [char; 5] = ['·', ':', '*', 'o', '@'];

/// Render `m` as an ASCII heatmap of at most `max_cells` columns/rows.
/// `perm` optionally reorders rows/cols first (the paper's class-then-
/// feature display order).
pub fn render_heatmap(m: &Matrix, perm: Option<&[usize]>, max_cells: usize) -> String {
    assert!(m.rows() == m.cols() && m.rows() > 0);
    let view = match perm {
        Some(p) => m.permuted(p),
        None => m.clone(),
    };
    let n = view.rows();
    let cells = n.min(max_cells.max(4));
    // bucket means
    let mut grid = vec![vec![0.0f64; cells]; cells];
    for (gi, row) in grid.iter_mut().enumerate() {
        let ilo = gi * n / cells;
        let ihi = ((gi + 1) * n / cells).max(ilo + 1);
        for (gj, cell) in row.iter_mut().enumerate() {
            let jlo = gj * n / cells;
            let jhi = ((gj + 1) * n / cells).max(jlo + 1);
            let mut acc = 0.0;
            let mut cnt = 0usize;
            for i in ilo..ihi {
                for j in jlo..jhi {
                    acc += view.get(i, j);
                    cnt += 1;
                }
            }
            *cell = acc / cnt as f64;
        }
    }
    let scale = grid
        .iter()
        .flatten()
        .map(|v| v.abs())
        .fold(0.0f64, f64::max);
    let mut out = String::new();
    out.push_str(&format!(
        "interaction heatmap {n}×{n} (cells {cells}×{cells}, |max| = {scale:.3e})\n"
    ));
    out.push_str(&format!("  neg: {} … pos: {}\n", NEG_RAMP[4], POS_RAMP[4]));
    for row in &grid {
        out.push(' ');
        for &v in row {
            out.push(bucket_char(v, scale));
        }
        out.push('\n');
    }
    out
}

fn bucket_char(v: f64, scale: f64) -> char {
    if scale == 0.0 {
        return ' ';
    }
    let t = (v / scale).clamp(-1.0, 1.0);
    let mag = (t.abs() * 4.999) as usize;
    if t.abs() < 0.04 {
        ' '
    } else if t < 0.0 {
        NEG_RAMP[mag]
    } else {
        POS_RAMP[mag]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_expected_dimensions() {
        let mut m = Matrix::zeros(10, 10);
        m.set(0, 0, -1.0);
        m.set(9, 9, 1.0);
        let s = render_heatmap(&m, None, 10);
        let grid_lines: Vec<&str> = s.lines().skip(2).collect();
        assert_eq!(grid_lines.len(), 10);
        assert!(grid_lines.iter().all(|l| l.len() == 11));
    }

    #[test]
    fn negative_and_positive_use_different_ramps() {
        let mut m = Matrix::zeros(4, 4);
        m.set(0, 0, -5.0);
        m.set(3, 3, 5.0);
        let s = render_heatmap(&m, None, 4);
        assert!(s.contains('#'));
        assert!(s.contains('@'));
    }

    #[test]
    fn downsamples_large_matrices() {
        let m = Matrix::zeros(500, 500);
        let s = render_heatmap(&m, None, 40);
        assert!(s.lines().count() <= 43);
    }

    #[test]
    fn permutation_reorders_display() {
        let mut m = Matrix::zeros(2, 2);
        m.set(0, 0, -1.0);
        let straight = render_heatmap(&m, None, 2);
        let flipped = render_heatmap(&m, Some(&[1, 0]), 2);
        assert_ne!(straight, flipped);
    }

    #[test]
    fn zero_matrix_is_blank() {
        let m = Matrix::zeros(6, 6);
        let s = render_heatmap(&m, None, 6);
        let body: String = s.lines().skip(2).collect();
        assert!(body.trim().is_empty());
    }
}
