//! Reporting: ASCII heatmaps (the terminal stand-in for the paper's
//! matplotlib figures), aligned tables and experiment-record helpers.
//! Session snapshot/top-k formatting lives in the facade crate
//! (`stiknn::report::session`) — it renders session/server types this
//! core crate deliberately does not depend on.

pub mod heatmap;
pub mod table;

pub use heatmap::render_heatmap;
pub use table::Table;
