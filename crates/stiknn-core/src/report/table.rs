//! Aligned text tables for bench output and the paper-style experiment
//! rows printed by the examples.

/// A simple column-aligned table builder.
#[derive(Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:<w$}", cell, w = widths[c]));
            }
            s.trim_end().to_string()
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligns_columns() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1.5".into()]);
        t.row(&["longer".into(), "2".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "name    value");
        assert_eq!(lines[2], "a       1.5");
        assert_eq!(lines[3], "longer  2");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new(&["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn rowf_accepts_display() {
        let mut t = Table::new(&["n", "time"]);
        t.rowf(&[&128usize, &"1.5ms"]);
        assert_eq!(t.n_rows(), 1);
        assert!(t.render().contains("128"));
    }
}
