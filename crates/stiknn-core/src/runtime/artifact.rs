//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. The manifest enumerates each AOT-lowered program with its
//! baked shapes; the runtime picks an artifact by (program, n, d, k) and
//! pads the test block up to the artifact's block size `b` using the mask
//! input. Train size n must match exactly — Algorithm 1's coefficients
//! depend on n, so train padding would change the answer (DESIGN.md §2).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled program instance.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub program: String,
    pub n: usize,
    pub d: usize,
    pub b: usize,
    pub k: usize,
}

/// The parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        if root.get("interchange").and_then(Json::as_str) != Some("hlo-text") {
            bail!("{path:?}: unsupported interchange format");
        }
        let mut artifacts = Vec::new();
        for entry in root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{path:?}: missing artifacts array"))?
        {
            let get_s = |k: &str| -> Result<String> {
                Ok(entry
                    .get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{path:?}: artifact missing '{k}'"))?
                    .to_string())
            };
            let get_n = |k: &str| -> Result<usize> {
                entry
                    .get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("{path:?}: artifact missing '{k}'"))
            };
            let spec = ArtifactSpec {
                name: get_s("name")?,
                file: get_s("file")?,
                program: get_s("program")?,
                n: get_n("n")?,
                d: get_n("d")?,
                b: get_n("b")?,
                k: get_n("k")?,
            };
            if !dir.join(&spec.file).exists() {
                bail!("artifact file missing: {:?}", dir.join(&spec.file));
            }
            artifacts.push(spec);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    /// Absolute path of an artifact's HLO text.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Find an artifact matching (program, n, d, k) exactly. When several
    /// block sizes exist, prefers the largest block ≤ a hint, else the
    /// largest available.
    pub fn find(&self, program: &str, n: usize, d: usize, k: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.program == program && a.n == n && a.d == d && a.k == k)
            .max_by_key(|a| a.b)
    }

    /// All artifacts of a given program type.
    pub fn of_program(&self, program: &str) -> Vec<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.program == program)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("stiknn_manifest_tests").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    const GOOD: &str = r#"{
      "version": 1, "interchange": "hlo-text",
      "artifacts": [
        {"name": "sti_n32_d2_b8_k3", "file": "a.hlo.txt", "program": "sti",
         "n": 32, "d": 2, "b": 8, "k": 3},
        {"name": "sti_n32_d2_b16_k3", "file": "b.hlo.txt", "program": "sti",
         "n": 32, "d": 2, "b": 16, "k": 3}
      ]
    }"#;

    #[test]
    fn load_and_find() {
        let dir = tmpdir("good");
        write_manifest(&dir, GOOD);
        std::fs::write(dir.join("a.hlo.txt"), "HloModule x").unwrap();
        std::fs::write(dir.join("b.hlo.txt"), "HloModule y").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        // prefers the larger block
        let found = m.find("sti", 32, 2, 3).unwrap();
        assert_eq!(found.b, 16);
        assert!(m.find("sti", 33, 2, 3).is_none());
        assert!(m.find("knn_shapley", 32, 2, 3).is_none());
        assert_eq!(m.of_program("sti").len(), 2);
    }

    #[test]
    fn missing_file_rejected() {
        let dir = tmpdir("missing");
        write_manifest(&dir, GOOD);
        std::fs::write(dir.join("a.hlo.txt"), "HloModule x").unwrap();
        // b.hlo.txt absent
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn bad_interchange_rejected() {
        let dir = tmpdir("badfmt");
        write_manifest(
            &dir,
            r#"{"version":1,"interchange":"proto","artifacts":[]}"#,
        );
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn absent_manifest_mentions_make_artifacts() {
        let dir = tmpdir("absent");
        let err = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(err.contains("make artifacts"), "{err}");
    }
}
