//! Compiled-executable cache and typed execution of the AOT artifacts.
//!
//! [`StiExecutor`] binds a PJRT CPU client to one `sti` (or `knn_shapley`)
//! artifact: it marshals f32/i32 slices into XLA literals, pads the test
//! block to the artifact's baked size `b` (padding rows have mask 0 and
//! contribute nothing — the L2 program multiplies every per-test matrix by
//! its mask entry), executes, and unmarshals the partial sums.
//!
//! Compilation happens once per artifact (at construction); execution is
//! allocation-light and thread-safe behind `&self` (the PJRT client
//! serializes execution internally; the coordinator runs one executor per
//! worker when it wants real parallelism).
//!
//! The real PJRT path lives behind the `xla` cargo feature because the
//! offline image does not vendor the `xla` crate closure; the default
//! build compiles a stub whose constructor fails with an actionable
//! message, so `Engine::Xla` jobs fail fast instead of failing to link
//! (DESIGN.md §2). Everything else — the manifest contract, shape checks,
//! the CLI and coordinator plumbing — is identical in both builds.

use super::artifact::Manifest;
use anyhow::{Context, Result};

/// Which computation backend a valuation job uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Pure-Rust Algorithm 1 (any shape).
    Rust,
    /// AOT XLA artifact via PJRT (shape must match an artifact).
    Xla,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "rust" => Some(Engine::Rust),
            "xla" => Some(Engine::Xla),
            _ => None,
        }
    }
}

#[cfg(feature = "xla")]
mod pjrt {
    use super::super::artifact::{ArtifactSpec, Manifest};
    use crate::util::matrix::Matrix;
    use anyhow::{bail, Context, Result};

    /// A compiled STI (or KNN-Shapley) block program bound to fixed shapes.
    pub struct StiExecutor {
        spec: ArtifactSpec,
        exe: xla::PjRtLoadedExecutable,
    }

    impl StiExecutor {
        /// Compile the artifact on a fresh PJRT CPU client.
        pub fn new(manifest: &Manifest, spec: &ArtifactSpec) -> Result<StiExecutor> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Self::with_client(&client, manifest, spec)
        }

        /// Compile the artifact on an existing client (one client can host
        /// many executables).
        pub fn with_client(
            client: &xla::PjRtClient,
            manifest: &Manifest,
            spec: &ArtifactSpec,
        ) -> Result<StiExecutor> {
            let path = manifest.path_of(spec);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", spec.name))?;
            Ok(StiExecutor {
                spec: spec.clone(),
                exe,
            })
        }

        pub fn spec(&self) -> &ArtifactSpec {
            &self.spec
        }

        /// Execute on one test block of size ≤ b. Returns the UNNORMALIZED
        /// (phi_sum, weight) pair for `sti` artifacts, where phi_sum is n×n.
        /// For `knn_shapley` artifacts use [`Self::run_values_block`].
        pub fn run_block(
            &self,
            train_x: &[f32],
            train_y: &[i32],
            test_x: &[f32],
            test_y: &[i32],
        ) -> Result<(Matrix, f64)> {
            if self.spec.program != "sti" {
                bail!("run_block on a {} artifact", self.spec.program);
            }
            let outs = self.execute_padded(train_x, train_y, test_x, test_y)?;
            let (phi_lit, w_lit) = (outs.0, outs.1);
            let n = self.spec.n;
            let phi_f32 = phi_lit.to_vec::<f32>().context("phi_sum to_vec")?;
            if phi_f32.len() != n * n {
                bail!("phi_sum has {} entries, expected {}", phi_f32.len(), n * n);
            }
            let phi = Matrix::from_vec(n, n, phi_f32.into_iter().map(|v| v as f64).collect());
            let w = w_lit.to_vec::<f32>().context("weight to_vec")?[0] as f64;
            Ok((phi, w))
        }

        /// Execute a `knn_shapley` artifact block: returns (s_sum, weight).
        pub fn run_values_block(
            &self,
            train_x: &[f32],
            train_y: &[i32],
            test_x: &[f32],
            test_y: &[i32],
        ) -> Result<(Vec<f64>, f64)> {
            if self.spec.program != "knn_shapley" {
                bail!("run_values_block on a {} artifact", self.spec.program);
            }
            let outs = self.execute_padded(train_x, train_y, test_x, test_y)?;
            let s = outs
                .0
                .to_vec::<f32>()
                .context("s_sum to_vec")?
                .into_iter()
                .map(|v| v as f64)
                .collect();
            let w = outs.1.to_vec::<f32>().context("weight to_vec")?[0] as f64;
            Ok((s, w))
        }

        fn execute_padded(
            &self,
            train_x: &[f32],
            train_y: &[i32],
            test_x: &[f32],
            test_y: &[i32],
        ) -> Result<(xla::Literal, xla::Literal)> {
            let (n, d, b) = (self.spec.n, self.spec.d, self.spec.b);
            if train_y.len() != n || train_x.len() != n * d {
                bail!(
                    "train shape ({}, {}) does not match artifact {} (n={n}, d={d})",
                    train_y.len(),
                    train_x.len(),
                    self.spec.name
                );
            }
            let t = test_y.len();
            if t == 0 || t > b {
                bail!("test block size {t} out of range 1..={b}");
            }
            if test_x.len() != t * d {
                bail!("test_x len {} != t*d = {}", test_x.len(), t * d);
            }
            // pad test block to b with mask 0 (padded features replicate row 0
            // so distances stay finite)
            let mut px = Vec::with_capacity(b * d);
            px.extend_from_slice(test_x);
            let mut py = Vec::with_capacity(b);
            py.extend_from_slice(test_y);
            let mut mask = vec![1.0f32; t];
            for _ in t..b {
                px.extend_from_slice(&test_x[..d]);
                py.push(test_y[0]);
                mask.push(0.0);
            }

            let lit_train_x = xla::Literal::vec1(train_x).reshape(&[n as i64, d as i64])?;
            let lit_train_y = xla::Literal::vec1(train_y);
            let lit_test_x = xla::Literal::vec1(&px).reshape(&[b as i64, d as i64])?;
            let lit_test_y = xla::Literal::vec1(&py);
            let lit_mask = xla::Literal::vec1(&mask);

            let result = self
                .exe
                .execute::<xla::Literal>(&[
                    lit_train_x,
                    lit_train_y,
                    lit_test_x,
                    lit_test_y,
                    lit_mask,
                ])
                .context("PJRT execute")?[0][0]
                .to_literal_sync()?;
            // aot.py lowers with return_tuple=True: (phi_sum, weight)
            Ok(result.to_tuple2()?)
        }
    }
}

#[cfg(not(feature = "xla"))]
mod stub {
    use super::super::artifact::{ArtifactSpec, Manifest};
    use crate::util::matrix::Matrix;
    use anyhow::{bail, Result};

    /// Stub executor for builds without the `xla` feature: construction
    /// always fails, carrying the artifact name and path so failure modes
    /// stay actionable (and testable) without a PJRT runtime.
    pub struct StiExecutor {
        spec: ArtifactSpec,
    }

    impl StiExecutor {
        pub fn new(manifest: &Manifest, spec: &ArtifactSpec) -> Result<StiExecutor> {
            let path = manifest.path_of(spec);
            bail!(
                "cannot compile artifact {} ({}): this build has no XLA/PJRT \
                 runtime (cargo feature `xla` disabled) — rebuild with \
                 `--features xla` and the vendored xla crate, or use \
                 --engine rust",
                spec.name,
                path.display()
            )
        }

        pub fn spec(&self) -> &ArtifactSpec {
            &self.spec
        }

        pub fn run_block(
            &self,
            _train_x: &[f32],
            _train_y: &[i32],
            _test_x: &[f32],
            _test_y: &[i32],
        ) -> Result<(Matrix, f64)> {
            bail!(
                "artifact {}: no XLA/PJRT runtime in this build",
                self.spec.name
            )
        }

        pub fn run_values_block(
            &self,
            _train_x: &[f32],
            _train_y: &[i32],
            _test_x: &[f32],
            _test_y: &[i32],
        ) -> Result<(Vec<f64>, f64)> {
            bail!(
                "artifact {}: no XLA/PJRT runtime in this build",
                self.spec.name
            )
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::StiExecutor;
#[cfg(not(feature = "xla"))]
pub use stub::StiExecutor;

/// Convenience: find + compile the right artifact for a dataset shape.
pub fn executor_for(
    manifest: &Manifest,
    program: &str,
    n: usize,
    d: usize,
    k: usize,
) -> Result<StiExecutor> {
    let spec = manifest.find(program, n, d, k).with_context(|| {
        let available: Vec<String> = manifest
            .of_program(program)
            .iter()
            .map(|a| format!("(n={}, d={}, b={}, k={})", a.n, a.d, a.b, a.k))
            .collect();
        format!(
            "no '{program}' artifact for (n={n}, d={d}, k={k}); available: {} — \
             add the shape to python/compile/aot.py DEFAULT_GRID and re-run \
             `make artifacts`, or use --engine rust",
            available.join(", ")
        )
    })?;
    StiExecutor::new(manifest, spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_parse_roundtrip() {
        assert_eq!(Engine::parse("rust"), Some(Engine::Rust));
        assert_eq!(Engine::parse("xla"), Some(Engine::Xla));
        assert_eq!(Engine::parse("cuda"), None);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_constructor_fails_with_artifact_context() {
        let dir = std::env::temp_dir().join("stiknn_executor_stub_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule m").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"interchange":"hlo-text","artifacts":[
                {"name":"sti_stub","file":"m.hlo.txt","program":"sti",
                 "n":8,"d":2,"b":2,"k":3}]}"#,
        )
        .unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let spec = manifest.find("sti", 8, 2, 3).unwrap();
        let err = StiExecutor::new(&manifest, spec).err().expect("stub must fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("sti_stub"), "{msg}");
        assert!(msg.contains("m.hlo.txt"), "{msg}");
        assert!(msg.contains("--engine rust"), "{msg}");
    }
}
