//! XLA/PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` (`make artifacts`) and executes them on the
//! PJRT CPU client from the Rust hot path — Python never runs at request
//! time.
//!
//! * [`artifact`] — the `artifacts/manifest.json` registry and shape
//!   matching.
//! * [`executor`] — compiled-executable cache plus the typed entry points
//!   ([`executor::StiExecutor`]) that marshal datasets into XLA literals,
//!   handle test-block padding via the mask input, and unmarshal the
//!   partial-sum outputs.

pub mod artifact;
pub mod executor;

pub use artifact::{ArtifactSpec, Manifest};
pub use executor::{executor_for, Engine, StiExecutor};

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";
