//! Axiom checkers for computed interaction matrices — the §3.2 structural
//! claims as executable checks, used by the test suite, the examples and
//! the `axioms` bench:
//!
//! * efficiency: Σ_{i≤j} φ_ij = a_test (upper triangle INCLUDING the
//!   diagonal — the precise form of the paper's claim, DESIGN.md §1)
//! * symmetry: φ_ij = φ_ji
//! * positivity of main terms: φ_ii ≥ 0 (likelihood valuation)
//! * approximate centering: mean(φ) = a_test/n² ≈ 0

use crate::knn::KnnClassifier;
use crate::util::matrix::Matrix;

/// Result of checking one axiom.
#[derive(Clone, Debug)]
pub struct AxiomReport {
    pub name: &'static str,
    pub holds: bool,
    pub observed: f64,
    pub expected: f64,
    pub tolerance: f64,
}

impl AxiomReport {
    fn new(name: &'static str, observed: f64, expected: f64, tol: f64) -> Self {
        AxiomReport {
            name,
            holds: (observed - expected).abs() <= tol,
            observed,
            expected,
            tolerance: tol,
        }
    }
}

/// Check all §3.2 axioms of an averaged STI matrix against its dataset.
pub fn check_all(
    phi: &Matrix,
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    k: usize,
    tol: f64,
) -> Vec<AxiomReport> {
    let n = train_y.len();
    assert_eq!(phi.rows(), n);
    let a_test = KnnClassifier::new(train_x, train_y, d, k).likelihood(test_x, test_y);

    let mut out = Vec::new();

    // Efficiency (upper triangle incl. diagonal sums to a_test).
    out.push(AxiomReport::new(
        "efficiency",
        phi.upper_triangle_sum(),
        a_test,
        tol,
    ));

    // Symmetry (max asymmetry must be ~0).
    let max_asym = (0..n)
        .flat_map(|i| ((i + 1)..n).map(move |j| (i, j)))
        .map(|(i, j)| (phi.get(i, j) - phi.get(j, i)).abs())
        .fold(0.0, f64::max);
    out.push(AxiomReport::new("symmetry", max_asym, 0.0, tol));

    // Main-term positivity (min diagonal entry ≥ 0).
    let min_diag = phi.diagonal().into_iter().fold(f64::INFINITY, f64::min);
    out.push(AxiomReport {
        name: "main_terms_nonnegative",
        holds: min_diag >= -tol,
        observed: min_diag,
        expected: 0.0,
        tolerance: tol,
    });

    // Centering: the paper states mean(φ) = a_test/n² ≈ 0; the exact
    // identity (the paper's proof overlooks that the symmetric matrix
    // double-counts off-diagonal pairs) is
    //   Σ_all φ = 2·Σ_{i≤j} φ − Σ_i φ_ii = 2·a_test − trace,
    // so mean(φ) = (2·a_test − trace)/n² — still O(1/n²)-small, which is
    // the substantive claim. We check the exact identity.
    let trace: f64 = phi.diagonal().iter().sum();
    out.push(AxiomReport::new(
        "centering",
        phi.mean(),
        (2.0 * a_test - trace) / (n * n) as f64,
        tol,
    ));

    out
}

/// True iff every axiom holds.
pub fn all_hold(reports: &[AxiomReport]) -> bool {
    reports.iter().all(|r| r.holds)
}

/// Render the reports as aligned text rows (for examples / CLI output).
pub fn format_reports(reports: &[AxiomReport]) -> String {
    let mut s = String::new();
    for r in reports {
        s.push_str(&format!(
            "  {:<24} {}  observed={:+.6e} expected={:+.6e} (tol {:.1e})\n",
            r.name,
            if r.holds { "OK  " } else { "FAIL" },
            r.observed,
            r.expected,
            r.tolerance
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shapley::sti_knn::{sti_knn, StiParams};
    use crate::util::rng::Rng;

    fn random_problem(seed: u64, n: usize, t: usize, d: usize) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        (
            (0..n * d).map(|_| rng.normal() as f32).collect(),
            (0..n).map(|_| rng.below(2) as i32).collect(),
            (0..t * d).map(|_| rng.normal() as f32).collect(),
            (0..t).map(|_| rng.below(2) as i32).collect(),
        )
    }

    #[test]
    fn all_axioms_hold_for_sti_knn() {
        for seed in 0..5u64 {
            let (tx, ty, sx, sy) = random_problem(seed, 25, 9, 2);
            let phi = sti_knn(&tx, &ty, 2, &sx, &sy, &StiParams::new(5));
            let reports = check_all(&phi, &tx, &ty, 2, &sx, &sy, 5, 1e-9);
            assert!(
                all_hold(&reports),
                "seed {seed}:\n{}",
                format_reports(&reports)
            );
        }
    }

    #[test]
    fn efficiency_detects_corruption() {
        let (tx, ty, sx, sy) = random_problem(9, 12, 4, 2);
        let mut phi = sti_knn(&tx, &ty, 2, &sx, &sy, &StiParams::new(3));
        phi.add_at(0, 5, 0.25); // corrupt one upper-triangle entry
        let reports = check_all(&phi, &tx, &ty, 2, &sx, &sy, 3, 1e-9);
        let eff = reports.iter().find(|r| r.name == "efficiency").unwrap();
        assert!(!eff.holds);
        let sym = reports.iter().find(|r| r.name == "symmetry").unwrap();
        assert!(!sym.holds);
    }

    #[test]
    fn centering_shrinks_with_n() {
        // mean(φ) = (2·a_test − trace)/n² — quadratically small in n
        let (tx, ty, sx, sy) = random_problem(3, 40, 6, 2);
        let phi = sti_knn(&tx, &ty, 2, &sx, &sy, &StiParams::new(5));
        let a_test = KnnClassifier::new(&tx, &ty, 2, 5).likelihood(&sx, &sy);
        let trace: f64 = phi.diagonal().iter().sum();
        assert!((phi.mean() - (2.0 * a_test - trace) / 1600.0).abs() < 1e-12);
        // |mean| ≤ (2·a_test + trace)/n² ~ 1/(n·k): vanishes with n
        assert!(phi.mean().abs() < 5e-3);
    }

    #[test]
    fn format_is_stable() {
        let (tx, ty, sx, sy) = random_problem(1, 10, 3, 2);
        let phi = sti_knn(&tx, &ty, 2, &sx, &sy, &StiParams::new(3));
        let text = format_reports(&check_all(&phi, &tx, &ty, 2, &sx, &sy, 3, 1e-9));
        assert!(text.contains("efficiency"));
        assert!(text.contains("OK"));
    }
}
