//! `stiknn::delta` — exact live training-set mutations (add / remove /
//! relabel) for valuation sessions in **O(t·(d + n)) per edit** instead
//! of a full O(t·(n·d + n log n)) recompute (DESIGN.md §11).
//!
//! # Why edits are cheap in rank space
//!
//! Everything a per-test STI contribution needs is a function of the
//! test point's *distance ranking* of the train set (Eq. 6–8): the
//! sorted label-match vector u_p determines the superdiagonal c_p, and
//! (rank, colval = c_p[rank]) rows determine both the retained-row pair
//! queries and the implicit value fold. A single training-set edit only
//! perturbs that ranking locally:
//!
//! * **add** — the new point lands at one sorted position per test
//!   (found by an O(log n) binary search over the retained sorted
//!   distances; computing its distance is O(d)); every rank at or above
//!   it shifts up by one.
//! * **remove** — the removed point's rank drops out; ranks above shift
//!   down by one.
//! * **relabel** — the ranking is untouched entirely; only u_p changes.
//!
//! The superdiagonal recursion's coefficients depend on n and on the
//! position within the ranking, so c_p must be *recomputed* — but that
//! is one O(n) pass per test over data already in memory (no distances,
//! no sort). The value vector is then re-folded from the repaired rows
//! in test order ([`refold_values`]), which keeps it **bit-identical**
//! to a from-scratch `values_accumulate` over the post-edit training
//! set: repaired (rank, colval) rows equal from-scratch prep rows to the
//! bit (same distances, same stable tie-break — an added point carries
//! the largest original index, so it sorts after every equal distance,
//! exactly like the keyed argsort; a removal preserves the relative
//! order of the survivors), and the fold applies the same expressions in
//! the same per-element order as `sweep_values`.
//!
//! Total edit cost: O(t·(d + n)) repair + O(t·n) refold, vs the full
//! recompute's O(t·(n·d + n log n)) — the d and log n factors are what
//! the delta path deletes. `benches/delta.rs` measures the gap.
//!
//! # Module layout
//!
//! * [`RetainedRows`] — per-test (rank, colval) rows (moved here from
//!   the session layer; they are rank-space state, not session state).
//! * [`MutableRows`] — the extra state a mutable session retains: test
//!   features/labels plus per-test sorted distances and the rank→index
//!   permutation (what the binary search and the repairs consume).
//! * [`Edit`] / [`repair_chunk`] — one edit's per-test row repair over a
//!   contiguous test chunk; chunks are independent, so the coordinator
//!   fans them out across workers bit-identically
//!   ([`crate::coordinator::repair_rows`]).
//! * [`refold_values`] — rebuild the [`ValueVector`] from repaired rows
//!   in test order (the bit-reproducibility anchor).
//! * [`ingest_rows`] — the mutable session's ingest path: captures
//!   distances + permutation alongside the usual rows, bit-identical to
//!   the plain implicit retained path (property-tested in
//!   `tests/delta_equivalence.rs`).
//! * [`MutationRecord`] — the mutation ledger entry persisted by v3
//!   snapshots (reproducibility: the edit sequence that produced the
//!   current train set, in order).

use crate::knn::distance::{argsort_by_distance_keyed, Metric};
use crate::knn::kernel::{distances_into_kernel, pair_dist, NormCache};
use crate::shapley::sti_knn::{superdiagonal_into, PreparedBatch, StiParams};
use crate::shapley::values::ValueVector;

/// Per-test `(rank, colval)` rows retained by an implicit session for
/// `cell`/`row` queries: exactly the Eq. 8 reconstruction state — for any
/// pair, φ_p[i,j] = colval_p of whichever of i, j ranks LATER. Ranks are
/// stored as u32 (n ≤ 2³² is already far past what the dense path could
/// ever materialize), halving the footprint vs the prep rows.
pub struct RetainedRows {
    pub(crate) n: usize,
    pub(crate) tests: usize,
    pub(crate) rank: Vec<u32>,
    pub(crate) colval: Vec<f64>,
}

impl RetainedRows {
    pub fn new(n: usize) -> Self {
        RetainedRows {
            n,
            tests: 0,
            rank: Vec::new(),
            colval: Vec::new(),
        }
    }

    /// Number of retained test rows.
    pub fn tests(&self) -> usize {
        self.tests
    }

    /// Train-set size the rows are currently shaped for.
    pub fn n(&self) -> usize {
        self.n
    }

    pub(crate) fn append_batch(&mut self, batch: &PreparedBatch) {
        debug_assert_eq!(batch.n(), self.n);
        for p in 0..batch.len() {
            self.rank
                .extend(batch.rank_row(p).iter().map(|&r| r as u32));
            self.colval.extend_from_slice(batch.colval_row(p));
        }
        self.tests += batch.len();
    }

    pub fn rank_row(&self, p: usize) -> &[u32] {
        &self.rank[p * self.n..(p + 1) * self.n]
    }

    pub fn colval_row(&self, p: usize) -> &[f64] {
        &self.colval[p * self.n..(p + 1) * self.n]
    }

    /// Σ_p φ_p[i,j] for one off-diagonal pair — O(tests).
    pub fn pair_sum(&self, i: usize, j: usize) -> f64 {
        let mut s = 0.0;
        for p in 0..self.tests {
            let rank = self.rank_row(p);
            let colval = self.colval_row(p);
            s += if rank[j] < rank[i] { colval[i] } else { colval[j] };
        }
        s
    }
}

/// The additional state a MUTABLE session retains beyond
/// [`RetainedRows`]: the ingested test set itself (features + labels —
/// O(t·d), needed to place an inserted point and to rebuild u_p after a
/// relabel) and, per test, the sorted distances plus the rank→original
/// permutation (O(t·n) — what the insert binary search and the rank
/// repairs read). Memory: 12n + 4d bytes per test on top of the 12n the
/// retained rows already hold.
pub struct MutableRows {
    pub(crate) d: usize,
    pub(crate) n: usize,
    pub(crate) tests: usize,
    pub(crate) test_x: Vec<f32>,
    pub(crate) test_y: Vec<i32>,
    /// Per-test distances in RANK order (ascending), `tests` rows of n.
    pub(crate) dist: Vec<f64>,
    /// Per-test rank→original-index permutation, `tests` rows of n.
    pub(crate) pos: Vec<u32>,
}

impl MutableRows {
    pub fn new(n: usize, d: usize) -> Self {
        MutableRows {
            d,
            n,
            tests: 0,
            test_x: Vec::new(),
            test_y: Vec::new(),
            dist: Vec::new(),
            pos: Vec::new(),
        }
    }

    pub fn tests(&self) -> usize {
        self.tests
    }

    pub fn dist_row(&self, p: usize) -> &[f64] {
        &self.dist[p * self.n..(p + 1) * self.n]
    }

    pub fn pos_row(&self, p: usize) -> &[u32] {
        &self.pos[p * self.n..(p + 1) * self.n]
    }

    pub fn test_label(&self, p: usize) -> i32 {
        self.test_y[p]
    }
}

/// One training-set edit. `Add` always appends at index n (the current
/// train size), which is what keeps repairs exact: the new point carries
/// the LARGEST original index, so the stable distance-then-index order
/// places it after every equal distance — precisely where a from-scratch
/// argsort would put it.
#[derive(Clone, Copy, Debug)]
pub enum Edit<'a> {
    /// Append a train point (features of length d, label). New id = n.
    Add { x: &'a [f32], y: i32 },
    /// Remove train point `index`; indices above it shift down by one.
    Remove { index: usize },
    /// Change train point `index`'s label. Ranks are untouched.
    Relabel { index: usize, y: i32 },
}

/// Stable wire tag for a mutation kind (part of the v3 snapshot format —
/// never renumber existing variants).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MutationOp {
    /// `index` is the id the point was assigned; `label` its label.
    Add,
    /// `index` is the index AT THE TIME OF THE EDIT (later records see
    /// the shifted numbering); `label` is unused (0).
    Remove,
    /// `index` as for Remove; `label` is the NEW label.
    Relabel,
}

impl MutationOp {
    pub fn tag(&self) -> u8 {
        match self {
            MutationOp::Add => 0,
            MutationOp::Remove => 1,
            MutationOp::Relabel => 2,
        }
    }

    pub fn from_tag(tag: u8) -> Option<MutationOp> {
        match tag {
            0 => Some(MutationOp::Add),
            1 => Some(MutationOp::Remove),
            2 => Some(MutationOp::Relabel),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            MutationOp::Add => "add",
            MutationOp::Remove => "remove",
            MutationOp::Relabel => "relabel",
        }
    }
}

/// One mutation-ledger entry: the monotone edit sequence number plus
/// what happened. Together with the batch ledger and the persisted train
/// set, the ledger documents how a v3 snapshot's training set came to be
/// (indices are as-of-edit-time; added features live in the persisted
/// train set, not the ledger).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MutationRecord {
    pub seq: u64,
    pub op: MutationOp,
    pub index: u64,
    pub label: i32,
}

/// Everything [`repair_chunk`] needs beyond the rows themselves. Built
/// once per edit; `train_y` is the POST-edit label vector (length
/// `new_n`).
pub struct RepairCtx<'a> {
    pub k: usize,
    pub metric: Metric,
    pub d: usize,
    pub old_n: usize,
    pub new_n: usize,
    pub train_y: &'a [i32],
    pub test_x: &'a [f32],
    pub test_y: &'a [i32],
}

/// Reusable per-worker scratch for [`repair_chunk`]: the rank-space
/// label-match vector u and the superdiagonal c.
#[derive(Default)]
pub struct RepairScratch {
    u: Vec<f64>,
    c: Vec<f64>,
}

impl RepairScratch {
    pub fn new() -> Self {
        RepairScratch::default()
    }
}

/// Repair one edit over a contiguous chunk of tests: read the old
/// (dist, pos) rows, write the new (dist, pos, rank, colval) rows.
/// `test_lo` is the chunk's global test offset (indexes `ctx.test_x` /
/// `ctx.test_y`). O(chunk·(d + n)) for Add, O(chunk·n) otherwise.
///
/// Chunks are fully independent — each test's repair reads only its own
/// old row and the shared ctx — so any chunking across workers produces
/// identical rows ([`crate::coordinator::repair_rows`] relies on this).
#[allow(clippy::too_many_arguments)]
pub fn repair_chunk(
    ctx: &RepairCtx<'_>,
    edit: &Edit<'_>,
    test_lo: usize,
    old_dist: &[f64],
    old_pos: &[u32],
    new_dist: &mut [f64],
    new_pos: &mut [u32],
    new_rank: &mut [u32],
    new_colval: &mut [f64],
    scratch: &mut RepairScratch,
) {
    let (old_n, new_n) = (ctx.old_n, ctx.new_n);
    assert_eq!(old_dist.len() % old_n.max(1), 0, "old dist chunk shape");
    let tests = if old_n == 0 { 0 } else { old_dist.len() / old_n };
    assert_eq!(old_pos.len(), tests * old_n, "old pos chunk shape");
    assert_eq!(new_dist.len(), tests * new_n, "new dist chunk shape");
    assert_eq!(new_pos.len(), tests * new_n, "new pos chunk shape");
    assert_eq!(new_rank.len(), tests * new_n, "new rank chunk shape");
    assert_eq!(new_colval.len(), tests * new_n, "new colval chunk shape");
    assert_eq!(ctx.train_y.len(), new_n, "post-edit labels / new_n mismatch");

    scratch.u.resize(new_n, 0.0);
    scratch.c.resize(new_n, 0.0);
    let inv_k = 1.0 / ctx.k as f64;

    for p in 0..tests {
        let g = test_lo + p;
        let od = &old_dist[p * old_n..(p + 1) * old_n];
        let op = &old_pos[p * old_n..(p + 1) * old_n];
        let nd = &mut new_dist[p * new_n..(p + 1) * new_n];
        let np = &mut new_pos[p * new_n..(p + 1) * new_n];
        let nr = &mut new_rank[p * new_n..(p + 1) * new_n];
        let nc = &mut new_colval[p * new_n..(p + 1) * new_n];

        match edit {
            Edit::Add { x, .. } => {
                // Distance computed exactly as kernel prep would:
                // `pair_dist` evaluates the same norm-form expression on
                // the same operands as `distances_into_kernel`, so the
                // stored value bit-matches a from-scratch run.
                let q = &ctx.test_x[g * ctx.d..(g + 1) * ctx.d];
                let dnew = pair_dist(ctx.metric, q, x);
                // Stable tie-break: the new point has the largest index,
                // so it goes AFTER every equal distance — upper bound.
                let r = od.partition_point(|&dv| dv <= dnew);
                nd[..r].copy_from_slice(&od[..r]);
                nd[r] = dnew;
                nd[r + 1..].copy_from_slice(&od[r..]);
                np[..r].copy_from_slice(&op[..r]);
                np[r] = old_n as u32;
                np[r + 1..].copy_from_slice(&op[r..]);
            }
            Edit::Remove { index } => {
                // O(n) scan beats carrying the old rank rows through the
                // repair plumbing; the whole per-test repair is O(n).
                let r = op
                    .iter()
                    .position(|&v| v as usize == *index)
                    .expect("removed index must appear in every pos row");
                nd[..r].copy_from_slice(&od[..r]);
                nd[r..].copy_from_slice(&od[r + 1..]);
                for (slot, &v) in np[..r].iter_mut().zip(&op[..r]) {
                    *slot = v - u32::from((v as usize) > *index);
                }
                for (slot, &v) in np[r..].iter_mut().zip(&op[r + 1..]) {
                    *slot = v - u32::from((v as usize) > *index);
                }
            }
            Edit::Relabel { .. } => {
                nd.copy_from_slice(od);
                np.copy_from_slice(op);
            }
        }

        // Common tail: rank = inverse permutation, u_p from the
        // post-edit labels, superdiagonal, scatter — the same
        // construction (and the same `superdiagonal_into`) as
        // `prepare_batch_scratch`, so the repaired row bit-matches a
        // from-scratch prep of the post-edit train set.
        let y = ctx.test_y[g];
        for (rr, &orig) in np.iter().enumerate() {
            nr[orig as usize] = rr as u32;
            scratch.u[rr] = if ctx.train_y[orig as usize] == y {
                inv_k
            } else {
                0.0
            };
        }
        superdiagonal_into(&scratch.u[..new_n], ctx.k, &mut scratch.c[..new_n]);
        for (rr, &orig) in np.iter().enumerate() {
            nc[orig as usize] = scratch.c[rr];
        }
    }
}

/// Rebuild the UNNORMALIZED value vector from retained rows, in test
/// order — the suffix-sum fold of `sweep_values` read off (rank, colval)
/// rows instead of a `PreparedBatch`. Same expressions
/// (`r·colval[i] + suffix[r+1]`, one addition per element per test) in
/// the same order, so the result is **bit-identical** to
/// `values_accumulate` over the same train set and test stream
/// (property-tested in `tests/delta_equivalence.rs`). O(tests·n).
pub fn refold_values(
    rows: &RetainedRows,
    train_y: &[i32],
    test_y: &[i32],
    k: usize,
) -> ValueVector {
    let n = rows.n;
    assert_eq!(train_y.len(), n, "train labels / rows mismatch");
    assert_eq!(test_y.len(), rows.tests, "test labels / rows mismatch");
    let mut vv = ValueVector::zeros(n);
    let inv_k = 1.0 / k as f64;
    let mut c_rank = vec![0.0f64; n];
    let mut suffix = vec![0.0f64; n + 1];
    for p in 0..rows.tests {
        let rank = rows.rank_row(p);
        let colval = rows.colval_row(p);
        let y = test_y[p];
        for i in 0..n {
            c_rank[rank[i] as usize] = colval[i];
        }
        suffix[n] = 0.0;
        for r in (0..n).rev() {
            suffix[r] = c_rank[r] + suffix[r + 1];
        }
        for i in 0..n {
            let r = rank[i];
            if train_y[i] == y {
                vv.main[i] += inv_k;
            }
            vv.inter[i] += (r as f64) * colval[i] + suffix[r as usize + 1];
        }
    }
    vv
}

/// Mutable-session ingest: for each test point, compute distances + the
/// stable argsort ONCE, retain (dist, pos) in [`MutableRows`] and
/// (rank, colval) in [`RetainedRows`], and fold the per-point values
/// into `vv`. Bit-identical to the plain retained implicit path
/// (`prepare_batch_cached` + `sweep_values`): the same kernel distance
/// calls against the same [`NormCache`], the same keyed argsort, the
/// same `superdiagonal_into` on the same u_p, and the same fold
/// expressions per element in test order. `norms` is the session's
/// long-lived cache over `train_x`. O(t·(n·d + n log n)) — the same as
/// any ingest; the delta savings are on EDITS, not ingests.
#[allow(clippy::too_many_arguments)]
pub fn ingest_rows(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    params: &StiParams,
    norms: &NormCache,
    rows: &mut RetainedRows,
    mrows: &mut MutableRows,
    vv: &mut ValueVector,
) {
    let n = train_y.len();
    assert_eq!(train_x.len(), n * d, "train shape mismatch");
    assert_eq!(test_x.len(), test_y.len() * d, "test shape mismatch");
    assert_eq!(rows.n, n, "retained rows / train mismatch");
    assert_eq!(mrows.n, n, "mutable rows / train mismatch");
    assert_eq!(mrows.d, d, "mutable rows / d mismatch");
    let k = params.k;
    let inv_k = 1.0 / k as f64;
    let mut dists = vec![0.0f64; n];
    let mut keys: Vec<u128> = Vec::new();
    let mut order = vec![0usize; n];
    let mut u = vec![0.0f64; n];
    let mut c = vec![0.0f64; n];
    let mut suffix = vec![0.0f64; n + 1];

    for (q, &y) in test_x.chunks_exact(d).zip(test_y) {
        distances_into_kernel(q, train_x, d, params.metric, norms, &mut dists);
        argsort_by_distance_keyed(&dists, &mut keys, &mut order);
        // u_p in rank order, exactly as prepare builds it.
        for (r, &orig) in order.iter().enumerate() {
            u[r] = if train_y[orig] == y { inv_k } else { 0.0 };
        }
        superdiagonal_into(&u, k, &mut c);
        // Retain (dist, pos) — rank order — and (rank, colval) — train
        // order — then fold: c is already c_rank, so the suffix pass
        // reads it directly.
        mrows.dist.extend(order.iter().map(|&orig| dists[orig]));
        mrows.pos.extend(order.iter().map(|&orig| orig as u32));
        mrows.test_x.extend_from_slice(q);
        mrows.test_y.push(y);
        let base = rows.rank.len();
        rows.rank.resize(base + n, 0);
        rows.colval.resize(base + n, 0.0);
        for (r, &orig) in order.iter().enumerate() {
            rows.rank[base + orig] = r as u32;
            rows.colval[base + orig] = c[r];
        }
        suffix[n] = 0.0;
        for r in (0..n).rev() {
            suffix[r] = c[r] + suffix[r + 1];
        }
        for i in 0..n {
            let r = rows.rank[base + i];
            if train_y[i] == y {
                vv.main[i] += inv_k;
            }
            vv.inter[i] += (r as f64) * rows.colval[base + i] + suffix[r as usize + 1];
        }
        rows.tests += 1;
        mrows.tests += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shapley::values::values_accumulate;
    use crate::util::rng::Rng;

    fn random_problem(
        seed: u64,
        n: usize,
        d: usize,
        t: usize,
    ) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        (
            (0..n * d).map(|_| rng.normal() as f32).collect(),
            (0..n).map(|_| rng.below(2) as i32).collect(),
            (0..t * d).map(|_| rng.normal() as f32).collect(),
            (0..t).map(|_| rng.below(2) as i32).collect(),
        )
    }

    /// Ingest through the delta path, returning all the state.
    fn delta_ingest(
        tx: &[f32],
        ty: &[i32],
        d: usize,
        qx: &[f32],
        qy: &[i32],
        k: usize,
    ) -> (RetainedRows, MutableRows, ValueVector) {
        let n = ty.len();
        let mut rows = RetainedRows::new(n);
        let mut mrows = MutableRows::new(n, d);
        let mut vv = ValueVector::zeros(n);
        let params = StiParams::new(k);
        let norms = NormCache::build(tx, d, params.metric);
        ingest_rows(
            tx,
            ty,
            d,
            qx,
            qy,
            &params,
            &norms,
            &mut rows,
            &mut mrows,
            &mut vv,
        );
        (rows, mrows, vv)
    }

    #[test]
    fn ingest_rows_is_bit_identical_to_values_accumulate() {
        let (tx, ty, qx, qy) = random_problem(3, 17, 3, 9);
        let (_, _, vv) = delta_ingest(&tx, &ty, 3, &qx, &qy, 4);
        let mut reference = ValueVector::zeros(17);
        values_accumulate(&tx, &ty, 3, &qx, &qy, &StiParams::new(4), &mut reference);
        for i in 0..17 {
            assert_eq!(vv.main_raw()[i].to_bits(), reference.main_raw()[i].to_bits());
            assert_eq!(
                vv.inter_raw()[i].to_bits(),
                reference.inter_raw()[i].to_bits()
            );
        }
    }

    #[test]
    fn refold_reproduces_the_folded_vector_bits() {
        let (tx, ty, qx, qy) = random_problem(7, 13, 2, 8);
        let (rows, mrows, vv) = delta_ingest(&tx, &ty, 2, &qx, &qy, 3);
        let refolded = refold_values(&rows, &ty, &mrows.test_y, 3);
        for i in 0..13 {
            assert_eq!(vv.main_raw()[i].to_bits(), refolded.main_raw()[i].to_bits());
            assert_eq!(
                vv.inter_raw()[i].to_bits(),
                refolded.inter_raw()[i].to_bits()
            );
        }
    }

    /// The core exactness claim at the row level: repairing after an
    /// edit equals re-preparing from scratch on the post-edit train set,
    /// to the BIT, for every (dist, pos, rank, colval) row.
    #[test]
    fn repaired_rows_bit_match_from_scratch_rows() {
        let (tx, ty, qx, qy) = random_problem(11, 12, 2, 6);
        // duplicate an existing point's features → duplicate distances,
        // the tie-break stress case
        let dup: Vec<f32> = tx[4..6].to_vec();
        let (_, mrows, _) = delta_ingest(&tx, &ty, 2, &qx, &qy, 3);

        for (edit_name, edit, new_tx, new_ty) in [
            (
                "add-dup",
                Edit::Add { x: &dup, y: 1 },
                {
                    let mut v = tx.clone();
                    v.extend_from_slice(&dup);
                    v
                },
                {
                    let mut v = ty.clone();
                    v.push(1);
                    v
                },
            ),
            (
                "remove",
                Edit::Remove { index: 4 },
                {
                    let mut v = tx.clone();
                    v.drain(8..10);
                    v
                },
                {
                    let mut v = ty.clone();
                    v.remove(4);
                    v
                },
            ),
            (
                "relabel",
                Edit::Relabel { index: 2, y: 1 - ty[2] },
                tx.clone(),
                {
                    let mut v = ty.clone();
                    v[2] = 1 - v[2];
                    v
                },
            ),
        ] {
            let new_n = new_ty.len();
            let ctx = RepairCtx {
                k: 3,
                metric: Metric::SqEuclidean,
                d: 2,
                old_n: 12,
                new_n,
                train_y: &new_ty,
                test_x: &qx,
                test_y: &qy,
            };
            let mut nd = vec![0.0; 6 * new_n];
            let mut np = vec![0u32; 6 * new_n];
            let mut nr = vec![0u32; 6 * new_n];
            let mut nc = vec![0.0; 6 * new_n];
            let mut scratch = RepairScratch::new();
            repair_chunk(
                &ctx, &edit, 0, &mrows.dist, &mrows.pos, &mut nd, &mut np, &mut nr, &mut nc,
                &mut scratch,
            );
            let (fresh_rows, fresh_mrows, _) = delta_ingest(&new_tx, &new_ty, 2, &qx, &qy, 3);
            for idx in 0..6 * new_n {
                assert_eq!(
                    nd[idx].to_bits(),
                    fresh_mrows.dist[idx].to_bits(),
                    "{edit_name} dist[{idx}]"
                );
                assert_eq!(np[idx], fresh_mrows.pos[idx], "{edit_name} pos[{idx}]");
                assert_eq!(nr[idx], fresh_rows.rank[idx], "{edit_name} rank[{idx}]");
                assert_eq!(
                    nc[idx].to_bits(),
                    fresh_rows.colval[idx].to_bits(),
                    "{edit_name} colval[{idx}]"
                );
            }
        }
    }

    #[test]
    fn chunked_repair_equals_one_chunk() {
        let (tx, ty, qx, qy) = random_problem(19, 10, 2, 7);
        let (_, mrows, _) = delta_ingest(&tx, &ty, 2, &qx, &qy, 2);
        let mut new_ty = ty.clone();
        new_ty.remove(3);
        let ctx = RepairCtx {
            k: 2,
            metric: Metric::SqEuclidean,
            d: 2,
            old_n: 10,
            new_n: 9,
            train_y: &new_ty,
            test_x: &qx,
            test_y: &qy,
        };
        let edit = Edit::Remove { index: 3 };
        let run = |splits: &[(usize, usize)]| {
            let mut nd = vec![0.0; 7 * 9];
            let mut np = vec![0u32; 7 * 9];
            let mut nr = vec![0u32; 7 * 9];
            let mut nc = vec![0.0; 7 * 9];
            let mut scratch = RepairScratch::new();
            for &(lo, hi) in splits {
                repair_chunk(
                    &ctx,
                    &edit,
                    lo,
                    &mrows.dist[lo * 10..hi * 10],
                    &mrows.pos[lo * 10..hi * 10],
                    &mut nd[lo * 9..hi * 9],
                    &mut np[lo * 9..hi * 9],
                    &mut nr[lo * 9..hi * 9],
                    &mut nc[lo * 9..hi * 9],
                    &mut scratch,
                );
            }
            (nd, np, nr, nc)
        };
        let whole = run(&[(0, 7)]);
        let parts = run(&[(0, 2), (2, 3), (3, 7)]);
        assert_eq!(whole.1, parts.1);
        assert_eq!(whole.2, parts.2);
        for i in 0..7 * 9 {
            assert_eq!(whole.0[i].to_bits(), parts.0[i].to_bits());
            assert_eq!(whole.3[i].to_bits(), parts.3[i].to_bits());
        }
    }

    #[test]
    fn mutation_op_tags_are_stable_and_invertible() {
        for op in [MutationOp::Add, MutationOp::Remove, MutationOp::Relabel] {
            assert_eq!(MutationOp::from_tag(op.tag()), Some(op));
        }
        assert_eq!(MutationOp::from_tag(3), None);
        assert_eq!(MutationOp::Add.label(), "add");
        assert_eq!(MutationOp::Remove.label(), "remove");
        assert_eq!(MutationOp::Relabel.label(), "relabel");
    }
}
