//! KNN-Shapley (Jia et al. 2019): exact *per-point* Shapley values in
//! O(t·n log n) — the baseline whose complexity the paper discusses in
//! §3.2 ("The baseline algorithm's complexity considering t").
//!
//! Recursion for one test point (train points sorted nearest-first,
//! 1-based in the comments):
//!
//!   s_{α_n} = 1[y_{α_n} = y_test] / n
//!   s_{α_i} = s_{α_{i+1}} + (1[y_{α_i}=y] − 1[y_{α_{i+1}}=y]) / k · min(k,i)/i

use crate::knn::distance::{argsort_by_distance, distances_into, Metric};

/// Per-point Shapley values for one test point, SORTED order.
pub fn knn_shapley_one_test_sorted(labels_sorted: &[i32], y_test: i32, k: usize) -> Vec<f64> {
    let n = labels_sorted.len();
    assert!(n >= 1 && k >= 1);
    let mtch = |r: usize| -> f64 {
        if labels_sorted[r] == y_test {
            1.0
        } else {
            0.0
        }
    };
    let mut s = vec![0.0f64; n];
    s[n - 1] = mtch(n - 1) / n as f64;
    for i in (1..n).rev() {
        // 1-based index of the nearer point is `i`, its 0-based slot i-1
        s[i - 1] = s[i]
            + (mtch(i - 1) - mtch(i)) / k as f64 * (k.min(i) as f64) / i as f64;
    }
    s
}

/// Averaged per-point Shapley values over a test set, ORIGINAL train
/// order. O(t·n log n).
pub fn knn_shapley(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    k: usize,
) -> Vec<f64> {
    let (sum, w) = knn_shapley_partial(train_x, train_y, d, test_x, test_y, k);
    sum.into_iter().map(|v| v / w).collect()
}

/// Unnormalized partial sums (coordinator work unit), ORIGINAL order.
pub fn knn_shapley_partial(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    k: usize,
) -> (Vec<f64>, f64) {
    let n = train_y.len();
    assert!(!test_y.is_empty(), "empty test set");
    assert_eq!(train_x.len(), n * d);
    assert_eq!(test_x.len(), test_y.len() * d);
    let mut acc = vec![0.0f64; n];
    let mut dists = vec![0.0f64; n];
    let mut labels_sorted = vec![0i32; n];
    for (q, &y) in test_x.chunks_exact(d).zip(test_y) {
        // lint: allow(raw-distance) — KNN-Shapley baseline oracle stays on the
        // reference loop on purpose: it must not share the kernel
        // dispatch path it is used to validate.
        distances_into(q, train_x, d, Metric::SqEuclidean, &mut dists);
        let order = argsort_by_distance(&dists);
        for (r, &o) in order.iter().enumerate() {
            labels_sorted[r] = train_y[o];
        }
        let s = knn_shapley_one_test_sorted(&labels_sorted, y, k);
        for (r, &o) in order.iter().enumerate() {
            acc[o] += s[r];
        }
    }
    (acc, test_y.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knn::valuation::u_subset;
    use crate::util::rng::Rng;

    /// Brute-force per-point Shapley: φ_i = Σ_S |S|!(n-|S|-1)!/n! ·
    /// (v(S∪i) − v(S)) — the definition KNN-Shapley shortcuts.
    fn brute_shapley(labels_sorted: &[i32], y: i32, k: usize) -> Vec<f64> {
        let n = labels_sorted.len();
        let match_sorted: Vec<bool> = labels_sorted.iter().map(|&l| l == y).collect();
        let mut fact = vec![1.0f64; n + 1];
        for i in 1..=n {
            fact[i] = fact[i - 1] * i as f64;
        }
        let mut out = vec![0.0; n];
        for i in 0..n {
            let rest: Vec<usize> = (0..n).filter(|&p| p != i).collect();
            let mut acc = 0.0;
            for mask in 0u64..(1 << (n - 1)) {
                let mut members: Vec<usize> = Vec::new();
                for (b, &p) in rest.iter().enumerate() {
                    if (mask >> b) & 1 == 1 {
                        members.push(p);
                    }
                }
                members.sort_unstable();
                let s = members.len();
                let v_without = u_subset(&match_sorted, &members, k);
                let mut with: Vec<usize> = members.clone();
                with.push(i);
                with.sort_unstable();
                let v_with = u_subset(&match_sorted, &with, k);
                acc += fact[s] * fact[n - s - 1] / fact[n] * (v_with - v_without);
            }
            out[i] = acc;
        }
        out
    }

    #[test]
    fn recursion_matches_bruteforce() {
        let mut rng = Rng::new(11);
        for n in 2..8usize {
            for k in 1..=n {
                let labels: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
                let fast = knn_shapley_one_test_sorted(&labels, 1, k);
                let brute = brute_shapley(&labels, 1, k);
                for (a, b) in fast.iter().zip(&brute) {
                    assert!(
                        (a - b).abs() < 1e-12,
                        "n={n} k={k} labels={labels:?}: {fast:?} vs {brute:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn values_sum_to_v_n() {
        // per-point efficiency: Σ_i s_i = v(N)
        let labels = [1, 0, 1, 1, 0, 0, 1];
        for k in 1..=7usize {
            let s = knn_shapley_one_test_sorted(&labels, 1, k);
            let v_n = labels.iter().take(k).filter(|&&l| l == 1).count() as f64 / k as f64;
            assert!((s.iter().sum::<f64>() - v_n).abs() < 1e-12, "k={k}");
        }
    }

    #[test]
    fn matching_points_get_higher_values() {
        let labels = [1, 0, 1, 0];
        let s = knn_shapley_one_test_sorted(&labels, 1, 2);
        assert!(s[0] > s[1]);
        assert!(s[2] > s[3]);
    }

    #[test]
    fn averaged_values_original_order() {
        let mut rng = Rng::new(5);
        let n = 12;
        let d = 2;
        let t = 4;
        let train_x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let train_y: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let test_x: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let test_y: Vec<i32> = (0..t).map(|_| rng.below(2) as i32).collect();
        let vals = knn_shapley(&train_x, &train_y, d, &test_x, &test_y, 3);
        assert_eq!(vals.len(), n);
        // efficiency on the average: Σ_i φ_i = mean_p v_p(N)
        let knn = crate::knn::KnnClassifier::new(&train_x, &train_y, d, 3);
        let v_n = knn.likelihood(&test_x, &test_y);
        assert!((vals.iter().sum::<f64>() - v_n).abs() < 1e-12);
    }
}
