//! Leave-one-out (LOO) valuation — the §1 strawman the Shapley family
//! improves on: value(i) = v(N) − v(N \ {i}).
//!
//! For the KNN valuation this has a closed form per test point: removing
//! train point i changes u only if i is among the k nearest, in which
//! case the (k+1)-th point slides into the neighborhood:
//!
//!   Δ_i = (1[y_i = y] − 1[y_{α_{k+1}} = y]) / k   if rank(i) < k
//!         0                                        otherwise
//! (when n ≤ k every point already votes and the replacement term is 0).

use crate::knn::distance::{argsort_by_distance, distances_into, Metric};

/// LOO values averaged over the test set, ORIGINAL train order. O(t·n log n).
pub fn loo(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    k: usize,
) -> Vec<f64> {
    let n = train_y.len();
    let t = test_y.len();
    assert!(t > 0 && k >= 1);
    assert_eq!(train_x.len(), n * d);
    let mut acc = vec![0.0f64; n];
    let mut dists = vec![0.0f64; n];
    for (q, &y) in test_x.chunks_exact(d).zip(test_y) {
        // lint: allow(raw-distance) — LOO baseline oracle stays on the
        // reference loop on purpose: it must not share the kernel
        // dispatch path it is used to validate.
        distances_into(q, train_x, d, Metric::SqEuclidean, &mut dists);
        let order = argsort_by_distance(&dists);
        let kk = k.min(n);
        // label-match of the replacement point (rank k, 0-based), if any
        let repl = if n > k {
            (train_y[order[k]] == y) as i32 as f64
        } else {
            0.0
        };
        for &o in order.iter().take(kk) {
            let mi = (train_y[o] == y) as i32 as f64;
            acc[o] += (mi - repl) / k as f64;
        }
    }
    for v in &mut acc {
        *v /= t as f64;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knn::KnnClassifier;

    /// Direct v(N) − v(N\{i}) via the classifier's likelihood — the
    /// definition, O(t·n²), used to validate the closed form.
    fn loo_direct(
        train_x: &[f32],
        train_y: &[i32],
        d: usize,
        test_x: &[f32],
        test_y: &[i32],
        k: usize,
    ) -> Vec<f64> {
        let n = train_y.len();
        let full = KnnClassifier::new(train_x, train_y, d, k).likelihood(test_x, test_y);
        (0..n)
            .map(|i| {
                let mut tx: Vec<f32> = Vec::with_capacity((n - 1) * d);
                let mut ty: Vec<i32> = Vec::with_capacity(n - 1);
                for j in 0..n {
                    if j != i {
                        tx.extend_from_slice(&train_x[j * d..(j + 1) * d]);
                        ty.push(train_y[j]);
                    }
                }
                full - KnnClassifier::new(&tx, &ty, d, k).likelihood(test_x, test_y)
            })
            .collect()
    }

    #[test]
    fn closed_form_matches_direct() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        for (n, k, t) in [(8usize, 3usize, 4usize), (12, 5, 3), (6, 6, 2), (5, 2, 5)] {
            let d = 2;
            let train_x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
            let train_y: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
            let test_x: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
            let test_y: Vec<i32> = (0..t).map(|_| rng.below(2) as i32).collect();
            let fast = loo(&train_x, &train_y, d, &test_x, &test_y, k);
            let direct = loo_direct(&train_x, &train_y, d, &test_x, &test_y, k);
            for (a, b) in fast.iter().zip(&direct) {
                assert!((a - b).abs() < 1e-12, "n={n} k={k}: {fast:?} vs {direct:?}");
            }
        }
    }

    #[test]
    fn far_points_have_zero_loo() {
        // a point never in any test point's k-neighborhood has LOO 0 —
        // the known blind spot of LOO that motivates Shapley (§1)
        let train_x = [0.0f32, 0.1, 0.2, 100.0];
        let train_y = [1, 1, 0, 1];
        let test_x = [0.05f32];
        let test_y = [1];
        let vals = loo(&train_x, &train_y, 1, &test_x, &test_y, 2);
        assert_eq!(vals[3], 0.0);
    }
}
