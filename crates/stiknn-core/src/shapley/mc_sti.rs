//! Monte-Carlo STI estimator — the accuracy-vs-budget baseline for the
//! ablation benches (what practitioners would run on a model where no
//! closed form exists, and what the O(2ⁿ) column of the paper's headline
//! claim degrades to under a fixed compute budget).
//!
//! Eq. (3) regrouped by subset size:
//!   φ_ij = (2/n) Σ_{s=0}^{n−2} C(n−2,s)/C(n−1,s) · E_{|S|=s}[Δ_ij(S)]
//!        = (2/n) Σ_s (n−1−s)/(n−1) · E_s[Δ],
//! so we estimate E_s[Δ] with `samples_per_size` uniform draws of S per
//! size (exact enumeration is used when C(n−2,s) ≤ samples_per_size).

use crate::knn::distance::{argsort_by_distance, distances_into, Metric};
use crate::knn::valuation::u_subset_mask;
use crate::shapley::sti_exact::binom;
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

/// MC estimate of φ_ij for one test point, sorted order.
pub fn mc_pair_interaction(
    match_bits: u64,
    n: usize,
    i: usize,
    j: usize,
    k: usize,
    samples_per_size: usize,
    rng: &mut Rng,
) -> f64 {
    assert!(i != j && i < n && j < n && n >= 2 && n <= 64);
    let rest: Vec<usize> = (0..n).filter(|&p| p != i && p != j).collect();
    let m = rest.len();
    let bit_i = 1u64 << i;
    let bit_j = 1u64 << j;
    let delta = |subset: u64| -> f64 {
        u_subset_mask(match_bits, subset | bit_i | bit_j, k)
            - u_subset_mask(match_bits, subset | bit_i, k)
            - u_subset_mask(match_bits, subset | bit_j, k)
            + u_subset_mask(match_bits, subset, k)
    };
    let mut acc = 0.0;
    for s in 0..=m {
        let size_weight = (n as f64 - 1.0 - s as f64) / (n as f64 - 1.0);
        if size_weight == 0.0 {
            continue;
        }
        let total = binom(m, s);
        let est = if total <= samples_per_size as f64 {
            // exact enumeration of this size stratum
            let mut sum = 0.0;
            let mut count = 0usize;
            enumerate_combinations(&rest, s, &mut |subset| {
                sum += delta(subset);
                count += 1;
            });
            if count == 0 {
                0.0
            } else {
                sum / count as f64
            }
        } else {
            let mut sum = 0.0;
            for _ in 0..samples_per_size {
                let picks = rng.sample_indices(m, s);
                let subset = picks.iter().fold(0u64, |a, &p| a | (1u64 << rest[p]));
                sum += delta(subset);
            }
            sum / samples_per_size as f64
        };
        acc += size_weight * est;
    }
    2.0 / n as f64 * acc
}

fn enumerate_combinations(items: &[usize], s: usize, f: &mut impl FnMut(u64)) {
    fn rec(items: &[usize], s: usize, start: usize, cur: u64, f: &mut impl FnMut(u64)) {
        if s == 0 {
            f(cur);
            return;
        }
        for idx in start..=items.len().saturating_sub(s) {
            rec(items, s - 1, idx + 1, cur | (1u64 << items[idx]), f);
        }
    }
    rec(items, s, 0, 0, f);
}

/// MC-estimated STI matrix averaged over a test set, ORIGINAL order.
pub fn mc_sti(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    k: usize,
    samples_per_size: usize,
    seed: u64,
) -> Matrix {
    let n = train_y.len();
    let t = test_y.len();
    assert!(t > 0 && n <= 64);
    let mut rng = Rng::new(seed);
    let mut acc = Matrix::zeros(n, n);
    let mut dists = vec![0.0f64; n];
    for (q, &y) in test_x.chunks_exact(d).zip(test_y) {
        // lint: allow(raw-distance) — Monte-Carlo STI estimator oracle stays on the
        // reference loop on purpose: it must not share the kernel
        // dispatch path it is used to validate.
        distances_into(q, train_x, d, Metric::SqEuclidean, &mut dists);
        let order = argsort_by_distance(&dists);
        let bits = order
            .iter()
            .enumerate()
            .fold(0u64, |a, (r, &o)| a | (((train_y[o] == y) as u64) << r));
        for a in 0..n {
            let ua = if (bits >> a) & 1 == 1 { 1.0 / k as f64 } else { 0.0 };
            acc.add_at(order[a], order[a], ua);
            for b in (a + 1)..n {
                let v = mc_pair_interaction(bits, n, a, b, k, samples_per_size, &mut rng);
                acc.add_at(order[a], order[b], v);
                acc.add_at(order[b], order[a], v);
            }
        }
    }
    acc.scale(1.0 / t as f64);
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shapley::sti_exact::{pair_interaction_masked, sti_weight};

    #[test]
    fn exhaustive_budget_equals_exact() {
        // samples_per_size >= C(n-2, s) everywhere -> exact enumeration
        let labels = [1, 0, 1, 1, 0, 1];
        let bits = 0b101101u64;
        let n = labels.len();
        let mut rng = Rng::new(1);
        for (i, j) in [(0, 1), (1, 4), (3, 5)] {
            let exact = pair_interaction_masked(bits, n, i, j, 2, sti_weight, 0);
            let mc = mc_pair_interaction(bits, n, i, j, 2, 1 << 12, &mut rng);
            assert!((exact - mc).abs() < 1e-12, "({i},{j}): {exact} vs {mc}");
        }
    }

    #[test]
    fn sampled_estimate_converges() {
        let labels = [1i32, 0, 1, 1, 0, 1, 0, 0, 1, 1, 0, 1];
        let n = labels.len();
        let bits = labels
            .iter()
            .enumerate()
            .fold(0u64, |a, (r, &l)| a | (((l == 1) as u64) << r));
        let exact = pair_interaction_masked(bits, n, 2, 9, 3, sti_weight, 0);
        let mut errs = Vec::new();
        for budget in [2usize, 1 << 12] {
            let mut rng = Rng::new(99);
            // average several replicates to smooth sampling noise
            let reps = 20;
            let mean: f64 = (0..reps)
                .map(|_| mc_pair_interaction(bits, n, 2, 9, 3, budget, &mut rng))
                .sum::<f64>()
                / reps as f64;
            errs.push((mean - exact).abs());
        }
        // the 2^12 budget exceeds every stratum size C(10, s) ≤ 252, so the
        // estimator degrades to exact enumeration
        assert!(errs[1] < 1e-12, "exhaustive budget should be exact: {errs:?}");
        assert!(errs[0] < 0.05, "low-budget estimate too noisy: {errs:?}");
    }

    #[test]
    fn full_matrix_symmetric() {
        let train_x = [0.0f32, 1.0, 2.0, 3.0, 4.0, 5.0];
        let train_y = [1, 0, 1, 0, 1, 0];
        let test_x = [0.5f32, 4.5];
        let test_y = [1, 0];
        let m = mc_sti(&train_x, &train_y, 1, &test_x, &test_y, 2, 8, 5);
        assert!(m.is_symmetric(1e-12));
    }
}
