//! Data-Shapley engines: the paper's O(tn²) STI-KNN (Algorithm 1), the
//! implicit O(t·n log n) per-point value engine built on its rank-space
//! structure ([`values`], DESIGN.md §10), the exact O(t·(d + n))-per-edit
//! training-set mutation kernel built on the same structure ([`delta`],
//! DESIGN.md §11), the O(2ⁿ) brute-force baseline it replaces (Eq. 3),
//! the per-point KNN-Shapley baseline (Jia et al. 2019), the SII variant
//! (§3.2), a Monte-Carlo estimator, leave-one-out, and the axiom
//! checkers.

pub mod axioms;
pub mod delta;
pub mod knn_shapley;
pub mod loo;
pub mod mc_sti;
pub mod sii;
pub mod sti_exact;
pub mod sti_knn;
pub mod values;

pub use sti_knn::{
    prepare_batch, prepare_batch_cached, prepare_batch_scratch, sti_knn, sti_knn_accumulate,
    sti_knn_partial, sweep_band, PREP_BATCH, PrepScratch, PreparedBatch, StiParams,
};
pub use values::{
    sti_point_values, sti_values, sweep_values, values_accumulate, PointValues, ValueVector,
    ValuesScratch,
};
