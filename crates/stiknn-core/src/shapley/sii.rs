//! SII-KNN: the paper's §3.2 extension — "The obtained result for STI
//! could be applied to SII [Grabisch & Roubens 1999] ... The only
//! difference would be in the coefficient."
//!
//! The whole Appendix-A derivation only uses the fact that the size weight
//! w(s) factors out of the subset sums, so the same recursion structure
//! holds with SII weights w(s) = s!(n−s−2)!/(n−1)! = 1/((n−1)·C(n−2,s)):
//!
//!   last term:  φ_{n−1,n} = −u(α_n)/(n−1)                (paper, §3.2)
//!   recursion:  φ_{j−2,j−1} = φ_{j−1,j} + D(j)·(u(α_j) − u(α_{j−1}))
//!   columns:    unchanged (Eq. 8's proof is weight-independent)
//!
//! where, following Appendix A.2 with SII weights,
//!
//!   D(j) = [j > k+1] · C(j−3, k−1) · Σ_{s=k−1}^{n−3} (w(s) + w(s+1)) ·
//!            C(n−j, s−k+1)
//!
//! (for STI this sum telescopes to the closed form 2(j−k−1)/((j−2)(j−1));
//! for SII we evaluate it numerically in O(n) per j — still O(n²) overall
//! per test point, dominated by the assembly anyway.)

use crate::knn::distance::{argsort_by_distance, distances_into, Metric};
use crate::shapley::sti_exact::{binom, sii_weight};
use crate::util::matrix::Matrix;

/// D(j) for the SII recursion (1-based j, 3 ≤ j ≤ n).
fn sii_d(n: usize, j: usize, k: usize) -> f64 {
    if j <= k + 1 {
        return 0.0;
    }
    let lead = binom(j - 3, k - 1);
    if lead == 0.0 {
        return 0.0;
    }
    let mut acc = 0.0;
    for s in (k - 1)..=(n - 3) {
        let c = binom(n - j, s - (k - 1));
        if c == 0.0 {
            continue;
        }
        acc += (sii_weight(n, s) + sii_weight(n, s + 1)) * c;
    }
    lead * acc
}

/// SII superdiagonal by rank (same layout as the STI engine: c[r] is the
/// column value of the point at rank r; c[0] duplicates c[1]).
fn sii_superdiagonal(u_sorted: &[f64], k: usize, c: &mut [f64]) {
    let n = u_sorted.len();
    let nf = n as f64;
    // General last term: −w(k−1)·C(n−2,k−1)·u(α_n) = −u(α_n)/(n−1), but the
    // s = k−1 stratum only exists for k ≤ n−1; at k = n every Δ vanishes
    // (u is fully linear), so the whole matrix is the zero interaction.
    // (The STI analogue needs no guard — Eq. 6's (n−k) factor is the guard.)
    c[n - 1] = if k < n {
        -u_sorted[n - 1] / (nf - 1.0)
    } else {
        0.0
    };
    for j in (3..=n).rev() {
        c[j - 2] = c[j - 1] + sii_d(n, j, k) * (u_sorted[j - 1] - u_sorted[j - 2]);
    }
    if n >= 2 {
        c[0] = c[1.min(n - 1)];
    }
}

/// SII pair-interaction matrix for one test point, SORTED order; diagonal
/// carries the main terms u(i) (same convention as the STI engine).
pub fn sii_one_test_sorted(labels_sorted: &[i32], y_test: i32, k: usize) -> Matrix {
    let n = labels_sorted.len();
    assert!(n >= 2, "need >= 2 train points");
    assert!(k >= 1 && k <= n, "SII-KNN requires 1 <= k <= n");
    let inv_k = 1.0 / k as f64;
    let u: Vec<f64> = labels_sorted
        .iter()
        .map(|&l| if l == y_test { inv_k } else { 0.0 })
        .collect();
    let mut c = vec![0.0; n];
    sii_superdiagonal(&u, k, &mut c);
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        m.set(i, i, u[i]);
        for j in (i + 1)..n {
            m.set(i, j, c[j]);
            m.set(j, i, c[j]);
        }
    }
    m
}

/// Averaged SII matrix over a test set, ORIGINAL order; O(t·n²).
pub fn sii_knn(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    k: usize,
) -> Matrix {
    let n = train_y.len();
    let t = test_y.len();
    assert!(t > 0, "empty test set");
    let mut acc = Matrix::zeros(n, n);
    let mut dists = vec![0.0f64; n];
    let mut labels_sorted = vec![0i32; n];
    for (q, &y) in test_x.chunks_exact(d).zip(test_y) {
        // lint: allow(raw-distance) — reference oracle for the exact SII path stays on the
        // reference loop on purpose: it must not share the kernel
        // dispatch path it is used to validate.
        distances_into(q, train_x, d, Metric::SqEuclidean, &mut dists);
        let order = argsort_by_distance(&dists);
        for (r, &o) in order.iter().enumerate() {
            labels_sorted[r] = train_y[o];
        }
        let m_sorted = sii_one_test_sorted(&labels_sorted, y, k);
        for a in 0..n {
            for b in 0..n {
                acc.add_at(order[a], order[b], m_sorted.get(a, b));
            }
        }
    }
    acc.scale(1.0 / t as f64);
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shapley::sti_exact::{exact_one_test_sorted, sii_weight};
    use crate::util::rng::Rng;

    #[test]
    fn fast_sii_matches_bruteforce() {
        let mut rng = Rng::new(23);
        for n in 3..9usize {
            for k in 1..=n {
                let labels: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
                let y = rng.below(2) as i32;
                let fast = sii_one_test_sorted(&labels, y, k);
                let exact = exact_one_test_sorted(&labels, y, k, sii_weight);
                assert!(
                    fast.max_abs_diff(&exact) < 1e-12,
                    "n={n} k={k} labels={labels:?} y={y}: err={:.3e}",
                    fast.max_abs_diff(&exact)
                );
            }
        }
    }

    #[test]
    fn last_term_closed_form() {
        // §3.2: φ_{n-1,n} = −u(α_n)/(n−1)
        let labels = [0, 1, 1, 0, 1];
        let m = sii_one_test_sorted(&labels, 1, 2);
        assert!((m.get(3, 4) + 0.5 / 4.0).abs() < 1e-15);
    }

    #[test]
    fn column_equality_holds_for_sii_too() {
        let labels = [1, 0, 0, 1, 1, 0];
        let m = sii_one_test_sorted(&labels, 1, 2);
        for j in 1..labels.len() {
            for i in 0..j {
                assert_eq!(m.get(i, j), m.get(0, j));
            }
        }
    }

    #[test]
    fn sti_and_sii_rank_points_consistently() {
        // different coefficients, same qualitative structure: strong
        // correlation between the two indices' off-diagonals
        let mut rng = Rng::new(31);
        let n = 12;
        let labels: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let sti = crate::shapley::sti_knn::sti_one_test_sorted(&labels, 1, 3);
        let sii = sii_one_test_sorted(&labels, 1, 3);
        let r = crate::util::stats::pearson(
            &sti.upper_triangle_entries(),
            &sii.upper_triangle_entries(),
        );
        assert!(r > 0.9, "STI/SII correlation {r}");
    }
}
