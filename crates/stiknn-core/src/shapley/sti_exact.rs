//! Brute-force Shapley-Taylor interaction (Eq. 3) — the O(2ⁿ) baseline the
//! paper replaces, kept as ground truth for every fast engine:
//!
//!   φ_ij = (2/n) Σ_{S ⊆ N\{i,j}} 1/C(n−1,|S|) ·
//!            (v(S∪{i,j}) − v(S∪{i}) − v(S∪{j}) + v(S))
//!
//! Implemented over subset bitmasks with the KNN valuation (Eq. 2) as an
//! O(k) popcount-style walk. Also provides the Lemma-1 variant that
//! restricts the size sum to s ≥ k−1 (the smaller sizes cancel exactly —
//! itself a tested invariant), and the generalized-weight form used to
//! cross-validate the SII engine.

use crate::knn::valuation::u_subset_mask;
use crate::util::matrix::Matrix;

/// Guard: 2^n subset enumerations with n above this would run for hours.
pub const MAX_EXACT_N: usize = 22;

/// C(n, k) as f64 (exact for the small n used here).
pub fn binom(n: usize, k: usize) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut acc = 1.0f64;
    for i in 0..k {
        acc = acc * (n - i) as f64 / (i + 1) as f64;
    }
    acc
}

/// Interaction weight as a function of subset size s, defining the index:
/// STI uses (2/n)·1/C(n−1,s); SII uses s!(n−s−2)!/(n−1)!.
pub type WeightFn = fn(n: usize, s: usize) -> f64;

/// STI weight (Eq. 3): (2/n)·1/C(n−1,s).
pub fn sti_weight(n: usize, s: usize) -> f64 {
    2.0 / n as f64 / binom(n - 1, s)
}

/// SII weight (Grabisch & Roubens): s!(n−s−2)!/(n−1)! = 1/((n−1)·C(n−2,s)).
pub fn sii_weight(n: usize, s: usize) -> f64 {
    1.0 / ((n - 1) as f64 * binom(n - 2, s))
}

/// Exact pair interaction for one (i, j) under an arbitrary size weight,
/// one test point. Inputs are in SORTED order (nearest-first);
/// `match_bits` bit r = 1 iff the rank-r train point's label matches.
/// `min_s` restricts the subset sizes (0 for the full Eq. 3; k−1 for the
/// Lemma-1 restricted sum).
pub fn pair_interaction_masked(
    match_bits: u64,
    n: usize,
    i: usize,
    j: usize,
    k: usize,
    weight: WeightFn,
    min_s: usize,
) -> f64 {
    assert!(i != j && i < n && j < n);
    assert!(n <= MAX_EXACT_N, "exact STI limited to n <= {MAX_EXACT_N}");
    // Enumerate subsets of the n-2 "rest" positions.
    let rest: Vec<usize> = (0..n).filter(|&p| p != i && p != j).collect();
    let m = rest.len();
    let bit_i = 1u64 << i;
    let bit_j = 1u64 << j;
    let mut acc = 0.0f64;
    for mask in 0u64..(1u64 << m) {
        let s = mask.count_ones() as usize;
        if s < min_s {
            continue;
        }
        // expand compact mask -> positions
        let mut subset = 0u64;
        let mut mm = mask;
        while mm != 0 {
            let b = mm.trailing_zeros() as usize;
            subset |= 1u64 << rest[b];
            mm &= mm - 1;
        }
        let delta = u_subset_mask(match_bits, subset | bit_i | bit_j, k)
            - u_subset_mask(match_bits, subset | bit_i, k)
            - u_subset_mask(match_bits, subset | bit_j, k)
            + u_subset_mask(match_bits, subset, k);
        if delta != 0.0 {
            acc += weight(n, s) * delta;
        }
    }
    acc
}

fn match_bits_of(labels_sorted: &[i32], y_test: i32) -> u64 {
    labels_sorted
        .iter()
        .enumerate()
        .fold(0u64, |acc, (r, &l)| acc | (((l == y_test) as u64) << r))
}

/// Full exact STI matrix for one test point, sorted order, diagonal =
/// main terms φ_ii = v({i}) − v(∅) (Eq. 4).
pub fn sti_exact_one_test_sorted(labels_sorted: &[i32], y_test: i32, k: usize) -> Matrix {
    exact_one_test_sorted(labels_sorted, y_test, k, sti_weight)
}

/// Generalized-weight variant (used for SII cross-validation).
pub fn exact_one_test_sorted(
    labels_sorted: &[i32],
    y_test: i32,
    k: usize,
    weight: WeightFn,
) -> Matrix {
    let n = labels_sorted.len();
    let bits = match_bits_of(labels_sorted, y_test);
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        let ui = if (bits >> i) & 1 == 1 { 1.0 / k as f64 } else { 0.0 };
        m.set(i, i, ui);
        for j in (i + 1)..n {
            let v = pair_interaction_masked(bits, n, i, j, k, weight, 0);
            m.set(i, j, v);
            m.set(j, i, v);
        }
    }
    m
}

/// Exact STI matrix averaged over a test set, in ORIGINAL train order —
/// the end-to-end O(2ⁿ) baseline (used by the scaling bench and the
/// equivalence tests). O(t·n²·2ⁿ).
pub fn sti_exact(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    k: usize,
) -> Matrix {
    use crate::knn::distance::{argsort_by_distance, distances, Metric};
    let n = train_y.len();
    assert!(n <= MAX_EXACT_N, "exact STI limited to n <= {MAX_EXACT_N}");
    let t = test_y.len();
    assert!(t > 0);
    let mut acc = Matrix::zeros(n, n);
    for (q, &y) in test_x.chunks_exact(d).zip(test_y) {
        let dists = distances(q, train_x, d, Metric::SqEuclidean);
        let order = argsort_by_distance(&dists);
        let labels_sorted: Vec<i32> = order.iter().map(|&o| train_y[o]).collect();
        let m_sorted = sti_exact_one_test_sorted(&labels_sorted, y, k);
        // scatter back: acc[order[a]][order[b]] += m_sorted[a][b]
        for a in 0..n {
            for b in 0..n {
                acc.add_at(order[a], order[b], m_sorted.get(a, b));
            }
        }
    }
    acc.scale(1.0 / t as f64);
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binom_values() {
        assert_eq!(binom(5, 2), 10.0);
        assert_eq!(binom(6, 0), 1.0);
        assert_eq!(binom(4, 5), 0.0);
        assert_eq!(binom(20, 10), 184_756.0);
    }

    #[test]
    fn weights_match_closed_forms() {
        // STI: (2/n)/C(n-1,s); SII: 1/((n-1) C(n-2,s))
        assert!((sti_weight(4, 2) - 2.0 / 4.0 / 3.0).abs() < 1e-15);
        assert!((sii_weight(4, 0) - 1.0 / 3.0).abs() < 1e-15);
    }

    #[test]
    fn lemma1_restricted_sum_equals_full_sum() {
        // sizes s < k-1 cancel exactly (Lemma 1)
        let labels = [1, 0, 1, 1, 0, 1];
        let bits = super::match_bits_of(&labels, 1);
        let n = labels.len();
        for k in 2..=4usize {
            for (i, j) in [(0, 1), (2, 5), (4, 5)] {
                let full = pair_interaction_masked(bits, n, i, j, k, sti_weight, 0);
                let restricted =
                    pair_interaction_masked(bits, n, i, j, k, sti_weight, k - 1);
                assert!(
                    (full - restricted).abs() < 1e-14,
                    "k={k} ({i},{j}): {full} vs {restricted}"
                );
            }
        }
    }

    #[test]
    fn eq6_closed_form_for_last_pair() {
        // φ_{n-1,n} = -2(n-k)/(n(n-1))·u(α_n) for random labelings
        let cases: [(&[i32], i32, usize); 3] =
            [(&[1, 0, 1, 1], 1, 2), (&[0, 0, 1, 0, 1], 0, 3), (&[1, 1, 1], 1, 1)];
        for (labels, y, k) in cases {
            let n = labels.len();
            let bits = super::match_bits_of(labels, y);
            let got = pair_interaction_masked(bits, n, n - 2, n - 1, k, sti_weight, 0);
            let u_n = if labels[n - 1] == y { 1.0 / k as f64 } else { 0.0 };
            let want = -2.0 * (n as f64 - k as f64) / (n as f64 * (n as f64 - 1.0)) * u_n;
            assert!((got - want).abs() < 1e-14, "labels={labels:?} k={k}");
        }
    }

    #[test]
    fn sii_last_pair_closed_form() {
        // §3.2: for SII, φ_{n-1,n} = -u(α_n)/(n-1)
        let labels = [0, 1, 0, 1, 1];
        let n = labels.len();
        let k = 2;
        let bits = super::match_bits_of(&labels, 1);
        let got = pair_interaction_masked(bits, n, n - 2, n - 1, k, sii_weight, 0);
        let u_n = 1.0 / k as f64; // last label matches
        assert!((got + u_n / (n as f64 - 1.0)).abs() < 1e-14);
    }

    #[test]
    fn matrix_is_symmetric_and_diag_is_main_term() {
        let labels = [1, 0, 0, 1];
        let m = sti_exact_one_test_sorted(&labels, 1, 2);
        assert!(m.is_symmetric(1e-15));
        assert_eq!(m.get(0, 0), 0.5);
        assert_eq!(m.get(1, 1), 0.0);
    }

    #[test]
    fn efficiency_upper_triangle_sums_to_v_n() {
        // Σ_{i<=j} φ_ij = v(N) − v(∅) = u(N) exactly (DESIGN.md §1)
        let labels = [1, 0, 1, 1, 0];
        for k in 1..=5usize {
            let m = sti_exact_one_test_sorted(&labels, 1, k);
            let v_n: f64 = labels
                .iter()
                .take(k)
                .filter(|&&l| l == 1)
                .count() as f64
                / k as f64;
            assert!(
                (m.upper_triangle_sum() - v_n).abs() < 1e-12,
                "k={k}: {} vs {v_n}",
                m.upper_triangle_sum()
            );
        }
    }

    #[test]
    fn averaged_exact_matrix_original_order() {
        // 1-D geometry where sort orders differ per test point
        let train_x = [0.0f32, 1.0, 2.0, 3.0];
        let train_y = [1, 0, 1, 0];
        let test_x = [0.1f32, 2.9];
        let test_y = [1, 0];
        let m = sti_exact(&train_x, &train_y, 1, &test_x, &test_y, 2);
        assert!(m.is_symmetric(1e-15));
        // efficiency holds on average too: mean of per-test v(N)
        let v0 = 0.5; // test 0 (y=1): nearest 2 = {0:1, 1:0} -> 1 match /2
        let v1 = 0.5; // test 1 (y=0): nearest 2 = {3:0, 2:1} -> 1 match /2
        assert!((m.upper_triangle_sum() - (v0 + v1) / 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "exact STI limited")]
    fn refuses_large_n() {
        let labels = vec![1i32; MAX_EXACT_N + 1];
        sti_exact_one_test_sorted(&labels, 1, 3);
    }
}
