//! STI-KNN (Algorithm 1): exact pair-interaction Shapley-Taylor values for
//! KNN models in O(t·n²) — the paper's contribution.
//!
//! Per test point (1-based indices as in the paper, train points sorted
//! nearest-first):
//!
//!   line 3:    φ_{n−1,n} = −2(n−k)/(n(n−1))·u(α_n)                 (Eq. 6)
//!   lines 4-10: φ_{j−2,j−1} = φ_{j−1,j} + [j > k+1]·
//!                 2(j−k−1)/((j−2)(j−1))·(u(α_j) − u(α_{j−1}))      (Eq. 7)
//!   lines 11-14: all upper-triangle entries of column j equal φ_{j−1,j}
//!                                                                  (Eq. 8)
//!   diagonal:  φ_ii = v({i}) − v(∅) = u(i)                         (Eq. 4/5)
//!   main:      average over test points                            (Eq. 9)
//!
//! The per-test assembly is expressed exactly like the L1 Pallas kernel
//! (DESIGN.md §2): with `rank[i]` the sorted position of train point i and
//! `colval[i]` the superdiagonal value at that position,
//!
//!   Φ[i,j] += colval[ if rank[i] > rank[j] { i } else { j } ]   (i ≠ j)
//!
//! accumulated over the upper triangle only (the matrix is symmetric) and
//! mirrored once at the end — this keeps the O(n²) inner loop allocation-
//! free and sequential over the output rows.
//!
//! # Two-phase API
//!
//! The hot path is split into an explicit two-phase API so the coordinator
//! can parallelize each phase along its natural axis without copying the
//! n×n accumulator per worker (DESIGN.md §7):
//!
//! * [`prepare_batch`] — per-test O(n log n) prep (distances → ranks →
//!   superdiagonal), embarrassingly parallel over test points; produces a
//!   [`PreparedBatch`] of (rank, column-value) rows.
//! * [`sweep_band`] — the O(batch·n²) select-add sweep over a row band
//!   `[r_lo, r_hi)` of the shared accumulator. Bands partition the rows,
//!   so concurrent sweeps into disjoint bands need no synchronization, and
//!   because every cell lives in exactly one row, any band partition
//!   preserves the per-cell `row[j] += v` accumulation order — results are
//!   bit-identical to the single-threaded sweep for any band layout.
//!
//! [`sti_knn_partial`] is the single-threaded composition of the two
//! phases over the full band `[0, n)`.


use crate::knn::distance::{argsort_by_distance_keyed, Metric};
use crate::knn::kernel::{distances_block, NormCache};
use crate::util::matrix::Matrix;

/// Parameters for an STI-KNN run.
#[derive(Clone, Copy, Debug)]
pub struct StiParams {
    /// KNN neighborhood size. Must satisfy 1 ≤ k ≤ n: Algorithm 1's
    /// closed forms are exact only on that domain (DESIGN.md §1).
    pub k: usize,
    pub metric: Metric,
}

impl StiParams {
    pub fn new(k: usize) -> Self {
        StiParams {
            k,
            metric: Metric::SqEuclidean,
        }
    }

    fn validate(&self, n: usize) {
        assert!(self.k >= 1, "k must be >= 1");
        assert!(
            self.k <= n,
            "STI-KNN is exact only for k <= n (k={}, n={}); see DESIGN.md §1",
            self.k,
            n
        );
        assert!(n >= 2, "need at least 2 training points for interactions");
    }
}

/// Test points per prepared batch in the single-threaded path (§Perf): the
/// assembly loop is memory-bound on the n×n accumulator if it streams the
/// whole matrix once per test point, so we batch `PREP_BATCH` test points'
/// (rank, column-value) rows and sweep the accumulator ONCE per batch,
/// iterating the batch in the middle loop — the accumulator row stays in
/// L1/L2 across all test points of the batch (measured 0.81 → 0.27
/// ns/pair-cell at n=600; see EXPERIMENTS.md §Perf). Public so the
/// session layer and benches can reason about the internal chunking
/// (chunk boundaries never change any cell's addition order, so the
/// choice is a pure perf knob — see `two_phase_composition_equals_partial`).
pub const PREP_BATCH: usize = 64;

/// Phase-1 output for a block of test points: everything the O(n²) sweep
/// needs, laid out for the branchless select-add inner loop. Memory is
/// O(len·n) — independent of how many workers later sweep it.
pub struct PreparedBatch {
    n: usize,
    len: usize,
    inv_k: f64,
    /// Wall nanoseconds spent inside the distance kernel
    /// ([`distances_block`]) while preparing this batch — the
    /// `coord.prep.kernel_ns` observability slice.
    kernel_ns: u64,
    /// rank as f64, `len` rows of n, original train order — f64 operands
    /// let LLVM lower the inner select to vcmppd + vblendvpd + vaddpd.
    rankf: Vec<f64>,
    /// per-point column values, `len` rows of n, original train order.
    colval: Vec<f64>,
    /// test labels, for the diagonal main terms (Eq. 4/5).
    test_y: Vec<i32>,
}

impl PreparedBatch {
    /// Number of test points in the batch.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Train-set size the batch was prepared against.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Merge weight of the batch (number of test points, Eq. 9).
    pub fn weight(&self) -> f64 {
        self.len as f64
    }

    /// 1/k — the per-match utility quantum (Eq. 2).
    pub fn inv_k(&self) -> f64 {
        self.inv_k
    }

    /// Test point `p`'s rank row, ORIGINAL train order: `rank_row(p)[i]`
    /// is train point i's sorted position for this test point, as f64
    /// (always an exact small integer).
    pub fn rank_row(&self, p: usize) -> &[f64] {
        &self.rankf[p * self.n..(p + 1) * self.n]
    }

    /// Test point `p`'s column-value row, ORIGINAL train order:
    /// `colval_row(p)[i]` is the Eq. 8 column value of train point i
    /// (= c_p[rank of i]).
    pub fn colval_row(&self, p: usize) -> &[f64] {
        &self.colval[p * self.n..(p + 1) * self.n]
    }

    /// Test point `p`'s label.
    pub fn test_label(&self, p: usize) -> i32 {
        self.test_y[p]
    }

    /// Nanoseconds this batch spent in the distance kernel.
    pub fn kernel_ns(&self) -> u64 {
        self.kernel_ns
    }
}

/// Reusable scratch for [`prepare_batch_scratch`]: the per-test distance,
/// superdiagonal, argsort-order and packed-sort-key buffers. One
/// `PrepScratch` serves any number of batches against the same (or
/// different) train sizes — the buffers are resized on demand and their
/// capacity never shrinks, so a long-lived stream of small batches
/// performs no per-test allocations at all.
#[derive(Default)]
pub struct PrepScratch {
    /// B×n distance tile filled by [`distances_block`] for one
    /// QUERY_BLOCK of test points at a time.
    dists_blk: Vec<f64>,
    c: Vec<f64>,
    order: Vec<usize>,
    keys: Vec<u128>,
}

impl PrepScratch {
    pub fn new() -> Self {
        PrepScratch::default()
    }

    fn resize(&mut self, n: usize) {
        self.c.resize(n, 0.0);
        self.order.resize(n, 0);
    }
}

/// Test points per blocked distance call inside prep: B queries share
/// each L1-resident tile of train rows ([`distances_block`]), so one
/// train-row load from memory feeds B dot products. 8 keeps the B×n
/// f64 tile small (n=32k → 2 MB) while capturing most of the reuse;
/// the acceptance bench (`benches/distance.rs`) measures the win at
/// B ∈ {8, 64}. Like [`PREP_BATCH`], a pure perf knob: block
/// boundaries cannot change any distance, rank, or column value.
const QUERY_BLOCK: usize = 8;

/// Lines 3–10 of Algorithm 1: the superdiagonal, indexed by RANK.
///
/// `u_sorted[r]` is u(α_{r+1}) (0-based rank r). Output `c[r]` is the
/// column value of the point at rank r, i.e. φ_{r,r+1} in 1-based paper
/// terms c[r] = φ_{(r+1)−1,(r+1)}; c[0] duplicates c[1] (column 1 has no
/// upper-triangle entries, the value is never used for a pair).
///
/// `pub(crate)` so the delta repair kernel (`shapley::delta`) rebuilds
/// post-edit column values through the EXACT same recursion — sharing
/// this function is what makes repaired rows bit-match from-scratch
/// prep rows.
pub(crate) fn superdiagonal_into(u_sorted: &[f64], k: usize, c: &mut [f64]) {
    let n = u_sorted.len();
    debug_assert!(n >= 2 && c.len() == n);
    let nf = n as f64;
    let kf = k as f64;
    // Eq. (6)
    c[n - 1] = -2.0 * (nf - kf) / (nf * (nf - 1.0)) * u_sorted[n - 1];
    // Eq. (7), j = n down to 3 (1-based); c index r = j-2 gets φ_{j-2,j-1}
    for j in (3..=n).rev() {
        let jf = j as f64;
        let prev = c[j - 1];
        c[j - 2] = if j > k + 1 {
            prev + 2.0 * (jf - kf - 1.0) / ((jf - 2.0) * (jf - 1.0))
                * (u_sorted[j - 1] - u_sorted[j - 2])
        } else {
            prev
        };
    }
    if n >= 2 {
        c[0] = c[1.min(n - 1)];
    }
}

/// Phase 1: prepare a block of test points for the O(n²) sweep — per test
/// point, distances → ranks → superdiagonal (Eq. 6/7) → scatter to
/// original train order. O(len·n·(d + log n)); embarrassingly parallel
/// over test points / blocks. Allocates its scratch internally; streaming
/// callers that prepare many batches should hold a [`PrepScratch`] and
/// call [`prepare_batch_scratch`] instead.
pub fn prepare_batch(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    params: &StiParams,
) -> PreparedBatch {
    let mut scratch = PrepScratch::new();
    prepare_batch_scratch(train_x, train_y, d, test_x, test_y, params, &mut scratch)
}

/// [`prepare_batch`] with caller-owned scratch: zero per-test allocations
/// (the distance-tile / superdiagonal / argsort-order buffers live in
/// `scratch` and are reused across calls). Builds a throwaway
/// [`NormCache`] internally; streaming callers that prepare many batches
/// against the SAME train set should build the cache once and call
/// [`prepare_batch_cached`]. The output batch is bit-identical to
/// [`prepare_batch`]'s — scratch reuse cannot change a single rank or
/// column value.
pub fn prepare_batch_scratch(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    params: &StiParams,
    scratch: &mut PrepScratch,
) -> PreparedBatch {
    let norms = NormCache::build(train_x, d, params.metric);
    prepare_batch_cached(train_x, train_y, d, test_x, test_y, params, &norms, scratch)
}

/// The prep primitive every hot path bottoms out in: distances through
/// the active SIMD kernel with cached per-train-row norms, computed in
/// [`QUERY_BLOCK`]-sized blocked tiles ([`distances_block`]), then the
/// packed-key argsort and superdiagonal per test point. `norms` MUST
/// describe `train_x` (checked); build it once per session / job and
/// reuse it across every batch. Kernel time is measured into the
/// batch's [`PreparedBatch::kernel_ns`].
#[allow(clippy::too_many_arguments)]
pub fn prepare_batch_cached(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    params: &StiParams,
    norms: &NormCache,
    scratch: &mut PrepScratch,
) -> PreparedBatch {
    let n = train_y.len();
    params.validate(n);
    assert_eq!(train_x.len(), n * d, "train shape mismatch");
    assert_eq!(test_x.len(), test_y.len() * d, "test shape mismatch");
    let len = test_y.len();
    let k = params.k;
    let inv_k = 1.0 / k as f64;

    let mut rankf = vec![0.0f64; len * n];
    let mut colval = vec![0.0f64; len * n];
    scratch.resize(n);
    let mut kernel_ns = 0u64;

    let mut lo = 0usize;
    while lo < len {
        let hi = (lo + QUERY_BLOCK).min(len);
        let b = hi - lo;
        scratch.dists_blk.resize(b * n, 0.0);
        let t0 = crate::obs::now();
        distances_block(
            &test_x[lo * d..hi * d],
            train_x,
            d,
            params.metric,
            norms,
            &mut scratch.dists_blk[..b * n],
        );
        kernel_ns += t0.elapsed().as_nanos() as u64;

        for slot in lo..hi {
            let dists = &scratch.dists_blk[(slot - lo) * n..(slot - lo + 1) * n];
            // Packed-key sort: identical order to argsort_by_distance
            // (the metrics are non-negative), measurably faster prep.
            argsort_by_distance_keyed(dists, &mut scratch.keys, &mut scratch.order);

            let y = test_y[slot];
            let rank_row = &mut rankf[slot * n..(slot + 1) * n];
            let col_row = &mut colval[slot * n..(slot + 1) * n];
            // u in sorted order (reuse col_row as the temp buffer), then
            // the superdiagonal by rank (Eq. 6/7).
            for (r, &orig) in scratch.order.iter().enumerate() {
                col_row[r] = if train_y[orig] == y { inv_k } else { 0.0 };
            }
            superdiagonal_into(&col_row[..n], k, &mut scratch.c);
            // Scatter to original order so the O(n²) loop is a pure
            // select-add.
            for (r, &orig) in scratch.order.iter().enumerate() {
                rank_row[orig] = r as f64;
                col_row[orig] = scratch.c[r];
            }
        }
        lo = hi;
    }

    PreparedBatch {
        n,
        len,
        inv_k,
        kernel_ns,
        rankf,
        colval,
        test_y: test_y.to_vec(),
    }
}

/// Phase 2: accumulate one prepared batch into the accumulator row band
/// `[r_lo, r_hi)` — the Pallas-kernel twin. `rows` is the band's slice of
/// the row-major accumulator, `(r_hi − r_lo)·n` long, columns in GLOBAL
/// train order. Covers both the diagonal main terms (Eq. 4/5) for rows in
/// the band and the upper-triangle select-add (Eq. 8); the batch is the
/// MIDDLE loop so each accumulator row stays hot across all test points of
/// the batch, and the inner select is branchless over f64 operands
/// (auto-vectorizes; AVX-512 via target-cpu=native).
///
/// Disjoint bands may be swept concurrently; each row's per-cell addition
/// order is (batch order, test order within batch) regardless of the band
/// layout, so results are bit-identical to a full-band sweep.
pub fn sweep_band(
    batch: &PreparedBatch,
    train_y: &[i32],
    r_lo: usize,
    r_hi: usize,
    rows: &mut [f64],
) {
    let n = batch.n;
    assert_eq!(train_y.len(), n, "train labels / batch mismatch");
    assert!(r_lo < r_hi && r_hi <= n, "bad band [{r_lo}, {r_hi}) for n={n}");
    assert_eq!(rows.len(), (r_hi - r_lo) * n, "band slice shape mismatch");

    // Diagonal main terms (Eq. 4/5) for rows owned by this band. Disjoint
    // from the upper-triangle cells, so phase order within the batch does
    // not affect any cell's addition order.
    for &y in &batch.test_y {
        for i in r_lo..r_hi {
            if train_y[i] == y {
                rows[(i - r_lo) * n + i] += batch.inv_k;
            }
        }
    }

    // Upper-triangle select-add (the hot loop).
    // (A 2-row-blocked variant that shares operand streams between
    // adjacent rows was tried and reverted: −8% at n=600 but +10% at
    // n=1600 — see EXPERIMENTS.md §Perf iteration log.)
    for i in r_lo..r_hi {
        let row = &mut rows[(i - r_lo) * n..(i - r_lo) * n + n];
        for p in 0..batch.len {
            let rankf = &batch.rankf[p * n..(p + 1) * n];
            let colval = &batch.colval[p * n..(p + 1) * n];
            let rif = rankf[i];
            let wci = colval[i];
            for j in (i + 1)..n {
                let v = if rankf[j] < rif { wci } else { colval[j] };
                row[j] += v;
            }
        }
    }
}

/// Accumulate one test batch's unnormalized contribution Σ_p Φ(u_p) into
/// an EXISTING n×n accumulator (upper triangle + diagonal, like
/// [`sweep_band`]) and return the batch's merge weight (its test count,
/// Eq. 9). This is the streaming-ingest primitive the session layer
/// (`stiknn-session`) builds on: because every cell's additions are
/// applied in test order regardless of how the stream is cut into
/// batches, ingesting any contiguous partition of a test set through
/// repeated calls is bit-identical to one [`sti_knn_partial`] run over
/// the whole set (DESIGN.md §9).
pub fn sti_knn_accumulate(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    params: &StiParams,
    acc: &mut Matrix,
) -> f64 {
    let n = train_y.len();
    params.validate(n);
    assert_eq!(train_x.len(), n * d, "train shape mismatch");
    assert_eq!(test_x.len(), test_y.len() * d, "test shape mismatch");
    assert_eq!(
        (acc.rows(), acc.cols()),
        (n, n),
        "accumulator shape mismatch"
    );
    let mut scratch = PrepScratch::new();
    let norms = NormCache::build(train_x, d, params.metric);
    for (chunk_x, chunk_y) in test_x
        .chunks(PREP_BATCH * d)
        .zip(test_y.chunks(PREP_BATCH))
    {
        let batch = prepare_batch_cached(
            train_x, train_y, d, chunk_x, chunk_y, params, &norms, &mut scratch,
        );
        sweep_band(&batch, train_y, 0, n, acc.data_mut());
    }
    test_y.len() as f64
}

/// Partial (unnormalized) STI-KNN over a slice of the test set: returns
/// (Σ_p Φ(u_p), weight = number of test points). This is the unit of work
/// the test-sharded coordinator path shards and merges (Eq. 9 linearity);
/// the banded path composes [`prepare_batch`]/[`sweep_band`] itself.
pub fn sti_knn_partial(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    params: &StiParams,
) -> (Matrix, f64) {
    let n = train_y.len();
    params.validate(n);
    let mut acc = Matrix::zeros(n, n);
    let weight = sti_knn_accumulate(train_x, train_y, d, test_x, test_y, params, &mut acc);
    acc.mirror_upper_to_lower();
    (acc, weight)
}

/// The full STI-KNN interaction matrix, averaged over the test set
/// (Eq. 9). Diagonal carries the main terms φ_ii (Eq. 4). O(t·n²).
pub fn sti_knn(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    params: &StiParams,
) -> Matrix {
    assert!(!test_y.is_empty(), "empty test set");
    let (mut acc, w) = sti_knn_partial(train_x, train_y, d, test_x, test_y, params);
    acc.scale(1.0 / w);
    acc
}

/// Single-test-point matrix (sorted-order inputs), exposed for tests and
/// the analysis suite: labels already ordered nearest-first.
pub fn sti_one_test_sorted(labels_sorted: &[i32], y_test: i32, k: usize) -> Matrix {
    let n = labels_sorted.len();
    StiParams::new(k).validate(n);
    let inv_k = 1.0 / k as f64;
    let u: Vec<f64> = labels_sorted
        .iter()
        .map(|&l| if l == y_test { inv_k } else { 0.0 })
        .collect();
    let mut c = vec![0.0; n];
    superdiagonal_into(&u, k, &mut c);
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        m.set(i, i, u[i]);
        for j in (i + 1)..n {
            m.set(i, j, c[j]);
            m.set(j, i, c[j]);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shapley::sti_exact;
    use crate::util::rng::Rng;

    #[test]
    fn matches_bruteforce_small_cases() {
        let mut rng = Rng::new(7);
        for n in 3..9usize {
            for k in 1..=n {
                let labels: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
                let y = rng.below(2) as i32;
                let fast = sti_one_test_sorted(&labels, y, k);
                let exact = sti_exact::sti_exact_one_test_sorted(&labels, y, k);
                assert!(
                    fast.max_abs_diff(&exact) < 1e-12,
                    "n={n} k={k} labels={labels:?} y={y}: {:.3e}",
                    fast.max_abs_diff(&exact)
                );
            }
        }
    }

    #[test]
    fn eq6_last_term() {
        // all-matching labels: φ_{n-1,n} = -2(n-k)/(n(n-1))·(1/k)
        let n = 6;
        let k = 2;
        let m = sti_one_test_sorted(&vec![1; n], 1, k);
        let expect = -2.0 * (n as f64 - k as f64) / (n as f64 * (n - 1) as f64) / k as f64;
        assert!((m.get(n - 2, n - 1) - expect).abs() < 1e-15);
    }

    #[test]
    fn column_equality_sorted_order() {
        let labels = [1, 0, 0, 1, 1, 0, 1];
        let m = sti_one_test_sorted(&labels, 1, 3);
        for j in 1..labels.len() {
            for i in 0..j {
                assert_eq!(m.get(i, j), m.get(0, j), "column {j} not constant");
            }
        }
    }

    #[test]
    fn close_points_share_value_below_k_plus_1() {
        // Algorithm 1 lines 5–9: the recursion only adds the Eq. 7
        // increment for 1-based columns j > k+1, and copies for j ≤ k+1 —
        // KNN cannot distinguish points that are always among the k
        // nearest, so 1-based columns 2..=k+1 (0-based 1..=k) all carry
        // the same value.
        let labels = [1, 0, 1, 0, 1, 0];
        let k = 4;
        let m = sti_one_test_sorted(&labels, 1, k);
        let c2 = m.get(0, 1); // 1-based column 2
        for j in 1..=k {
            assert_eq!(m.get(0, j), c2, "1-based column {} differs", j + 1);
        }
        // The first column past k+1 picks up the Eq. 7 increment here
        // (u(α_6) = 0 ≠ u(α_5) = 1/k), so the shared value must stop.
        assert_ne!(m.get(0, k + 1), c2, "column k+2 should differ");
    }

    #[test]
    fn averaged_matrix_is_symmetric_with_nonneg_diagonal() {
        let mut rng = Rng::new(42);
        let n = 20;
        let d = 3;
        let t = 7;
        let train_x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let train_y: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let test_x: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let test_y: Vec<i32> = (0..t).map(|_| rng.below(2) as i32).collect();
        let m = sti_knn(&train_x, &train_y, d, &test_x, &test_y, &StiParams::new(5));
        assert!(m.is_symmetric(0.0));
        assert!(m.diagonal().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn partial_linearity_matches_full() {
        // Eq. (9): summing two disjoint partials == one full run.
        let mut rng = Rng::new(3);
        let n = 15;
        let d = 2;
        let t = 6;
        let train_x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let train_y: Vec<i32> = (0..n).map(|_| rng.below(3) as i32).collect();
        let test_x: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let test_y: Vec<i32> = (0..t).map(|_| rng.below(3) as i32).collect();
        let params = StiParams::new(4);

        let (mut a, wa) =
            sti_knn_partial(&train_x, &train_y, d, &test_x[..3 * d], &test_y[..3], &params);
        let (b, wb) =
            sti_knn_partial(&train_x, &train_y, d, &test_x[3 * d..], &test_y[3..], &params);
        a.add_assign(&b);
        a.scale(1.0 / (wa + wb));
        let full = sti_knn(&train_x, &train_y, d, &test_x, &test_y, &params);
        assert!(a.max_abs_diff(&full) < 1e-12);
    }

    #[test]
    fn banded_sweep_is_bit_identical_to_full_sweep() {
        // The tentpole invariant: sweeping a prepared batch band-by-band
        // (any partition, including bands that don't divide n evenly)
        // produces the same BITS as the full-band sweep, because every
        // cell's addition order is unchanged.
        let mut rng = Rng::new(17);
        let n = 23;
        let d = 2;
        let t = 9;
        let train_x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let train_y: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let test_x: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let test_y: Vec<i32> = (0..t).map(|_| rng.below(2) as i32).collect();
        let params = StiParams::new(4);
        let batch = prepare_batch(&train_x, &train_y, d, &test_x, &test_y, &params);

        let mut full = Matrix::zeros(n, n);
        sweep_band(&batch, &train_y, 0, n, full.data_mut());

        for bands in [vec![(0usize, 5usize), (5, 23)], vec![(0, 7), (7, 14), (14, 21), (21, 23)]] {
            let mut banded = Matrix::zeros(n, n);
            for &(lo, hi) in &bands {
                let rows = &mut banded.data_mut()[lo * n..hi * n];
                sweep_band(&batch, &train_y, lo, hi, rows);
            }
            for (a, b) in full.data().iter().zip(banded.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "bands {bands:?} diverged");
            }
        }
    }

    #[test]
    fn two_phase_composition_equals_partial() {
        // prepare_batch + sweep_band over [0, n) in PREP_BATCH-sized chunks is
        // exactly sti_knn_partial (which is implemented that way), and a
        // different chunking agrees to the bit as well: chunk boundaries
        // don't change any cell's per-test addition order.
        let mut rng = Rng::new(29);
        let n = 18;
        let d = 2;
        let t = 11;
        let train_x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let train_y: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let test_x: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let test_y: Vec<i32> = (0..t).map(|_| rng.below(2) as i32).collect();
        let params = StiParams::new(3);

        let (reference, w) = sti_knn_partial(&train_x, &train_y, d, &test_x, &test_y, &params);
        assert_eq!(w, t as f64);

        let mut acc = Matrix::zeros(n, n);
        let mut weight = 0.0;
        for chunk in [(0usize, 4usize), (4, 9), (9, 11)] {
            let (lo, hi) = chunk;
            let batch = prepare_batch(
                &train_x, &train_y, d, &test_x[lo * d..hi * d], &test_y[lo..hi], &params,
            );
            weight += batch.weight();
            sweep_band(&batch, &train_y, 0, n, acc.data_mut());
        }
        acc.mirror_upper_to_lower();
        assert_eq!(weight, t as f64);
        for (a, b) in reference.data().iter().zip(acc.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn accumulate_over_contiguous_batches_is_bit_identical_to_partial() {
        // The streaming-ingest contract: cutting the test stream into any
        // contiguous batches and accumulating them in order leaves every
        // cell's addition sequence unchanged, so the raw accumulator bits
        // match a single sti_knn_partial over the whole set.
        let mut rng = Rng::new(91);
        let n = 17;
        let d = 3;
        let t = 10;
        let train_x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let train_y: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let test_x: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let test_y: Vec<i32> = (0..t).map(|_| rng.below(2) as i32).collect();
        let params = StiParams::new(4);

        let (reference, w) = sti_knn_partial(&train_x, &train_y, d, &test_x, &test_y, &params);
        assert_eq!(w, t as f64);

        let mut acc = Matrix::zeros(n, n);
        let mut weight = 0.0;
        for (lo, hi) in [(0usize, 1usize), (1, 6), (6, 10)] {
            weight += sti_knn_accumulate(
                &train_x, &train_y, d, &test_x[lo * d..hi * d], &test_y[lo..hi], &params, &mut acc,
            );
        }
        acc.mirror_upper_to_lower();
        assert_eq!(weight, t as f64);
        for (a, b) in reference.data().iter().zip(acc.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn scratch_reuse_is_bit_identical_to_fresh_allocation() {
        // PrepScratch is a pure allocation cache: preparing two different
        // batches through ONE scratch (dirty buffers between calls) gives
        // the same bits as fresh prepare_batch calls.
        let mut rng = Rng::new(53);
        let n = 21;
        let d = 3;
        let train_x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let train_y: Vec<i32> = (0..n).map(|_| rng.below(3) as i32).collect();
        let params = StiParams::new(5);
        let mut scratch = PrepScratch::new();
        for t in [4usize, 1, 7] {
            let test_x: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
            let test_y: Vec<i32> = (0..t).map(|_| rng.below(3) as i32).collect();
            let fresh = prepare_batch(&train_x, &train_y, d, &test_x, &test_y, &params);
            let reused = prepare_batch_scratch(
                &train_x, &train_y, d, &test_x, &test_y, &params, &mut scratch,
            );
            assert_eq!(fresh.len(), reused.len());
            for p in 0..t {
                for i in 0..n {
                    assert_eq!(
                        fresh.rank_row(p)[i].to_bits(),
                        reused.rank_row(p)[i].to_bits()
                    );
                    assert_eq!(
                        fresh.colval_row(p)[i].to_bits(),
                        reused.colval_row(p)[i].to_bits()
                    );
                }
                assert_eq!(fresh.test_label(p), reused.test_label(p));
            }
        }
    }

    // The kernel prep path (blocked SIMD distances + cached norms) must
    // reproduce a hand-built construction over SCALAR Metric::dist
    // distances bit-for-bit: the lane-tree distances differ from scalar
    // by rounding, but the stable argsort orders them identically (ties
    // from duplicated train rows included), and every rank / column
    // value downstream depends on distances only through that order.
    #[test]
    fn kernel_prep_bit_matches_scalar_reference_construction() {
        use crate::knn::distance::{argsort_by_distance, distances};
        let mut rng = Rng::new(63);
        let d = 5;
        let base: Vec<f32> = (0..10 * d).map(|_| rng.normal() as f32).collect();
        // 3 copies of each base row => deliberate exact distance ties
        let mut train_x = Vec::new();
        for _ in 0..3 {
            train_x.extend_from_slice(&base);
        }
        let n = 30;
        let train_y: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let t = 11; // not a multiple of QUERY_BLOCK: exercises the tail block
        let test_x: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let test_y: Vec<i32> = (0..t).map(|_| rng.below(2) as i32).collect();
        let k = 4;
        let inv_k = 1.0 / k as f64;

        for metric in [Metric::SqEuclidean, Metric::Manhattan, Metric::Cosine] {
            let params = StiParams { k, metric };
            let batch = prepare_batch(&train_x, &train_y, d, &test_x, &test_y, &params);
            assert_eq!(batch.len(), t);
            for (slot, q) in test_x.chunks_exact(d).enumerate() {
                let order = argsort_by_distance(&distances(q, &train_x, d, metric));
                let mut u = vec![0.0f64; n];
                for (r, &orig) in order.iter().enumerate() {
                    u[r] = if train_y[orig] == test_y[slot] { inv_k } else { 0.0 };
                }
                let mut c = vec![0.0f64; n];
                superdiagonal_into(&u, k, &mut c);
                for (r, &orig) in order.iter().enumerate() {
                    assert_eq!(
                        batch.rank_row(slot)[orig].to_bits(),
                        (r as f64).to_bits(),
                        "metric={metric:?} slot={slot}"
                    );
                    assert_eq!(
                        batch.colval_row(slot)[orig].to_bits(),
                        c[r].to_bits(),
                        "metric={metric:?} slot={slot}"
                    );
                }
            }
        }
    }

    // QUERY_BLOCK sub-blocking is a pure perf knob: a cached prep over
    // one shared NormCache bit-matches the throwaway-cache wrapper.
    #[test]
    fn cached_prep_is_bit_identical_to_wrapper() {
        let mut rng = Rng::new(71);
        let n = 19;
        let d = 4;
        let t = 13;
        let train_x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let train_y: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let test_x: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let test_y: Vec<i32> = (0..t).map(|_| rng.below(2) as i32).collect();
        let params = StiParams::new(3);
        let norms = NormCache::build(&train_x, d, params.metric);
        let mut scratch = PrepScratch::new();
        let cached = prepare_batch_cached(
            &train_x, &train_y, d, &test_x, &test_y, &params, &norms, &mut scratch,
        );
        let fresh = prepare_batch(&train_x, &train_y, d, &test_x, &test_y, &params);
        assert_eq!(cached.len(), fresh.len());
        for p in 0..t {
            for i in 0..n {
                assert_eq!(cached.rank_row(p)[i].to_bits(), fresh.rank_row(p)[i].to_bits());
                assert_eq!(
                    cached.colval_row(p)[i].to_bits(),
                    fresh.colval_row(p)[i].to_bits()
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "k <= n")]
    fn k_greater_than_n_is_rejected() {
        sti_one_test_sorted(&[1, 0, 1], 1, 4);
    }

    #[test]
    fn n_equals_2_minimal_case() {
        let m = sti_one_test_sorted(&[1, 1], 1, 1);
        // φ_{1,2} = -2(2-1)/(2·1)·u(α_2) = -1·1 = -1
        assert!((m.get(0, 1) + 1.0).abs() < 1e-15);
        assert_eq!(m.get(0, 0), 1.0); // main term u(1) = 1/k = 1
    }
}
