//! Implicit per-point value engine: exact STI mains + interaction row
//! sums in **O(n) per test point after the O(n log n) prep**, with no
//! n×n materialization (DESIGN.md §10).
//!
//! # The rank-space suffix-sum identity
//!
//! Eq. 8 makes every per-test interaction matrix column-constant in rank
//! space: for a pair with sorted positions (r_i, r_j), φ_p[i,j] =
//! c_p[max(r_i, r_j)], where c_p is the Eq. 6/7 superdiagonal. A point's
//! off-diagonal row sum therefore collapses — splitting the other points
//! into the r_i points ranked BELOW it (each pair takes its own column
//! value c_p[r_i]) and the points ranked ABOVE it (each pair takes that
//! point's column value):
//!
//!   rowsum_i(p) = Σ_{j≠i} c_p[max(r_i, r_j)]
//!               = r_i·c_p[r_i] + Σ_{s > r_i} c_p[s]
//!               = r_i·c_p[r_i] + suffix(c_p, r_i + 1)
//!
//! One right-to-left suffix-sum pass over c_p serves ALL n rows of one
//! test point, so per-point values (main φ_ii = u_p(i), Eq. 4/5, plus the
//! row sum above) cost O(n) per test point after the existing O(n log n)
//! prep — O(t·n log n) total and O(n) state, versus the dense engine's
//! O(t·n²) time and O(n²) memory. That is the same "exploit KNN rank
//! locality" move as Jia et al.'s O(n log n) KNN-Shapley (1908.08619),
//! applied to the interaction aggregates every downstream valuation
//! workload (top-k, mislabel ranking, removal/acquisition curves)
//! actually consumes.
//!
//! # Summation order (the bit-reproducibility contract)
//!
//! The engine fixes ONE summation order and documents it:
//!
//! * suffix sums right-to-left: `suffix[r] = c[r] + suffix[r+1]`,
//!   `suffix[n] = 0`;
//! * per-test row value evaluated as `r·c[r] + suffix[r+1]` (one
//!   multiply, one add — no FMA contraction in Rust's default float
//!   semantics);
//! * each accumulator element receives exactly ONE addition per test
//!   point, applied in test-stream order.
//!
//! Because every element sees the same additions in the same order no
//! matter how the stream is cut, [`values_accumulate`] over ANY
//! contiguous partition of a test set is **bit-identical** to a one-shot
//! run (mirroring `sti_knn_accumulate`'s contract). Equality against the
//! dense engine's `diag + rowsums` is a different association order and
//! therefore holds to ≤ 1e-12, not bitwise — `tests/values_equivalence.rs`
//! asserts both sides.

use super::sti_knn::{
    prepare_batch_cached, PrepScratch, PreparedBatch, StiParams, PREP_BATCH,
};
use crate::knn::kernel::NormCache;
use crate::util::matrix::Matrix;

/// Which value engine computes per-point aggregates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Materialize the n×n interaction accumulator (O(t·n²) time,
    /// O(n²) memory) and read values off it. Supports cell/row/matrix
    /// queries; required when the full interaction structure is needed.
    Dense,
    /// Rank-space suffix-sum identity (this module): O(t·n log n) time,
    /// O(n) state. Per-point values only — the matrix never exists.
    Implicit,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "dense" | "matrix" => Some(Engine::Dense),
            "implicit" | "values" => Some(Engine::Implicit),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Engine::Dense => "dense",
            Engine::Implicit => "implicit",
        }
    }
}

/// O(n) per-point value accumulator: the implicit twin of the n×n
/// matrix accumulator. Holds the UNNORMALIZED sums Σ_p over ingested
/// test points; normalization (scale by 1/t, Eq. 9) happens at read
/// time, exactly like the session layer's matrix path.
#[derive(Clone, Debug)]
pub struct ValueVector {
    n: usize,
    /// Σ_p u_p(i) — the diagonal main terms (Eq. 4/5). `pub(crate)` so
    /// the delta refold (`shapley::delta::refold_values`) applies the
    /// same per-element additions as [`sweep_values`].
    pub(crate) main: Vec<f64>,
    /// Σ_p Σ_{j≠i} φ_p[i,j] — the off-diagonal interaction row sums via
    /// the suffix-sum identity.
    pub(crate) inter: Vec<f64>,
}

impl ValueVector {
    pub fn zeros(n: usize) -> Self {
        ValueVector {
            n,
            main: vec![0.0; n],
            inter: vec![0.0; n],
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Raw (unnormalized) main-term sums.
    pub fn main_raw(&self) -> &[f64] {
        &self.main
    }

    /// Raw (unnormalized) off-diagonal interaction row sums.
    pub fn inter_raw(&self) -> &[f64] {
        &self.inter
    }

    /// Averaged main values φ_ii (Eq. 9 with weight 1/inv_w).
    pub fn main_values(&self, inv_w: f64) -> Vec<f64> {
        self.main.iter().map(|&m| m * inv_w).collect()
    }

    /// Averaged total row sums φ_ii + Σ_{j≠i} φ_ij — the same quantity as
    /// the dense path's `diag + rowsums` (session `TopBy::RowSum`).
    pub fn rowsum_values(&self, inv_w: f64) -> Vec<f64> {
        self.main
            .iter()
            .zip(&self.inter)
            .map(|(&m, &s)| (m + s) * inv_w)
            .collect()
    }

    /// Eq. 9 linearity: fold another partial vector into this one
    /// (elementwise) — for callers that compute partials over disjoint
    /// test shards and combine them (the vector analogue of
    /// `Matrix::add_assign`). Note this merge carries only the ≤ 1e-12
    /// Eq. 9 guarantee, NOT the bit-reproducibility contract: combining
    /// per-shard sums associates additions differently than streaming
    /// the same tests through one vector. (The coordinator's
    /// value-sharded path avoids that by folding published blocks into
    /// a single vector in stream order.)
    pub fn add_assign(&mut self, other: &ValueVector) {
        assert_eq!(self.n, other.n, "value vector size mismatch");
        for (a, b) in self.main.iter_mut().zip(&other.main) {
            *a += b;
        }
        for (a, b) in self.inter.iter_mut().zip(&other.inter) {
            *a += b;
        }
    }

    /// Reassemble a vector from raw (unnormalized) main/inter sums — the
    /// snapshot-restore path. Lengths must agree.
    pub fn from_raw_parts(main: Vec<f64>, inter: Vec<f64>) -> Self {
        assert_eq!(main.len(), inter.len(), "main/inter length mismatch");
        ValueVector {
            n: main.len(),
            main,
            inter,
        }
    }

    /// Reconstruct the value vector from a RAW dense accumulator (upper
    /// triangle + diagonal populated, as `sweep_band` writes it) — the
    /// dense→implicit snapshot migration path. Exact up to the f64
    /// association order of the row-sum reduction (≤ 1e-12 vs a
    /// pure-implicit history, not bitwise).
    pub fn from_raw_accumulator(acc: &Matrix) -> Self {
        let n = acc.rows();
        assert_eq!(acc.cols(), n, "square accumulator required");
        let mut vv = ValueVector::zeros(n);
        for i in 0..n {
            let main = acc.get(i, i);
            vv.main[i] = main;
            // the shared fixed-order row reduction (DESIGN.md §10),
            // minus the diagonal it includes
            vv.inter[i] = acc.sym_row_sum_from_upper(i) - main;
        }
        vv
    }
}

/// Scratch for [`sweep_values`]: the rank-space superdiagonal and its
/// suffix sums, reused across batches.
#[derive(Default)]
pub struct ValuesScratch {
    /// c_p by rank: `c_rank[r]` = column value of the point at rank r.
    c_rank: Vec<f64>,
    /// `suffix[r]` = Σ_{s ≥ r} c_rank[s]; length n+1, `suffix[n]` = 0.
    suffix: Vec<f64>,
}

impl ValuesScratch {
    pub fn new() -> Self {
        ValuesScratch::default()
    }

    fn resize(&mut self, n: usize) {
        self.c_rank.resize(n, 0.0);
        self.suffix.resize(n + 1, 0.0);
    }
}

/// Phase-2 twin of `sweep_band` for the implicit engine: fold one
/// prepared batch into a [`ValueVector`] in **O(len·n)** (vs the dense
/// sweep's O(len·n²)). Per test point: rebuild c_p in rank space from the
/// batch's original-order rows, one right-to-left suffix pass, then one
/// O(n) scatter of `r·c[r] + suffix[r+1]` (see the module docs for the
/// fixed summation order).
pub fn sweep_values(
    batch: &PreparedBatch,
    train_y: &[i32],
    vv: &mut ValueVector,
    scratch: &mut ValuesScratch,
) {
    let n = batch.n();
    assert_eq!(train_y.len(), n, "train labels / batch mismatch");
    assert_eq!(vv.n, n, "value vector / batch mismatch");
    scratch.resize(n);
    let inv_k = batch.inv_k();
    for p in 0..batch.len() {
        let rank = batch.rank_row(p);
        let colval = batch.colval_row(p);
        let y = batch.test_label(p);
        // c_p by rank (colval is scattered to original order; rank is the
        // inverse permutation, so this is a gather).
        for i in 0..n {
            scratch.c_rank[rank[i] as usize] = colval[i];
        }
        scratch.suffix[n] = 0.0;
        for r in (0..n).rev() {
            scratch.suffix[r] = scratch.c_rank[r] + scratch.suffix[r + 1];
        }
        for i in 0..n {
            let r = rank[i];
            if train_y[i] == y {
                vv.main[i] += inv_k;
            }
            vv.inter[i] += r * colval[i] + scratch.suffix[r as usize + 1];
        }
    }
}

/// Accumulate one test batch's unnormalized per-point values into an
/// EXISTING [`ValueVector`] and return the batch's merge weight (its
/// test count, Eq. 9) — the streaming primitive mirroring
/// `sti_knn_accumulate`. O(len·(n·d + n log n)) total.
///
/// Contract (same as the matrix twin): every vector element receives its
/// per-test additions in test order regardless of how the stream is cut
/// into batches, so ingesting any contiguous partition of a test set
/// through repeated calls is **bit-identical** to one call over the
/// whole set.
pub fn values_accumulate(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    params: &StiParams,
    vv: &mut ValueVector,
) -> f64 {
    let n = train_y.len();
    assert_eq!(train_x.len(), n * d, "train shape mismatch");
    assert_eq!(test_x.len(), test_y.len() * d, "test shape mismatch");
    assert_eq!(vv.n, n, "value vector shape mismatch");
    let mut prep = PrepScratch::new();
    let mut scratch = ValuesScratch::new();
    let norms = NormCache::build(train_x, d, params.metric);
    for (chunk_x, chunk_y) in test_x
        .chunks(PREP_BATCH * d)
        .zip(test_y.chunks(PREP_BATCH))
    {
        let batch = prepare_batch_cached(
            train_x, train_y, d, chunk_x, chunk_y, params, &norms, &mut prep,
        );
        sweep_values(&batch, train_y, vv, &mut scratch);
    }
    test_y.len() as f64
}

/// Per-point STI values, averaged over the test set (Eq. 9).
#[derive(Clone, Debug)]
pub struct PointValues {
    /// φ_ii — the main terms (Eq. 4/5).
    pub main: Vec<f64>,
    /// φ_ii + Σ_{j≠i} φ_ij — total contribution including synergies
    /// (the session layer's `TopBy::RowSum` quantity).
    pub rowsum: Vec<f64>,
}

/// One-shot per-point STI values via the implicit engine:
/// O(t·(n·d + n log n)) time, O(n) state, no n×n matrix anywhere.
pub fn sti_values(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    params: &StiParams,
) -> PointValues {
    assert!(!test_y.is_empty(), "empty test set");
    let mut vv = ValueVector::zeros(train_y.len());
    let w = values_accumulate(train_x, train_y, d, test_x, test_y, params, &mut vv);
    let inv_w = 1.0 / w;
    PointValues {
        main: vv.main_values(inv_w),
        rowsum: vv.rowsum_values(inv_w),
    }
}

/// Per-point STI values through either engine — the switch the analysis
/// suite routes through. `Dense` materializes the full matrix and reads
/// `diag + rowsums` off it (the reference); `Implicit` never builds it.
/// Both agree to ≤ 1e-12 (`tests/values_equivalence.rs`).
pub fn sti_point_values(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    params: &StiParams,
    engine: Engine,
) -> PointValues {
    match engine {
        Engine::Implicit => sti_values(train_x, train_y, d, test_x, test_y, params),
        Engine::Dense => {
            let m = super::sti_knn::sti_knn(train_x, train_y, d, test_x, test_y, params);
            let n = m.rows();
            let main: Vec<f64> = (0..n).map(|i| m.get(i, i)).collect();
            let rowsum: Vec<f64> = (0..n).map(|i| m.row(i).iter().sum()).collect();
            PointValues { main, rowsum }
        }
    }
}

/// Class-split interaction sums via the same rank-space trick, for the
/// mislabel detector: `out[i][c]` = (1/t)·Σ_p Σ_{j≠i, y_j=c} φ_p[i,j] —
/// point i's total interaction with class-c points — in
/// **O(t·n·classes)** instead of the dense path's O(t·n² + n²·classes).
///
/// Derivation: restrict the suffix-sum identity to class members. With
/// `count_c(<r)` the number of class-c points ranked below r and
/// `suffix_c(r)` the class-c-restricted suffix sum of c_p,
///
///   rowsum_{i,c}(p) = count_c(<r_i)·c_p[r_i] + suffix_c(r_i + 1)
///
/// (j = i is excluded automatically: the count stops below r_i and the
/// suffix starts above it).
pub fn class_interaction_sums(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    params: &StiParams,
    classes: usize,
) -> Matrix {
    let n = train_y.len();
    assert!(!test_y.is_empty(), "empty test set");
    assert!(classes >= 1, "need at least one class");
    assert!(
        train_y.iter().all(|&y| y >= 0 && (y as usize) < classes),
        "train labels must lie in 0..classes"
    );
    let mut out = Matrix::zeros(n, classes);
    let mut prep = PrepScratch::new();
    // rank → original index (inverse of the batch's rank rows).
    let mut pos = vec![0usize; n];
    let mut c_rank = vec![0.0f64; n];
    // Flattened per-class suffix sums, (n+1) slots per class.
    let mut suffix = vec![0.0f64; classes * (n + 1)];
    let mut counts = vec![0.0f64; classes];
    let t = test_y.len() as f64;

    let norms = NormCache::build(train_x, d, params.metric);
    for (chunk_x, chunk_y) in test_x
        .chunks(PREP_BATCH * d)
        .zip(test_y.chunks(PREP_BATCH))
    {
        let batch = prepare_batch_cached(
            train_x, train_y, d, chunk_x, chunk_y, params, &norms, &mut prep,
        );
        for p in 0..batch.len() {
            let rank = batch.rank_row(p);
            let colval = batch.colval_row(p);
            for i in 0..n {
                let r = rank[i] as usize;
                pos[r] = i;
                c_rank[r] = colval[i];
            }
            // class-restricted suffix sums, right-to-left
            for c in 0..classes {
                suffix[c * (n + 1) + n] = 0.0;
            }
            for r in (0..n).rev() {
                let cls = train_y[pos[r]] as usize;
                for c in 0..classes {
                    let base = c * (n + 1);
                    suffix[base + r] = if c == cls {
                        c_rank[r] + suffix[base + r + 1]
                    } else {
                        suffix[base + r + 1]
                    };
                }
            }
            // left-to-right: prefix counts + the identity per (i, c)
            counts.iter_mut().for_each(|c| *c = 0.0);
            for r in 0..n {
                let i = pos[r];
                for c in 0..classes {
                    out.add_at(i, c, counts[c] * c_rank[r] + suffix[c * (n + 1) + r + 1]);
                }
                counts[train_y[i] as usize] += 1.0;
            }
        }
    }
    out.scale(1.0 / t);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shapley::sti_knn::{prepare_batch, sti_knn};
    use crate::util::rng::Rng;

    fn random_problem(
        seed: u64,
        n: usize,
        d: usize,
        t: usize,
        classes: usize,
    ) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        (
            (0..n * d).map(|_| rng.normal() as f32).collect(),
            (0..n).map(|_| rng.below(classes) as i32).collect(),
            (0..t * d).map(|_| rng.normal() as f32).collect(),
            (0..t).map(|_| rng.below(classes) as i32).collect(),
        )
    }

    #[test]
    fn implicit_matches_dense_diag_plus_rowsums() {
        for (seed, n, d, t, k) in [
            (1u64, 17usize, 2usize, 9usize, 4usize),
            (2, 30, 3, 5, 1),
            (3, 12, 1, 13, 12), // k = n
            (4, 25, 2, 1, 7),   // single test point
        ] {
            let (tx, ty, qx, qy) = random_problem(seed, n, d, t, 3);
            let params = StiParams::new(k);
            let dense = sti_point_values(&tx, &ty, d, &qx, &qy, &params, Engine::Dense);
            let implicit = sti_point_values(&tx, &ty, d, &qx, &qy, &params, Engine::Implicit);
            for i in 0..n {
                assert!(
                    (dense.main[i] - implicit.main[i]).abs() < 1e-12,
                    "main[{i}] seed={seed}: {} vs {}",
                    dense.main[i],
                    implicit.main[i]
                );
                assert!(
                    (dense.rowsum[i] - implicit.rowsum[i]).abs() < 1e-12,
                    "rowsum[{i}] seed={seed}: {} vs {}",
                    dense.rowsum[i],
                    implicit.rowsum[i]
                );
            }
        }
    }

    #[test]
    fn contiguous_partition_is_bit_identical() {
        let (tx, ty, qx, qy) = random_problem(11, 19, 2, 12, 2);
        let params = StiParams::new(5);
        let mut one_shot = ValueVector::zeros(19);
        values_accumulate(&tx, &ty, 2, &qx, &qy, &params, &mut one_shot);
        let mut parts = ValueVector::zeros(19);
        for (lo, hi) in [(0usize, 1usize), (1, 5), (5, 12)] {
            values_accumulate(&tx, &ty, 2, &qx[lo * 2..hi * 2], &qy[lo..hi], &params, &mut parts);
        }
        for i in 0..19 {
            assert_eq!(one_shot.main[i].to_bits(), parts.main[i].to_bits());
            assert_eq!(one_shot.inter[i].to_bits(), parts.inter[i].to_bits());
        }
    }

    #[test]
    fn sweep_values_matches_direct_accumulate_bits() {
        // values_accumulate is prepare + sweep_values composed; a manual
        // composition with its own scratch must agree to the bit.
        let (tx, ty, qx, qy) = random_problem(21, 14, 3, 7, 2);
        let params = StiParams::new(3);
        let mut via_accumulate = ValueVector::zeros(14);
        values_accumulate(&tx, &ty, 3, &qx, &qy, &params, &mut via_accumulate);
        let mut manual = ValueVector::zeros(14);
        let mut scratch = ValuesScratch::new();
        let batch = prepare_batch(&tx, &ty, 3, &qx, &qy, &params);
        sweep_values(&batch, &ty, &mut manual, &mut scratch);
        for i in 0..14 {
            assert_eq!(via_accumulate.main[i].to_bits(), manual.main[i].to_bits());
            assert_eq!(via_accumulate.inter[i].to_bits(), manual.inter[i].to_bits());
        }
    }

    #[test]
    fn from_raw_accumulator_matches_streamed_values() {
        let (tx, ty, qx, qy) = random_problem(31, 16, 2, 8, 3);
        let params = StiParams::new(4);
        let mut acc = crate::util::matrix::Matrix::zeros(16, 16);
        crate::shapley::sti_knn::sti_knn_accumulate(&tx, &ty, 2, &qx, &qy, &params, &mut acc);
        let from_dense = ValueVector::from_raw_accumulator(&acc);
        let mut streamed = ValueVector::zeros(16);
        values_accumulate(&tx, &ty, 2, &qx, &qy, &params, &mut streamed);
        for i in 0..16 {
            assert!((from_dense.main[i] - streamed.main[i]).abs() < 1e-12);
            assert!((from_dense.inter[i] - streamed.inter[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn minimal_cases_match_the_closed_forms() {
        // n = 2, k = 1, both labels match: φ_11 = φ_22 = 1,
        // φ_12 = −2(2−1)/(2·1)·1 = −1 → rowsum_i = 1 + (−1) = 0.
        let pv = sti_values(
            &[0.0, 1.0],
            &[1, 1],
            1,
            &[0.1],
            &[1],
            &StiParams::new(1),
        );
        assert_eq!(pv.main, vec![1.0, 1.0]);
        assert!((pv.rowsum[0]).abs() < 1e-15);
        assert!((pv.rowsum[1]).abs() < 1e-15);

        // all-same-label at n = 4, k = 2: every main term is 1/k.
        let pv = sti_values(
            &[0.0, 1.0, 2.0, 3.0],
            &[0, 0, 0, 0],
            1,
            &[0.4],
            &[0],
            &StiParams::new(2),
        );
        for &m in &pv.main {
            assert!((m - 0.5).abs() < 1e-15);
        }
    }

    #[test]
    fn efficiency_axiom_via_values() {
        // Σ_i main_i + (1/2)·Σ_i (rowsum_i − main_i) = upper-triangle sum
        // including the diagonal = a_test (DESIGN.md §1) — checkable with
        // no matrix at all.
        let (tx, ty, qx, qy) = random_problem(41, 20, 2, 6, 2);
        let k = 5;
        let params = StiParams::new(k);
        let pv = sti_values(&tx, &ty, 2, &qx, &qy, &params);
        let trace: f64 = pv.main.iter().sum();
        let offdiag: f64 = pv
            .rowsum
            .iter()
            .zip(&pv.main)
            .map(|(&r, &m)| r - m)
            .sum();
        let upper = trace + offdiag / 2.0;
        // a_test averaged over tests: fraction of k-neighbourhood matches
        let m = sti_knn(&tx, &ty, 2, &qx, &qy, &params);
        assert!((upper - m.upper_triangle_sum()).abs() < 1e-10);
    }

    #[test]
    fn class_sums_match_dense_matrix() {
        let (tx, ty, qx, qy) = random_problem(51, 18, 2, 7, 3);
        let params = StiParams::new(4);
        let sums = class_interaction_sums(&tx, &ty, 2, &qx, &qy, &params, 3);
        let m = sti_knn(&tx, &ty, 2, &qx, &qy, &params);
        for i in 0..18 {
            for c in 0..3 {
                let direct: f64 = (0..18)
                    .filter(|&j| j != i && ty[j] as usize == c)
                    .map(|j| m.get(i, j))
                    .sum();
                assert!(
                    (sums.get(i, c) - direct).abs() < 1e-12,
                    "i={i} c={c}: {} vs {direct}",
                    sums.get(i, c)
                );
            }
        }
        // class sums partition the full off-diagonal row sum
        let pv = sti_values(&tx, &ty, 2, &qx, &qy, &params);
        for i in 0..18 {
            let total: f64 = (0..3).map(|c| sums.get(i, c)).sum();
            assert!((total - (pv.rowsum[i] - pv.main[i])).abs() < 1e-12);
        }
    }

    #[test]
    fn add_assign_merges_disjoint_shards_within_tolerance() {
        let (tx, ty, qx, qy) = random_problem(61, 13, 2, 10, 2);
        let params = StiParams::new(3);
        let mut whole = ValueVector::zeros(13);
        values_accumulate(&tx, &ty, 2, &qx, &qy, &params, &mut whole);
        let mut a = ValueVector::zeros(13);
        let mut b = ValueVector::zeros(13);
        values_accumulate(&tx, &ty, 2, &qx[..6 * 2], &qy[..6], &params, &mut a);
        values_accumulate(&tx, &ty, 2, &qx[6 * 2..], &qy[6..], &params, &mut b);
        a.add_assign(&b);
        for i in 0..13 {
            assert!((a.main[i] - whole.main[i]).abs() < 1e-12);
            assert!((a.inter[i] - whole.inter[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn engine_parse_and_labels() {
        assert_eq!(Engine::parse("implicit"), Some(Engine::Implicit));
        assert_eq!(Engine::parse("values"), Some(Engine::Implicit));
        assert_eq!(Engine::parse("dense"), Some(Engine::Dense));
        assert_eq!(Engine::parse("matrix"), Some(Engine::Dense));
        assert_eq!(Engine::parse("xla"), None);
        assert_eq!(Engine::Implicit.label(), "implicit");
        assert_eq!(Engine::Dense.label(), "dense");
    }

    #[test]
    #[should_panic(expected = "empty test set")]
    fn empty_test_set_is_rejected() {
        sti_values(&[0.0, 1.0], &[0, 1], 1, &[], &[], &StiParams::new(1));
    }
}
