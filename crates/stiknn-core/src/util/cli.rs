//! Declarative command-line parsing substrate (no clap in the offline
//! image). Supports subcommands, `--flag`, `--key value` / `--key=value`
//! options with defaults, and generated `--help` text.

use std::collections::BTreeMap;
use std::fmt;

/// Specification for one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// A parsed argument set for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn parse_as<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError>
    where
        T::Err: fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| CliError(format!("--{name}={s}: {e}"))),
        }
    }

    pub fn require<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError>
    where
        T::Err: fmt::Display,
    {
        self.parse_as::<T>(name)?
            .ok_or_else(|| CliError(format!("missing required option --{name}")))
    }
}

/// True when an argv slice asks for help (`--help` / `-h`) — shared by
/// every subcommand so the convention can't drift.
pub fn wants_help(argv: &[String]) -> bool {
    argv.iter().any(|a| a == "--help" || a == "-h")
}

/// A command: name, help, options. Parse an argv slice against it.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let d = match (o.is_flag, o.default) {
                (true, _) => " (flag)".to_string(),
                (false, Some(d)) => format!(" (default: {d})"),
                (false, None) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
        }
        s
    }

    /// Parse argv (not including the subcommand name itself).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        // seed defaults
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError(format!("unknown option --{key}")))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(CliError(format!("flag --{key} takes no value")));
                    }
                    args.flags.insert(key, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{key} needs a value")))?
                        }
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        // check required
        for o in &self.opts {
            if !o.is_flag && o.default.is_none() && !args.values.contains_key(o.name) {
                return Err(CliError(format!(
                    "missing required option --{}\n\n{}",
                    o.name,
                    self.usage()
                )));
            }
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("value", "compute data values")
            .opt("dataset", "dataset name", "circle")
            .opt("k", "KNN parameter", "5")
            .req("out", "output path")
            .flag("verbose", "log more")
    }

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&["--out", "x.csv"])).unwrap();
        assert_eq!(a.get("dataset"), Some("circle"));
        assert_eq!(a.require::<usize>("k").unwrap(), 5);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn overrides_and_flags() {
        let a = cmd()
            .parse(&argv(&["--k=9", "--verbose", "--out=o", "--dataset", "moon"]))
            .unwrap();
        assert_eq!(a.require::<usize>("k").unwrap(), 9);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("dataset"), Some("moon"));
    }

    #[test]
    fn missing_required_errors() {
        let e = cmd().parse(&argv(&[])).unwrap_err();
        assert!(e.0.contains("--out"));
    }

    #[test]
    fn unknown_option_errors() {
        let e = cmd().parse(&argv(&["--out=o", "--bogus", "1"])).unwrap_err();
        assert!(e.0.contains("bogus"));
    }

    #[test]
    fn bad_parse_type_errors() {
        let a = cmd().parse(&argv(&["--out=o", "--k", "abc"])).unwrap();
        assert!(a.require::<usize>("k").is_err());
    }

    #[test]
    fn positional_collected() {
        let a = cmd().parse(&argv(&["--out=o", "extra1", "extra2"])).unwrap();
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn usage_mentions_all_options() {
        let u = cmd().usage();
        for name in ["dataset", "k", "out", "verbose"] {
            assert!(u.contains(name));
        }
    }

    #[test]
    fn wants_help_detects_both_spellings_anywhere() {
        assert!(wants_help(&argv(&["--dataset", "moon", "--help"])));
        assert!(wants_help(&argv(&["-h"])));
        assert!(!wants_help(&argv(&["--helpful"])));
        assert!(!wants_help(&argv(&[])));
    }
}
