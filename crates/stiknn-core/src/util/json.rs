//! Minimal JSON parser/serializer (no serde in the offline image).
//!
//! Covers the full JSON grammar needed by the artifact manifest, run
//! configs and report outputs: objects, arrays, strings with escapes,
//! numbers, booleans, null. Numbers are held as f64 (adequate: the
//! manifest only carries small integers and hashes-as-strings).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Maximum container nesting depth. The parser is recursive, and it sits
/// on untrusted surfaces (the serve protocol's stdin) — without a cap, a
/// single line of ~100k `[`s would overflow the stack and abort the
/// process instead of producing a parse error.
const MAX_DEPTH: usize = 128;

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            src: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience: None if not an object / key missing.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.src.len()
            && matches!(self.src[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.src.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.src[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs unsupported (not needed by our
                            // manifests); map lone surrogates to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.src[start]);
                    if start + len > self.src.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.src[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if !n.is_finite() {
                // JSON has no NaN/Infinity tokens; `{n}` would emit "NaN"
                // and corrupt the stream (the serve protocol and BENCH
                // artifacts both flow through here). Mirror the common
                // serializer convention (e.g. Python's allow_nan=False
                // alternatives, Go's strict mode): non-finite → null.
                out.push_str("null");
            } else if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape_into(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let s = r#"{"version": 1, "artifacts": [{"name": "sti_n32", "n": 32, "ok": true, "x": null}]}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("sti_n32"));
        assert_eq!(arts[0].get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(arts[0].get("x"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("b", Json::Bool(false)),
            ("n", Json::num(-12.5)),
            ("s", Json::str("he said \"hi\"\n")),
            ("a", Json::arr([Json::num(1.0), Json::Null])),
        ]);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\tbA\n""#).unwrap();
        assert_eq!(v.as_str(), Some("a\tbA\n"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("3.25e2").unwrap().as_f64(), Some(325.0));
        assert_eq!(Json::parse("-7").unwrap().as_f64(), Some(-7.0));
        assert_eq!(Json::parse("0.5").unwrap().as_f64(), Some(0.5));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo ∀x""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo ∀x"));
    }

    #[test]
    fn integer_display_has_no_decimal() {
        assert_eq!(Json::num(32.0).to_string(), "32");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // `format!("{}", f64::NAN)` is "NaN" — not a JSON token. The
        // writer must never emit it.
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let text = Json::num(v).to_string();
            assert_eq!(text, "null");
            assert_eq!(Json::parse(&text).unwrap(), Json::Null);
        }
        // ... including nested inside containers
        let v = Json::obj(vec![("bad", Json::num(f64::NAN)), ("good", Json::num(2.0))]);
        let text = v.to_string();
        assert_eq!(text, r#"{"bad":null,"good":2}"#);
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn writer_escapes_round_trip_through_parser() {
        // every byte class the writer escapes: quote, backslash, the
        // named control escapes, and raw sub-0x20 controls
        let nasty = "q\"uote b\\ackslash \n\r\t bell\u{7} esc\u{1b} nul\u{0} ok";
        let v = Json::obj(vec![
            ("plain", Json::str(nasty)),
            // keys go through the same escaper as values
            (nasty, Json::Bool(true)),
        ]);
        let text = v.to_string();
        assert!(!text.contains('\u{7}'), "raw control byte leaked: {text}");
        assert!(text.contains("\\u0007") && text.contains("\\u001b") && text.contains("\\u0000"));
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v, "round trip changed the value: {text}");
        assert_eq!(back.get("plain").unwrap().as_str(), Some(nasty));
    }

    #[test]
    fn unicode_strings_round_trip_unescaped() {
        let v = Json::str("héllo ∀x — δ≤ε");
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn pathological_nesting_errors_instead_of_overflowing_the_stack() {
        // one untrusted serve-protocol line must never abort the process
        let bomb = "[".repeat(100_000);
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.msg.contains("nesting"), "{err}");
        // mixed object/array nesting hits the same cap
        let bomb = "{\"a\":[".repeat(50_000);
        assert!(Json::parse(&bomb).is_err());
        // ... while reasonable nesting still parses
        let fine = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&fine).is_ok());
    }
}
