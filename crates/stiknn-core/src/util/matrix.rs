//! Dense row-major f64 matrix — the interaction-matrix container.
//!
//! Deliberately minimal: the library only needs construction, indexed
//! access, elementwise combination, triangle reductions and (for the
//! analysis suite) row extraction. No linear algebra beyond that.

/// Dense row-major matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] += v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Two adjacent mutable rows (i, i+1) — kept from the reverted
    /// 2-row-blocked assembly sweep (EXPERIMENTS.md §Perf iteration log).
    #[inline]
    pub fn rows2_mut(&mut self, i: usize) -> (&mut [f64], &mut [f64]) {
        debug_assert!(i + 1 < self.rows);
        let (a, b) = self.data[i * self.cols..].split_at_mut(self.cols);
        (a, &mut b[..self.cols])
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// self += other (elementwise).
    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// self += w * other.
    pub fn add_scaled(&mut self, other: &Matrix, w: f64) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += w * b;
        }
    }

    /// self *= s (elementwise).
    pub fn scale(&mut self, s: f64) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Mean of all entries.
    pub fn mean(&self) -> f64 {
        self.sum() / (self.rows * self.cols) as f64
    }

    /// Full row-i sum of the SYMMETRIC matrix this upper-triangle (+
    /// diagonal) storage represents: the diagonal cell, then the stored
    /// (i, j>i) run ascending, then the mirrored (j<i, i) column
    /// ascending — in exactly that order. The implicit value engine's
    /// bit-identity contracts (session `point_values` vs
    /// `point_value_at`, dense→implicit snapshot migration) depend on
    /// every consumer reducing in this one order, which is why the loop
    /// lives here once (DESIGN.md §10).
    pub fn sym_row_sum_from_upper(&self, i: usize) -> f64 {
        debug_assert_eq!(self.rows, self.cols, "square only");
        let mut s = self.get(i, i);
        for j in (i + 1)..self.cols {
            s += self.get(i, j);
        }
        for j in 0..i {
            s += self.get(j, i);
        }
        s
    }

    /// Sum over the upper triangle INCLUDING the diagonal (the quantity the
    /// STI efficiency axiom constrains — see DESIGN.md §1).
    pub fn upper_triangle_sum(&self) -> f64 {
        assert_eq!(self.rows, self.cols, "square only");
        let mut acc = 0.0;
        for i in 0..self.rows {
            for j in i..self.cols {
                acc += self.get(i, j);
            }
        }
        acc
    }

    /// Strict upper-triangle entries (i < j), flattened.
    pub fn upper_triangle_entries(&self) -> Vec<f64> {
        assert_eq!(self.rows, self.cols, "square only");
        let mut out = Vec::with_capacity(self.rows * (self.rows - 1) / 2);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                out.push(self.get(i, j));
            }
        }
        out
    }

    /// Diagonal entries.
    pub fn diagonal(&self) -> Vec<f64> {
        assert_eq!(self.rows, self.cols, "square only");
        (0..self.rows).map(|i| self.get(i, i)).collect()
    }

    /// Max |a - b| over entries.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Max |a| over entries.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().map(|a| a.abs()).fold(0.0, f64::max)
    }

    /// Is the matrix symmetric within `tol`?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self.get(i, j) - self.get(j, i)).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Copy the strict upper triangle into the lower triangle, making the
    /// matrix symmetric. The assembly engines accumulate the upper
    /// triangle only (`shapley::sti_knn::sweep_band`) and mirror once at
    /// the end.
    pub fn mirror_upper_to_lower(&mut self) {
        assert_eq!(self.rows, self.cols, "square only");
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = self.get(i, j);
                self.set(j, i, v);
            }
        }
    }

    /// Reorder rows and columns by `perm` (out[i][j] = self[perm[i]][perm[j]]).
    pub fn permuted(&self, perm: &[usize]) -> Matrix {
        assert_eq!(self.rows, self.cols);
        assert_eq!(perm.len(), self.rows);
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.set(i, j, self.get(perm[i], perm[j]));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
    }

    #[test]
    fn add_scaled_and_scale() {
        let mut a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![10.0, 20.0, 30.0, 40.0]);
        a.add_scaled(&b, 0.5);
        assert_eq!(a.data(), &[6.0, 12.0, 18.0, 24.0]);
        a.scale(2.0);
        assert_eq!(a.get(0, 0), 12.0);
    }

    #[test]
    fn upper_triangle_sum_includes_diagonal() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 99.0, 3.0]);
        assert_eq!(m.upper_triangle_sum(), 6.0);
    }

    #[test]
    fn sym_row_sum_reads_only_the_upper_storage() {
        // lower-triangle garbage (99s) must not contribute
        let m = Matrix::from_vec(3, 3, vec![1.0, 2.0, 3.0, 99.0, 4.0, 5.0, 99.0, 99.0, 6.0]);
        assert_eq!(m.sym_row_sum_from_upper(0), 1.0 + 2.0 + 3.0);
        assert_eq!(m.sym_row_sum_from_upper(1), 4.0 + 5.0 + 2.0);
        assert_eq!(m.sym_row_sum_from_upper(2), 6.0 + 3.0 + 5.0);
    }

    #[test]
    fn upper_triangle_entries_strict() {
        let m = Matrix::from_vec(3, 3, vec![0.0, 1.0, 2.0, 9.0, 0.0, 3.0, 9.0, 9.0, 0.0]);
        assert_eq!(m.upper_triangle_entries(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn symmetry_check() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        assert!(m.is_symmetric(0.0));
        let m2 = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.5, 1.0]);
        assert!(!m2.is_symmetric(0.1));
        assert!(m2.is_symmetric(1.0));
    }

    #[test]
    fn mirror_copies_upper_to_lower() {
        let mut m = Matrix::from_vec(3, 3, vec![1.0, 2.0, 3.0, 0.0, 4.0, 5.0, 0.0, 0.0, 6.0]);
        m.mirror_upper_to_lower();
        assert!(m.is_symmetric(0.0));
        assert_eq!(m.get(1, 0), 2.0);
        assert_eq!(m.get(2, 0), 3.0);
        assert_eq!(m.get(2, 1), 5.0);
        assert_eq!(m.get(0, 0), 1.0);
    }

    #[test]
    fn permuted_reorders_rows_and_cols() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let p = m.permuted(&[1, 0]);
        assert_eq!(p.data(), &[4.0, 3.0, 2.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_validates_shape() {
        Matrix::from_vec(2, 2, vec![1.0]);
    }
}
