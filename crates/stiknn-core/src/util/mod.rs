//! Substrate utilities built in-repo (the offline image vendors only the
//! `xla` crate closure — no clap/serde/rand/proptest/criterion), see
//! DESIGN.md §3.

pub mod cli;
pub mod json;
pub mod matrix;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
