//! Property-based testing harness (no proptest in the offline image).
//!
//! A property is a closure over a [`Gen`] that panics on violation. The
//! runner executes it for many seeds; on failure it re-runs with the same
//! seed under decreasing `size` to report the smallest reproduction it
//! can find (size-based shrinking: generators are asked for smaller
//! structures rather than shrinking produced values — simpler, and in
//! practice small sizes reproduce rank/ordering bugs reliably).

use super::rng::Rng;

/// Generation context handed to properties: a PRNG plus a size budget.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    /// usize in [lo, hi] inclusive, biased toward the low end as `size`
    /// shrinks.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = (hi - lo).min(self.size.max(1));
        lo + self.rng.below(span + 1)
    }

    /// f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    /// Vector of labels in 0..classes.
    pub fn labels(&mut self, n: usize, classes: usize) -> Vec<i32> {
        (0..n).map(|_| self.rng.below(classes) as i32).collect()
    }

    /// n×d feature matrix with standard-normal entries, flattened row-major.
    pub fn features(&mut self, n: usize, d: usize) -> Vec<f32> {
        (0..n * d).map(|_| self.rng.normal() as f32).collect()
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub struct PropFailure {
    pub seed: u64,
    pub size: usize,
    pub message: String,
}

/// Run `prop` for `cases` seeds at the default size. Panics with the
/// smallest discovered failing (seed, size) and the original panic text.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    if let Some(f) = check_quiet(cases, 24, &prop) {
        panic!(
            "property '{name}' failed: seed={} size={} — {}\n\
             reproduce with: Gen {{ rng: Rng::new({}), size: {} }}",
            f.seed, f.size, f.message, f.seed, f.size
        );
    }
}

/// Like [`check`] but returns the failure instead of panicking (used by the
/// harness's own tests).
pub fn check_quiet(
    cases: u64,
    size: usize,
    prop: &(impl Fn(&mut Gen) + std::panic::RefUnwindSafe),
) -> Option<PropFailure> {
    for case in 0..cases {
        let seed = 0x5EED_0000u64.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        if let Some(msg) = run_one(seed, size, prop) {
            // shrink: retry the same seed at smaller sizes, keep the smallest
            // size that still fails.
            let mut best = PropFailure {
                seed,
                size,
                message: msg,
            };
            let mut s = size / 2;
            while s >= 2 {
                match run_one(seed, s, prop) {
                    Some(msg) => {
                        best = PropFailure {
                            seed,
                            size: s,
                            message: msg,
                        };
                        s /= 2;
                    }
                    None => break,
                }
            }
            return Some(best);
        }
    }
    None
}

fn run_one(
    seed: u64,
    size: usize,
    prop: &(impl Fn(&mut Gen) + std::panic::RefUnwindSafe),
) -> Option<String> {
    let result = std::panic::catch_unwind(|| {
        let mut g = Gen {
            rng: Rng::new(seed),
            size,
        };
        prop(&mut g);
    });
    match result {
        Ok(()) => None,
        Err(payload) => Some(panic_message(payload)),
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("commutativity", 50, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_is_caught_and_shrunk() {
        // Fails whenever the generated vector has length >= 3; the shrinker
        // should find a small failing size rather than the initial 24.
        let failure = check_quiet(20, 24, &|g: &mut Gen| {
            let n = g.usize_in(1, 40);
            assert!(n < 3, "vector too long: {n}");
        });
        let f = failure.expect("property should fail");
        assert!(f.size <= 24);
        assert!(f.message.contains("vector too long"));
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 100, |g| {
            let n = g.usize_in(2, 30);
            assert!((2..=30).contains(&n));
            let ls = g.labels(n, 3);
            assert_eq!(ls.len(), n);
            assert!(ls.iter().all(|&l| (0..3).contains(&l)));
            let fs = g.features(n, 2);
            assert_eq!(fs.len(), n * 2);
        });
    }
}
