//! Deterministic PRNG substrate: splitmix64-seeded xoshiro256++.
//!
//! Every stochastic component in the library (dataset generators, the
//! Monte-Carlo STI estimator, the property-test harness) draws from this
//! generator so that runs are reproducible from a single `u64` seed.

/// xoshiro256++ PRNG (Blackman & Vigna). Not cryptographic; fast, good
/// equidistribution, trivially seedable via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the full 256-bit state from one u64 via splitmix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (e.g. per worker / per dataset).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0)");
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let low = m as u64;
            if low >= bound {
                return (m >> 64) as usize;
            }
            // rejection zone
            let threshold = bound.wrapping_neg() % bound;
            if low >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `m` distinct indices from 0..n (Floyd's algorithm when m << n,
    /// shuffle otherwise).
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        if m * 3 > n {
            let mut p = self.permutation(n);
            p.truncate(m);
            return p;
        }
        let mut chosen = std::collections::HashSet::with_capacity(m);
        let mut out = Vec::with_capacity(m);
        for j in (n - m)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(13);
        let p = r.permutation(50);
        let mut seen = vec![false; 50];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        for (n, m) in [(100, 5), (10, 10), (1000, 999), (50, 20)] {
            let s = r.sample_indices(n, m);
            assert_eq!(s.len(), m);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), m);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(19);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.range(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
