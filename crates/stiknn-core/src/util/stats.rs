//! Statistical helpers: mean/std, Pearson correlation, Spearman rank
//! correlation, simple least-squares fits used by the scaling benches
//! (log-log slope estimation) and the k-sensitivity analysis (§3.2).

/// Arithmetic mean. Empty slice -> NaN.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Pearson correlation coefficient. Returns NaN for degenerate inputs.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() {
        return f64::NAN;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        let dx = x - mx;
        let dy = y - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx == 0.0 || vy == 0.0 {
        return f64::NAN;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Ranks with average tie-handling (for Spearman). Sorts under the IEEE
/// total order (`total_cmp`, the crate-wide value-ordering convention —
/// see `session::top_k_of`): a non-total comparator falling back to
/// `Equal` on NaN makes the sort order depend on the input permutation,
/// silently corrupting every Spearman computed over it. Under the total
/// order NaNs land deterministically past +∞, each its own tie group
/// (NaN == NaN is false).
pub fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]).then(a.cmp(&b)));
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0; // 1-based average rank
        for &p in &idx[i..=j] {
            out[p] = avg;
        }
        i = j + 1;
    }
    out
}

/// Spearman rank correlation.
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

/// Least-squares slope+intercept of y over x.
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let slope = num / den;
    (slope, my - slope * mx)
}

/// Log-log slope: the empirical polynomial order of y(x). Used by the
/// scaling benches to verify the O(n²)/O(t) complexity claims.
///
/// Pairs with a non-positive (or non-finite) coordinate are FILTERED
/// before the fit: `ln()` of a zero/negative timing sample is NaN/-∞,
/// which would poison the fitted slope and let a complexity assertion
/// pass vacuously (NaN compares false against any threshold). Panics if
/// fewer than [`LOGLOG_MIN_SAMPLES`] pairs survive — a slope fitted
/// through one or two points is not evidence of anything.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mut lx = Vec::with_capacity(xs.len());
    let mut ly = Vec::with_capacity(ys.len());
    for (&x, &y) in xs.iter().zip(ys) {
        if x > 0.0 && y > 0.0 && x.is_finite() && y.is_finite() {
            lx.push(x.ln());
            ly.push(y.ln());
        }
    }
    assert!(
        lx.len() >= LOGLOG_MIN_SAMPLES,
        "loglog_slope: only {} positive finite sample pairs (of {}) — need at \
         least {LOGLOG_MIN_SAMPLES} for a meaningful slope",
        lx.len(),
        xs.len()
    );
    linfit(&lx, &ly).0
}

/// Minimum surviving sample pairs for a [`loglog_slope`] fit.
pub const LOGLOG_MIN_SAMPLES: usize = 3;

/// Percentile (nearest-rank on a sorted copy), p in [0, 100]. Total
/// order: NaNs sort past +∞ instead of panicking the sort mid-bench.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [-2.0, -4.0, -6.0, -8.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_independent_near_zero() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [2.0, 1.0, 4.0, 3.0, 3.0];
        let r = pearson(&x, &y);
        assert!(r.abs() < 0.8);
    }

    #[test]
    fn pearson_degenerate_nan() {
        assert!(pearson(&[1.0, 1.0], &[2.0, 3.0]).is_nan());
    }

    #[test]
    fn ranks_with_ties() {
        assert_eq!(ranks(&[10.0, 20.0, 20.0, 30.0]), vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn spearman_monotone_is_one() {
        let x = [1.0, 5.0, 2.0, 9.0];
        let y = [10.0, 500.0, 20.0, 900.0]; // same order
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linfit_exact_line() {
        let (s, b) = linfit(&[0.0, 1.0, 2.0], &[1.0, 3.0, 5.0]);
        assert!((s - 2.0).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
    }

    #[test]
    fn loglog_slope_quadratic() {
        let xs = [10.0, 20.0, 40.0, 80.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x * x).collect();
        assert!((loglog_slope(&xs, &ys) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_basics() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn ranks_with_nan_are_deterministic_and_do_not_panic() {
        // NaN sorts past +∞ under the total order, so the finite values
        // keep their ranks no matter where the NaN sits in the input …
        let a = ranks(&[f64::NAN, 10.0, 20.0]);
        let b = ranks(&[10.0, f64::NAN, 20.0]);
        let c = ranks(&[10.0, 20.0, f64::NAN]);
        assert_eq!(a, vec![3.0, 1.0, 2.0]);
        assert_eq!(b, vec![1.0, 3.0, 2.0]);
        assert_eq!(c, vec![1.0, 2.0, 3.0]);
        // … and a clean slice is unaffected by the comparator change
        assert_eq!(ranks(&[10.0, 20.0, 20.0, 30.0]), vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn percentile_with_nan_does_not_panic() {
        let xs = [5.0, f64::NAN, 1.0];
        // NaN lands at the top under the total order; the lower
        // percentiles stay meaningful
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn loglog_slope_filters_non_positive_samples() {
        // a zero timing sample (a too-fast clock read) must not poison
        // the fit with ln(0) = -∞
        let xs = [10.0, 20.0, 0.0, 40.0, 80.0];
        let ys = [100.0, 400.0, 0.0, 1600.0, 6400.0];
        assert!((loglog_slope(&xs, &ys) - 2.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "loglog_slope")]
    fn loglog_slope_rejects_too_few_samples() {
        // two surviving pairs fit a line exactly — that is not evidence
        loglog_slope(&[10.0, 20.0, -1.0], &[100.0, 400.0, 900.0]);
    }
}
