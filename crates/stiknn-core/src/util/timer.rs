//! Wall-clock timing helpers used by the bench harness and the pipeline
//! metrics.
//!
//! Since DESIGN.md §14 the [`Stopwatch`] is a thin recorder into the
//! obs layer: build one with [`Stopwatch::recording`] and every lap
//! also lands in the registry histogram `<prefix>.<lap>_ns`, so ad-hoc
//! phase timings share the metrics vocabulary instead of living in a
//! parallel one. `Stopwatch::new` keeps the old standalone behavior
//! (a disabled handle records nothing).

use crate::obs::ObsHandle;
use std::time::{Duration, Instant};

/// Measure the wall time of a closure, returning (result, elapsed).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = crate::obs::now();
    let out = f();
    (out, start.elapsed())
}

/// A simple stopwatch with named laps, for coarse pipeline phase timing.
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    laps: Vec<(String, Duration)>,
    obs: ObsHandle,
    prefix: String,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::recording(ObsHandle::disabled(), "stopwatch")
    }

    /// A stopwatch whose laps also record into obs histograms named
    /// `<prefix>.<lap>_ns` (no-op with a disabled handle).
    pub fn recording(obs: ObsHandle, prefix: &str) -> Self {
        let now = crate::obs::now();
        Stopwatch {
            start: now,
            last: now,
            laps: Vec::new(),
            obs,
            prefix: prefix.to_string(),
        }
    }

    /// Record a lap since the previous lap (or start).
    pub fn lap(&mut self, name: &str) -> Duration {
        let now = crate::obs::now();
        let d = now - self.last;
        self.last = now;
        if self.obs.is_enabled() {
            self.obs.observe_ns(
                &format!("{}.{name}_ns", self.prefix),
                d.as_nanos().min(u64::MAX as u128) as u64,
            );
        }
        self.laps.push((name.to_string(), d));
        d
    }

    pub fn total(&self) -> Duration {
        self.last - self.start
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }
}

/// Format a Duration human-readably (ns/µs/ms/s automatically).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_result() {
        let (v, d) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn stopwatch_laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(1));
        sw.lap("a");
        std::thread::sleep(Duration::from_millis(1));
        sw.lap("b");
        assert_eq!(sw.laps().len(), 2);
        assert!(sw.total() >= Duration::from_millis(2));
    }

    #[test]
    fn stopwatch_laps_record_into_obs() {
        let obs = ObsHandle::enabled("sw");
        let mut sw = Stopwatch::recording(obs.clone(), "phase");
        sw.lap("prep");
        sw.lap("prep");
        sw.lap("sweep");
        let reg = obs.registry().unwrap();
        assert_eq!(reg.histogram("phase.prep_ns").count(), 2);
        assert_eq!(reg.histogram("phase.sweep_ns").count(), 1);
        // The in-memory lap log still works alongside the roll-up.
        assert_eq!(sw.laps().len(), 3);
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert!(fmt_duration(Duration::from_micros(1500)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }
}
