//! Wall-clock timing helpers used by the bench harness and the pipeline
//! metrics.

use std::time::{Duration, Instant};

/// Measure the wall time of a closure, returning (result, elapsed).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed())
}

/// A simple stopwatch with named laps, for coarse pipeline phase timing.
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    laps: Vec<(String, Duration)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch {
            start: now,
            last: now,
            laps: Vec::new(),
        }
    }

    /// Record a lap since the previous lap (or start).
    pub fn lap(&mut self, name: &str) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        self.laps.push((name.to_string(), d));
        d
    }

    pub fn total(&self) -> Duration {
        self.last - self.start
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }
}

/// Format a Duration human-readably (ns/µs/ms/s automatically).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_result() {
        let (v, d) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn stopwatch_laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(1));
        sw.lap("a");
        std::thread::sleep(Duration::from_millis(1));
        sw.lap("b");
        assert_eq!(sw.laps().len(), 2);
        assert!(sw.total() >= Duration::from_millis(2));
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert!(fmt_duration(Duration::from_micros(1500)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }
}
