//! # stiknn-server — the concurrent multi-session serve layer
//!
//! Hosts many named [`session::ValuationSession`]s in one process behind
//! a [`server::SessionRegistry`] (per-session RwLocks, LRU spill to the
//! v3 snapshot store, background autosave) and multiplexes clients onto
//! them over the NDJSON protocol — stdio or TCP, plus the registry verbs
//! `open`/`use`/`close`/`list` and the shard-identity verb `shard`
//! (DESIGN.md §12/§13).
//!
//! Lower-layer modules are re-exported so in-crate paths like
//! `crate::session::...` keep resolving exactly as they did in the
//! monolith.

pub mod server;

pub use stiknn_core::{analysis, coordinator, data, knn, obs, shapley, util};
pub use stiknn_session::{session, shard};
