//! Concurrent multi-session serve layer (DESIGN.md §12).
//!
//! `stiknn serve` grew from one session / one client on stdio into a
//! process that hosts MANY named [`ValuationSession`]s (the
//! [`SessionRegistry`]) and serves many clients at once: a TCP listener
//! (`serve --listen ADDR`) accepts connections and runs each on its own
//! thread over the exact same NDJSON protocol stdio uses
//! ([`crate::session::protocol`]), so a stdio pipe and a socket client
//! are indistinguishable to the command layer.
//!
//! Each connection carries one piece of state — the name of its CURRENT
//! session — steered by four registry verbs on top of the single-session
//! command set:
//!
//! ```text
//! {"cmd":"open","name":"a"}                → create session "a" (or
//!     attach if it exists) and make it current. Optional fields for
//!     fresh sessions: "k", "engine" ("dense"|"implicit"), "mutable";
//!     or "snapshot": a store file to restore (its header supplies
//!     k/metric/engine/mutability).
//! {"cmd":"use","name":"a"}                 → switch current session
//! {"cmd":"close","name":"a"}               → drop a session ("name"
//!     optional: defaults to current). State is NOT saved — `snapshot`
//!     first to keep it.
//! {"cmd":"list"}                           → registry listing + current
//! {"cmd":"shard"}                          → this process's shard
//!     identity (`serve --shard-of J/N`; null when unsharded) plus the
//!     train-set fingerprint a shard coordinator verifies (DESIGN.md §13)
//! ```
//!
//! Everything else (`ingest`/`query`/`values`/`topk`/`stats`/
//! `snapshot`/`ping`/mutations) routes to the current session through
//! its RwLock: reads share the lock, writes serialize per session while
//! other sessions proceed untouched. `shutdown` ends the CONNECTION —
//! over TCP the server keeps running for everyone else; on stdio, where
//! the connection is the process, it ends the process like before.
//!
//! Concurrency contract (property-tested in
//! `tests/server_concurrency.rs`): any interleaving of client traffic
//! leaves every session bit-identical to a serialized replay of that
//! session's own write commands in revision order — including across
//! LRU spill→reload cycles through the v3 snapshot store and autosave
//! checkpoints (`registry`).

pub mod registry;

pub use registry::{
    start_autosave, Autosave, RegistryConfig, SessionInfo, SessionRegistry, ShardIdentity,
    TrainData,
};

use crate::obs::trace::{hex_id, parse_hex_id};
use crate::obs::{SpanCtx, SpanRecord};
use crate::session::protocol::{self, Access, KNOWN_COMMANDS};
use crate::session::{Engine, SessionConfig};
use crate::util::json::Json;
use anyhow::Result;
use std::io::{BufRead, Write};
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::Arc;

/// One client's view of the registry: the shared registry handle plus
/// the name of the session its commands currently route to.
pub struct Connection {
    registry: Arc<SessionRegistry>,
    current: Option<String>,
}

impl Connection {
    /// `current`: the session this connection starts on (the CLI presets
    /// the default session so single-session clients never need `open`).
    pub fn new(registry: Arc<SessionRegistry>, current: Option<String>) -> Self {
        Connection { registry, current }
    }

    pub fn current(&self) -> Option<&str> {
        self.current.as_deref()
    }

    /// Execute one NDJSON command line → (response, end-connection?).
    /// Never panics on untrusted input; every failure is an
    /// `{"ok":false}` response and the connection keeps serving.
    ///
    /// With observability attached to the registry (DESIGN.md §14),
    /// every command is counted (`server.commands` / `server.errors`)
    /// and timed into a per-command histogram
    /// (`server.cmd.<cmd>_ns`; unknown command names share one
    /// `server.cmd.unknown_ns` bucket so clients cannot inflate metric
    /// cardinality). With `serve --slow-ms N`, commands at or over the
    /// threshold additionally log one structured stderr record.
    pub fn execute(&mut self, line: &str) -> (Json, bool) {
        let slow_ms = self.registry.slow_ms();
        let timed = self.registry.obs().is_enabled() || slow_ms.is_some();
        let t0 = timed.then(crate::obs::now);
        let v = match Json::parse(line) {
            Ok(v) => v,
            Err(e) => {
                let obs = self.registry.obs();
                obs.inc("server.commands");
                obs.inc("server.errors");
                return (protocol::err(format!("bad json: {e}")), false);
            }
        };
        let Some(cmd) = v.get("cmd").and_then(Json::as_str).map(str::to_string) else {
            let obs = self.registry.obs();
            obs.inc("server.commands");
            obs.inc("server.errors");
            return (protocol::err("missing string field 'cmd'"), false);
        };
        let known = matches!(
            cmd.as_str(),
            "shutdown" | "open" | "use" | "close" | "list" | "shard" | "metrics" | "trace"
        ) || protocol::access_of(&cmd).is_some();
        let label = if known { cmd.as_str() } else { "unknown" };
        // Per-command span (DESIGN.md §16): a request carrying `"trace"`
        // context ADOPTS the caller's trace (always recorded — sampling
        // is the root's decision) and gets its spans echoed back as
        // `"spans"`; otherwise this is a (sampling-gated) root span.
        // With `--trace off` every branch is a no-op and responses are
        // byte-identical.
        let trace = self.registry.trace().clone();
        let ctx = protocol::parse_trace_ctx(&v);
        let mark = if ctx.is_some() { trace.seq() } else { 0 };
        let mut span = match ctx {
            Some(c) => trace.adopt(c.trace_id, c.span_id, &format!("member.{label}")),
            None => trace.root(&format!("cmd.{label}")),
        };
        if span.is_recording() {
            span.field("cmd", label);
            if let Some(name) = self.current.as_deref() {
                span.field("session", name);
            }
        }
        let span_ctx = span.ctx();
        let trace_tag = span_ctx.map_or_else(|| "-".to_string(), |c| hex_id(c.trace_id));
        let (mut response, shutdown) = self.dispatch(&cmd, &v, span_ctx);
        if let Some(c) = ctx {
            span.finish(); // record BEFORE collecting the echo
            protocol::attach_spans(&mut response, &trace.spans_since(c.trace_id, mark));
        }
        let obs = self.registry.obs();
        obs.inc("server.commands");
        if response.get("ok").and_then(Json::as_bool) == Some(false) {
            obs.inc("server.errors");
        }
        if let Some(t0) = t0 {
            let ns = t0.elapsed().as_nanos() as u64;
            obs.observe_ns(&format!("server.cmd.{label}_ns"), ns);
            if let Some(limit) = slow_ms {
                let ms = ns / 1_000_000;
                if ms >= limit {
                    obs.inc("server.slow_queries");
                    let session = self.current.as_deref().unwrap_or("-");
                    let rev = response
                        .get("rev")
                        .and_then(Json::as_f64)
                        .map_or_else(|| "-".to_string(), |r| format!("{r}"));
                    obs.event(
                        "slow_query",
                        &[
                            ("cmd", label.to_string()),
                            ("session", session.to_string()),
                            ("rev", rev.clone()),
                            ("elapsed_ms", ms.to_string()),
                            ("trace", trace_tag.clone()),
                        ],
                    );
                    // lint: allow(bare-eprintln) — the `slow-query` line
                    // format (not `event=`) is pinned by cli_smoke.rs.
                    eprintln!(
                        "stiknn serve: slow-query cmd={label} session={session} \
                         rev={rev} elapsed_ms={ms} trace={trace_tag}"
                    );
                }
            }
        }
        (response, shutdown)
    }

    /// Route one parsed command (the uninstrumented core of
    /// [`Self::execute`]). `scope` is the enclosing command span, passed
    /// through to write commands so session-level spans nest under it.
    fn dispatch(&mut self, cmd: &str, v: &Json, scope: Option<SpanCtx>) -> (Json, bool) {
        match cmd {
            "shutdown" => (
                protocol::ok("shutdown", vec![("shutdown", Json::Bool(true))]),
                true,
            ),
            "open" => (self.do_open(v), false),
            "use" => (self.do_use(v), false),
            "close" => (self.do_close(v), false),
            "list" => (self.do_list(), false),
            "shard" => (self.do_shard(), false),
            "trace" => (self.do_trace(v), false),
            // Process-wide telemetry is a registry-level question; the
            // per-session form (no "scope", or "scope":"session") routes
            // to the current session like any read.
            "metrics" if v.get("scope").and_then(Json::as_str) == Some("process") => {
                (self.do_metrics_process(v), false)
            }
            _ => match protocol::access_of(cmd) {
                Some(access) => (self.route(cmd, v, access, scope), false),
                None => (
                    protocol::err(format!(
                        "unknown command '{cmd}' \
                         (expected open|use|close|list|shard|trace|{KNOWN_COMMANDS})"
                    )),
                    false,
                ),
            },
        }
    }

    /// Route a single-session command to the current session under the
    /// appropriate lock mode. Registry-level failures (unknown session,
    /// spill reload errors) and command failures are both `{"ok":false}`.
    /// Write commands run with the session's trace scope set to the
    /// command span (bracketed under the write guard, so concurrent
    /// writers cannot observe each other's scope).
    fn route(&self, cmd: &str, v: &Json, access: Access, scope: Option<SpanCtx>) -> Json {
        let Some(name) = self.current.as_deref() else {
            return protocol::err(
                "no session selected on this connection (send \
                 {\"cmd\":\"open\",\"name\":...} or use an existing session)",
            );
        };
        let result = match access {
            Access::Read => self.registry.with_session_read(name, |s| {
                protocol::dispatch_read(s, cmd, v).unwrap_or_else(protocol::fail_json)
            }),
            Access::Write => self.registry.with_session_write(name, |s| {
                s.set_trace_scope(scope);
                let resp = protocol::dispatch_write(s, cmd, v).unwrap_or_else(protocol::fail_json);
                s.set_trace_scope(None);
                resp
            }),
        };
        result.unwrap_or_else(|e| protocol::err(format!("{e:#}")))
    }

    /// The `trace` verb (DESIGN.md §16) — process scope, like
    /// `metrics scope=process`: the span store lives on the registry.
    /// `{"cmd":"trace"}` lists recent ROOT spans (newest first, `"limit"`
    /// caps the count, default 16); `{"cmd":"trace","id":"<hex16>"}`
    /// returns every stored span of that trace, wire-formatted exactly
    /// like the `"spans"` echo so one renderer serves both.
    fn do_trace(&self, v: &Json) -> Json {
        let trace = self.registry.trace();
        if !trace.is_enabled() {
            return protocol::ok(
                "trace",
                vec![
                    ("enabled", Json::Bool(false)),
                    ("mode", Json::str(trace.mode().label())),
                ],
            );
        }
        if let Some(idv) = v.get("id") {
            let Some(id) = idv.as_str().and_then(parse_hex_id) else {
                return protocol::err("'id' must be a 16-hex-digit trace id");
            };
            let spans = trace.spans_of(id);
            return protocol::ok(
                "trace",
                vec![
                    ("enabled", Json::Bool(true)),
                    ("id", Json::str(hex_id(id))),
                    ("spans", Json::arr(spans.iter().map(SpanRecord::to_json))),
                ],
            );
        }
        let limit = v.get("limit").and_then(Json::as_usize).unwrap_or(16);
        let roots = trace.recent_roots(limit);
        protocol::ok(
            "trace",
            vec![
                ("enabled", Json::Bool(true)),
                ("mode", Json::str(trace.mode().label())),
                ("dropped", Json::num(trace.dropped() as f64)),
                ("roots", Json::arr(roots.iter().map(SpanRecord::to_json))),
            ],
        )
    }

    fn do_open(&mut self, v: &Json) -> Json {
        let Some(name) = v.get("name").and_then(Json::as_str).map(str::to_string) else {
            return protocol::err("open needs a string 'name'");
        };
        let snapshot = v
            .get("snapshot")
            .and_then(Json::as_str)
            .map(PathBuf::from);
        // Config precedence: a snapshot supplies its own header-derived
        // config; otherwise optional overrides modify the registry base.
        let config = if snapshot.is_some() {
            None
        } else {
            match open_overrides(self.registry.base_config(), v) {
                Ok(c) => c,
                Err(msg) => return protocol::err(msg),
            }
        };
        match self.registry.open(&name, snapshot.as_deref(), config) {
            Ok(created) => {
                self.current = Some(name.clone());
                protocol::ok(
                    "open",
                    vec![
                        ("name", Json::str(name)),
                        ("created", Json::Bool(created)),
                    ],
                )
            }
            Err(e) => protocol::err(format!("{e:#}")),
        }
    }

    fn do_use(&mut self, v: &Json) -> Json {
        let Some(name) = v.get("name").and_then(Json::as_str).map(str::to_string) else {
            return protocol::err("use needs a string 'name'");
        };
        if !self.registry.exists(&name) {
            return protocol::err(format!(
                "unknown session '{name}' (open it first, or `list` the registry)"
            ));
        }
        self.current = Some(name.clone());
        protocol::ok("use", vec![("name", Json::str(name))])
    }

    fn do_close(&mut self, v: &Json) -> Json {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .map(str::to_string)
            .or_else(|| self.current.clone());
        let Some(name) = name else {
            return protocol::err("close needs a 'name' (no current session to default to)");
        };
        match self.registry.close(&name) {
            Ok(()) => {
                if self.current.as_deref() == Some(name.as_str()) {
                    self.current = None;
                }
                protocol::ok("close", vec![("name", Json::str(name))])
            }
            Err(e) => protocol::err(format!("{e:#}")),
        }
    }

    /// Report this server's shard identity (`serve --shard-of J/N`,
    /// `null` when unsharded) plus the invariants a shard coordinator
    /// verifies before routing traffic: every member of a shard group
    /// must serve the SAME train set (name + fingerprint) with the same
    /// base k (DESIGN.md §13). Registry-level, not per-session — the
    /// identity belongs to the process.
    fn do_shard(&self) -> Json {
        let train = self.registry.train();
        let fp = crate::session::dataset_fingerprint(&train.x, &train.y, train.d);
        let mut fields = vec![match self.registry.shard() {
            Some(id) => ("shard", Json::num(id.index as f64)),
            None => ("shard", Json::Null),
        }];
        if let Some(id) = self.registry.shard() {
            fields.push(("of", Json::num(id.count as f64)));
        }
        fields.extend([
            ("train", Json::str(train.name.as_str())),
            ("n", Json::num(train.y.len() as f64)),
            ("d", Json::num(train.d as f64)),
            ("k", Json::num(self.registry.base_config().k as f64)),
            ("fingerprint", Json::str(format!("{fp:016x}"))),
        ]);
        protocol::ok("shard", fields)
    }

    /// Process-wide telemetry (`{"cmd":"metrics","scope":"process"}`,
    /// DESIGN.md §14): the server registry's full snapshot plus one
    /// summary row per session — revision, tests, and `rev_lag` (writes
    /// a crash right now would lose, i.e. live revision minus the last
    /// checkpointed one). Optional `"metric":"name"` looks up a single
    /// server-level metric instead.
    fn do_metrics_process(&self, v: &Json) -> Json {
        let obs = self.registry.obs();
        if let Some(m) = v.get("metric") {
            let Some(name) = m.as_str() else {
                return protocol::err("'metric' must be a string name");
            };
            let Some(reg) = obs.registry() else {
                return protocol::err(format!(
                    "metrics are disabled on this server; '{name}' is not being \
                     collected (serve with --obs on)"
                ));
            };
            return match reg.lookup(name) {
                Some(value) => protocol::ok(
                    "metrics",
                    vec![("metric", Json::str(name)), ("value", value)],
                ),
                None => protocol::err(format!("unknown metric '{name}'")),
            };
        }
        let lags: std::collections::BTreeMap<String, u64> =
            self.registry.revision_lag().into_iter().collect();
        let infos = self.registry.list();
        protocol::ok(
            "metrics",
            vec![
                ("scope", Json::str("process")),
                ("enabled", Json::Bool(obs.is_enabled())),
                (
                    "sessions",
                    Json::arr(infos.iter().map(|i| {
                        Json::obj(vec![
                            ("name", Json::str(i.name.as_str())),
                            ("resident", Json::Bool(i.resident)),
                            ("dirty", Json::Bool(i.dirty)),
                            ("tests", Json::num(i.tests as f64)),
                            ("rev", Json::num(i.revision as f64)),
                            (
                                "rev_lag",
                                Json::num(lags.get(&i.name).copied().unwrap_or(0) as f64),
                            ),
                        ])
                    })),
                ),
                ("metrics", obs.snapshot_json()),
            ],
        )
    }

    fn do_list(&self) -> Json {
        let infos = self.registry.list();
        protocol::ok(
            "list",
            vec![
                (
                    "current",
                    match &self.current {
                        Some(n) => Json::str(n.as_str()),
                        None => Json::Null,
                    },
                ),
                (
                    "sessions",
                    Json::arr(infos.iter().map(|i| {
                        Json::obj(vec![
                            ("name", Json::str(i.name.as_str())),
                            ("resident", Json::Bool(i.resident)),
                            ("dirty", Json::Bool(i.dirty)),
                            ("engine", Json::str(i.engine.label())),
                            ("mutable", Json::Bool(i.mutable)),
                            ("n", Json::num(i.n as f64)),
                            ("tests", Json::num(i.tests as f64)),
                            ("rev", Json::num(i.revision as f64)),
                        ])
                    })),
                ),
            ],
        )
    }
}

/// Fresh-session config overrides for `open`: `Ok(None)` = no overrides
/// given (registry decides), `Err` = a human-readable rejection.
fn open_overrides(base: SessionConfig, v: &Json) -> Result<Option<SessionConfig>, String> {
    let mut c = base;
    let mut any = false;
    let mut explicit_engine = None;
    if let Some(kv) = v.get("k") {
        let Some(k) = kv.as_usize().filter(|&k| k >= 1) else {
            return Err("'k' must be a positive integer".to_string());
        };
        c.k = k;
        any = true;
    }
    if let Some(e) = v.get("engine") {
        let Some(engine) = e.as_str().and_then(Engine::parse) else {
            return Err("'engine' must be dense or implicit".to_string());
        };
        c.engine = engine;
        explicit_engine = Some(engine);
        any = true;
    }
    if let Some(m) = v.get("mutable") {
        let Some(mutable) = m.as_bool() else {
            return Err("'mutable' must be a boolean".to_string());
        };
        if mutable {
            if explicit_engine == Some(Engine::Dense) {
                return Err(
                    "a mutable session requires the implicit engine (drop \"engine\":\"dense\")"
                        .to_string(),
                );
            }
            // --mutable semantics: implies implicit engine + retained rows
            c.engine = Engine::Implicit;
            c.retain_rows = true;
        }
        c.mutable = mutable;
        any = true;
    }
    Ok(any.then_some(c))
}

/// Drive one connection over any byte stream until `shutdown` or EOF —
/// the multi-session twin of [`crate::session::protocol::serve`], with
/// the same robustness contract: malformed lines (including non-UTF-8
/// bytes) answer `{"ok":false}` and the loop keeps serving; only real
/// I/O failures (a half-closed socket mid-write) end it via `Err`.
pub fn serve_connection<R: BufRead, W: Write>(
    conn: &mut Connection,
    mut input: R,
    mut output: W,
) -> Result<()> {
    let mut buf = Vec::new();
    loop {
        buf.clear();
        if input.read_until(b'\n', &mut buf)? == 0 {
            break; // EOF (clean client disconnect)
        }
        let line = String::from_utf8_lossy(&buf);
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let (response, shutdown) = conn.execute(trimmed);
        writeln!(output, "{response}")?;
        output.flush()?;
        if shutdown {
            break;
        }
    }
    Ok(())
}

/// Thread-per-connection accept loop over an already-bound listener
/// (binding is the caller's job so `--listen 127.0.0.1:0` can report
/// the chosen port before the loop starts). Every connection starts on
/// `default_session`. Runs until the process exits; a failed accept or
/// a misbehaving client ends (at most) that one connection — errors are
/// logged to stderr and never propagate across clients.
pub fn listen(
    registry: Arc<SessionRegistry>,
    listener: TcpListener,
    default_session: Option<String>,
) -> Result<()> {
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                let obs = registry.obs();
                obs.inc("server.accept_failed");
                obs.event_logged("stiknn serve", "accept_failed", &[("error", e.to_string())]);
                continue;
            }
        };
        let registry = Arc::clone(&registry);
        let default_session = default_session.clone();
        std::thread::spawn(move || {
            let obs = registry.obs().clone();
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "?".to_string());
            let reader = match stream.try_clone() {
                Ok(s) => std::io::BufReader::new(s),
                Err(e) => {
                    obs.inc("server.clone_failed");
                    obs.event_logged(
                        "stiknn serve",
                        "clone_failed",
                        &[("peer", peer.clone()), ("error", e.to_string())],
                    );
                    return;
                }
            };
            obs.inc("server.connections_opened");
            obs.gauge_add("server.connections_active", 1);
            let mut conn = Connection::new(registry, default_session);
            if let Err(e) = serve_connection(&mut conn, reader, &stream) {
                // a half-closed or reset client is business as usual for
                // a server — log and move on, the registry is untouched
                obs.inc("server.conn_errors");
                obs.event_logged(
                    "stiknn serve",
                    "conn_ended",
                    &[("peer", peer.clone()), ("error", format!("{e:#}"))],
                );
            }
            obs.gauge_add("server.connections_active", -1);
            obs.inc("server.connections_closed");
        });
    }
    Ok(())
}
