//! The named-session registry behind the concurrent server
//! (DESIGN.md §12): many [`ValuationSession`]s in one process, each
//! behind its own `RwLock`, with an LRU cap that spills cold sessions to
//! the v3 snapshot store and an autosave thread that checkpoints dirty
//! ones.
//!
//! # Locking discipline
//!
//! Two lock levels, always acquired registry-mutex → session-lock and
//! never the other way around (no thread holds a session guard while
//! touching the registry), so the system cannot deadlock:
//!
//! * one registry `Mutex` guards the name→entry map, the LRU clock and
//!   spill/reload transitions — held only for map lookups and (briefly)
//!   for a spill or reload, never across command execution;
//! * one `RwLock` per session serializes that session's writes
//!   (`ingest`/`add_train`/`remove_train`/`relabel`) while letting its
//!   reads (`value`/`topk`/`stats`/`snapshot`) run concurrently.
//!
//! Serialized-replay equivalence: a write command mutates exactly one
//! session, under that session's exclusive write guard, and bumps its
//! [`ValuationSession::revision`] by one. Any interleaving of client
//! traffic therefore equals SOME serial order of each session's writes —
//! the order the revisions record — and replaying that order against a
//! fresh session reproduces the final state bit-for-bit (every session
//! operation is deterministic; property-tested in
//! `tests/server_concurrency.rs`).
//!
//! # Spill / reload
//!
//! Eviction `try_write`s the victim (a session busy with an in-flight
//! command — or poisoned — is skipped and the next-coldest tried; the
//! cap is re-enforced on every acquire, so a skipped round recovers on
//! the next touch), saves it to `state_dir` via the bit-exact snapshot
//! store, marks the slot `evicted`, and drops the resident state. A
//! command that acquired the slot just before eviction observes the
//! `evicted` flag after locking and re-routes through the registry,
//! which reloads the spilled snapshot transparently — restore is
//! bit-identical, so a spill/reload cycle is invisible to the replay
//! invariant. Sessions whose state cannot round-trip a snapshot
//! (immutable retained-rows sessions: per-test rows are not persisted
//! for them) are never chosen for eviction, so the resident count can
//! exceed the cap when only those remain. Poisoned sessions (a command
//! panicked mid-mutation) refuse all further commands and are never
//! persisted — their in-memory state cannot be trusted.

use crate::obs::{ObsHandle, TraceHandle};
use crate::session::{store, Engine, SessionConfig, ValuationSession};
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError, RwLock};
use std::time::Duration;

/// The training set every fresh session in a registry is built over
/// (mutable sessions diverge from it as they edit; their snapshots carry
/// their own train set).
#[derive(Clone, Debug)]
pub struct TrainData {
    pub name: String,
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub d: usize,
}

impl TrainData {
    pub fn from_dataset(ds: &crate::data::Dataset) -> Self {
        TrainData {
            name: ds.name.clone(),
            x: ds.train_x.clone(),
            y: ds.train_y.clone(),
            d: ds.d,
        }
    }
}

/// A serve process's identity under exact test-set sharding
/// (DESIGN.md §13): this process owns shard `index` of `count` in a
/// coordinator's contiguous partition of the global test stream. Carried
/// by the registry (set once at startup via
/// [`SessionRegistry::with_shard`], reported by the `shard` protocol
/// verb) so a `ShardedSession` (`stiknn-session`'s `shard` module) can
/// verify it is talking to the member it thinks it is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardIdentity {
    pub index: u64,
    pub count: u64,
}

impl ShardIdentity {
    /// `index` of `count`, zero-based; rejects `index >= count` and
    /// `count == 0` (the CLI surfaces this for a bad `--shard-of J/N`).
    pub fn new(index: u64, count: u64) -> Result<Self> {
        ensure!(count >= 1, "a shard group needs at least 1 member");
        ensure!(
            index < count,
            "shard index {index} out of range for a group of {count} \
             (indices are zero-based: 0..{count})"
        );
        Ok(ShardIdentity { index, count })
    }
}

/// Registry-level knobs (per-session semantics live in [`SessionConfig`]).
#[derive(Clone, Debug)]
pub struct RegistryConfig {
    /// Template for sessions opened without explicit config (protocol
    /// `open` without a snapshot derives overrides from this).
    pub base: SessionConfig,
    /// LRU cap on RESIDENT sessions (0 = unlimited). Requires
    /// `state_dir` — evicted sessions live as snapshots.
    pub max_resident: usize,
    /// Where spills and autosave checkpoints go (`None` = neither).
    pub state_dir: Option<PathBuf>,
}

/// One resident session: the lock every command goes through, plus the
/// eviction flag that re-routes commands which raced a spill.
pub struct Slot {
    lock: RwLock<ValuationSession>,
    /// Set (under the write guard) when this slot is spilled or closed;
    /// a command that acquired the Arc before that must re-route through
    /// the registry instead of touching the detached state.
    evicted: AtomicBool,
}

/// What `list` reports per session. For spilled sessions the values are
/// from the moment of the spill — exact, since a spilled session cannot
/// change.
#[derive(Clone, Debug)]
pub struct SessionInfo {
    pub name: String,
    pub resident: bool,
    /// Writes applied since the last checkpoint (always false once
    /// spilled — spilling checkpoints).
    pub dirty: bool,
    pub n: usize,
    pub tests: u64,
    pub engine: Engine,
    pub mutable: bool,
    pub revision: u64,
}

/// Spill-time summary kept for non-resident sessions.
#[derive(Clone, Copy, Debug)]
struct Summary {
    n: usize,
    tests: u64,
    engine: Engine,
    mutable: bool,
    revision: u64,
}

fn summarize(s: &ValuationSession) -> Summary {
    Summary {
        n: s.n(),
        tests: s.tests_seen(),
        engine: s.engine(),
        mutable: s.is_mutable(),
        revision: s.revision(),
    }
}

struct Entry {
    /// `Some` while resident, `None` while spilled.
    slot: Option<Arc<Slot>>,
    config: SessionConfig,
    /// The session's own metrics handle (DESIGN.md §14) — kept HERE so
    /// its registry survives spill/reload cycles (the live session is
    /// dropped on spill; its counters must not be).
    obs: ObsHandle,
    /// Last snapshot written for this session (spill or autosave).
    snapshot: Option<PathBuf>,
    /// Session revision covered by that snapshot (dirtiness = live
    /// revision beyond this).
    saved_rev: u64,
    /// LRU stamp from the registry clock.
    last_touch: u64,
    summary: Summary,
}

struct Inner {
    map: HashMap<String, Entry>,
    clock: u64,
}

impl Inner {
    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }
}

/// The named-session registry. All methods take `&self`; share it as an
/// `Arc<SessionRegistry>` across connection threads.
pub struct SessionRegistry {
    train: TrainData,
    config: RegistryConfig,
    shard: Option<ShardIdentity>,
    /// Server-wide telemetry (DESIGN.md §14): lock wait/hold, spill and
    /// autosave accounting, command latency. Disabled unless attached
    /// via [`Self::with_obs`] — every hook degrades to a no-op.
    obs: ObsHandle,
    /// Slow-query threshold in milliseconds (`serve --slow-ms N`):
    /// commands taking `>= N` ms log a structured stderr record. `None`
    /// = off; `Some(0)` logs every command (deterministic for tests).
    slow_ms: Option<u64>,
    /// Process-wide span store (DESIGN.md §16, `serve --trace`). ONE
    /// store per server — every session records into it, so a trace that
    /// crosses sessions (and the spans members echo back to a
    /// coordinating request) lands in one place for the `trace` verb.
    trace: TraceHandle,
    inner: Mutex<Inner>,
}

impl SessionRegistry {
    pub fn new(train: TrainData, config: RegistryConfig) -> Result<Self> {
        ensure!(
            config.max_resident == 0 || config.state_dir.is_some(),
            "a resident-session cap needs a state dir to spill into"
        );
        if let Some(dir) = &config.state_dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating state dir {}", dir.display()))?;
        }
        Ok(SessionRegistry {
            train,
            config,
            shard: None,
            obs: ObsHandle::disabled(),
            slow_ms: None,
            trace: TraceHandle::disabled(),
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                clock: 0,
            }),
        })
    }

    /// Attach the server-wide metrics registry (DESIGN.md §14).
    /// Builder-style, like [`Self::with_shard`]: set it before the
    /// registry is shared across connection threads. Sessions opened
    /// afterwards each get their OWN enabled handle (named after the
    /// session), which answers the per-session `metrics` verb.
    pub fn with_obs(mut self, obs: ObsHandle) -> Self {
        self.obs = obs;
        self
    }

    /// The server-wide metrics handle (disabled unless [`Self::with_obs`]
    /// attached one).
    pub fn obs(&self) -> &ObsHandle {
        &self.obs
    }

    /// Set the slow-query threshold (`serve --slow-ms N`); `Some(0)`
    /// logs every command.
    pub fn with_slow_ms(mut self, slow_ms: Option<u64>) -> Self {
        self.slow_ms = slow_ms;
        self
    }

    /// Attach the process-wide tracing handle (`serve --trace`,
    /// DESIGN.md §16). Builder-style, like [`Self::with_obs`]: set it
    /// before the registry is shared. Every session opened or reloaded
    /// afterwards records into this one span store.
    pub fn with_trace(mut self, trace: TraceHandle) -> Self {
        self.trace = trace;
        self
    }

    /// The server-wide tracing handle (disabled unless
    /// [`Self::with_trace`] attached one).
    pub fn trace(&self) -> &TraceHandle {
        &self.trace
    }

    pub fn slow_ms(&self) -> Option<u64> {
        self.slow_ms
    }

    /// Stamp this registry with a shard identity (`serve --shard-of J/N`).
    /// Builder-style because the identity is fixed for the process
    /// lifetime — set it before the registry is shared across connection
    /// threads.
    pub fn with_shard(mut self, shard: ShardIdentity) -> Self {
        self.shard = Some(shard);
        self
    }

    /// This process's shard identity, if it serves as part of a shard
    /// group (reported by the `shard` protocol verb).
    pub fn shard(&self) -> Option<ShardIdentity> {
        self.shard
    }

    /// Registry session names: 1–64 chars of `[A-Za-z0-9._-]` — they
    /// become spill file names, so nothing that could traverse paths.
    pub fn valid_name(name: &str) -> bool {
        !name.is_empty()
            && name.len() <= 64
            && name
                .bytes()
                .all(|b| b.is_ascii_alphanumeric() || b == b'.' || b == b'_' || b == b'-')
    }

    pub fn base_config(&self) -> SessionConfig {
        self.config.base
    }

    pub fn train(&self) -> &TrainData {
        &self.train
    }

    /// Lock the registry map, surviving a poisoned mutex (a panicking
    /// connection thread must not take the whole server down — the map
    /// itself is only ever mutated through small, non-panicking steps).
    fn inner(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Open (create or attach) the named session; `true` = created.
    ///
    /// * `snapshot` — restore from this file instead of starting fresh
    ///   (mutable snapshots carry their own train set; immutable ones are
    ///   fingerprint-checked against the registry's training data).
    /// * `config` — `Some`: use exactly this config. `None`: derive k,
    ///   metric, engine and mutability from the snapshot header, or fall
    ///   back to the registry base config for fresh sessions.
    ///
    /// Attaching to an existing name ignores `snapshot`/`config` — the
    /// session is whatever it already is.
    pub fn open(
        &self,
        name: &str,
        snapshot: Option<&Path>,
        config: Option<SessionConfig>,
    ) -> Result<bool> {
        ensure!(
            Self::valid_name(name),
            "invalid session name '{name}' (1-64 characters of [A-Za-z0-9._-])"
        );
        let mut inner = self.inner();
        if inner.map.contains_key(name) {
            let stamp = inner.tick();
            inner
                .map
                .get_mut(name)
                .expect("checked contains_key above")
                .last_touch = stamp;
            return Ok(false);
        }
        let config = match (config, snapshot) {
            (Some(c), _) => c,
            (None, Some(path)) => config_from_header(&store::read_header(path)?, self.config.base),
            (None, None) => self.config.base,
        };
        let mut session = match snapshot {
            Some(path) if config.mutable => ValuationSession::restore_mutable(path, config)?,
            Some(path) => ValuationSession::restore(
                path,
                self.train.x.clone(),
                self.train.y.clone(),
                self.train.d,
                config,
            )?,
            None => ValuationSession::new(
                self.train.x.clone(),
                self.train.y.clone(),
                self.train.d,
                config,
            )?,
        };
        // With server-wide observability on, each session gets its own
        // named handle — the per-session `metrics` verb answers from it.
        let session_obs = if self.obs.is_enabled() {
            ObsHandle::enabled(name)
        } else {
            ObsHandle::disabled()
        };
        session.set_obs(session_obs.clone());
        session.set_trace(self.trace.clone());
        let stamp = inner.tick();
        let summary = summarize(&session);
        inner.map.insert(
            name.to_string(),
            Entry {
                slot: Some(Arc::new(Slot {
                    lock: RwLock::new(session),
                    evicted: AtomicBool::new(false),
                })),
                config,
                obs: session_obs,
                snapshot: None,
                saved_rev: summary.revision,
                last_touch: stamp,
                summary,
            },
        );
        self.enforce_cap(&mut inner, name)?;
        Ok(true)
    }

    pub fn exists(&self, name: &str) -> bool {
        self.inner().map.contains_key(name)
    }

    /// Drop the named session. In-flight commands on it finish first
    /// (exclusive lock); the state is NOT saved — `snapshot` it before
    /// closing if it should survive.
    pub fn close(&self, name: &str) -> Result<()> {
        let mut inner = self.inner();
        let Some(entry) = inner.map.remove(name) else {
            bail!("unknown session '{name}' (see `list`)");
        };
        if let Some(slot) = entry.slot {
            // Drain in-flight commands, then flag stragglers that cloned
            // the Arc before removal: they re-route and get a clean
            // "unknown session" error instead of writing into the void.
            let _guard = slot.lock.write().unwrap_or_else(PoisonError::into_inner);
            slot.evicted.store(true, Ordering::Release);
        }
        Ok(())
    }

    /// Resident slot for `name`: touches the LRU stamp, transparently
    /// reloading a spilled session (and possibly spilling another to
    /// stay under the cap).
    fn acquire(&self, name: &str) -> Result<Arc<Slot>> {
        let mut inner = self.inner();
        let stamp = inner.tick();
        let Some(entry) = inner.map.get_mut(name) else {
            bail!("unknown session '{name}' (open it first, or `list` the registry)");
        };
        entry.last_touch = stamp;
        if let Some(slot) = &entry.slot {
            let slot = Arc::clone(slot);
            // Re-enforce even on the resident fast path: an earlier
            // eviction round may have skipped busy victims, leaving the
            // registry over cap — this is where it recovers.
            self.enforce_cap(&mut inner, name)?;
            return Ok(slot);
        }
        // Reload the spilled snapshot. Restore is bit-identical, and the
        // revision counter is re-seeded so the write ordering stays
        // monotone across the cycle. Done under the registry mutex:
        // routing pauses rather than double-loading the same session.
        let path = entry
            .snapshot
            .clone()
            .expect("a spilled session always has a snapshot");
        let config = entry.config;
        let session_obs = entry.obs.clone();
        let revision = entry.summary.revision;
        let mut session = if config.mutable {
            ValuationSession::restore_mutable(&path, config)
        } else {
            ValuationSession::restore(
                &path,
                self.train.x.clone(),
                self.train.y.clone(),
                self.train.d,
                config,
            )
        }
        .with_context(|| format!("reloading spilled session '{name}' from {}", path.display()))?;
        session.set_revision(revision);
        // Re-attach the SAME per-session metrics handle: a spill/reload
        // cycle must be invisible to the session's counters too. The
        // trace handle likewise (all sessions share the process store).
        session.set_obs(session_obs);
        session.set_trace(self.trace.clone());
        self.obs.inc("registry.reloads");
        let slot = Arc::new(Slot {
            lock: RwLock::new(session),
            evicted: AtomicBool::new(false),
        });
        inner
            .map
            .get_mut(name)
            .expect("entry looked up above")
            .slot = Some(Arc::clone(&slot));
        self.enforce_cap(&mut inner, name)?;
        Ok(slot)
    }

    /// Spill coldest spillable sessions (never `just_touched`) until the
    /// resident count fits the cap. Victims are tried with `try_write`:
    /// a session busy with an in-flight command is skipped (the cap is
    /// over-run this round rather than stalling every client behind one
    /// slow command), and the next acquire re-enforces.
    fn enforce_cap(&self, inner: &mut Inner, just_touched: &str) -> Result<()> {
        let cap = self.config.max_resident;
        if cap == 0 {
            return Ok(());
        }
        let mut resident = inner.map.values().filter(|e| e.slot.is_some()).count();
        if resident <= cap {
            return Ok(());
        }
        let mut candidates: Vec<(u64, String)> = inner
            .map
            .iter()
            .filter(|(n, e)| {
                e.slot.is_some() && n.as_str() != just_touched && spillable(&e.config)
            })
            .map(|(n, e)| (e.last_touch, n.clone()))
            .collect();
        candidates.sort(); // coldest first
        for (_, victim) in candidates {
            if resident <= cap {
                break;
            }
            if self.spill_entry(inner, &victim)? {
                resident -= 1;
            }
        }
        Ok(())
    }

    /// Try to spill one resident session. `Ok(false)` = skipped: its
    /// lock was busy (an in-flight command) or poisoned (state that must
    /// never be persisted).
    fn spill_entry(&self, inner: &mut Inner, name: &str) -> Result<bool> {
        let dir = self
            .config
            .state_dir
            .as_ref()
            .expect("cap enforcement requires a state dir");
        let path = store::spill_path(dir, name);
        let entry = inner.map.get_mut(name).expect("victim was just selected");
        let slot = Arc::clone(entry.slot.as_ref().expect("victim is resident"));
        let Ok(session) = slot.lock.try_write() else {
            return Ok(false);
        };
        // Save only if the on-disk snapshot is stale (autosave may have
        // checkpointed this exact revision already).
        if entry.snapshot.as_deref() != Some(path.as_path())
            || entry.saved_rev != session.revision()
        {
            let bytes = session
                .save(&path)
                .with_context(|| format!("spilling session '{name}' to {}", path.display()))?;
            self.obs.add("registry.spill_bytes", bytes);
        }
        self.obs.inc("registry.spills");
        self.obs.event(
            "spill",
            &[
                ("session", name.to_string()),
                ("rev", session.revision().to_string()),
            ],
        );
        entry.saved_rev = session.revision();
        entry.snapshot = Some(path);
        entry.summary = summarize(&session);
        slot.evicted.store(true, Ordering::Release);
        drop(session);
        entry.slot = None;
        Ok(true)
    }

    /// Run `f` under the named session's shared (read) guard.
    ///
    /// A POISONED session lock is an error, not a recovery: poisoning
    /// means a command panicked mid-mutation, so the state behind the
    /// lock may be half-edited — serving it would silently break the
    /// serialized-replay invariant. The session stays refusing until
    /// `close`d (and reopened from its last good checkpoint).
    pub fn with_session_read<T>(
        &self,
        name: &str,
        f: impl FnOnce(&ValuationSession) -> T,
    ) -> Result<T> {
        let mut f = Some(f);
        loop {
            let slot = self.acquire(name)?;
            let t_wait = self.obs.is_enabled().then(crate::obs::now);
            let Ok(guard) = slot.lock.read() else {
                bail!("{}", poisoned_msg(name));
            };
            if slot.evicted.load(Ordering::Acquire) {
                continue; // raced a spill/close — re-route
            }
            if let Some(t) = t_wait {
                self.obs
                    .observe_ns("registry.lock_wait_ns", t.elapsed().as_nanos() as u64);
            }
            let t_hold = self.obs.is_enabled().then(crate::obs::now);
            let f = f.take().expect("loop exits after the first call");
            let out = f(&guard);
            if let Some(t) = t_hold {
                self.obs
                    .observe_ns("registry.lock_hold_ns", t.elapsed().as_nanos() as u64);
            }
            return Ok(out);
        }
    }

    /// Run `f` under the named session's exclusive (write) guard.
    /// Poisoned locks are refused — see [`Self::with_session_read`].
    pub fn with_session_write<T>(
        &self,
        name: &str,
        f: impl FnOnce(&mut ValuationSession) -> T,
    ) -> Result<T> {
        let mut f = Some(f);
        loop {
            let slot = self.acquire(name)?;
            let t_wait = self.obs.is_enabled().then(crate::obs::now);
            let Ok(mut guard) = slot.lock.write() else {
                bail!("{}", poisoned_msg(name));
            };
            if slot.evicted.load(Ordering::Acquire) {
                continue;
            }
            if let Some(t) = t_wait {
                self.obs
                    .observe_ns("registry.lock_wait_ns", t.elapsed().as_nanos() as u64);
            }
            let t_hold = self.obs.is_enabled().then(crate::obs::now);
            let f = f.take().expect("loop exits after the first call");
            let out = f(&mut guard);
            if let Some(t) = t_hold {
                self.obs
                    .observe_ns("registry.lock_hold_ns", t.elapsed().as_nanos() as u64);
            }
            return Ok(out);
        }
    }

    /// Registry listing, name-sorted. Resident rows read live state via
    /// `try_read` — a session busy with a long command (or poisoned)
    /// reports its last recorded summary instead of stalling the whole
    /// registry behind one lock. Spilled rows report their (exact)
    /// spill-time summary.
    pub fn list(&self) -> Vec<SessionInfo> {
        let inner = self.inner();
        let mut rows: Vec<SessionInfo> = inner
            .map
            .iter()
            .map(|(name, e)| {
                if let Some(slot) = &e.slot {
                    if let Ok(s) = slot.lock.try_read() {
                        return SessionInfo {
                            name: name.clone(),
                            resident: true,
                            dirty: s.revision() != e.saved_rev,
                            n: s.n(),
                            tests: s.tests_seen(),
                            engine: s.engine(),
                            mutable: s.is_mutable(),
                            revision: s.revision(),
                        };
                    }
                }
                SessionInfo {
                    name: name.clone(),
                    resident: e.slot.is_some(),
                    dirty: e.slot.is_some() && e.summary.revision != e.saved_rev,
                    n: e.summary.n,
                    tests: e.summary.tests,
                    engine: e.summary.engine,
                    mutable: e.summary.mutable,
                    revision: e.summary.revision,
                }
            })
            .collect();
        rows.sort_by(|a, b| a.name.cmp(&b.name));
        rows
    }

    /// Checkpoint every resident dirty session to the state dir (the
    /// autosave body; also callable directly). Returns how many sessions
    /// were written. Saves happen under per-session READ guards with the
    /// registry mutex released, so a checkpoint of a large session stalls
    /// neither routing nor that session's queries — only its writers.
    pub fn checkpoint_dirty(&self) -> Result<usize> {
        let Some(dir) = self.config.state_dir.clone() else {
            return Ok(0);
        };
        self.obs.inc("registry.autosave_runs");
        let names: Vec<String> = {
            let inner = self.inner();
            inner
                .map
                .iter()
                .filter(|(_, e)| e.slot.is_some())
                .map(|(n, _)| n.clone())
                .collect()
        };
        let mut written = 0;
        for name in names {
            // Re-resolve per session: it may have been closed or spilled
            // since the list was taken (both already persist or discard
            // its state — nothing to do here).
            let (slot, saved_rev) = {
                let inner = self.inner();
                match inner.map.get(&name) {
                    Some(e) => match &e.slot {
                        Some(s) => (Arc::clone(s), e.saved_rev),
                        None => continue,
                    },
                    None => continue,
                }
            };
            let path = store::spill_path(&dir, &name);
            let (rev, summary) = {
                // A poisoned session must never be persisted (its state
                // may be half-mutated) — skip it, like a busy victim.
                let Ok(session) = slot.lock.read() else {
                    continue;
                };
                if slot.evicted.load(Ordering::Acquire) {
                    continue;
                }
                let rev = session.revision();
                if rev == saved_rev {
                    continue;
                }
                let bytes = session
                    .save(&path)
                    .with_context(|| format!("autosaving session '{name}'"))?;
                self.obs.inc("registry.autosave_saved");
                self.obs.add("registry.autosave_bytes", bytes);
                (rev, summarize(&session))
            };
            written += 1;
            self.obs.event("autosave", &[("session", name.clone())]);
            // Record what the snapshot covers — but ONLY on the same slot
            // we saved (ptr_eq): the name may have been closed and reopened
            // as a brand-new session in the window where no lock is held,
            // and stamping the old state's path onto it would later let a
            // spill skip a needed save and reload stale state. A writer
            // may also have moved the session past `rev`; then
            // saved_rev < revision and it correctly stays dirty. (The
            // session guard is dropped first: never hold it while taking
            // the registry mutex.)
            let mut inner = self.inner();
            if let Some(e) = inner.map.get_mut(&name) {
                if e.slot.as_ref().is_some_and(|s| Arc::ptr_eq(s, &slot)) {
                    e.snapshot = Some(path);
                    e.summary = summary;
                    if e.saved_rev < rev {
                        e.saved_rev = rev;
                    }
                }
            }
        }
        Ok(written)
    }

    /// Per-session revision lag, name-sorted: live write revision minus
    /// the revision the last checkpoint covers (how many writes a crash
    /// right now would lose). Resident-but-busy sessions fall back to
    /// their last recorded summary, like [`Self::list`].
    pub fn revision_lag(&self) -> Vec<(String, u64)> {
        let inner = self.inner();
        let mut rows: Vec<(String, u64)> = inner
            .map
            .iter()
            .map(|(name, e)| {
                let live = e
                    .slot
                    .as_ref()
                    .and_then(|s| s.lock.try_read().ok().map(|g| g.revision()))
                    .unwrap_or(e.summary.revision);
                (name.clone(), live.saturating_sub(e.saved_rev))
            })
            .collect();
        rows.sort();
        rows
    }

    /// The named session's own metrics handle (the one its `metrics`
    /// verb answers from); `None` for unknown names.
    pub fn session_obs(&self, name: &str) -> Option<ObsHandle> {
        self.inner().map.get(name).map(|e| e.obs.clone())
    }
}

/// Can this session's full state round-trip a snapshot? Immutable
/// retained-rows sessions cannot (per-test rows are only persisted for
/// mutable sessions), so they are pinned resident.
fn spillable(config: &SessionConfig) -> bool {
    config.mutable || !config.retain_rows
}

fn poisoned_msg(name: &str) -> String {
    format!(
        "session '{name}' is poisoned: a command panicked mid-operation, so its \
         in-memory state cannot be trusted — `close` it and reopen from its last \
         good snapshot"
    )
}

/// Session config implied by a snapshot header (protocol `open` with a
/// snapshot and no explicit overrides): valuation semantics (k, metric)
/// and capability shape (engine, mutability) come from the file;
/// performance knobs stay at the registry base.
fn config_from_header(h: &store::SnapshotHeader, base: SessionConfig) -> SessionConfig {
    let mut c = base;
    c.k = h.k as usize;
    c.metric = h.metric;
    c.engine = h.engine;
    c.retain_rows = h.mutable;
    c.mutable = h.mutable;
    c
}

/// Handle to the background autosave thread; dropping it stops the
/// thread promptly (condvar wakeup, then join).
pub struct Autosave {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Drop for Autosave {
    fn drop(&mut self) {
        let (flag, cvar) = &*self.stop;
        *flag.lock().unwrap_or_else(PoisonError::into_inner) = true;
        cvar.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Start the autosave loop: every `interval`, checkpoint dirty resident
/// sessions into the registry's state dir. Failures are logged to
/// stderr and retried next tick — a full disk must not kill the serving
/// process, and the previous good checkpoint survives (snapshot writes
/// are atomic-by-rename).
pub fn start_autosave(registry: Arc<SessionRegistry>, interval: Duration) -> Autosave {
    let stop = Arc::new((Mutex::new(false), Condvar::new()));
    let stop2 = Arc::clone(&stop);
    let handle = std::thread::spawn(move || {
        let (flag, cvar) = &*stop2;
        let mut stopped = flag.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            let (guard, _) = cvar
                .wait_timeout(stopped, interval)
                .unwrap_or_else(PoisonError::into_inner);
            stopped = guard;
            if *stopped {
                return;
            }
            drop(stopped); // never checkpoint while holding the stop flag
            if let Err(e) = registry.checkpoint_dirty() {
                registry.obs().inc("registry.autosave_failures");
                registry.obs().event_logged(
                    "stiknn serve",
                    "autosave_failed",
                    &[("error", format!("{e:#}"))],
                );
            }
            stopped = flag.lock().unwrap_or_else(PoisonError::into_inner);
        }
    });
    Autosave {
        stop,
        handle: Some(handle),
    }
}
