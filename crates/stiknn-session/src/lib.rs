//! # stiknn-session — streaming valuation sessions and the shard layer
//!
//! The stateful layer between the pure engines (`stiknn-core`) and the
//! multi-session server (`stiknn-server`):
//!
//! * [`session`] — [`session::ValuationSession`] holds unnormalized
//!   engine state between requests, ingests test batches incrementally
//!   (exact by Eq. 9 additivity), snapshots/restores through the
//!   versioned binary store ([`session::store`], v3 carries mutable
//!   payloads), and answers the single-session NDJSON command set
//!   ([`session::protocol`]).
//! * [`shard`] — the client-side multi-node fan-out (DESIGN.md §13):
//!   [`shard::ShardedSession`] opens the same session on N shard
//!   servers, routes each ingest batch by global test index
//!   ([`shard::ShardPlan`]), merges per-shard raw sums exactly in shard
//!   order, and consolidates/rebalances via per-shard snapshots
//!   (`snapshot_all` → `rescatter`).
//! * [`removal`] — the exact iterative removal curve, which needs a live
//!   mutable session and therefore lives here rather than in
//!   `stiknn-core`'s `analysis` module (the facade stitches it back into
//!   `stiknn::analysis::removal`).
//!
//! The core algorithm modules are re-exported so in-crate paths like
//! `crate::shapley::...` keep resolving exactly as they did in the
//! monolith.

pub mod removal;
pub mod session;
pub mod shard;

pub use stiknn_core::{analysis, coordinator, data, knn, obs, shapley, util};
