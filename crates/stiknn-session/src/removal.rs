//! The EXACT iterative removal order — the one analysis routine that
//! drives a live mutable [`ValuationSession`] (remove-best → repair →
//! re-rank via the delta subsystem, DESIGN.md §11), which is why it
//! lives in `stiknn-session` rather than `stiknn-core::analysis`. The
//! `stiknn` facade re-exports it at its pre-split path
//! (`stiknn::analysis::removal::sti_iterative_removal_order`), so
//! callers never see the crate boundary.

use crate::analysis::removal::argmin_by_value;
use crate::data::Dataset;
use crate::session::{SessionConfig, TopBy, ValuationSession};
use crate::shapley::values::Engine;
use crate::shapley::StiParams;

/// EXACT iterative removal order (remove-best → repair → re-rank),
/// lowest value first, via a mutable valuation session (DESIGN.md §11).
/// Greedy steps stop once the train set would shrink below
/// `max(min_keep, k, 2)`; the surviving points are appended in
/// final-ranking order so the result is a full permutation of
/// `0..n_train` (what `analysis::removal::removal_curve` consumes). All
/// indices are in ORIGINAL train numbering.
///
/// Every step's ranking is exactly the from-scratch values of the
/// current reduced train set (bit-identical —
/// `tests/delta_equivalence.rs`), at O(removals·t·n) total instead of
/// the O(removals·t·(n·d + n log n)) a recompute-per-step would cost.
pub fn sti_iterative_removal_order(
    ds: &Dataset,
    params: &StiParams,
    min_keep: usize,
) -> Vec<usize> {
    let n = ds.n_train();
    let config = SessionConfig::new(params.k)
        .with_metric(params.metric)
        .with_engine(Engine::Implicit)
        .with_retained_rows(true)
        .with_mutable(true);
    let mut session =
        ValuationSession::new(ds.train_x.clone(), ds.train_y.clone(), ds.d, config)
            .expect("dataset shapes were validated at load time");
    session
        .ingest(&ds.test_x, &ds.test_y)
        .expect("dataset test split is shape-consistent");
    // live session index → original train index (removals shift both
    // the session's numbering and this map identically)
    let mut orig: Vec<usize> = (0..n).collect();
    let mut order = Vec::with_capacity(n);
    let floor = min_keep.max(params.k).max(2);
    while session.n() > floor {
        let vals = session
            .point_values(TopBy::RowSum)
            .expect("test points were ingested");
        let i = argmin_by_value(&vals);
        order.push(orig.remove(i));
        session
            .remove_train(i)
            .expect("the floor keeps n above k and 2");
    }
    let vals = session
        .point_values(TopBy::RowSum)
        .expect("test points were ingested");
    let mut rest: Vec<usize> = (0..session.n()).collect();
    rest.sort_by(|&a, &b| vals[a].total_cmp(&vals[b]).then(a.cmp(&b)));
    order.extend(rest.into_iter().map(|i| orig[i]));
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::removal::{removal_curve, sti_removal_order};
    use crate::data::{corrupt, load_dataset};

    #[test]
    fn iterative_removal_is_exact_at_every_step() {
        // the reroute's contract: each greedy choice must be the argmin
        // of a FROM-SCRATCH valuation of the current reduced train set —
        // simulate exactly that (recompute per step) and compare orders
        let mut ds = load_dataset("circle", 40, 12, 9).unwrap();
        corrupt::flip_labels(&mut ds, 0.15, 2);
        let params = crate::shapley::StiParams::new(4);
        let min_keep = 30;
        let fast = sti_iterative_removal_order(&ds, &params, min_keep);
        assert_eq!(fast.len(), 40, "full permutation");

        let mut keep: Vec<usize> = (0..40).collect();
        let mut slow = Vec::new();
        while keep.len() > min_keep {
            let sub = ds.retain_train(&keep);
            let pv = crate::shapley::values::sti_values(
                &sub.train_x, &sub.train_y, sub.d, &ds.test_x, &ds.test_y, &params,
            );
            let i = argmin_by_value(&pv.rowsum);
            slow.push(keep.remove(i));
        }
        assert_eq!(
            &fast[..slow.len()],
            slow.as_slice(),
            "greedy choices must match recompute-per-step exactly"
        );
    }

    #[test]
    fn iterative_first_choice_matches_static_order() {
        // before any removal the two orders see the same values, so the
        // first element must agree (ties break by index in both)
        let mut ds = load_dataset("moon", 50, 15, 3).unwrap();
        corrupt::flip_labels(&mut ds, 0.1, 7);
        let params = crate::shapley::StiParams::new(5);
        let static_order =
            sti_removal_order(&ds, &params, crate::shapley::values::Engine::Implicit);
        let iterative = sti_iterative_removal_order(&ds, &params, 20);
        assert_eq!(static_order[0], iterative[0]);
    }

    #[test]
    fn iterative_order_drives_a_removal_curve() {
        let ds = load_dataset("circle", 60, 20, 5).unwrap();
        let params = crate::shapley::StiParams::new(3);
        let order = sti_iterative_removal_order(&ds, &params, 10);
        assert_eq!(order.len(), 60);
        // a permutation: every index exactly once
        let mut seen = vec![false; 60];
        for &i in &order {
            assert!(!seen[i], "index {i} repeated");
            seen[i] = true;
        }
        let curve = removal_curve(&ds, &order, 10, 10, 3);
        assert!(curve.len() >= 2);
        assert_eq!(curve[0].0, 0);
    }
}
