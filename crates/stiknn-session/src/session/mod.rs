//! Incremental valuation sessions — the long-lived layer that turns the
//! one-shot pipeline into a service (DESIGN.md §9).
//!
//! Eq. 9 makes the interaction matrix a weighted average over test
//! points: Φ = (1/t)·Σ_τ Φ_τ. The sum is exactly additive under
//! streaming test arrivals, so a deployment never has to recompute from
//! scratch when new evaluation data lands. A [`ValuationSession`] owns
//! the UNNORMALIZED n×n accumulator plus a per-batch weight ledger,
//! ingests test batches through the existing two-phase hot path
//! ([`crate::shapley::sti_knn_accumulate`] single-threaded, or the
//! coordinator's banded prep pool via [`crate::coordinator::ingest_banded`]
//! for large batches), and answers queries against the live matrix at any
//! time — normalization happens at read time, so ingest stays O(t·n²)
//! total with no per-query rescaling of state.
//!
//! Exactness: every accumulator cell receives its per-test additions in
//! test order no matter how the stream is cut into batches, so ingesting
//! any contiguous partition of a test set — including a snapshot/restore
//! cycle mid-stream ([`store`]) — is **bit-identical** to one-shot
//! `sti_knn` (property-tested in `tests/session_equivalence.rs`).
//! Re-ordering batches changes addition order and is therefore only
//! equal up to f64 associativity (~1e-12), not bitwise.
//!
//! # Engines (DESIGN.md §10)
//!
//! Sessions run one of two engines ([`SessionConfig::with_engine`]):
//!
//! * [`Engine::Dense`] (default) — the n×n accumulator above. Supports
//!   every query, costs O(t·n²) ingest and O(n²) memory.
//! * [`Engine::Implicit`] — the rank-space suffix-sum value engine
//!   (`shapley::values`): the session holds an O(n) [`ValueVector`]
//!   instead of the matrix, ingest costs O(t·n log n), and
//!   `point_values`/`top_k`/`stats` are answered from the vector.
//!   `cell`/`row`/`matrix` need pair-level state the vector doesn't
//!   carry; with [`SessionConfig::with_retained_rows`] the session
//!   additionally keeps each test point's `(rank, colval)` row (O(t·n)
//!   memory, the caller's trade-off) and answers `cell` in O(t) /
//!   `row` in O(t·n) by reducing over retained rows on the fly —
//!   otherwise those queries return `None` and the serve protocol
//!   rejects them with reason `engine`.
//!
//! Both engines ingest the same stream additively (Eq. 9), and the
//! implicit path keeps the same bit-reproducibility contract: any
//! contiguous partition of a test stream produces identical bits.
//!
//! # Mutable sessions (DESIGN.md §11)
//!
//! With [`SessionConfig::with_mutable`] (implicit engine + retained rows
//! required) the training set becomes a live object:
//! [`ValuationSession::add_train`], [`ValuationSession::remove_train`]
//! and [`ValuationSession::relabel_train`] apply exact edits in O(t·(d + n))
//! per edit via the delta subsystem ([`crate::shapley::delta`]) instead
//! of a full O(t·(n·d + n log n)) recompute — post-edit state is
//! bit-identical to a from-scratch session over the edited train set.
//! Every edit is appended to a mutation ledger
//! ([`ValuationSession::mutations`]) that v3 snapshots persist alongside
//! the train set and the retained rows, so a mutable session restores
//! completely ([`ValuationSession::restore_mutable`]) and its training
//! set's provenance stays auditable.
//!
//! * [`store`]    — versioned, checksummed binary snapshots
//! * [`protocol`] — NDJSON command loop backing `stiknn serve`

pub mod protocol;
pub mod store;

pub use crate::shapley::delta::{MutationOp, MutationRecord};
pub use crate::shapley::values::Engine;
pub use store::{dataset_fingerprint, Snapshot, SnapshotHeader, SnapshotPayload};

use crate::coordinator::progress::Progress;
use crate::coordinator::{ingest_banded_with, ingest_values_with, repair_rows, ValuationJob};
use crate::data::Dataset;
use crate::knn::distance::Metric;
use crate::knn::kernel::NormCache;
use crate::obs::{ObsHandle, SpanCtx, TraceHandle};
use crate::shapley::delta::{self, Edit, MutableRows, RepairCtx, RetainedRows};
use crate::shapley::sti_knn::{
    prepare_batch_cached, sti_knn_accumulate, PrepScratch, StiParams, PREP_BATCH,
};
use crate::shapley::values::{sweep_values, values_accumulate, ValueVector, ValuesScratch};
use crate::util::matrix::Matrix;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

/// Ranking used by top-k point-value queries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopBy {
    /// Diagonal main terms φ_ii (Eq. 4/5) — each point's own effect.
    Main,
    /// φ_ii + Σ_{j≠i} φ_ij — main effect plus all pairwise interactions,
    /// the "total contribution including synergies" view.
    RowSum,
}

impl TopBy {
    pub fn parse(s: &str) -> Option<TopBy> {
        match s {
            "main" | "diag" => Some(TopBy::Main),
            "rowsum" | "total" => Some(TopBy::RowSum),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            TopBy::Main => "main",
            TopBy::RowSum => "rowsum",
        }
    }
}

/// Session tuning knobs (the valuation semantics are fixed by k/metric;
/// the engine fixes which queries are answerable; everything else is
/// pure performance).
#[derive(Clone, Copy, Debug)]
pub struct SessionConfig {
    pub k: usize,
    pub metric: Metric,
    /// Which state the session maintains: the n×n matrix accumulator
    /// (`Dense`, default) or the O(n) value vector (`Implicit`).
    pub engine: Engine,
    /// Implicit engine only: additionally retain each ingested test
    /// point's `(rank, colval)` row (O(t·n) memory) so `cell`/`row`
    /// queries stay answerable via an O(t) on-the-fly reduction.
    /// Ignored by the dense engine (the matrix answers those directly).
    pub retain_rows: bool,
    /// Allow live training-set edits (add/remove/relabel, DESIGN.md
    /// §11). Requires the implicit engine WITH retained rows — the
    /// repairs read and rewrite them — and additionally retains the
    /// ingested test set plus per-test sorted distances (O(t·(d + n))
    /// extra memory). Construction fails otherwise.
    pub mutable: bool,
    /// Worker threads for the parallel ingest path (prep pool + bands).
    pub workers: usize,
    /// Test points per prep block in the parallel ingest path.
    pub block_size: usize,
    /// Batches with at least this many test points go through the
    /// coordinator's banded prep pool; smaller ones take the
    /// single-threaded hot path (thread spin-up would dominate). Either
    /// path produces identical bits, so this is a pure perf knob.
    pub parallel_min: usize,
}

impl SessionConfig {
    pub fn new(k: usize) -> Self {
        SessionConfig {
            k,
            metric: Metric::SqEuclidean,
            engine: Engine::Dense,
            retain_rows: false,
            mutable: false,
            workers: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4),
            block_size: 32,
            parallel_min: 256,
        }
    }

    pub fn with_metric(mut self, metric: Metric) -> Self {
        self.metric = metric;
        self
    }

    /// Select the session engine (`Engine::Implicit` | `Engine::Dense`).
    pub fn with_engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// Implicit engine: keep per-test `(rank, colval)` rows for
    /// `cell`/`row` queries (O(t·n) memory). NOTE: retention ingest runs
    /// single-threaded — rows must append in test order, so the parallel
    /// prep pool (`workers`/`parallel_min`) is bypassed in this mode.
    pub fn with_retained_rows(mut self, retain: bool) -> Self {
        self.retain_rows = retain;
        self
    }

    /// Enable live training-set edits (DESIGN.md §11). Only valid
    /// together with `with_engine(Engine::Implicit)` AND
    /// `with_retained_rows(true)` — session construction enforces it.
    pub fn with_mutable(mut self, mutable: bool) -> Self {
        self.mutable = mutable;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn with_block_size(mut self, block: usize) -> Self {
        self.block_size = block.max(1);
        self
    }

    pub fn with_parallel_min(mut self, parallel_min: usize) -> Self {
        self.parallel_min = parallel_min.max(1);
        self
    }
}

/// One entry of the per-batch weight ledger: `seq` is the monotone batch
/// sequence number, `len` the test count the entry accounts for (its
/// Eq. 9 merge weight). The ledger is persisted in snapshots, so a
/// restored session continues its sequence instead of restarting at 0.
///
/// The ledger is COMPACTED once it exceeds [`LEDGER_COMPACT_AT`] entries
/// (oldest half folded into one record that keeps the first `seq` and
/// sums the lens), so a long-lived serve deployment ingesting millions
/// of small batches holds O(1) ledger state and snapshot overhead. After
/// compaction an entry may therefore cover MANY ingests — `seq` (not the
/// entry count) is what tracks how many batches a session has seen
/// ([`ValuationSession::batches_ingested`]), and Σ len == tests stays an
/// integrity invariant the store verifies on decode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchRecord {
    pub seq: u64,
    pub len: u64,
}

/// Ledger length that triggers compaction of the oldest half.
pub const LEDGER_COMPACT_AT: usize = 4096;

/// Summary statistics over the live (averaged) matrix.
#[derive(Clone, Copy, Debug)]
pub struct SessionStats {
    pub n: usize,
    pub k: usize,
    pub tests: u64,
    pub batches: u64,
    /// Σ φ_ii of the averaged matrix (0 while no tests are ingested).
    pub trace: f64,
    /// Mean strict-upper-triangle entry of the averaged matrix.
    pub mean_offdiag: f64,
    /// Upper triangle including the diagonal — the efficiency-axiom
    /// quantity (DESIGN.md §1).
    pub upper_sum: f64,
}

/// The engine-specific valuation state (DESIGN.md §10/§11).
/// `RetainedRows` lives in `shapley::delta` — it is rank-space state the
/// delta repairs rewrite in place.
enum EngineState {
    /// Unnormalized Σ_τ Φ_τ, upper triangle + diagonal only (exactly the
    /// layout `sweep_band` writes); mirrored + scaled at query time.
    Dense { acc: Matrix },
    /// Unnormalized per-point value sums (main + interaction rowsums),
    /// plus optionally the retained per-test rows for pair queries, plus
    /// (mutable sessions only) the test set + per-test sorted distances
    /// the delta repairs consume.
    Implicit {
        values: ValueVector,
        rows: Option<RetainedRows>,
        live: Option<MutableRows>,
    },
}

/// A long-lived incremental valuation: train set + engine state + ledger.
pub struct ValuationSession {
    train_x: Vec<f32>,
    train_y: Vec<i32>,
    d: usize,
    /// Per-train-row norm cache for the SIMD distance kernels
    /// (DESIGN.md §15). Pure performance state — every distance is
    /// bit-identical with or without it — kept in lockstep with
    /// `train_x` by `add_train`/`remove_train` and rebuilt (never
    /// serialized) on construction and restore.
    norms: NormCache,
    config: SessionConfig,
    state: EngineState,
    ledger: Vec<BatchRecord>,
    mutations: Vec<MutationRecord>,
    tests_seen: u64,
    /// Train-set fingerprint, LAZY: edits invalidate it (`None`) instead
    /// of paying an O(n·d) rehash per edit — it is only consumed by
    /// snapshot save/restore, never by the edit/query hot paths.
    fingerprint: Option<u64>,
    /// Monotone count of state-changing operations (non-empty ingests +
    /// edits) — the serialization handle of the concurrent server layer
    /// (DESIGN.md §12): every mutating protocol response reports it, so
    /// clients can totally order the writes a session actually applied.
    /// In-memory only; restores start at 0 unless the owner re-seeds it
    /// ([`Self::set_revision`], which the server registry uses to keep
    /// the count monotone across an LRU spill/reload cycle).
    revision: u64,
    /// Telemetry handle (DESIGN.md §14). Disabled by default — every
    /// hook is then a no-op, so results are bit-identical with metrics
    /// on or off (`tests/obs_invariants.rs`). Never serialized.
    obs: ObsHandle,
    /// Tracing handle (DESIGN.md §16). Same zero-overhead contract as
    /// `obs`: disabled by default, and a disabled handle never reads the
    /// clock or touches the span store. Never serialized.
    trace: TraceHandle,
    /// The enclosing request span, if any — set by the protocol/server
    /// layer around a dispatched command so the session's ingest/edit
    /// spans (and the synthesized coordinator phase spans) parent under
    /// the command's span instead of starting parallel roots.
    trace_scope: Option<SpanCtx>,
}

impl ValuationSession {
    /// Fresh session over an owned train set. Fails on shape mismatches
    /// or a k outside Algorithm 1's exact domain 1 ≤ k ≤ n.
    pub fn new(
        train_x: Vec<f32>,
        train_y: Vec<i32>,
        d: usize,
        config: SessionConfig,
    ) -> Result<Self> {
        let n = train_y.len();
        ensure!(n >= 2, "need at least 2 training points for interactions");
        ensure!(d >= 1, "need at least 1 feature dimension");
        ensure!(
            train_x.len() == n * d,
            "train shape mismatch: {} features for {} points (d={d})",
            train_x.len(),
            n
        );
        ensure!(
            config.k >= 1 && config.k <= n,
            "STI-KNN is exact only for 1 <= k <= n (k={}, n={n})",
            config.k
        );
        ensure!(
            !config.mutable || (config.engine == Engine::Implicit && config.retain_rows),
            "a mutable session requires the implicit engine with retained rows \
             (with_engine(Engine::Implicit).with_retained_rows(true)) — the delta \
             repairs read and rewrite the per-test rank-space rows"
        );
        let fingerprint = dataset_fingerprint(&train_x, &train_y, d);
        let norms = NormCache::build(&train_x, d, config.metric);
        let state = match config.engine {
            Engine::Dense => EngineState::Dense {
                acc: Matrix::zeros(n, n),
            },
            Engine::Implicit => EngineState::Implicit {
                values: ValueVector::zeros(n),
                rows: config.retain_rows.then(|| RetainedRows::new(n)),
                live: config.mutable.then(|| MutableRows::new(n, d)),
            },
        };
        Ok(ValuationSession {
            train_x,
            train_y,
            d,
            norms,
            config,
            state,
            ledger: Vec::new(),
            mutations: Vec::new(),
            tests_seen: 0,
            fingerprint: Some(fingerprint),
            revision: 0,
            obs: ObsHandle::disabled(),
            trace: TraceHandle::disabled(),
            trace_scope: None,
        })
    }

    /// Fresh session over a registry dataset's train part.
    pub fn from_dataset(ds: &Dataset, config: SessionConfig) -> Result<Self> {
        Self::new(ds.train_x.clone(), ds.train_y.clone(), ds.d, config)
    }

    /// Resume from a snapshot. The caller supplies the SAME train set the
    /// snapshot was taken against (sessions don't persist training data);
    /// k, metric, n, d and the train-set fingerprint are all verified, so
    /// a mismatched resume fails loudly instead of silently producing
    /// wrong values.
    ///
    /// Engine compatibility: a dense snapshot restores into a dense
    /// session bit-exactly, and into an implicit session by DERIVING the
    /// value vector from the stored accumulator (the dense→implicit
    /// migration path — subsequent results agree with a pure-implicit
    /// history to ≤ 1e-12, not bitwise). An implicit snapshot carries no
    /// pair-level state, so restoring it into a dense session is refused,
    /// as is restoring any non-empty snapshot with `retain_rows` set
    /// (per-test rows are in-memory only and cannot be reconstructed).
    pub fn restore(
        path: &Path,
        train_x: Vec<f32>,
        train_y: Vec<i32>,
        d: usize,
        config: SessionConfig,
    ) -> Result<Self> {
        let snap = store::read_snapshot(path)?;
        // Redirect mutable snapshots BEFORE any train-set comparison: a
        // mutable session's train set has been edited, so it legitimately
        // matches no external dataset and every later check would fire
        // with a misleading message.
        if matches!(snap.payload, SnapshotPayload::Mutable(_)) {
            bail!(
                "snapshot at {} was taken by a MUTABLE session (it carries its own \
                 train set, retained rows and mutation ledger); restore it with \
                 ValuationSession::restore_mutable / `serve --mutable --restore`",
                path.display()
            );
        }
        // The converse is refused too: an immutable snapshot carries no
        // retained rows or test set, so a mutable session restored from
        // it would hold tests_seen > 0 with ZERO repairable rows — the
        // first edit would silently zero every restored value.
        ensure!(
            !config.mutable,
            "cannot restore a non-mutable snapshot into a mutable session: \
             per-test rows and the test set are only persisted by v3 mutable \
             snapshots (save from a --mutable session, or start fresh)"
        );
        let mut session = Self::new(train_x, train_y, d, config)?;
        let h = &snap.header;
        ensure!(
            h.k as usize == session.config.k,
            "snapshot was taken with k={} but the session is configured with k={}",
            h.k,
            session.config.k
        );
        ensure!(
            h.metric == session.config.metric,
            "snapshot metric {:?} != session metric {:?}",
            h.metric,
            session.config.metric
        );
        ensure!(
            h.n as usize == session.n() && h.d as usize == session.d,
            "snapshot train shape (n={}, d={}) != session train shape (n={}, d={})",
            h.n,
            h.d,
            session.n(),
            session.d
        );
        ensure!(
            h.fingerprint == session.fingerprint(),
            "snapshot fingerprint {:016x} != train-set fingerprint {:016x}: \
             the snapshot was taken against different training data",
            h.fingerprint,
            session.fingerprint()
        );
        if session.config.engine == Engine::Implicit && session.config.retain_rows && h.tests > 0 {
            bail!(
                "cannot restore a non-empty snapshot ({} tests) with retain_rows: \
                 per-test (rank, colval) rows are not persisted, so cell/row \
                 answers over the restored history would be incomplete",
                h.tests
            );
        }
        let (n, d) = (session.n(), session.d);
        session.state = match (snap.payload, session.config.engine) {
            (SnapshotPayload::Dense(raw), Engine::Dense) => EngineState::Dense { acc: raw },
            (SnapshotPayload::Dense(raw), Engine::Implicit) => EngineState::Implicit {
                values: ValueVector::from_raw_accumulator(&raw),
                rows: session.config.retain_rows.then(|| RetainedRows::new(n)),
                live: session.config.mutable.then(|| MutableRows::new(n, d)),
            },
            (SnapshotPayload::Implicit { main, inter }, Engine::Implicit) => {
                EngineState::Implicit {
                    values: ValueVector::from_raw_parts(main, inter),
                    rows: session.config.retain_rows.then(|| RetainedRows::new(n)),
                    live: session.config.mutable.then(|| MutableRows::new(n, d)),
                }
            }
            (SnapshotPayload::Implicit { .. }, Engine::Dense) => bail!(
                "snapshot was taken by an implicit-engine session (value vector only) \
                 and cannot populate a dense matrix session; restore with \
                 SessionConfig::with_engine(Engine::Implicit) / --engine implicit"
            ),
            (SnapshotPayload::Mutable(_), _) => {
                unreachable!("mutable payloads are redirected before the engine match")
            }
        };
        session.tests_seen = h.tests;
        session.ledger = snap.ledger;
        Ok(session)
    }

    /// Resume a MUTABLE session from a v3 mutable snapshot. Unlike
    /// [`Self::restore`], no training data is supplied: the edited train
    /// set lives IN the snapshot (the whole point of mutability is that
    /// it no longer matches any external dataset), along with the
    /// retained rows, per-test distances, test set, batch ledger and
    /// mutation ledger — the restored session is bit-identical to the
    /// one that saved it, ready for further queries, ingests and edits.
    /// k, metric and the train-set fingerprint are verified against the
    /// header; `config` must have `mutable` set (engine/retained-rows
    /// requirements follow from that).
    pub fn restore_mutable(path: &Path, config: SessionConfig) -> Result<Self> {
        ensure!(
            config.mutable && config.engine == Engine::Implicit && config.retain_rows,
            "restore_mutable needs a mutable session config \
             (with_engine(Engine::Implicit).with_retained_rows(true).with_mutable(true))"
        );
        let snap = store::read_snapshot(path)?;
        let h = snap.header;
        let SnapshotPayload::Mutable(payload) = snap.payload else {
            bail!(
                "snapshot at {} is not a mutable-session snapshot (payload kind \
                 '{}'); restore it with ValuationSession::restore and the matching \
                 train set instead",
                path.display(),
                h.engine.label()
            );
        };
        ensure!(
            h.k as usize == config.k,
            "snapshot was taken with k={} but the session is configured with k={}",
            h.k,
            config.k
        );
        ensure!(
            h.metric == config.metric,
            "snapshot metric {:?} != session metric {:?}",
            h.metric,
            config.metric
        );
        let store::MutablePayload {
            main,
            inter,
            train_x,
            train_y,
            test_x,
            test_y,
            rank,
            colval,
            dist,
            pos,
        } = *payload;
        let (n, d) = (h.n as usize, h.d as usize);
        let tests = h.tests as usize;
        ensure!(n >= 2, "mutable snapshot has n={n} (< 2) train points");
        ensure!(d >= 1, "mutable snapshot has d=0");
        // Both bounds of Algorithm 1's exact domain: this constructor
        // bypasses Self::new, so k >= 1 must be re-checked here — a
        // crafted k=0 snapshot would otherwise divide by zero (1/k) on
        // the next ingest or edit.
        ensure!(
            config.k >= 1 && config.k <= n,
            "snapshot train set has n={n} but the session is configured with k={} \
             (STI-KNN is exact only for 1 <= k <= n)",
            config.k
        );
        let fingerprint = dataset_fingerprint(&train_x, &train_y, d);
        ensure!(
            fingerprint == h.fingerprint,
            "snapshot fingerprint {:016x} != fingerprint {:016x} recomputed from \
             its own train payload: the snapshot is internally inconsistent",
            h.fingerprint,
            fingerprint
        );
        // The checksum is FNV, not a MAC, and the repair kernels index
        // train arrays by these rows without bounds checks beyond slice
        // panics — a crafted or bit-rotted snapshot must fail HERE with
        // an error, not panic a live serve on its first edit. Per test
        // row: pos must be a permutation of 0..n, rank its inverse, and
        // the distances sorted ascending (also rejects NaN, which would
        // break the insert binary search).
        let mut seen = vec![false; n];
        for p in 0..tests {
            let pos_row = &pos[p * n..(p + 1) * n];
            let rank_row = &rank[p * n..(p + 1) * n];
            let dist_row = &dist[p * n..(p + 1) * n];
            seen.iter_mut().for_each(|s| *s = false);
            for (r, &orig) in pos_row.iter().enumerate() {
                let orig = orig as usize;
                ensure!(
                    orig < n && !seen[orig] && rank_row[orig] as usize == r,
                    "mutable snapshot row {p} is corrupt: pos/rank are not \
                     inverse permutations of 0..{n}"
                );
                seen[orig] = true;
                ensure!(
                    r == 0 || dist_row[r - 1] <= dist_row[r],
                    "mutable snapshot row {p} is corrupt: distances are not \
                     sorted ascending at rank {r}"
                );
            }
        }
        let rows = RetainedRows {
            n,
            tests,
            rank,
            colval,
        };
        let live = MutableRows {
            d,
            n,
            tests,
            test_x,
            test_y,
            dist,
            pos,
        };
        let norms = NormCache::build(&train_x, d, config.metric);
        Ok(ValuationSession {
            train_x,
            train_y,
            d,
            norms,
            config,
            state: EngineState::Implicit {
                values: ValueVector::from_raw_parts(main, inter),
                rows: Some(rows),
                live: Some(live),
            },
            ledger: snap.ledger,
            mutations: snap.mutations,
            tests_seen: h.tests,
            fingerprint: Some(fingerprint),
            revision: 0,
            obs: ObsHandle::disabled(),
            trace: TraceHandle::disabled(),
            trace_scope: None,
        })
    }

    // -- identity ------------------------------------------------------

    pub fn n(&self) -> usize {
        self.train_y.len()
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn k(&self) -> usize {
        self.config.k
    }

    pub fn tests_seen(&self) -> u64 {
        self.tests_seen
    }

    pub fn ledger(&self) -> &[BatchRecord] {
        &self.ledger
    }

    /// Total ingest calls over the session's lifetime (including before
    /// a restore). Derived from the monotone batch sequence, so it
    /// survives ledger compaction — `ledger().len()` does not.
    pub fn batches_ingested(&self) -> u64 {
        self.ledger.last().map(|b| b.seq + 1).unwrap_or(0)
    }

    /// The train-set fingerprint (see [`dataset_fingerprint`]). After an
    /// edit this recomputes on demand (O(n·d)) — edits only invalidate
    /// it, so the O(t·(d + n)) per-edit bound stays honest.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
            .unwrap_or_else(|| dataset_fingerprint(&self.train_x, &self.train_y, self.d))
    }

    /// Which engine this session runs (fixed at construction).
    pub fn engine(&self) -> Engine {
        self.config.engine
    }

    /// Whether live training-set edits are enabled (DESIGN.md §11).
    pub fn is_mutable(&self) -> bool {
        self.config.mutable
    }

    /// The mutation ledger: every edit applied over the session's
    /// lifetime (including before a [`Self::restore_mutable`]), in
    /// order, with as-of-edit-time indices. Empty for immutable
    /// sessions.
    pub fn mutations(&self) -> &[MutationRecord] {
        &self.mutations
    }

    /// Monotone per-session write counter: bumps by exactly 1 on every
    /// applied state change (non-empty ingest, add/remove/relabel) and
    /// never on reads or failed commands. Two observations with equal
    /// revisions saw identical state; sorting a session's write commands
    /// by the revision each response reported reproduces the exact
    /// serialization order the session applied them in.
    pub fn revision(&self) -> u64 {
        self.revision
    }

    /// Re-seed the write counter — used by the server registry after an
    /// LRU spill/reload so revisions stay monotone across the cycle
    /// (snapshots do not persist the counter).
    pub(crate) fn set_revision(&mut self, revision: u64) {
        self.revision = revision;
    }

    /// Attach a telemetry handle (DESIGN.md §14): ingest/edit timings
    /// and the coordinator's `coord.*` roll-up start landing in its
    /// registry. Sessions start with a disabled handle, and the hooks
    /// never influence results either way (`tests/obs_invariants.rs`).
    pub fn set_obs(&mut self, obs: ObsHandle) {
        self.obs = obs;
    }

    /// The session's telemetry handle (disabled unless [`Self::set_obs`]
    /// was called — e.g. by `serve` with observability on).
    pub fn obs(&self) -> &ObsHandle {
        &self.obs
    }

    /// Attach a tracing handle (DESIGN.md §16): ingest/edit spans and
    /// the synthesized coordinator phase spans start recording into its
    /// span store. Disabled by default, same zero-overhead contract as
    /// [`Self::set_obs`].
    pub fn set_trace(&mut self, trace: TraceHandle) {
        self.trace = trace;
    }

    /// The session's tracing handle.
    pub fn trace(&self) -> &TraceHandle {
        &self.trace
    }

    /// Set (or clear) the enclosing request span the next operations
    /// should parent under. The protocol/server layer brackets each
    /// dispatched command with this; it is NOT cleared automatically.
    pub fn set_trace_scope(&mut self, scope: Option<SpanCtx>) {
        self.trace_scope = scope;
    }

    /// Current training labels (live view — edits change it).
    pub fn train_labels(&self) -> &[i32] {
        &self.train_y
    }

    /// Current features of train point `i` (length d). Panics if out of
    /// range.
    pub fn train_row(&self, i: usize) -> &[f32] {
        &self.train_x[i * self.d..(i + 1) * self.d]
    }

    /// Whether this session retains per-test rows (implicit engine only).
    pub fn retains_rows(&self) -> bool {
        matches!(&self.state, EngineState::Implicit { rows: Some(_), .. })
    }

    /// Can `cell`/`row` queries be answered? Dense sessions always can;
    /// implicit sessions only with retained rows. The serve protocol uses
    /// this to reject matrix queries with reason `engine` instead of
    /// conflating them with the empty-session case.
    pub fn supports_matrix_queries(&self) -> bool {
        match &self.state {
            EngineState::Dense { .. } => true,
            EngineState::Implicit { rows, .. } => rows.is_some(),
        }
    }

    // -- ingest --------------------------------------------------------

    /// Ingest one test batch (flattened row-major features + labels) and
    /// return its test count. Empty batches are a no-op. Batches of at
    /// least `config.parallel_min` points run through the coordinator's
    /// parallel prep pool (banded for the dense engine, value-sharded for
    /// the implicit one); every path appends the same additions in the
    /// same order, so the routing never changes a single bit of the
    /// state.
    pub fn ingest(&mut self, test_x: &[f32], test_y: &[i32]) -> Result<usize> {
        ensure!(
            test_x.len() == test_y.len() * self.d,
            "test batch shape mismatch: {} features for {} labels (d={})",
            test_x.len(),
            test_y.len(),
            self.d
        );
        if test_y.is_empty() {
            return Ok(0);
        }
        // Owned timer (no borrow of self): records into
        // `session.ingest_ns` when it drops at function exit.
        let _ingest_timer = self.obs.timer("session.ingest_ns");
        // Request-scoped span (DESIGN.md §16): a child of the enclosing
        // command span when the protocol layer set one, else a
        // (sampling-gated) root for directly-driven sessions. With
        // tracing off this is a no-op that never reads the clock.
        let mut ingest_span = self.trace.span_under(self.trace_scope, "session.ingest");
        if ingest_span.is_recording() {
            ingest_span.field("engine", self.config.engine.label());
            ingest_span.field("points", test_y.len().to_string());
        }
        let params = StiParams {
            k: self.config.k,
            metric: self.config.metric,
        };
        let parallel = test_y.len() >= self.config.parallel_min;
        let mut job = ValuationJob::new(self.config.k)
            .with_workers(self.config.workers)
            .with_block_size(self.config.block_size);
        job.metric = self.config.metric;
        // Coordinator roll-up sinks resolved once per batch; disabled
        // obs makes this a plain job-local Progress.
        let progress = Progress::with_obs(&self.obs);
        match &mut self.state {
            EngineState::Dense { acc } => {
                if parallel {
                    ingest_banded_with(
                        &self.train_x,
                        &self.train_y,
                        self.d,
                        test_x,
                        test_y,
                        &job,
                        acc,
                        &progress,
                    )?;
                } else {
                    sti_knn_accumulate(
                        &self.train_x,
                        &self.train_y,
                        self.d,
                        test_x,
                        test_y,
                        &params,
                        acc,
                    );
                }
            }
            EngineState::Implicit { values, rows, live } => {
                match rows {
                    // Mutable sessions additionally retain the test set
                    // and per-test sorted distances; the delta ingest
                    // computes distances + argsort once per test and is
                    // bit-identical to the plain retained path
                    // (tests/delta_equivalence.rs).
                    Some(retained) if live.is_some() => {
                        delta::ingest_rows(
                            &self.train_x,
                            &self.train_y,
                            self.d,
                            test_x,
                            test_y,
                            &params,
                            &self.norms,
                            retained,
                            live.as_mut().expect("checked by the guard"),
                            values,
                        );
                    }
                    // Retention needs every prepared row, so it runs its
                    // own chunk loop (prep scratch reused across chunks);
                    // bit-identical to the other paths — same per-test
                    // math, same per-element addition order.
                    Some(retained) => {
                        let mut prep = PrepScratch::new();
                        let mut scratch = ValuesScratch::new();
                        for (chunk_x, chunk_y) in test_x
                            .chunks(PREP_BATCH * self.d)
                            .zip(test_y.chunks(PREP_BATCH))
                        {
                            let batch = prepare_batch_cached(
                                &self.train_x,
                                &self.train_y,
                                self.d,
                                chunk_x,
                                chunk_y,
                                &params,
                                &self.norms,
                                &mut prep,
                            );
                            sweep_values(&batch, &self.train_y, values, &mut scratch);
                            retained.append_batch(&batch);
                        }
                    }
                    None if parallel => {
                        ingest_values_with(
                            &self.train_x,
                            &self.train_y,
                            self.d,
                            test_x,
                            test_y,
                            &job,
                            values,
                            &progress,
                        )?;
                    }
                    None => {
                        values_accumulate(
                            &self.train_x,
                            &self.train_y,
                            self.d,
                            test_x,
                            test_y,
                            &params,
                            values,
                        );
                    }
                }
            }
        }
        // Coordinator phase spans, synthesized from the Progress roll-up
        // the parallel pipeline already keeps — threading a live span
        // through the worker pool would put trace plumbing on the hot
        // path. Busy time sums across workers, so a phase can "outlast"
        // the batch's wall time; the renderer clamps self-time at zero.
        if let Some(ctx) = ingest_span.ctx() {
            let prep_ns = progress.prep_ns();
            if prep_ns > 0 {
                let prep_id =
                    self.trace
                        .record_synth(ctx.trace_id, ctx.span_id, "coord.prep", prep_ns, &[]);
                let kernel_ns = progress.kernel_ns();
                if kernel_ns > 0 {
                    self.trace.record_synth(
                        ctx.trace_id,
                        prep_id,
                        "coord.prep.kernel",
                        kernel_ns,
                        &[],
                    );
                }
            }
            let sweep_ns = progress.sweep_ns();
            if sweep_ns > 0 {
                let phase = match self.config.engine {
                    Engine::Dense => "coord.sweep",
                    Engine::Implicit => "coord.fold",
                };
                self.trace
                    .record_synth(ctx.trace_id, ctx.span_id, phase, sweep_ns, &[]);
            }
        }
        let seq = self.ledger.last().map(|b| b.seq + 1).unwrap_or(0);
        self.ledger.push(BatchRecord {
            seq,
            len: test_y.len() as u64,
        });
        if self.ledger.len() >= LEDGER_COMPACT_AT {
            // Fold the oldest half into one record (first seq, summed
            // lens): bounds ledger memory and snapshot size for
            // long-lived sessions while preserving Σ len == tests and
            // the monotone seq that batches_ingested() derives from.
            let half = self.ledger.len() / 2;
            let merged = BatchRecord {
                seq: self.ledger[0].seq,
                len: self.ledger[..half].iter().map(|b| b.len).sum(),
            };
            self.ledger.splice(..half, [merged]);
        }
        self.tests_seen += test_y.len() as u64;
        self.revision += 1;
        self.obs.inc("session.ingest_batches");
        self.obs.add("session.ingest_points", test_y.len() as u64);
        Ok(test_y.len())
    }

    // -- live training-set edits (DESIGN.md §11) -----------------------

    /// Append a train point (features of length d, any i32 label) and
    /// return its id (= the previous n; ids of existing points never
    /// change on add). O(t·(d + n)): per retained test, one O(d)
    /// distance, one O(log n) binary search, one O(n) rank-shift +
    /// superdiagonal repair, then one O(t·n) value refold — the
    /// post-edit state is bit-identical to a from-scratch session over
    /// the extended train set (`tests/delta_equivalence.rs`). Mutable
    /// sessions only.
    pub fn add_train(&mut self, x: &[f32], y: i32) -> Result<usize> {
        self.ensure_mutable("add_train")?;
        ensure!(
            x.len() == self.d,
            "new train point has {} features but the session's d is {}",
            x.len(),
            self.d
        );
        ensure!(
            x.iter().all(|v| v.is_finite()),
            "new train point features must be finite (distances to a non-finite \
             point would poison every ranking)"
        );
        let old_n = self.n();
        self.train_x.extend_from_slice(x);
        self.train_y.push(y);
        self.norms.push_row(x);
        let record = MutationRecord {
            seq: self.next_mutation_seq(),
            op: MutationOp::Add,
            index: old_n as u64,
            label: y,
        };
        self.repair_after_edit(Edit::Add { x, y }, old_n, record);
        Ok(old_n)
    }

    /// Remove train point `index`; indices above it shift down by one
    /// (order is preserved — that is what keeps the stable
    /// distance-then-index ranking of the survivors, and therefore the
    /// repair, exact). Fails if the session is immutable, the index is
    /// out of range, or removal would shrink n below k (or below 2) —
    /// Algorithm 1's closed forms are only exact for 1 ≤ k ≤ n.
    pub fn remove_train(&mut self, index: usize) -> Result<()> {
        self.ensure_mutable("remove_train")?;
        let old_n = self.n();
        ensure!(
            index < old_n,
            "remove_train index {index} out of range (n={old_n})"
        );
        ensure!(
            old_n - 1 >= 2,
            "cannot remove train point {index}: a session needs at least 2 \
             training points for interactions"
        );
        ensure!(
            old_n - 1 >= self.config.k,
            "cannot remove train point {index}: n would shrink to {} below k={} \
             (STI-KNN is exact only for k <= n; drop k first or keep the point)",
            old_n - 1,
            self.config.k
        );
        self.train_x.drain(index * self.d..(index + 1) * self.d);
        self.train_y.remove(index);
        self.norms.remove_row(index);
        let record = MutationRecord {
            seq: self.next_mutation_seq(),
            op: MutationOp::Remove,
            index: index as u64,
            label: 0,
        };
        self.repair_after_edit(Edit::Remove { index }, old_n, record);
        Ok(())
    }

    /// Change train point `index`'s label. The cheapest edit: rankings
    /// are untouched, only the per-test superdiagonals and the value
    /// refold run (O(t·n) total). Mutable sessions only.
    pub fn relabel_train(&mut self, index: usize, y: i32) -> Result<()> {
        self.ensure_mutable("relabel_train")?;
        let old_n = self.n();
        ensure!(
            index < old_n,
            "relabel_train index {index} out of range (n={old_n})"
        );
        self.train_y[index] = y;
        let record = MutationRecord {
            seq: self.next_mutation_seq(),
            op: MutationOp::Relabel,
            index: index as u64,
            label: y,
        };
        self.repair_after_edit(Edit::Relabel { index, y }, old_n, record);
        Ok(())
    }

    fn ensure_mutable(&self, what: &str) -> Result<()> {
        ensure!(
            self.config.mutable,
            "{what} requires a mutable session \
             (SessionConfig::with_mutable(true) / serve --mutable)"
        );
        Ok(())
    }

    fn next_mutation_seq(&self) -> u64 {
        self.mutations.last().map(|m| m.seq + 1).unwrap_or(0)
    }

    /// The shared edit tail: repair every retained test row (fanned out
    /// across workers for large sessions — bit-identical to
    /// single-threaded, `coordinator::repair_rows`), refold the value
    /// vector in test order, refresh the train-set fingerprint, and
    /// append the ledger record. Called AFTER `train_x`/`train_y` hold
    /// the post-edit data.
    fn repair_after_edit(&mut self, edit: Edit<'_>, old_n: usize, record: MutationRecord) {
        let _edit_timer = self.obs.timer("session.edit_ns");
        self.obs.inc("session.edits");
        let mut edit_span = self.trace.span_under(self.trace_scope, "session.edit");
        if edit_span.is_recording() {
            edit_span.field("op", record.op.label());
        }
        let new_n = self.train_y.len();
        let EngineState::Implicit { values, rows, live } = &mut self.state else {
            unreachable!("mutable sessions are always implicit (enforced at construction)");
        };
        let rows = rows.as_mut().expect("mutable sessions retain rows");
        let live = live.as_mut().expect("mutable sessions retain live state");
        let workers = if live.tests >= self.config.parallel_min {
            self.config.workers
        } else {
            1
        };
        let ctx = RepairCtx {
            k: self.config.k,
            metric: self.config.metric,
            d: self.d,
            old_n,
            new_n,
            train_y: &self.train_y,
            test_x: &live.test_x,
            test_y: &live.test_y,
        };
        let repaired = repair_rows(&ctx, &edit, live.tests, &live.dist, &live.pos, workers);
        live.dist = repaired.dist;
        live.pos = repaired.pos;
        live.n = new_n;
        rows.rank = repaired.rank;
        rows.colval = repaired.colval;
        rows.n = new_n;
        *values = delta::refold_values(rows, &self.train_y, &live.test_y, self.config.k);
        // Invalidate rather than rehash: recomputing the fingerprint here
        // would be O(n·d) per edit — the factor the delta path deletes.
        self.fingerprint = None;
        self.mutations.push(record);
        self.revision += 1;
    }

    // -- queries (all normalize at read time) --------------------------

    /// 1/t — the read-time normalization factor. `None` while empty.
    fn inv_weight(&self) -> Option<f64> {
        if self.tests_seen == 0 {
            None
        } else {
            Some(1.0 / self.tests_seen as f64)
        }
    }

    /// Averaged φ_ij (symmetric — (i,j) and (j,i) agree). `None` while
    /// the session is empty, an index is out of range, or the implicit
    /// engine runs without retained rows (pair-level state doesn't exist;
    /// [`Self::supports_matrix_queries`] distinguishes that case). The
    /// diagonal φ_ii is always answerable — it IS a per-point value.
    pub fn cell(&self, i: usize, j: usize) -> Option<f64> {
        let inv_w = self.inv_weight()?;
        Some(self.raw_cell(i, j)? * inv_w)
    }

    /// UNNORMALIZED Σ_τ φ_ij(τ) over this session's ingested tests — the
    /// shard-merge primitive (DESIGN.md §13): Eq. 8 makes the test-set
    /// sum additive across shards, so a coordinator folds these raw sums
    /// and normalizes ONCE by the total test count. Same answerability as
    /// [`Self::cell`], except an EMPTY session answers 0.0 (an exact
    /// additive identity — a zero-test shard contributes nothing).
    pub fn raw_cell(&self, i: usize, j: usize) -> Option<f64> {
        if i >= self.n() || j >= self.n() {
            return None;
        }
        match &self.state {
            EngineState::Dense { acc } => {
                let (lo, hi) = if i <= j { (i, j) } else { (j, i) };
                Some(acc.get(lo, hi))
            }
            EngineState::Implicit { values, .. } if i == j => Some(values.main_raw()[i]),
            EngineState::Implicit { rows, .. } => rows.as_ref().map(|r| r.pair_sum(i, j)),
        }
    }

    /// Averaged row i of the symmetric matrix (diagonal included).
    /// Implicit sessions answer this only with retained rows (an O(t·n)
    /// reduction); otherwise `None`.
    pub fn row(&self, i: usize) -> Option<Vec<f64>> {
        let inv_w = self.inv_weight()?;
        let mut out = self.raw_row(i)?;
        for v in &mut out {
            *v *= inv_w;
        }
        Some(out)
    }

    /// Unnormalized row i — the shard-merge primitive behind
    /// [`Self::row`] (see [`Self::raw_cell`] for the contract; an empty
    /// session answers all zeros).
    pub fn raw_row(&self, i: usize) -> Option<Vec<f64>> {
        let n = self.n();
        if i >= n {
            return None;
        }
        match &self.state {
            EngineState::Dense { acc } => Some(
                (0..n)
                    .map(|j| {
                        let (lo, hi) = if i <= j { (i, j) } else { (j, i) };
                        acc.get(lo, hi)
                    })
                    .collect(),
            ),
            EngineState::Implicit { values, rows, .. } => {
                let retained = rows.as_ref()?;
                let mut out = vec![0.0f64; n];
                for p in 0..retained.tests {
                    let rank = retained.rank_row(p);
                    let colval = retained.colval_row(p);
                    let ri = rank[i];
                    let ci = colval[i];
                    for (j, slot) in out.iter_mut().enumerate() {
                        *slot += if rank[j] < ri { ci } else { colval[j] };
                    }
                }
                // the j == i lane above added colval[i] per test, which is
                // meaningless — the diagonal is the main-term sum
                out[i] = values.main_raw()[i];
                Some(out)
            }
        }
    }

    /// The full averaged interaction matrix — exactly what one-shot
    /// `sti_knn` over every ingested test point would return, to the bit
    /// (same accumulator, same mirror-then-scale finalization). Dense
    /// engine only: implicit sessions never materialize it (`None`).
    pub fn matrix(&self) -> Option<Matrix> {
        let inv_w = self.inv_weight()?;
        match &self.state {
            EngineState::Dense { acc } => {
                let mut m = acc.clone();
                m.mirror_upper_to_lower();
                m.scale(inv_w);
                Some(m)
            }
            EngineState::Implicit { .. } => None,
        }
    }

    /// Per-point values under the given ranking — answered from the O(n)
    /// value vector in implicit mode, from the accumulator in dense mode
    /// (both agree to ≤ 1e-12; `tests/values_equivalence.rs`).
    pub fn point_values(&self, by: TopBy) -> Option<Vec<f64>> {
        let inv_w = self.inv_weight()?;
        Some(match &self.state {
            EngineState::Dense { acc } => point_values_raw(acc, inv_w, by),
            EngineState::Implicit { values, .. } => match by {
                TopBy::Main => values.main_values(inv_w),
                TopBy::RowSum => values.rowsum_values(inv_w),
            },
        })
    }

    /// One point's (main, rowsum) pair — O(1)/O(n) instead of building
    /// the full vectors (the dense RowSum vector costs an O(n²) matrix
    /// reduction). Bit-identical to the corresponding entries of
    /// [`Self::point_values`] (same expressions, same order). This is
    /// what the protocol's single-point `values` query reads.
    pub fn point_value_at(&self, i: usize) -> Option<(f64, f64)> {
        let inv_w = self.inv_weight()?;
        if i >= self.n() {
            return None;
        }
        Some(match &self.state {
            EngineState::Dense { acc } => (
                acc.get(i, i) * inv_w,
                acc.sym_row_sum_from_upper(i) * inv_w,
            ),
            EngineState::Implicit { values, .. } => (
                values.main_raw()[i] * inv_w,
                (values.main_raw()[i] + values.inter_raw()[i]) * inv_w,
            ),
        })
    }

    /// UNNORMALIZED per-point sums `(main_i, rowsum_i)` over this
    /// session's ingested tests — the shard-merge primitive behind
    /// [`Self::point_values`] (DESIGN.md §13). Eq. 8 additivity: the
    /// element-wise sum of these vectors across shards equals the raw
    /// sums of one session that ingested every shard's tests, so a
    /// coordinator folds them in shard order and normalizes once by the
    /// total test count. Always answerable — an empty session returns
    /// all zeros (the exact additive identity), which is what lets a
    /// zero-test shard participate in a merge.
    pub fn raw_point_sums(&self) -> (Vec<f64>, Vec<f64>) {
        let n = self.n();
        match &self.state {
            EngineState::Dense { acc } => (
                (0..n).map(|i| acc.get(i, i)).collect(),
                (0..n).map(|i| acc.sym_row_sum_from_upper(i)).collect(),
            ),
            EngineState::Implicit { values, .. } => (
                values.main_raw().to_vec(),
                (0..n)
                    .map(|i| values.main_raw()[i] + values.inter_raw()[i])
                    .collect(),
            ),
        }
    }

    /// Top-k (index, value), descending; ties break by index.
    pub fn top_k(&self, k: usize, by: TopBy) -> Option<Vec<(usize, f64)>> {
        Some(top_k_of(&self.point_values(by)?, k))
    }

    /// Summary statistics (zeros while the session is empty). Dense: one
    /// O(n²) triangle walk + one O(n) diagonal pass. Implicit: two O(n)
    /// passes — Σ_i inter_i double-counts each unordered pair, so the
    /// strict-upper sum is Σ_i inter_i / 2.
    pub fn stats(&self) -> SessionStats {
        let n = self.n();
        let inv_w = self.inv_weight().unwrap_or(0.0);
        let pairs = (n * (n - 1) / 2) as f64;
        // (trace, strict upper, upper incl. diagonal), all unnormalized
        let (trace_raw, strict_upper_raw, upper_raw) = match &self.state {
            EngineState::Dense { acc } => {
                let upper = acc.upper_triangle_sum();
                let trace: f64 = acc.diagonal().iter().sum();
                (trace, upper - trace, upper)
            }
            EngineState::Implicit { values, .. } => {
                let trace: f64 = values.main_raw().iter().sum();
                let half_inter: f64 = values.inter_raw().iter().sum::<f64>() / 2.0;
                (trace, half_inter, trace + half_inter)
            }
        };
        SessionStats {
            n,
            k: self.config.k,
            tests: self.tests_seen,
            batches: self.batches_ingested(),
            trace: trace_raw * inv_w,
            mean_offdiag: if pairs > 0.0 {
                strict_upper_raw * inv_w / pairs
            } else {
                0.0
            },
            upper_sum: upper_raw * inv_w,
        }
    }

    // -- persistence ---------------------------------------------------

    /// Write a snapshot (see [`store`] for the format — dense sessions
    /// persist the raw accumulator, immutable implicit sessions the O(n)
    /// value vector with retained rows deliberately NOT persisted;
    /// MUTABLE sessions persist everything needed to resume edits: the
    /// live train set, the test set, retained + distance rows, and the
    /// mutation ledger). Returns the byte count written.
    ///
    /// The write is atomic-by-rename (temp sibling file, then rename
    /// over the target): deployments snapshot to the SAME path on a
    /// schedule, and a crash or full disk mid-write must never destroy
    /// the previous good snapshot.
    pub fn save(&self, path: &Path) -> Result<u64> {
        let payload = match &self.state {
            EngineState::Dense { acc } => store::EncodePayload::Dense(acc.data()),
            EngineState::Implicit {
                values,
                rows: Some(rows),
                live: Some(live),
            } => store::EncodePayload::Mutable {
                main: values.main_raw(),
                inter: values.inter_raw(),
                train_x: &self.train_x,
                train_y: &self.train_y,
                test_x: &live.test_x,
                test_y: &live.test_y,
                rank: &rows.rank,
                colval: &rows.colval,
                dist: &live.dist,
                pos: &live.pos,
            },
            EngineState::Implicit { values, .. } => store::EncodePayload::Implicit {
                main: values.main_raw(),
                inter: values.inter_raw(),
            },
        };
        let bytes = store::encode(
            self.config.k as u32,
            self.config.metric,
            self.n() as u64,
            self.d as u64,
            self.fingerprint(),
            self.tests_seen,
            &self.ledger,
            &self.mutations,
            payload,
        );
        // PID-unique temp sibling: two processes snapshotting the same
        // target must not interleave writes into one temp file.
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(format!(".{}.tmp", std::process::id()));
        let tmp = std::path::PathBuf::from(tmp_name);
        let written = (|| -> std::io::Result<()> {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            // Flush data blocks to disk BEFORE the rename becomes
            // visible: rename-without-fsync can survive a crash while
            // the data doesn't, leaving a truncated file at the target.
            f.sync_all()
        })();
        if let Err(e) = written {
            let _ = std::fs::remove_file(&tmp);
            return Err(e)
                .with_context(|| format!("writing snapshot temp file {}", tmp.display()));
        }
        if let Err(e) = std::fs::rename(&tmp, path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e)
                .with_context(|| format!("renaming snapshot into place at {}", path.display()));
        }
        Ok(bytes.len() as u64)
    }
}

/// Per-point values from a RAW accumulator (upper triangle + diagonal)
/// and a normalization factor — shared by live sessions and decoded
/// snapshots. RowSum expands the symmetric row without materializing the
/// mirror via the one fixed-order reduction
/// (`Matrix::sym_row_sum_from_upper`), keeping it bit-identical to
/// `ValuationSession::point_value_at` and the dense→implicit migration.
pub(crate) fn point_values_raw(acc: &Matrix, inv_w: f64, by: TopBy) -> Vec<f64> {
    let n = acc.rows();
    match by {
        TopBy::Main => (0..n).map(|i| acc.get(i, i) * inv_w).collect(),
        TopBy::RowSum => (0..n)
            .map(|i| acc.sym_row_sum_from_upper(i) * inv_w)
            .collect(),
    }
}

/// Top-k (index, value) pairs, value-descending with index tiebreak.
/// Uses `total_cmp` (not `partial_cmp` + Equal fallback): snapshots
/// round-trip NaN cells bit-exactly and the library ingest path doesn't
/// forbid them, and a non-total comparator can make `sort_by` panic —
/// which would kill a live serve session mid-query. Under the IEEE total
/// order NaNs land deterministically at the extremes instead.
pub fn top_k_of(values: &[f64], k: usize) -> Vec<(usize, f64)> {
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| values[b].total_cmp(&values[a]).then(a.cmp(&b)));
    idx.into_iter()
        .take(k)
        .map(|i| (i, values[i]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shapley::sti_knn::sti_knn;
    use crate::util::rng::Rng;

    fn random_problem(seed: u64, n: usize, d: usize, t: usize) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        (
            (0..n * d).map(|_| rng.normal() as f32).collect(),
            (0..n).map(|_| rng.below(2) as i32).collect(),
            (0..t * d).map(|_| rng.normal() as f32).collect(),
            (0..t).map(|_| rng.below(2) as i32).collect(),
        )
    }

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("stiknn_session_{}_{tag}.snap", std::process::id()))
    }

    #[test]
    fn incremental_ingest_matches_one_shot_bits() {
        let (tx, ty, qx, qy) = random_problem(5, 19, 3, 9);
        let reference = sti_knn(&tx, &ty, 3, &qx, &qy, &StiParams::new(4));
        let mut s = ValuationSession::new(tx, ty, 3, SessionConfig::new(4)).unwrap();
        for (lo, hi) in [(0usize, 2usize), (2, 3), (3, 9)] {
            s.ingest(&qx[lo * 3..hi * 3], &qy[lo..hi]).unwrap();
        }
        assert_eq!(s.tests_seen(), 9);
        assert_eq!(s.ledger().len(), 3);
        let live = s.matrix().unwrap();
        for (a, b) in reference.data().iter().zip(live.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // cell/row agree with the full matrix, including the mirrored side
        assert_eq!(s.cell(7, 2).unwrap().to_bits(), live.get(7, 2).to_bits());
        assert_eq!(s.cell(2, 7), s.cell(7, 2));
        for (j, v) in s.row(5).unwrap().iter().enumerate() {
            assert_eq!(v.to_bits(), live.get(5, j).to_bits());
        }
    }

    #[test]
    fn parallel_ingest_path_is_bit_identical_to_sequential() {
        let (tx, ty, qx, qy) = random_problem(23, 31, 2, 20);
        let mut seq = ValuationSession::new(
            tx.clone(), ty.clone(), 2,
            SessionConfig::new(5).with_parallel_min(1000),
        ).unwrap();
        let mut par = ValuationSession::new(
            tx, ty, 2,
            SessionConfig::new(5).with_parallel_min(1).with_workers(3).with_block_size(4),
        ).unwrap();
        for (lo, hi) in [(0usize, 11usize), (11, 20)] {
            seq.ingest(&qx[lo * 2..hi * 2], &qy[lo..hi]).unwrap();
            par.ingest(&qx[lo * 2..hi * 2], &qy[lo..hi]).unwrap();
        }
        let (a, b) = (seq.matrix().unwrap(), par.matrix().unwrap());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn snapshot_restore_roundtrip_is_bit_identical_and_resumable() {
        let (tx, ty, qx, qy) = random_problem(41, 15, 2, 8);
        let reference = sti_knn(&tx, &ty, 2, &qx, &qy, &StiParams::new(3));

        let mut s = ValuationSession::new(tx.clone(), ty.clone(), 2, SessionConfig::new(3)).unwrap();
        s.ingest(&qx[..5 * 2], &qy[..5]).unwrap();
        let path = temp_path("roundtrip");
        s.save(&path).unwrap();

        let mut restored =
            ValuationSession::restore(&path, tx, ty, 2, SessionConfig::new(3)).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(restored.tests_seen(), 5);
        assert_eq!(restored.ledger(), s.ledger());
        restored.ingest(&qx[5 * 2..], &qy[5..]).unwrap();
        // ledger sequence continues across the restore
        assert_eq!(restored.ledger().last().unwrap().seq, 1);

        let live = restored.matrix().unwrap();
        for (a, b) in reference.data().iter().zip(live.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn restore_rejects_mismatches() {
        let (tx, ty, qx, qy) = random_problem(77, 12, 2, 4);
        let mut s = ValuationSession::new(tx.clone(), ty.clone(), 2, SessionConfig::new(3)).unwrap();
        s.ingest(&qx, &qy).unwrap();
        let path = temp_path("mismatch");
        s.save(&path).unwrap();

        // wrong k
        let err = ValuationSession::restore(&path, tx.clone(), ty.clone(), 2, SessionConfig::new(4))
            .unwrap_err()
            .to_string();
        assert!(err.contains("k="), "{err}");
        // wrong metric
        let err = ValuationSession::restore(
            &path, tx.clone(), ty.clone(), 2,
            SessionConfig::new(3).with_metric(Metric::Manhattan),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("metric"), "{err}");
        // different training data
        let mut tx2 = tx.clone();
        tx2[0] += 1.0;
        let err = ValuationSession::restore(&path, tx2, ty, 2, SessionConfig::new(3))
            .unwrap_err()
            .to_string();
        assert!(err.contains("fingerprint"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_session_queries_are_none_and_stats_zero() {
        let (tx, ty, _, _) = random_problem(9, 10, 2, 1);
        let s = ValuationSession::new(tx, ty, 2, SessionConfig::new(2)).unwrap();
        assert!(s.cell(0, 1).is_none());
        assert!(s.row(0).is_none());
        assert!(s.matrix().is_none());
        assert!(s.top_k(3, TopBy::Main).is_none());
        let st = s.stats();
        assert_eq!(st.tests, 0);
        assert_eq!(st.trace, 0.0);
        assert_eq!(st.mean_offdiag, 0.0);
        // empty ingest is a no-op, not an error
        let mut s = s;
        assert_eq!(s.ingest(&[], &[]).unwrap(), 0);
        assert_eq!(s.ledger().len(), 0);
    }

    #[test]
    fn out_of_range_queries_are_none() {
        let (tx, ty, qx, qy) = random_problem(13, 8, 2, 3);
        let mut s = ValuationSession::new(tx, ty, 2, SessionConfig::new(2)).unwrap();
        s.ingest(&qx, &qy).unwrap();
        assert!(s.cell(0, 8).is_none());
        assert!(s.cell(8, 0).is_none());
        assert!(s.row(8).is_none());
        assert!(s.cell(0, 7).is_some());
    }

    #[test]
    fn topk_and_stats_agree_with_matrix() {
        let (tx, ty, qx, qy) = random_problem(31, 14, 3, 6);
        let mut s = ValuationSession::new(tx, ty, 3, SessionConfig::new(4)).unwrap();
        s.ingest(&qx, &qy).unwrap();
        let m = s.matrix().unwrap();

        let top = s.top_k(14, TopBy::Main).unwrap();
        assert_eq!(top.len(), 14);
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1, "not descending: {top:?}");
        }
        for &(i, v) in &top {
            assert_eq!(v.to_bits(), m.get(i, i).to_bits());
        }

        let rowsum = s.point_values(TopBy::RowSum).unwrap();
        for i in 0..14 {
            let direct: f64 = (0..14).map(|j| m.get(i, j)).sum::<f64>();
            assert!((rowsum[i] - direct).abs() < 1e-12, "row {i}");
        }

        let st = s.stats();
        assert_eq!(st.tests, 6);
        assert_eq!(st.batches, 1);
        assert!((st.trace - m.diagonal().iter().sum::<f64>()).abs() < 1e-12);
        assert!((st.upper_sum - m.upper_triangle_sum()).abs() < 1e-12);
    }

    #[test]
    fn bad_construction_is_rejected() {
        assert!(ValuationSession::new(vec![0.0; 4], vec![0, 1], 2, SessionConfig::new(3)).is_err(),
            "k > n");
        assert!(ValuationSession::new(vec![0.0; 3], vec![0, 1], 2, SessionConfig::new(1)).is_err(),
            "shape mismatch");
        assert!(ValuationSession::new(vec![0.0; 2], vec![0], 2, SessionConfig::new(1)).is_err(),
            "n < 2");
        let mut s =
            ValuationSession::new(vec![0.0, 0.1, 1.0, 1.1], vec![0, 1], 2, SessionConfig::new(1))
                .unwrap();
        assert!(s.ingest(&[0.5], &[0]).is_err(), "batch shape mismatch");
    }

    #[test]
    fn ledger_compaction_bounds_state_and_preserves_invariants() {
        let (tx, ty, qx, qy) = random_problem(61, 6, 1, 1);
        let reference_batches = (LEDGER_COMPACT_AT as u64) + 50;
        let mut s = ValuationSession::new(tx, ty, 1, SessionConfig::new(2)).unwrap();
        for _ in 0..reference_batches {
            s.ingest(&qx, &qy).unwrap();
        }
        // compaction kept the ledger bounded...
        assert!(s.ledger().len() < LEDGER_COMPACT_AT, "{}", s.ledger().len());
        // ...while the batch count and the Σ len == tests invariant hold
        assert_eq!(s.batches_ingested(), reference_batches);
        assert_eq!(s.stats().batches, reference_batches);
        assert_eq!(s.tests_seen(), reference_batches);
        let total: u64 = s.ledger().iter().map(|b| b.len).sum();
        assert_eq!(total, s.tests_seen());
        // a snapshot of the compacted ledger round-trips (decode re-checks
        // the sum invariant) and the restored session keeps counting
        let path = temp_path("compaction");
        s.save(&path).unwrap();
        let (tx, ty, qx, qy) = random_problem(61, 6, 1, 1);
        let mut restored = ValuationSession::restore(&path, tx, ty, 1, SessionConfig::new(2))
            .unwrap();
        let _ = std::fs::remove_file(&path);
        restored.ingest(&qx, &qy).unwrap();
        assert_eq!(restored.batches_ingested(), reference_batches + 1);
    }

    #[test]
    fn top_k_of_truncates_and_tiebreaks_by_index() {
        let top = top_k_of(&[1.0, 3.0, 3.0, -1.0], 3);
        assert_eq!(top, vec![(1, 3.0), (2, 3.0), (0, 1.0)]);
        assert_eq!(top_k_of(&[1.0], 5), vec![(0, 1.0)]);
    }

    #[test]
    fn implicit_session_values_match_dense_session() {
        let (tx, ty, qx, qy) = random_problem(71, 18, 2, 9);
        let mut dense =
            ValuationSession::new(tx.clone(), ty.clone(), 2, SessionConfig::new(4)).unwrap();
        let mut imp = ValuationSession::new(
            tx, ty, 2,
            SessionConfig::new(4).with_engine(Engine::Implicit),
        )
        .unwrap();
        assert_eq!(imp.engine(), Engine::Implicit);
        assert!(!imp.supports_matrix_queries());
        for (lo, hi) in [(0usize, 4usize), (4, 9)] {
            dense.ingest(&qx[lo * 2..hi * 2], &qy[lo..hi]).unwrap();
            imp.ingest(&qx[lo * 2..hi * 2], &qy[lo..hi]).unwrap();
        }
        for by in [TopBy::Main, TopBy::RowSum] {
            let a = dense.point_values(by).unwrap();
            let b = imp.point_values(by).unwrap();
            for i in 0..18 {
                assert!((a[i] - b[i]).abs() < 1e-12, "{by:?}[{i}]");
            }
        }
        // diagonal cells answerable without retained rows; pairs are not
        assert!(imp.cell(3, 3).is_some());
        assert!((imp.cell(3, 3).unwrap() - dense.cell(3, 3).unwrap()).abs() < 1e-12);
        assert!(imp.cell(0, 1).is_none());
        assert!(imp.row(0).is_none());
        assert!(imp.matrix().is_none());
        // stats agree across engines
        let (sd, si) = (dense.stats(), imp.stats());
        assert_eq!(si.tests, sd.tests);
        assert!((sd.trace - si.trace).abs() < 1e-12);
        assert!((sd.mean_offdiag - si.mean_offdiag).abs() < 1e-12);
        assert!((sd.upper_sum - si.upper_sum).abs() < 1e-12);
    }

    #[test]
    fn retained_rows_answer_cells_and_rows() {
        let (tx, ty, qx, qy) = random_problem(83, 15, 3, 7);
        let mut dense =
            ValuationSession::new(tx.clone(), ty.clone(), 3, SessionConfig::new(3)).unwrap();
        let mut imp = ValuationSession::new(
            tx, ty, 3,
            SessionConfig::new(3)
                .with_engine(Engine::Implicit)
                .with_retained_rows(true),
        )
        .unwrap();
        assert!(imp.retains_rows());
        assert!(imp.supports_matrix_queries());
        for (lo, hi) in [(0usize, 2usize), (2, 7)] {
            dense.ingest(&qx[lo * 3..hi * 3], &qy[lo..hi]).unwrap();
            imp.ingest(&qx[lo * 3..hi * 3], &qy[lo..hi]).unwrap();
        }
        for i in 0..15 {
            for j in 0..15 {
                let a = dense.cell(i, j).unwrap();
                let b = imp.cell(i, j).unwrap();
                assert!((a - b).abs() < 1e-12, "cell({i},{j}): {a} vs {b}");
            }
            let (ra, rb) = (dense.row(i).unwrap(), imp.row(i).unwrap());
            for j in 0..15 {
                assert!((ra[j] - rb[j]).abs() < 1e-12, "row({i})[{j}]");
            }
        }
        // symmetric by construction
        assert_eq!(imp.cell(2, 9), imp.cell(9, 2));
    }

    #[test]
    fn implicit_snapshot_roundtrip_is_bit_identical_and_resumable() {
        let (tx, ty, qx, qy) = random_problem(97, 14, 2, 8);
        let config = SessionConfig::new(3).with_engine(Engine::Implicit);
        let mut reference =
            ValuationSession::new(tx.clone(), ty.clone(), 2, config).unwrap();
        reference.ingest(&qx, &qy).unwrap();

        let mut s = ValuationSession::new(tx.clone(), ty.clone(), 2, config).unwrap();
        s.ingest(&qx[..5 * 2], &qy[..5]).unwrap();
        let path = temp_path("implicit_roundtrip");
        s.save(&path).unwrap();
        let mut restored =
            ValuationSession::restore(&path, tx.clone(), ty.clone(), 2, config).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(restored.engine(), Engine::Implicit);
        assert_eq!(restored.tests_seen(), 5);
        restored.ingest(&qx[5 * 2..], &qy[5..]).unwrap();

        // bit-identical to the uninterrupted session, both rankings
        for by in [TopBy::Main, TopBy::RowSum] {
            let a = reference.point_values(by).unwrap();
            let b = restored.point_values(by).unwrap();
            for i in 0..14 {
                assert_eq!(a[i].to_bits(), b[i].to_bits(), "{by:?}[{i}]");
            }
        }
    }

    #[test]
    fn engine_mismatched_restores_are_refused_or_migrated() {
        let (tx, ty, qx, qy) = random_problem(103, 12, 2, 5);
        // implicit snapshot → dense session: refused
        let mut imp = ValuationSession::new(
            tx.clone(), ty.clone(), 2,
            SessionConfig::new(3).with_engine(Engine::Implicit),
        )
        .unwrap();
        imp.ingest(&qx, &qy).unwrap();
        let path = temp_path("engine_mismatch");
        imp.save(&path).unwrap();
        let err = ValuationSession::restore(
            &path, tx.clone(), ty.clone(), 2, SessionConfig::new(3),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("implicit"), "{err}");
        // non-empty restore with retain_rows: refused (rows not persisted)
        let err = ValuationSession::restore(
            &path, tx.clone(), ty.clone(), 2,
            SessionConfig::new(3)
                .with_engine(Engine::Implicit)
                .with_retained_rows(true),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("retain_rows"), "{err}");
        let _ = std::fs::remove_file(&path);

        // dense snapshot → implicit session: migrates (values derived)
        let mut dense =
            ValuationSession::new(tx.clone(), ty.clone(), 2, SessionConfig::new(3)).unwrap();
        dense.ingest(&qx, &qy).unwrap();
        let path = temp_path("dense_to_implicit");
        dense.save(&path).unwrap();
        let migrated = ValuationSession::restore(
            &path, tx, ty, 2,
            SessionConfig::new(3).with_engine(Engine::Implicit),
        )
        .unwrap();
        let _ = std::fs::remove_file(&path);
        for by in [TopBy::Main, TopBy::RowSum] {
            let a = dense.point_values(by).unwrap();
            let b = migrated.point_values(by).unwrap();
            for i in 0..12 {
                assert!((a[i] - b[i]).abs() < 1e-12, "{by:?}[{i}]");
            }
        }
    }

    #[test]
    fn implicit_parallel_ingest_is_bit_identical_to_sequential() {
        let (tx, ty, qx, qy) = random_problem(109, 26, 2, 20);
        let base = SessionConfig::new(5).with_engine(Engine::Implicit);
        let mut seq = ValuationSession::new(
            tx.clone(), ty.clone(), 2, base.with_parallel_min(1000),
        )
        .unwrap();
        let mut par = ValuationSession::new(
            tx, ty, 2,
            base.with_parallel_min(1).with_workers(3).with_block_size(4),
        )
        .unwrap();
        for (lo, hi) in [(0usize, 11usize), (11, 20)] {
            seq.ingest(&qx[lo * 2..hi * 2], &qy[lo..hi]).unwrap();
            par.ingest(&qx[lo * 2..hi * 2], &qy[lo..hi]).unwrap();
        }
        for by in [TopBy::Main, TopBy::RowSum] {
            let a = seq.point_values(by).unwrap();
            let b = par.point_values(by).unwrap();
            for i in 0..26 {
                assert_eq!(a[i].to_bits(), b[i].to_bits(), "{by:?}[{i}]");
            }
        }
    }
}
