//! NDJSON command protocol backing `stiknn serve` (DESIGN.md §9).
//!
//! One JSON object per input line, one JSON response per output line,
//! flushed after every response so a fronting service can drive the
//! session over a pipe without buffering games. Malformed input and
//! failed commands produce `{"ok":false,"error":...}` and the loop keeps
//! serving — only `shutdown` (or EOF on stdin) ends it.
//!
//! Commands:
//!
//! ```text
//! {"cmd":"ping"}                     → {"ok":true,"engine":...,"n":...,"t":...}
//!                                      (health check — NEVER mutates state)
//! {"cmd":"ingest","x":[...flattened features...],"y":[...labels...]}
//! {"cmd":"query","i":0,"j":1}        → one averaged cell
//! {"cmd":"query","i":0}              → one averaged row
//! {"cmd":"values"}                   → per-point main + rowsum arrays
//! {"cmd":"values","i":3}             → one point's (main, rowsum) pair
//! {"cmd":"values","raw":true}        → UNNORMALIZED per-point sums plus
//!                                      the test count they cover — the
//!                                      shard-merge fetch (DESIGN.md §13);
//!                                      works on an EMPTY session (zeros)
//! {"cmd":"query",...,"raw":true}     → unnormalized cell/row + tests
//! {"cmd":"topk","k":10,"by":"main"}  → top-k point values (by: main|rowsum)
//! {"cmd":"stats"}                    → summary statistics (incl. engine)
//! {"cmd":"metrics"}                  → the session's telemetry snapshot
//!                                      (DESIGN.md §14); "metric":"name"
//!                                      looks up one metric by name
//! {"cmd":"add_train","x":[...d features...],"y":label}
//!                                    → {"index":new id,"n":...} (mutable only)
//! {"cmd":"remove_train","i":3}       → remove a train point (mutable only)
//! {"cmd":"relabel","i":3,"y":1}      → change a train label (mutable only)
//! {"cmd":"snapshot","path":"x.snap"} → persist the session (store.rs)
//! {"cmd":"shutdown"}                 → acknowledge and exit
//! ```
//!
//! Engine interaction (DESIGN.md §10): an implicit-engine session
//! without retained rows has no pair-level state, so off-diagonal `query`
//! cells and full `query` rows are REJECTED with
//! `{"ok":false,"reason":"engine",...}` — a distinct, machine-checkable
//! reason (vs the empty-session error), so a fronting service can route
//! such queries to a dense deployment instead of retrying. `values`,
//! `topk`, `stats`, diagonal cells, `ingest` and `snapshot` work in every
//! engine.
//!
//! Mutation commands (DESIGN.md §11) are the protocol face of the delta
//! subsystem: on a `serve --mutable` session they apply exact O(t·(d+n))
//! edits and answer with the new point id / updated counts. On an
//! immutable session they are rejected with
//! `{"ok":false,"reason":"mutable",...}` — again machine-checkable, so a
//! router can direct writes to the mutable deployment.
//!
//! Every successful state-changing response (`ingest`, `add_train`,
//! `remove_train`, `relabel`) carries `"rev"` — the session's monotone
//! write revision AFTER the command applied. Under the concurrent server
//! ([`crate::server`], DESIGN.md §12) sorting a session's write
//! responses by `rev` reconstructs the exact order that session applied
//! them in; the multi-session verbs (`open`/`close`/`use`/`list`) live
//! in the server layer, not here.

use super::{TopBy, ValuationSession};
use crate::obs::trace::parse_hex_id;
use crate::obs::{SpanCtx, SpanRecord};
use crate::util::json::Json;
use anyhow::Result;
use std::io::{BufRead, Write};
use std::path::Path;

/// Drive `session` from NDJSON commands on `input`, writing NDJSON
/// responses to `output`, until `shutdown` or EOF.
///
/// Reads lines as BYTES (not `BufRead::lines`): a non-UTF-8 byte from a
/// buggy client must produce an `{"ok":false}` response like any other
/// malformed input, not an io error that kills the session. Real I/O
/// failures (broken pipe, closed fd) still end the loop via `Err`.
pub fn serve<R: BufRead, W: Write>(
    session: &mut ValuationSession,
    mut input: R,
    mut output: W,
) -> Result<()> {
    let mut buf = Vec::new();
    loop {
        buf.clear();
        if input.read_until(b'\n', &mut buf)? == 0 {
            break; // EOF
        }
        // Lossy conversion: invalid bytes become U+FFFD, which then fails
        // JSON parsing and is answered as a per-line error.
        let line = String::from_utf8_lossy(&buf);
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let (response, shutdown) = handle(session, trimmed);
        writeln!(output, "{response}")?;
        output.flush()?;
        if shutdown {
            break;
        }
    }
    Ok(())
}

/// A failed command: the message plus an optional machine-checkable
/// reason tag (`"engine"` for queries the session's engine cannot
/// answer). `From<String>` keeps the plain-`?` call sites terse.
pub struct Fail {
    pub(crate) msg: String,
    pub(crate) reason: Option<&'static str>,
}

impl From<String> for Fail {
    fn from(msg: String) -> Self {
        Fail { msg, reason: None }
    }
}

fn engine_fail(what: &str, session: &ValuationSession) -> Fail {
    Fail {
        msg: format!(
            "{what} requires pair-level state the '{}' engine does not keep \
             (run the session with --engine dense, or implicit with retained rows)",
            session.engine().label()
        ),
        reason: Some("engine"),
    }
}

fn mutable_fail(what: &str) -> Fail {
    Fail {
        msg: format!(
            "{what} requires a mutable session (run `stiknn serve --mutable`)"
        ),
        reason: Some("mutable"),
    }
}

/// How a single-session command touches session state. The concurrent
/// server (DESIGN.md §12) routes `Read` commands through the session's
/// RwLock read guard — so they run concurrently with each other — and
/// `Write` commands through the write guard, serializing them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    Read,
    Write,
}

/// Classify a single-session command name. `None` for unknown commands
/// and for connection-level verbs (`shutdown`, and the server layer's
/// `open`/`close`/`use`/`list`) that never touch a session directly.
pub fn access_of(cmd: &str) -> Option<Access> {
    match cmd {
        // `snapshot` is a read: `ValuationSession::save` takes &self,
        // so checkpoints run concurrently with queries.
        "ping" | "query" | "values" | "topk" | "stats" | "snapshot" | "metrics" => {
            Some(Access::Read)
        }
        "ingest" | "add_train" | "remove_train" | "relabel" => Some(Access::Write),
        _ => None,
    }
}

/// Execute one read-class command against a shared session reference.
/// `cmd` must be `Access::Read`-classified; anything else is a bug in
/// the caller's routing, not in client input.
pub fn dispatch_read(
    session: &ValuationSession,
    cmd: &str,
    v: &Json,
) -> Result<Json, Fail> {
    match cmd {
        "ping" => Ok(ping_json(session)),
        "query" => do_query(session, v),
        "values" => do_values(session, v),
        "topk" => do_topk(session, v),
        "stats" => Ok(stats_json(session)),
        "metrics" => do_metrics(session, v),
        "snapshot" => do_snapshot(session, v),
        other => unreachable!("dispatch_read routed non-read command '{other}'"),
    }
}

/// Execute one write-class command against an exclusive session
/// reference.
pub fn dispatch_write(
    session: &mut ValuationSession,
    cmd: &str,
    v: &Json,
) -> Result<Json, Fail> {
    match cmd {
        "ingest" => do_ingest(session, v),
        "add_train" => do_add_train(session, v),
        "remove_train" => do_remove_train(session, v),
        "relabel" => do_relabel(session, v),
        other => unreachable!("dispatch_write routed non-write command '{other}'"),
    }
}

/// The single-session unknown-command message (the server layer appends
/// its registry verbs to its own copy).
pub const KNOWN_COMMANDS: &str = "ping|ingest|query|values|topk|stats|metrics|\
     add_train|remove_train|relabel|snapshot|shutdown";

/// Parse the optional `"trace"` REQUEST field — the NDJSON trace-context
/// carrier (DESIGN.md §16): `{"trace":{"id":<hex16>,"parent":<hex16>}}`.
/// Returns `None` when absent or malformed: trace context is best-effort
/// telemetry, so a bad carrier must never fail the command it rode on.
/// (Responses never use the `"trace"` key — the `stats` response already
/// carries a numeric matrix `trace` — member spans echo back as
/// `"spans"` instead.)
pub fn parse_trace_ctx(v: &Json) -> Option<SpanCtx> {
    let t = v.get("trace")?;
    let trace_id = parse_hex_id(t.get("id")?.as_str()?)?;
    let parent_id = parse_hex_id(t.get("parent")?.as_str()?)?;
    Some(SpanCtx {
        trace_id,
        span_id: parent_id,
    })
}

/// Attach finished member spans to a response as `"spans":[...]`. Only
/// called for requests that CARRIED trace context, so an untraced
/// script's responses stay byte-identical with tracing on or off.
pub fn attach_spans(resp: &mut Json, spans: &[SpanRecord]) {
    if spans.is_empty() {
        return;
    }
    if let Json::Obj(m) = resp {
        m.insert(
            "spans".to_string(),
            Json::arr(spans.iter().map(SpanRecord::to_json)),
        );
    }
}

/// Execute one command line → (response, shutdown?). Never panics on
/// untrusted input; every failure is a `{"ok":false}` response.
///
/// A request carrying `"trace"` context joins the caller's trace: the
/// command runs under an ADOPTED `member.<cmd>` span (always recorded —
/// sampling is decided at the trace root, so a member's own sampling
/// setting can never fracture a coordinator's tree), the session's
/// ingest/edit spans nest under it via the trace scope, and every span
/// this command produced is echoed back on the response as `"spans"`
/// for the caller to import into its own store.
pub fn handle(session: &mut ValuationSession, line: &str) -> (Json, bool) {
    let v = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return (err(format!("bad json: {e}")), false),
    };
    let Some(cmd) = v.get("cmd").and_then(Json::as_str).map(str::to_string) else {
        return (err("missing string field 'cmd'"), false);
    };
    if cmd == "shutdown" {
        return (ok("shutdown", vec![("shutdown", Json::Bool(true))]), true);
    }
    let ctx = parse_trace_ctx(&v);
    let trace = session.trace().clone();
    let mut member_span = None;
    let mut mark = 0;
    if let Some(c) = ctx {
        mark = trace.seq();
        let span = trace.adopt(c.trace_id, c.span_id, &format!("member.{cmd}"));
        session.set_trace_scope(span.ctx());
        member_span = Some(span);
    }
    let result = match access_of(&cmd) {
        Some(Access::Read) => dispatch_read(session, &cmd, &v),
        Some(Access::Write) => dispatch_write(session, &cmd, &v),
        None => Err(Fail::from(format!(
            "unknown command '{cmd}' (expected {KNOWN_COMMANDS})"
        ))),
    };
    let mut resp = match result {
        Ok(j) => j,
        Err(fail) => fail_json(fail),
    };
    if let Some(span) = member_span {
        session.set_trace_scope(None);
        span.finish(); // records on drop, BEFORE the echo collection
        let c = ctx.expect("member_span implies ctx");
        attach_spans(&mut resp, &trace.spans_since(c.trace_id, mark));
    }
    (resp, false)
}

pub fn err(msg: impl Into<String>) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg.into())),
    ])
}

pub fn fail_json(f: Fail) -> Json {
    let mut fields = vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(f.msg)),
    ];
    if let Some(reason) = f.reason {
        fields.push(("reason", Json::str(reason)));
    }
    Json::obj(fields)
}

pub fn ok(cmd: &str, fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("ok", Json::Bool(true)), ("cmd", Json::str(cmd))];
    all.extend(fields);
    Json::obj(all)
}

const EMPTY: &str = "no test points ingested yet or index out of range";

/// Parse the optional `"raw":true` flag: shard coordinators fetch
/// UNNORMALIZED sums and normalize once after the cross-shard fold
/// (DESIGN.md §13).
fn parse_raw(v: &Json) -> Result<bool, Fail> {
    match v.get("raw") {
        None => Ok(false),
        Some(x) => x
            .as_bool()
            .ok_or_else(|| Fail::from("'raw' must be a boolean".to_string())),
    }
}

/// Parse a JSON array of features into f32s. Rejects rather than
/// narrows: "1e400" parses to f64 ∞, and finite f64s beyond f32 range
/// cast to ∞ — either would fold garbage distances into the shared
/// state forever while the command answered ok:true.
fn parse_features(xs: &[Json]) -> Result<Vec<f32>, Fail> {
    let mut out = Vec::with_capacity(xs.len());
    for e in xs {
        let f = e
            .as_f64()
            .ok_or_else(|| "non-numeric entry in 'x'".to_string())?;
        if !f.is_finite() || f.abs() > f32::MAX as f64 {
            return Err("entry in 'x' is not a finite f32-range number"
                .to_string()
                .into());
        }
        out.push(f as f32);
    }
    Ok(out)
}

/// Parse one JSON value as an i32 label. `as i32` would saturate
/// out-of-range labels to ±i32::MAX and silently misclassify the point —
/// reject instead.
fn parse_label(e: &Json) -> Result<i32, Fail> {
    let f = e
        .as_f64()
        .filter(|f| f.fract() == 0.0 && *f >= i32::MIN as f64 && *f <= i32::MAX as f64)
        .ok_or_else(|| "'y' must be an integer label in i32 range".to_string())?;
    Ok(f as i32)
}

fn do_ingest(session: &mut ValuationSession, v: &Json) -> Result<Json, Fail> {
    let xs = v
        .get("x")
        .and_then(Json::as_arr)
        .ok_or_else(|| "ingest needs a numeric array 'x' (flattened features)".to_string())?;
    let ys = v
        .get("y")
        .and_then(Json::as_arr)
        .ok_or_else(|| "ingest needs an integer array 'y' (labels)".to_string())?;
    let test_x = parse_features(xs)?;
    let mut test_y = Vec::with_capacity(ys.len());
    for e in ys {
        test_y.push(
            parse_label(e)
                .map_err(|_| Fail::from("entry in 'y' must be an integer label in i32 range".to_string()))?,
        );
    }
    let ingested = session
        .ingest(&test_x, &test_y)
        .map_err(|e| format!("{e:#}"))?;
    Ok(ok(
        "ingest",
        vec![
            ("ingested", Json::num(ingested as f64)),
            ("tests", Json::num(session.tests_seen() as f64)),
            ("batches", Json::num(session.batches_ingested() as f64)),
            ("rev", Json::num(session.revision() as f64)),
        ],
    ))
}

fn do_query(session: &ValuationSession, v: &Json) -> Result<Json, Fail> {
    let i = v
        .get("i")
        .and_then(Json::as_usize)
        .ok_or_else(|| "query needs a train index 'i'".to_string())?;
    let raw = parse_raw(v)?;
    let raw_fields = |fields: &mut Vec<(&str, Json)>| {
        fields.push(("raw", Json::Bool(true)));
        fields.push(("tests", Json::num(session.tests_seen() as f64)));
    };
    match v.get("j") {
        Some(j) => {
            let j = j
                .as_usize()
                .ok_or_else(|| "'j' must be a train index".to_string())?;
            // Off-diagonal cells need pair-level state; reject with the
            // machine-checkable `engine` reason BEFORE the empty/range
            // check so callers can tell a capability gap from bad input.
            // Diagonal cells are per-point values and always answerable.
            if i != j && !session.supports_matrix_queries() {
                return Err(engine_fail("an off-diagonal cell query", session));
            }
            let value = if raw {
                session.raw_cell(i, j)
            } else {
                session.cell(i, j)
            }
            .ok_or_else(|| EMPTY.to_string())?;
            let mut fields = vec![
                ("i", Json::num(i as f64)),
                ("j", Json::num(j as f64)),
                ("value", Json::num(value)),
            ];
            if raw {
                raw_fields(&mut fields);
            }
            Ok(ok("query", fields))
        }
        None => {
            if !session.supports_matrix_queries() {
                return Err(engine_fail("a full matrix-row query", session));
            }
            let row = if raw {
                session.raw_row(i)
            } else {
                session.row(i)
            }
            .ok_or_else(|| EMPTY.to_string())?;
            let mut fields = vec![
                ("i", Json::num(i as f64)),
                ("row", Json::arr(row.into_iter().map(Json::num))),
            ];
            if raw {
                raw_fields(&mut fields);
            }
            Ok(ok("query", fields))
        }
    }
}

fn do_values(session: &ValuationSession, v: &Json) -> Result<Json, Fail> {
    if parse_raw(v)? {
        if v.get("i").is_some() {
            return Err(Fail::from(
                "'raw' applies to the full-array values form only (drop 'i')".to_string(),
            ));
        }
        // Raw sums are answerable even on an EMPTY session (all zeros):
        // a zero-test shard must still contribute its exact additive
        // identity to a cross-shard merge.
        let (main, rowsum) = session.raw_point_sums();
        return Ok(ok(
            "values",
            vec![
                ("raw", Json::Bool(true)),
                ("tests", Json::num(session.tests_seen() as f64)),
                ("main", Json::arr(main.into_iter().map(Json::num))),
                ("rowsum", Json::arr(rowsum.into_iter().map(Json::num))),
            ],
        ));
    }
    match v.get("i") {
        // Single point: O(1)/O(n) via point_value_at — a hot polling
        // path must not rebuild full value vectors (the dense rowsum
        // vector costs an O(n²) matrix reduction).
        Some(x) => {
            let i = x
                .as_usize()
                .filter(|&i| i < session.n())
                .ok_or_else(|| "'i' must be a train index".to_string())?;
            let (main, rowsum) = session
                .point_value_at(i)
                .ok_or_else(|| "no test points ingested yet".to_string())?;
            Ok(ok(
                "values",
                vec![
                    ("i", Json::num(i as f64)),
                    ("main", Json::num(main)),
                    ("rowsum", Json::num(rowsum)),
                ],
            ))
        }
        None => {
            let main = session
                .point_values(TopBy::Main)
                .ok_or_else(|| "no test points ingested yet".to_string())?;
            let rowsum = session
                .point_values(TopBy::RowSum)
                .ok_or_else(|| "no test points ingested yet".to_string())?;
            Ok(ok(
                "values",
                vec![
                    ("main", Json::arr(main.into_iter().map(Json::num))),
                    ("rowsum", Json::arr(rowsum.into_iter().map(Json::num))),
                ],
            ))
        }
    }
}

fn do_topk(session: &ValuationSession, v: &Json) -> Result<Json, Fail> {
    let k = match v.get("k") {
        None => 10,
        Some(x) => x
            .as_usize()
            .ok_or_else(|| "'k' must be a non-negative integer".to_string())?,
    };
    let by = match v.get("by") {
        None => TopBy::Main,
        Some(x) => x
            .as_str()
            .and_then(TopBy::parse)
            .ok_or_else(|| "'by' must be main or rowsum".to_string())?,
    };
    let entries = session
        .top_k(k, by)
        .ok_or_else(|| "no test points ingested yet".to_string())?;
    Ok(ok(
        "topk",
        vec![
            ("by", Json::str(by.label())),
            (
                "points",
                Json::arr(entries.iter().map(|&(index, value)| {
                    Json::obj(vec![
                        ("index", Json::num(index as f64)),
                        ("value", Json::num(value)),
                    ])
                })),
            ),
        ],
    ))
}

fn stats_json(session: &ValuationSession) -> Json {
    let st = session.stats();
    ok(
        "stats",
        vec![
            ("n", Json::num(st.n as f64)),
            ("k", Json::num(st.k as f64)),
            ("engine", Json::str(session.engine().label())),
            ("tests", Json::num(st.tests as f64)),
            ("batches", Json::num(st.batches as f64)),
            ("trace", Json::num(st.trace)),
            ("mean_offdiag", Json::num(st.mean_offdiag)),
            ("upper_sum", Json::num(st.upper_sum)),
        ],
    )
}

/// Health-check response: engine, train size, tests ingested. Reads
/// nothing mutable and allocates O(1) — safe for a load balancer to
/// fire at any rate against a live `serve`.
fn ping_json(session: &ValuationSession) -> Json {
    ok(
        "ping",
        vec![
            ("engine", Json::str(session.engine().label())),
            ("mutable", Json::Bool(session.is_mutable())),
            ("n", Json::num(session.n() as f64)),
            ("t", Json::num(session.tests_seen() as f64)),
        ],
    )
}

/// `metrics`: the session's telemetry snapshot (DESIGN.md §14). Always
/// answers — a session without an attached registry reports
/// `"enabled":false` with a null `"metrics"` payload, so an operator can
/// tell "observability off" from "no traffic yet". With an optional
/// `"metric":"name"` field it returns that one metric's value instead of
/// the full snapshot; unknown names are a clean per-line error.
fn do_metrics(session: &ValuationSession, v: &Json) -> Result<Json, Fail> {
    let obs = session.obs();
    if let Some(m) = v.get("metric") {
        let name = m
            .as_str()
            .ok_or_else(|| "'metric' must be a string name".to_string())?;
        let Some(reg) = obs.registry() else {
            return Err(Fail::from(format!(
                "metrics are disabled on this session; '{name}' is not being \
                 collected (serve with --obs on)"
            )));
        };
        let value = reg
            .lookup(name)
            .ok_or_else(|| format!("unknown metric '{name}'"))?;
        return Ok(ok(
            "metrics",
            vec![("metric", Json::str(name)), ("value", value)],
        ));
    }
    Ok(ok(
        "metrics",
        vec![
            ("scope", Json::str("session")),
            ("enabled", Json::Bool(obs.is_enabled())),
            ("n", Json::num(session.n() as f64)),
            ("tests", Json::num(session.tests_seen() as f64)),
            ("batches", Json::num(session.batches_ingested() as f64)),
            ("mutations", Json::num(session.mutations().len() as f64)),
            ("rev", Json::num(session.revision() as f64)),
            ("metrics", obs.snapshot_json()),
        ],
    ))
}

fn do_add_train(session: &mut ValuationSession, v: &Json) -> Result<Json, Fail> {
    if !session.is_mutable() {
        return Err(mutable_fail("add_train"));
    }
    let xs = v
        .get("x")
        .and_then(Json::as_arr)
        .ok_or_else(|| "add_train needs a numeric array 'x' (d features)".to_string())?;
    let y = parse_label(
        v.get("y")
            .ok_or_else(|| "add_train needs an integer label 'y'".to_string())?,
    )?;
    let x = parse_features(xs)?;
    let index = session.add_train(&x, y).map_err(|e| format!("{e:#}"))?;
    Ok(ok(
        "add_train",
        vec![
            ("index", Json::num(index as f64)),
            ("n", Json::num(session.n() as f64)),
            ("mutations", Json::num(session.mutations().len() as f64)),
            ("rev", Json::num(session.revision() as f64)),
        ],
    ))
}

fn do_remove_train(session: &mut ValuationSession, v: &Json) -> Result<Json, Fail> {
    if !session.is_mutable() {
        return Err(mutable_fail("remove_train"));
    }
    let i = v
        .get("i")
        .and_then(Json::as_usize)
        .ok_or_else(|| "remove_train needs a train index 'i'".to_string())?;
    session.remove_train(i).map_err(|e| format!("{e:#}"))?;
    Ok(ok(
        "remove_train",
        vec![
            ("i", Json::num(i as f64)),
            ("n", Json::num(session.n() as f64)),
            ("mutations", Json::num(session.mutations().len() as f64)),
            ("rev", Json::num(session.revision() as f64)),
        ],
    ))
}

fn do_relabel(session: &mut ValuationSession, v: &Json) -> Result<Json, Fail> {
    if !session.is_mutable() {
        return Err(mutable_fail("relabel"));
    }
    let i = v
        .get("i")
        .and_then(Json::as_usize)
        .ok_or_else(|| "relabel needs a train index 'i'".to_string())?;
    let y = parse_label(
        v.get("y")
            .ok_or_else(|| "relabel needs an integer label 'y'".to_string())?,
    )?;
    session.relabel_train(i, y).map_err(|e| format!("{e:#}"))?;
    Ok(ok(
        "relabel",
        vec![
            ("i", Json::num(i as f64)),
            ("y", Json::num(y as f64)),
            ("n", Json::num(session.n() as f64)),
            ("mutations", Json::num(session.mutations().len() as f64)),
            ("rev", Json::num(session.revision() as f64)),
        ],
    ))
}

fn do_snapshot(session: &ValuationSession, v: &Json) -> Result<Json, Fail> {
    let path = v
        .get("path")
        .and_then(Json::as_str)
        .ok_or_else(|| "snapshot needs a string 'path'".to_string())?;
    let bytes = session
        .save(Path::new(path))
        .map_err(|e| format!("{e:#}"))?;
    Ok(ok(
        "snapshot",
        vec![
            ("path", Json::str(path)),
            ("bytes", Json::num(bytes as f64)),
        ],
    ))
}

#[cfg(test)]
mod tests {
    use super::super::{Engine, SessionConfig};
    use super::*;
    use crate::util::rng::Rng;
    use std::io::Cursor;

    fn tiny_session() -> ValuationSession {
        tiny_session_with(SessionConfig::new(3))
    }

    fn tiny_session_with(config: SessionConfig) -> ValuationSession {
        let mut rng = Rng::new(3);
        let n = 8;
        let d = 2;
        let train_x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let train_y: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        ValuationSession::new(train_x, train_y, d, config).unwrap()
    }

    fn responses(input: &str) -> Vec<Json> {
        let mut session = tiny_session();
        let mut out = Vec::new();
        serve(&mut session, Cursor::new(input.as_bytes().to_vec()), &mut out).unwrap();
        String::from_utf8(out)
            .unwrap()
            .lines()
            .map(|l| Json::parse(l).expect("every response line is valid JSON"))
            .collect()
    }

    #[test]
    fn full_round_trip() {
        let snap = std::env::temp_dir().join(format!(
            "stiknn_protocol_{}_roundtrip.snap",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&snap);
        let input = format!(
            concat!(
                r#"{{"cmd":"ingest","x":[0.5,0.5,-1.0,0.25],"y":[0,1]}}"#, "\n",
                r#"{{"cmd":"query","i":0,"j":1}}"#, "\n",
                r#"{{"cmd":"query","i":2}}"#, "\n",
                r#"{{"cmd":"topk","k":3,"by":"rowsum"}}"#, "\n",
                r#"{{"cmd":"stats"}}"#, "\n",
                r#"{{"cmd":"snapshot","path":"{}"}}"#, "\n",
                r#"{{"cmd":"shutdown"}}"#, "\n",
            ),
            snap.display()
        );
        let rs = responses(&input);
        assert_eq!(rs.len(), 7);
        for r in &rs {
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        }
        assert_eq!(rs[0].get("ingested").unwrap().as_usize(), Some(2));
        assert_eq!(rs[0].get("tests").unwrap().as_usize(), Some(2));
        assert!(rs[1].get("value").unwrap().as_f64().is_some());
        assert_eq!(rs[2].get("row").unwrap().as_arr().unwrap().len(), 8);
        assert_eq!(rs[3].get("points").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(rs[4].get("tests").unwrap().as_usize(), Some(2));
        assert!(snap.exists(), "snapshot file written");
        assert_eq!(rs[6].get("shutdown").unwrap().as_bool(), Some(true));
        let _ = std::fs::remove_file(&snap);
    }

    #[test]
    fn errors_do_not_kill_the_loop() {
        let input = concat!(
            "this is not json\n",
            r#"{"nocmd":1}"#, "\n",
            r#"{"cmd":"frobnicate"}"#, "\n",
            r#"{"cmd":"query","i":0,"j":1}"#, "\n", // empty session → error
            r#"{"cmd":"ingest","x":[0.1,0.2],"y":[0.5]}"#, "\n", // non-integer label
            r#"{"cmd":"ingest","x":[0.1],"y":[0]}"#, "\n", // shape mismatch
            r#"{"cmd":"stats"}"#, "\n",
        );
        let rs = responses(input);
        assert_eq!(rs.len(), 7);
        for r in &rs[..6] {
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
            assert!(r.get("error").unwrap().as_str().is_some());
        }
        // the loop survived everything above
        assert_eq!(rs[6].get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(rs[6].get("tests").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn ingest_rejects_out_of_range_input_without_corrupting_state() {
        let input = concat!(
            // f64 infinity via over-range literal
            r#"{"cmd":"ingest","x":[1e400,0.0],"y":[0]}"#, "\n",
            // finite f64 beyond f32 range would cast to f32 ∞
            r#"{"cmd":"ingest","x":[1e39,0.0],"y":[0]}"#, "\n",
            // integer label outside i32 range would saturate
            r#"{"cmd":"ingest","x":[0.1,0.2],"y":[3000000000]}"#, "\n",
            r#"{"cmd":"stats"}"#, "\n",
        );
        let rs = responses(input);
        assert_eq!(rs.len(), 4);
        for r in &rs[..3] {
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
        }
        // nothing leaked into the accumulator
        assert_eq!(rs[3].get("tests").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn shutdown_stops_processing_later_lines() {
        let input = concat!(
            r#"{"cmd":"shutdown"}"#, "\n",
            r#"{"cmd":"stats"}"#, "\n",
        );
        let rs = responses(input);
        assert_eq!(rs.len(), 1, "nothing after shutdown is answered");
    }

    #[test]
    fn blank_lines_are_skipped() {
        let rs = responses("\n   \n{\"cmd\":\"stats\"}\n");
        assert_eq!(rs.len(), 1);
    }

    #[test]
    fn invalid_utf8_input_gets_an_error_response_not_a_dead_session() {
        let mut input: Vec<u8> = Vec::new();
        input.extend_from_slice(b"\xff\xfe not utf8 \xff\n");
        input.extend_from_slice(b"{\"cmd\":\"stats\"}\n");
        let mut session = tiny_session();
        let mut out = Vec::new();
        serve(&mut session, Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let rs: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(rs.len(), 2, "{text}");
        assert_eq!(rs[0].get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(rs[1].get("ok").unwrap().as_bool(), Some(true), "loop survived");
    }

    #[test]
    fn implicit_engine_rejects_matrix_queries_with_engine_reason() {
        let mut s = tiny_session_with(SessionConfig::new(3).with_engine(Engine::Implicit));
        let (r, _) = handle(
            &mut s,
            r#"{"cmd":"ingest","x":[0.5,0.5,-1.0,0.25],"y":[0,1]}"#,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        // off-diagonal cell and full row: rejected with reason "engine"
        for q in [r#"{"cmd":"query","i":0,"j":1}"#, r#"{"cmd":"query","i":2}"#] {
            let (r, _) = handle(&mut s, q);
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
            assert_eq!(r.get("reason").unwrap().as_str(), Some("engine"), "{r}");
        }
        // diagonal cell, values, topk, stats all still work
        let (r, _) = handle(&mut s, r#"{"cmd":"query","i":2,"j":2}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        let (r, _) = handle(&mut s, r#"{"cmd":"values","i":0}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert!(r.get("rowsum").unwrap().as_f64().is_some());
        let (r, _) = handle(&mut s, r#"{"cmd":"topk","k":3,"by":"rowsum"}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        let (r, _) = handle(&mut s, r#"{"cmd":"stats"}"#);
        assert_eq!(r.get("engine").unwrap().as_str(), Some("implicit"), "{r}");
        // empty-session errors do NOT carry the engine reason
        let mut empty = tiny_session();
        let (r, _) = handle(&mut empty, r#"{"cmd":"query","i":0,"j":1}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        assert!(r.get("reason").is_none(), "{r}");
    }

    #[test]
    fn implicit_with_retained_rows_answers_matrix_queries() {
        let mut dense = tiny_session();
        let mut imp = tiny_session_with(
            SessionConfig::new(3)
                .with_engine(Engine::Implicit)
                .with_retained_rows(true),
        );
        let ingest = r#"{"cmd":"ingest","x":[0.5,0.5,-1.0,0.25],"y":[0,1]}"#;
        handle(&mut dense, ingest);
        handle(&mut imp, ingest);
        let (a, _) = handle(&mut dense, r#"{"cmd":"query","i":0,"j":1}"#);
        let (b, _) = handle(&mut imp, r#"{"cmd":"query","i":0,"j":1}"#);
        assert_eq!(b.get("ok").unwrap().as_bool(), Some(true), "{b}");
        let (av, bv) = (
            a.get("value").unwrap().as_f64().unwrap(),
            b.get("value").unwrap().as_f64().unwrap(),
        );
        assert!((av - bv).abs() < 1e-12, "{av} vs {bv}");
        let (r, _) = handle(&mut imp, r#"{"cmd":"query","i":2}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert_eq!(r.get("row").unwrap().as_arr().unwrap().len(), 8);
    }

    #[test]
    fn values_command_matches_topk_ranking() {
        let mut s = tiny_session();
        handle(
            &mut s,
            r#"{"cmd":"ingest","x":[0.5,0.5,-1.0,0.25],"y":[0,1]}"#,
        );
        let (all, _) = handle(&mut s, r#"{"cmd":"values"}"#);
        assert_eq!(all.get("ok").unwrap().as_bool(), Some(true), "{all}");
        let main = all.get("main").unwrap().as_arr().unwrap();
        let rowsum = all.get("rowsum").unwrap().as_arr().unwrap();
        assert_eq!(main.len(), 8);
        assert_eq!(rowsum.len(), 8);
        // single-point form agrees with the arrays
        let (one, _) = handle(&mut s, r#"{"cmd":"values","i":5}"#);
        assert_eq!(
            one.get("main").unwrap().as_f64().unwrap().to_bits(),
            main[5].as_f64().unwrap().to_bits()
        );
        // out-of-range index is a clean error
        let (bad, _) = handle(&mut s, r#"{"cmd":"values","i":8}"#);
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false), "{bad}");
    }

    #[test]
    fn ping_reports_state_and_never_mutates() {
        let mut s = tiny_session();
        let (r, shutdown) = handle(&mut s, r#"{"cmd":"ping"}"#);
        assert!(!shutdown);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert_eq!(r.get("engine").unwrap().as_str(), Some("dense"));
        assert_eq!(r.get("n").unwrap().as_usize(), Some(8));
        assert_eq!(r.get("t").unwrap().as_usize(), Some(0));
        assert_eq!(r.get("mutable").unwrap().as_bool(), Some(false));
        // still answers (and counts) correctly after an ingest
        handle(
            &mut s,
            r#"{"cmd":"ingest","x":[0.5,0.5,-1.0,0.25],"y":[0,1]}"#,
        );
        let (r, _) = handle(&mut s, r#"{"cmd":"ping"}"#);
        assert_eq!(r.get("t").unwrap().as_usize(), Some(2));
        assert_eq!(s.tests_seen(), 2, "ping must not touch state");
    }

    fn mutable_session() -> ValuationSession {
        tiny_session_with(
            SessionConfig::new(3)
                .with_engine(Engine::Implicit)
                .with_retained_rows(true)
                .with_mutable(true),
        )
    }

    #[test]
    fn mutation_commands_edit_a_mutable_session() {
        let mut s = mutable_session();
        handle(
            &mut s,
            r#"{"cmd":"ingest","x":[0.5,0.5,-1.0,0.25],"y":[0,1]}"#,
        );
        // add → new id 8, n grows to 9
        let (r, _) = handle(&mut s, r#"{"cmd":"add_train","x":[0.1,-0.2],"y":1}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert_eq!(r.get("index").unwrap().as_usize(), Some(8));
        assert_eq!(r.get("n").unwrap().as_usize(), Some(9));
        assert_eq!(r.get("mutations").unwrap().as_usize(), Some(1));
        // relabel
        let (r, _) = handle(&mut s, r#"{"cmd":"relabel","i":0,"y":1}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert_eq!(r.get("n").unwrap().as_usize(), Some(9));
        // remove → n shrinks back to 8
        let (r, _) = handle(&mut s, r#"{"cmd":"remove_train","i":8}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert_eq!(r.get("n").unwrap().as_usize(), Some(8));
        assert_eq!(r.get("mutations").unwrap().as_usize(), Some(3));
        // queries still served from the repaired state
        let (r, _) = handle(&mut s, r#"{"cmd":"query","i":0,"j":1}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        let (r, _) = handle(&mut s, r#"{"cmd":"values","i":0}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        // bad edits are clean per-line errors: out-of-range, bad label
        for bad in [
            r#"{"cmd":"remove_train","i":99}"#,
            r#"{"cmd":"relabel","i":0,"y":0.5}"#,
            r#"{"cmd":"add_train","x":[0.1],"y":0}"#,
        ] {
            let (r, _) = handle(&mut s, bad);
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
        }
    }

    #[test]
    fn mutation_commands_rejected_on_immutable_sessions_with_reason() {
        let mut s = tiny_session();
        for cmd in [
            r#"{"cmd":"add_train","x":[0.1,-0.2],"y":1}"#,
            r#"{"cmd":"remove_train","i":0}"#,
            r#"{"cmd":"relabel","i":0,"y":1}"#,
        ] {
            let (r, _) = handle(&mut s, cmd);
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
            assert_eq!(r.get("reason").unwrap().as_str(), Some("mutable"), "{r}");
        }
    }

    #[test]
    fn raw_fetches_are_unnormalized_and_transport_exact() {
        let mut s = tiny_session();
        // raw works on an EMPTY session (zeros, tests 0) — a zero-test
        // shard must contribute its exact additive identity to a merge
        let (r, _) = handle(&mut s, r#"{"cmd":"values","raw":true}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert_eq!(r.get("tests").unwrap().as_usize(), Some(0));
        assert!(r
            .get("main")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .all(|x| x.as_f64() == Some(0.0)));
        handle(
            &mut s,
            r#"{"cmd":"ingest","x":[0.5,0.5,-1.0,0.25],"y":[0,1]}"#,
        );
        let (raw, _) = handle(&mut s, r#"{"cmd":"values","raw":true}"#);
        let (norm, _) = handle(&mut s, r#"{"cmd":"values"}"#);
        let inv = 1.0 / raw.get("tests").unwrap().as_f64().unwrap();
        // raw × 1/t reproduces the normalized answers TO THE BIT — this
        // is both the Eq. 8 identity and the transport-exactness check
        // (finite f64 round-trips NDJSON unchanged)
        for key in ["main", "rowsum"] {
            let rs = raw.get(key).unwrap().as_arr().unwrap();
            let ns = norm.get(key).unwrap().as_arr().unwrap();
            for (a, b) in rs.iter().zip(ns) {
                assert_eq!(
                    (a.as_f64().unwrap() * inv).to_bits(),
                    b.as_f64().unwrap().to_bits()
                );
            }
        }
        let (c, _) = handle(&mut s, r#"{"cmd":"query","i":0,"j":1,"raw":true}"#);
        assert_eq!(c.get("tests").unwrap().as_usize(), Some(2));
        let (cn, _) = handle(&mut s, r#"{"cmd":"query","i":0,"j":1}"#);
        assert_eq!(
            (c.get("value").unwrap().as_f64().unwrap() * inv).to_bits(),
            cn.get("value").unwrap().as_f64().unwrap().to_bits()
        );
        let (row, _) = handle(&mut s, r#"{"cmd":"query","i":2,"raw":true}"#);
        let (rown, _) = handle(&mut s, r#"{"cmd":"query","i":2}"#);
        for (a, b) in row
            .get("row")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .zip(rown.get("row").unwrap().as_arr().unwrap())
        {
            assert_eq!(
                (a.as_f64().unwrap() * inv).to_bits(),
                b.as_f64().unwrap().to_bits()
            );
        }
        // raw + single-point form, and a non-boolean raw: clean errors
        for bad in [
            r#"{"cmd":"values","i":0,"raw":true}"#,
            r#"{"cmd":"values","raw":1}"#,
        ] {
            let (r, _) = handle(&mut s, bad);
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
        }
    }

    #[test]
    fn metrics_on_a_disabled_session_still_answers() {
        let mut s = tiny_session();
        let (r, _) = handle(&mut s, r#"{"cmd":"metrics"}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert_eq!(r.get("enabled").unwrap().as_bool(), Some(false));
        assert_eq!(r.get("scope").unwrap().as_str(), Some("session"));
        assert!(matches!(r.get("metrics"), Some(Json::Null)), "{r}");
        // single-metric lookup on a disabled session is a clean error
        let (r, _) = handle(&mut s, r#"{"cmd":"metrics","metric":"session.edits"}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
        assert!(
            r.get("error").unwrap().as_str().unwrap().contains("disabled"),
            "{r}"
        );
    }

    #[test]
    fn metrics_snapshot_reflects_traffic_and_lookup_finds_one_metric() {
        use crate::obs::ObsHandle;
        let mut s = tiny_session();
        s.set_obs(ObsHandle::enabled("proto-test"));
        handle(
            &mut s,
            r#"{"cmd":"ingest","x":[0.5,0.5,-1.0,0.25],"y":[0,1]}"#,
        );
        let (r, _) = handle(&mut s, r#"{"cmd":"metrics"}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert_eq!(r.get("enabled").unwrap().as_bool(), Some(true));
        assert_eq!(r.get("tests").unwrap().as_usize(), Some(2));
        let snap = r.get("metrics").unwrap();
        let counters = snap.get("counters").unwrap();
        assert_eq!(
            counters.get("session.ingest_batches").and_then(Json::as_usize),
            Some(1),
            "{snap}"
        );
        assert_eq!(
            counters.get("session.ingest_points").and_then(Json::as_usize),
            Some(2)
        );
        let hist = snap.get("histograms").unwrap().get("session.ingest_ns");
        assert_eq!(
            hist.and_then(|h| h.get("count")).and_then(Json::as_usize),
            Some(1),
            "{snap}"
        );
        // single-metric lookup answers with just that value
        let (one, _) = handle(&mut s, r#"{"cmd":"metrics","metric":"session.ingest_points"}"#);
        assert_eq!(one.get("ok").unwrap().as_bool(), Some(true), "{one}");
        assert_eq!(one.get("value").unwrap().as_usize(), Some(2));
        // unknown metric → clean per-line error naming the metric
        let (bad, _) = handle(&mut s, r#"{"cmd":"metrics","metric":"no.such"}"#);
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false), "{bad}");
        assert!(
            bad.get("error").unwrap().as_str().unwrap().contains("unknown metric"),
            "{bad}"
        );
    }

    #[test]
    fn traced_requests_echo_member_spans_untraced_do_not() {
        use crate::obs::TraceHandle;
        let mut s = tiny_session();
        s.set_trace(TraceHandle::enabled());
        // Untraced request: NO "spans" key, even with tracing enabled —
        // the echo only rides on requests that carried context.
        let (r, _) = handle(
            &mut s,
            r#"{"cmd":"ingest","x":[0.5,0.5,-1.0,0.25],"y":[0,1]}"#,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert!(r.get("spans").is_none(), "{r}");
        // Traced request: member.<cmd> (adopted under the carried parent)
        // plus the nested session.ingest span echo back.
        let (r, _) = handle(
            &mut s,
            r#"{"cmd":"ingest","x":[0.25,-0.5],"y":[1],"trace":{"id":"00000000000000aa","parent":"00000000000000aa"}}"#,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        let spans = r.get("spans").unwrap().as_arr().unwrap();
        assert!(spans.len() >= 2, "{r}");
        for sp in spans {
            assert_eq!(sp.get("trace").unwrap().as_str(), Some("00000000000000aa"));
        }
        let member = spans
            .iter()
            .find(|sp| sp.get("name").unwrap().as_str() == Some("member.ingest"))
            .expect("member span echoed");
        assert_eq!(
            member.get("parent").unwrap().as_str(),
            Some("00000000000000aa")
        );
        let ingest = spans
            .iter()
            .find(|sp| sp.get("name").unwrap().as_str() == Some("session.ingest"))
            .expect("session span echoed");
        assert_eq!(
            ingest.get("parent").unwrap().as_str(),
            member.get("span").unwrap().as_str(),
            "session span nests under the member span"
        );
        // The sticky scope was cleared: a later untraced ingest's span is
        // a fresh ROOT, not a child of the finished member span.
        let (r, _) = handle(&mut s, r#"{"cmd":"ingest","x":[0.0,1.0],"y":[0]}"#);
        assert!(r.get("spans").is_none(), "{r}");
        let roots = s.trace().recent_roots(16);
        assert!(
            roots.iter().any(|sp| sp.name == "session.ingest"),
            "untraced ingest after a traced one starts its own root"
        );
    }

    #[test]
    fn trace_context_on_a_trace_disabled_session_is_harmless() {
        let mut s = tiny_session();
        let (r, _) = handle(
            &mut s,
            r#"{"cmd":"ingest","x":[0.5,0.5],"y":[0],"trace":{"id":"0000000000000001","parent":"0000000000000001"}}"#,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert!(r.get("spans").is_none(), "no store, nothing to echo: {r}");
        // Malformed carriers are ignored, never an error.
        let (r, _) = handle(&mut s, r#"{"cmd":"stats","trace":"not an object"}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        let (r, _) = handle(&mut s, r#"{"cmd":"stats","trace":{"id":"xyz"}}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    }

    #[test]
    fn ingested_values_match_direct_session_use() {
        let mut a = tiny_session();
        let mut b = tiny_session();
        let qx = [0.5f32, 0.5, -1.0, 0.25];
        let qy = [0i32, 1];
        a.ingest(&qx, &qy).unwrap();
        let (resp, _) = handle(
            &mut b,
            r#"{"cmd":"ingest","x":[0.5,0.5,-1.0,0.25],"y":[0,1]}"#,
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let (cell, _) = handle(&mut b, r#"{"cmd":"query","i":0,"j":1}"#);
        let via_protocol = cell.get("value").unwrap().as_f64().unwrap();
        assert_eq!(via_protocol.to_bits(), a.cell(0, 1).unwrap().to_bits());
    }
}
