//! Versioned binary snapshot store for [`ValuationSession`]s
//! (DESIGN.md §9/§10).
//!
//! A snapshot captures everything a session needs to resume exactly where
//! it left off: the engine payload (RAW unnormalized accumulator for
//! dense sessions, RAW value vector for implicit ones), the test count,
//! and the per-batch weight ledger, guarded by enough metadata to refuse
//! a mismatched resume (k, metric, train-set fingerprint). Restore is
//! **bit-identical**: f64 cells round-trip through `to_le_bytes`/
//! `from_le_bytes`, which preserve every bit pattern including ±0 and
//! NaN payloads, so a snapshot/restore cycle mid-stream cannot perturb
//! the final state (asserted by `tests/session_equivalence.rs` and
//! `tests/values_equivalence.rs`).
//!
//! ## Format (version 3, all integers and floats little-endian)
//!
//! ```text
//! offset  size        field
//! 0       8           magic  b"STIKNNSS"
//! 8       4           format version (u32) = 3
//! 12      4           k (u32)
//! 16      1           metric tag (u8): 0 = sqeuclidean, 1 = manhattan, 2 = cosine
//! 17      1           payload kind (u8): 0 = dense matrix, 1 = implicit value
//!                     vector, 2 = mutable session (v3+ only)
//! 18      8           n, train-set size (u64)
//! 26      8           d, feature dimension (u64)
//! 34      8           train-set fingerprint (u64, FNV-1a over d, n, features, labels)
//! 42      8           total test points ingested t (u64)
//! 50      8           ledger length L (u64)
//! 58      16·L        ledger entries: (seq u64, len u64) per ingested batch
//! 58+16L  payload     kind 0: 8·n² raw accumulator, row-major f64
//!                             (upper triangle + diagonal)
//!                     kind 1: 8·n raw main sums, then 8·n raw
//!                             interaction-rowsum sums (f64 each)
//!                     kind 2 (a mutable session's COMPLETE state, §11):
//!                             8·n main, 8·n inter        (raw value vector)
//!                             4·n·d train features (f32) + 4·n labels (i32)
//!                             4·t·d test features (f32)  + 4·t labels (i32)
//!                             4·t·n rank (u32) + 8·t·n colval (f64)
//!                             8·t·n dist (f64) + 4·t·n pos (u32)
//!                             8 mutation-ledger length M (u64)
//!                             21·M records: seq u64, op tag u8, index u64,
//!                                           label i32
//! end−8   8           FNV-1a checksum over every preceding byte (u64)
//! ```
//!
//! Version 1 files (written before the implicit engine existed) are the
//! same layout WITHOUT the payload-kind byte and always carry a dense
//! matrix payload; version 2 files are identical to version 3 for kinds
//! 0/1. [`decode`] reads all of them, so old snapshots restore into
//! current builds — immutably (mutable state only exists in kind-2
//! payloads).

use super::BatchRecord;
use crate::knn::distance::Metric;
use crate::shapley::delta::{MutationOp, MutationRecord};
use crate::shapley::values::Engine;
use crate::util::matrix::Matrix;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

/// First 8 bytes of every snapshot file.
pub const MAGIC: [u8; 8] = *b"STIKNNSS";

/// Current snapshot format version.
pub const VERSION: u32 = 3;

/// Oldest version [`decode`] still reads.
pub const MIN_VERSION: u32 = 1;

/// Payload-kind byte for a mutable-session snapshot (kinds 0/1 are the
/// [`Engine`] tags; never renumber).
pub const MUTABLE_TAG: u8 = 2;

/// Bytes per serialized [`MutationRecord`]: seq u64 + op u8 + index u64
/// + label i32.
const MUTATION_RECORD_BYTES: usize = 21;

/// Decoded snapshot metadata (everything but the ledger and the payload).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SnapshotHeader {
    pub version: u32,
    pub k: u32,
    pub metric: Metric,
    /// Which engine wrote the payload (v1 files are always `Dense`;
    /// mutable snapshots are `Implicit` — see [`Self::mutable`]).
    pub engine: Engine,
    /// Whether the payload is a complete mutable-session state (kind 2,
    /// v3+): train set + retained rows + mutation ledger persisted.
    pub mutable: bool,
    pub n: u64,
    pub d: u64,
    pub fingerprint: u64,
    pub tests: u64,
    /// Ledger ENTRY count — after compaction one entry may cover many
    /// ingests; the lifetime batch count is `last ledger seq + 1`.
    pub batches: u64,
}

/// A mutable session's complete persisted state (kind-2 payload): the
/// raw value vector, the LIVE train set (the whole point — after edits
/// it matches no external dataset), the retained test set, and the
/// per-test rank-space rows the delta repairs consume (DESIGN.md §11).
#[derive(Clone, Debug)]
pub struct MutablePayload {
    pub main: Vec<f64>,
    pub inter: Vec<f64>,
    pub train_x: Vec<f32>,
    pub train_y: Vec<i32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
    /// Per-test rank rows, train order, t·n.
    pub rank: Vec<u32>,
    /// Per-test column-value rows, train order, t·n.
    pub colval: Vec<f64>,
    /// Per-test sorted distances, rank order, t·n.
    pub dist: Vec<f64>,
    /// Per-test rank→original-index permutations, t·n.
    pub pos: Vec<u32>,
}

/// The engine-specific state a snapshot carries (all raw/unnormalized).
#[derive(Clone, Debug)]
pub enum SnapshotPayload {
    /// Accumulator as stored: upper triangle + diagonal populated,
    /// strict lower triangle all zeros.
    Dense(Matrix),
    /// Value vector sums: `main[i]` = Σ_p u_p(i), `inter[i]` =
    /// Σ_p Σ_{j≠i} φ_p[i,j].
    Implicit { main: Vec<f64>, inter: Vec<f64> },
    /// A mutable session's complete state (boxed — it is by far the
    /// largest variant).
    Mutable(Box<MutablePayload>),
}

/// A fully decoded (and checksum-verified) snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub header: SnapshotHeader,
    pub ledger: Vec<BatchRecord>,
    /// The mutation ledger (kind-2 payloads only; empty otherwise).
    pub mutations: Vec<MutationRecord>,
    pub payload: SnapshotPayload,
}

impl Snapshot {
    /// The averaged interaction matrix this snapshot represents (mirror +
    /// scale by 1/tests, exactly like the live session / one-shot
    /// `sti_knn`). `None` before any test points were ingested or when
    /// the payload is a value vector (implicit sessions never had one).
    pub fn averaged_matrix(&self) -> Option<Matrix> {
        if self.header.tests == 0 {
            return None;
        }
        match &self.payload {
            SnapshotPayload::Dense(raw) => {
                let mut m = raw.clone();
                m.mirror_upper_to_lower();
                m.scale(1.0 / self.header.tests as f64);
                Some(m)
            }
            SnapshotPayload::Implicit { .. } | SnapshotPayload::Mutable(_) => None,
        }
    }

    /// Averaged per-point values straight from the snapshot (no training
    /// data needed) — works for BOTH payload kinds. `None` before any
    /// test points were ingested.
    pub fn point_values(&self, by: super::TopBy) -> Option<Vec<f64>> {
        if self.header.tests == 0 {
            return None;
        }
        let inv_w = 1.0 / self.header.tests as f64;
        fn from_vectors(main: &[f64], inter: &[f64], inv_w: f64, by: super::TopBy) -> Vec<f64> {
            match by {
                super::TopBy::Main => main.iter().map(|&m| m * inv_w).collect(),
                super::TopBy::RowSum => main
                    .iter()
                    .zip(inter)
                    .map(|(&m, &s)| (m + s) * inv_w)
                    .collect(),
            }
        }
        Some(match &self.payload {
            SnapshotPayload::Dense(raw) => super::point_values_raw(raw, inv_w, by),
            SnapshotPayload::Implicit { main, inter } => from_vectors(main, inter, inv_w, by),
            SnapshotPayload::Mutable(p) => from_vectors(&p.main, &p.inter, inv_w, by),
        })
    }

    /// Top-k point values straight from the snapshot. `None` before any
    /// test points were ingested.
    pub fn top_k(&self, k: usize, by: super::TopBy) -> Option<Vec<(usize, f64)>> {
        Some(super::top_k_of(&self.point_values(by)?, k))
    }
}

/// Stable wire tag for a metric (part of the snapshot format — never
/// renumber existing variants).
pub fn metric_tag(metric: Metric) -> u8 {
    match metric {
        Metric::SqEuclidean => 0,
        Metric::Manhattan => 1,
        Metric::Cosine => 2,
    }
}

/// Inverse of [`metric_tag`].
pub fn metric_from_tag(tag: u8) -> Option<Metric> {
    match tag {
        0 => Some(Metric::SqEuclidean),
        1 => Some(Metric::Manhattan),
        2 => Some(Metric::Cosine),
        _ => None,
    }
}

/// Stable wire tag for a payload kind (never renumber).
pub fn payload_tag(engine: Engine) -> u8 {
    match engine {
        Engine::Dense => 0,
        Engine::Implicit => 1,
    }
}

/// Inverse of [`payload_tag`].
pub fn engine_from_tag(tag: u8) -> Option<Engine> {
    match tag {
        0 => Some(Engine::Dense),
        1 => Some(Engine::Implicit),
        _ => None,
    }
}

/// Incremental FNV-1a (64-bit) — the snapshot checksum and the train-set
/// fingerprint hash. Not cryptographic; detects corruption and honest
/// mismatches, which is the contract here.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// The snapshot checksum function (FNV-1a, 64-bit) over a byte slice —
/// exposed so external tooling (and the corruption tests) can craft or
/// verify snapshot trailers without reimplementing the hash.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv::new();
    h.write(bytes);
    h.finish()
}

/// Identity of a training set for snapshot-compatibility checks: FNV-1a
/// over (d, n, feature bits, labels). Two train sets fingerprint equal
/// iff they are bitwise the same data in the same order — exactly the
/// condition under which a resumed session keeps producing bit-identical
/// results.
pub fn dataset_fingerprint(train_x: &[f32], train_y: &[i32], d: usize) -> u64 {
    let mut h = Fnv::new();
    h.write(&(d as u64).to_le_bytes());
    h.write(&(train_y.len() as u64).to_le_bytes());
    for v in train_x {
        h.write(&v.to_le_bytes());
    }
    for v in train_y {
        h.write(&v.to_le_bytes());
    }
    h.finish()
}

/// Borrowed payload for [`encode`].
#[derive(Clone, Copy, Debug)]
pub enum EncodePayload<'a> {
    /// Raw n×n accumulator, row-major.
    Dense(&'a [f64]),
    /// Raw value-vector sums, n each.
    Implicit { main: &'a [f64], inter: &'a [f64] },
    /// A mutable session's complete state (see [`MutablePayload`] for
    /// the field shapes; t = the `tests` header field).
    Mutable {
        main: &'a [f64],
        inter: &'a [f64],
        train_x: &'a [f32],
        train_y: &'a [i32],
        test_x: &'a [f32],
        test_y: &'a [i32],
        rank: &'a [u32],
        colval: &'a [f64],
        dist: &'a [f64],
        pos: &'a [u32],
    },
}

/// Serialize one snapshot to its byte representation (always the current
/// format version). `mutations` must be empty unless the payload is
/// [`EncodePayload::Mutable`] — only kind-2 payloads carry the mutation
/// ledger on the wire.
#[allow(clippy::too_many_arguments)]
pub fn encode(
    k: u32,
    metric: Metric,
    n: u64,
    d: u64,
    fingerprint: u64,
    tests: u64,
    ledger: &[BatchRecord],
    mutations: &[MutationRecord],
    payload: EncodePayload<'_>,
) -> Vec<u8> {
    let (kind, payload_bytes) = match payload {
        EncodePayload::Dense(raw) => {
            assert_eq!(raw.len() as u64, n * n, "raw accumulator shape mismatch");
            assert!(mutations.is_empty(), "dense snapshots carry no mutations");
            (payload_tag(Engine::Dense), 8 * raw.len())
        }
        EncodePayload::Implicit { main, inter } => {
            assert_eq!(main.len() as u64, n, "main vector shape mismatch");
            assert_eq!(inter.len() as u64, n, "inter vector shape mismatch");
            assert!(mutations.is_empty(), "implicit snapshots carry no mutations");
            (payload_tag(Engine::Implicit), 8 * (main.len() + inter.len()))
        }
        EncodePayload::Mutable {
            main,
            inter,
            train_x,
            train_y,
            test_x,
            test_y,
            rank,
            colval,
            dist,
            pos,
        } => {
            let (nn, tt, dd) = (n as usize, tests as usize, d as usize);
            assert_eq!(main.len(), nn, "main vector shape mismatch");
            assert_eq!(inter.len(), nn, "inter vector shape mismatch");
            assert_eq!(train_x.len(), nn * dd, "train feature shape mismatch");
            assert_eq!(train_y.len(), nn, "train label shape mismatch");
            assert_eq!(test_x.len(), tt * dd, "test feature shape mismatch");
            assert_eq!(test_y.len(), tt, "test label shape mismatch");
            assert_eq!(rank.len(), tt * nn, "rank rows shape mismatch");
            assert_eq!(colval.len(), tt * nn, "colval rows shape mismatch");
            assert_eq!(dist.len(), tt * nn, "dist rows shape mismatch");
            assert_eq!(pos.len(), tt * nn, "pos rows shape mismatch");
            (
                MUTABLE_TAG,
                16 * nn + 4 * nn * dd + 4 * nn + 4 * tt * dd + 4 * tt + 24 * tt * nn
                    + 8
                    + MUTATION_RECORD_BYTES * mutations.len(),
            )
        }
    };
    let mut out = Vec::with_capacity(58 + 16 * ledger.len() + payload_bytes + 8);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&k.to_le_bytes());
    out.push(metric_tag(metric));
    out.push(kind);
    out.extend_from_slice(&n.to_le_bytes());
    out.extend_from_slice(&d.to_le_bytes());
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out.extend_from_slice(&tests.to_le_bytes());
    out.extend_from_slice(&(ledger.len() as u64).to_le_bytes());
    for rec in ledger {
        out.extend_from_slice(&rec.seq.to_le_bytes());
        out.extend_from_slice(&rec.len.to_le_bytes());
    }
    fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
        for v in vs {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
        for v in vs {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn put_i32s(out: &mut Vec<u8>, vs: &[i32]) {
        for v in vs {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn put_u32s(out: &mut Vec<u8>, vs: &[u32]) {
        for v in vs {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    match payload {
        EncodePayload::Dense(raw) => put_f64s(&mut out, raw),
        EncodePayload::Implicit { main, inter } => {
            put_f64s(&mut out, main);
            put_f64s(&mut out, inter);
        }
        EncodePayload::Mutable {
            main,
            inter,
            train_x,
            train_y,
            test_x,
            test_y,
            rank,
            colval,
            dist,
            pos,
        } => {
            put_f64s(&mut out, main);
            put_f64s(&mut out, inter);
            put_f32s(&mut out, train_x);
            put_i32s(&mut out, train_y);
            put_f32s(&mut out, test_x);
            put_i32s(&mut out, test_y);
            put_u32s(&mut out, rank);
            put_f64s(&mut out, colval);
            put_f64s(&mut out, dist);
            put_u32s(&mut out, pos);
            out.extend_from_slice(&(mutations.len() as u64).to_le_bytes());
            for m in mutations {
                out.extend_from_slice(&m.seq.to_le_bytes());
                out.push(m.op.tag());
                out.extend_from_slice(&m.index.to_le_bytes());
                out.extend_from_slice(&m.label.to_le_bytes());
            }
        }
    }
    let checksum = fnv1a(&out);
    out.extend_from_slice(&checksum.to_le_bytes());
    out
}

/// Byte-stream cursor for decoding.
struct Rd<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, len: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + len <= self.bytes.len(),
            "snapshot truncated at byte {} (wanted {} more)",
            self.pos,
            len
        );
        let s = &self.bytes[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64_vec(&mut self, len: usize) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    fn f32_vec(&mut self, len: usize) -> Result<Vec<f32>> {
        let bytes = self.take(len * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect())
    }

    fn i32_vec(&mut self, len: usize) -> Result<Vec<i32>> {
        let bytes = self.take(len * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
            .collect())
    }

    fn u32_vec(&mut self, len: usize) -> Result<Vec<u32>> {
        let bytes = self.take(len * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
            .collect())
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

/// Decode and fully validate a snapshot byte stream (magic, version,
/// checksum, internal consistency). Reads versions [`MIN_VERSION`]
/// through [`VERSION`].
pub fn decode(bytes: &[u8]) -> Result<Snapshot> {
    ensure!(bytes.len() >= 57 + 8, "snapshot too short ({} bytes)", bytes.len());
    // Checksum first: everything else assumes intact bytes.
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    let mut h = Fnv::new();
    h.write(body);
    ensure!(
        h.finish() == stored,
        "snapshot checksum mismatch (file corrupt or not a snapshot)"
    );

    let mut rd = Rd { bytes: body, pos: 0 };
    let magic = rd.take(8)?;
    ensure!(magic == &MAGIC[..], "bad snapshot magic {:02x?}", magic);
    let version = rd.u32()?;
    if !(MIN_VERSION..=VERSION).contains(&version) {
        bail!(
            "unsupported snapshot version {version} (this build reads versions \
             {MIN_VERSION}..={VERSION})"
        );
    }
    let k = rd.u32()?;
    let metric_tag = rd.u8()?;
    let Some(metric) = metric_from_tag(metric_tag) else {
        bail!("unknown metric tag {metric_tag} in snapshot");
    };
    // v1 predates the payload-kind byte: those files are always dense.
    let (engine, mutable) = if version >= 2 {
        let tag = rd.u8()?;
        if tag == MUTABLE_TAG {
            if version < 3 {
                bail!("mutable payload (kind 2) in a version-{version} snapshot (needs v3)");
            }
            (Engine::Implicit, true)
        } else {
            let Some(engine) = engine_from_tag(tag) else {
                bail!("unknown payload kind {tag} in snapshot");
            };
            (engine, false)
        }
    } else {
        (Engine::Dense, false)
    };
    let n = rd.u64()?;
    let d = rd.u64()?;
    let fingerprint = rd.u64()?;
    let tests = rd.u64()?;
    let ledger_len = rd.u64()?;

    // Shape sanity BEFORE allocating anything sized by file contents: the
    // remaining body must be exactly ledger + payload. Every multiplication
    // is checked — a crafted header must produce a clean error, not a
    // wrap-around that defeats this guard (the checksum is FNV, not a MAC,
    // so headers are attacker-controllable). For mutable payloads the
    // mutation-ledger length is not in the header, so the check is
    // "fixed part exact, remainder a whole number of records" here and
    // an exact length check once the record count is read.
    let (nn, dd, tt) = (n as usize, d as usize, tests as usize);
    let fixed_payload_bytes = if mutable {
        // main+inter, train x/y, test x/y, rank+colval+dist+pos, M count
        (|| {
            let main_inter = nn.checked_mul(16)?;
            let train = nn.checked_mul(dd)?.checked_mul(4)?.checked_add(nn.checked_mul(4)?)?;
            let test = tt.checked_mul(dd)?.checked_mul(4)?.checked_add(tt.checked_mul(4)?)?;
            let rows = tt.checked_mul(nn)?.checked_mul(24)?;
            main_inter
                .checked_add(train)?
                .checked_add(test)?
                .checked_add(rows)?
                .checked_add(8)
        })()
    } else {
        match engine {
            Engine::Dense => nn.checked_mul(nn).and_then(|c| c.checked_mul(8)),
            Engine::Implicit => nn.checked_mul(16),
        }
    };
    let expected = (ledger_len as usize)
        .checked_mul(16)
        .and_then(|l| fixed_payload_bytes.and_then(|p| l.checked_add(p)));
    let Some(expected_bytes) = expected else {
        bail!("snapshot header sizes overflow (n={n}, d={d}, tests={tests}, ledger={ledger_len})");
    };
    let remaining = body.len() - rd.pos;
    if mutable {
        ensure!(
            remaining >= expected_bytes
                && (remaining - expected_bytes) % MUTATION_RECORD_BYTES == 0,
            "snapshot body is {remaining} bytes but header implies {expected_bytes} \
             plus whole mutation records (n={n}, d={d}, tests={tests}, ledger={ledger_len})"
        );
    } else {
        ensure!(
            remaining == expected_bytes,
            "snapshot body is {remaining} bytes but header implies {expected_bytes} \
             (n={n}, ledger={ledger_len})"
        );
    }

    let mut ledger = Vec::with_capacity(ledger_len as usize);
    let mut ledger_total = 0u64;
    for _ in 0..ledger_len {
        let seq = rd.u64()?;
        let len = rd.u64()?;
        ledger_total = ledger_total
            .checked_add(len)
            .ok_or_else(|| anyhow::anyhow!("weight ledger sum overflows u64"))?;
        ledger.push(BatchRecord { seq, len });
    }
    ensure!(
        ledger_total == tests,
        "weight ledger sums to {ledger_total} but snapshot records {tests} tests"
    );

    let mut mutations = Vec::new();
    let payload = if mutable {
        let main = rd.f64_vec(nn)?;
        let inter = rd.f64_vec(nn)?;
        let train_x = rd.f32_vec(nn * dd)?;
        let train_y = rd.i32_vec(nn)?;
        let test_x = rd.f32_vec(tt * dd)?;
        let test_y = rd.i32_vec(tt)?;
        let rank = rd.u32_vec(tt * nn)?;
        let colval = rd.f64_vec(tt * nn)?;
        let dist = rd.f64_vec(tt * nn)?;
        let pos = rd.u32_vec(tt * nn)?;
        let m_count = rd.u64()? as usize;
        // checked: m_count is attacker-controllable and must not wrap
        ensure!(
            m_count.checked_mul(MUTATION_RECORD_BYTES) == Some(body.len() - rd.pos),
            "mutation ledger records {m_count} entries but {} bytes remain",
            body.len() - rd.pos
        );
        mutations.reserve(m_count);
        for _ in 0..m_count {
            let seq = rd.u64()?;
            let tag = rd.u8()?;
            let Some(op) = MutationOp::from_tag(tag) else {
                bail!("unknown mutation op tag {tag} in snapshot");
            };
            let index = rd.u64()?;
            let label = rd.i32()?;
            mutations.push(MutationRecord {
                seq,
                op,
                index,
                label,
            });
        }
        SnapshotPayload::Mutable(Box::new(MutablePayload {
            main,
            inter,
            train_x,
            train_y,
            test_x,
            test_y,
            rank,
            colval,
            dist,
            pos,
        }))
    } else {
        match engine {
            Engine::Dense => {
                let raw = rd.f64_vec(nn * nn)?;
                SnapshotPayload::Dense(Matrix::from_vec(nn, nn, raw))
            }
            Engine::Implicit => {
                let main = rd.f64_vec(nn)?;
                let inter = rd.f64_vec(nn)?;
                SnapshotPayload::Implicit { main, inter }
            }
        }
    };

    Ok(Snapshot {
        header: SnapshotHeader {
            version,
            k,
            metric,
            engine,
            mutable,
            n,
            d,
            fingerprint,
            tests,
            batches: ledger_len,
        },
        ledger,
        mutations,
        payload,
    })
}

/// Read + decode a snapshot file.
pub fn read_snapshot(path: &Path) -> Result<Snapshot> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading snapshot {}", path.display()))?;
    decode(&bytes).with_context(|| format!("decoding snapshot {}", path.display()))
}

/// Decode ONLY the fixed-size header prefix of a snapshot file — the
/// cheap peek the server registry uses to derive a session's config
/// (k, metric, engine, mutable) from a snapshot before paying for the
/// full restore, and to describe spilled sessions without loading them.
///
/// NOT checksum-verified: the checksum trails the whole file, so a peek
/// would have to read everything to check it — exactly what this avoids.
/// Any action taken on the header (an actual restore) re-reads the file
/// through [`read_snapshot`], which verifies it completely.
pub fn read_header(path: &Path) -> Result<SnapshotHeader> {
    use std::io::Read;
    let f = std::fs::File::open(path)
        .with_context(|| format!("reading snapshot {}", path.display()))?;
    // v1 headers are 57 bytes (no payload-kind byte), v2/v3 are 58.
    let mut buf = Vec::with_capacity(58);
    f.take(58)
        .read_to_end(&mut buf)
        .with_context(|| format!("reading snapshot {}", path.display()))?;
    decode_header(&buf)
        .with_context(|| format!("decoding snapshot header {}", path.display()))
}

/// Byte-level twin of [`read_header`] — public so the fuzz harness
/// (`stiknn::verify`) can drive the header parser on raw untrusted
/// bytes without touching the filesystem.
pub fn decode_header(bytes: &[u8]) -> Result<SnapshotHeader> {
    let mut rd = Rd { bytes, pos: 0 };
    let magic = rd.take(8)?;
    ensure!(magic == &MAGIC[..], "bad snapshot magic {:02x?}", magic);
    let version = rd.u32()?;
    if !(MIN_VERSION..=VERSION).contains(&version) {
        bail!(
            "unsupported snapshot version {version} (this build reads versions \
             {MIN_VERSION}..={VERSION})"
        );
    }
    let k = rd.u32()?;
    let metric_tag = rd.u8()?;
    let Some(metric) = metric_from_tag(metric_tag) else {
        bail!("unknown metric tag {metric_tag} in snapshot");
    };
    let (engine, mutable) = if version >= 2 {
        let tag = rd.u8()?;
        if tag == MUTABLE_TAG {
            if version < 3 {
                bail!("mutable payload (kind 2) in a version-{version} snapshot (needs v3)");
            }
            (Engine::Implicit, true)
        } else {
            let Some(engine) = engine_from_tag(tag) else {
                bail!("unknown payload kind {tag} in snapshot");
            };
            (engine, false)
        }
    } else {
        (Engine::Dense, false)
    };
    Ok(SnapshotHeader {
        version,
        k,
        metric,
        engine,
        mutable,
        n: rd.u64()?,
        d: rd.u64()?,
        fingerprint: rd.u64()?,
        tests: rd.u64()?,
        batches: rd.u64()?,
    })
}

/// Where the server registry spills/checkpoints the session `name`
/// inside `dir`. `name` must already be registry-validated (the registry
/// only admits `[A-Za-z0-9._-]` names, so the join cannot traverse).
pub fn spill_path(dir: &Path, name: &str) -> std::path::PathBuf {
    dir.join(format!("{name}.session.snap"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        let raw: Vec<f64> = (0..9).map(|i| i as f64 * 0.25 - 1.0).collect();
        encode(
            3,
            Metric::SqEuclidean,
            3,
            2,
            0xDEAD_BEEF,
            5,
            &[BatchRecord { seq: 0, len: 2 }, BatchRecord { seq: 1, len: 3 }],
            &[],
            EncodePayload::Dense(&raw),
        )
    }

    fn sample_implicit() -> Vec<u8> {
        encode(
            2,
            Metric::Manhattan,
            3,
            4,
            0xFEED_F00D,
            7,
            &[BatchRecord { seq: 0, len: 7 }],
            &[],
            EncodePayload::Implicit {
                main: &[0.5, 0.0, 1.5],
                inter: &[-0.25, 0.75, -1.0],
            },
        )
    }

    /// A tiny mutable-session snapshot: n=2, d=1, t=1, one mutation.
    fn sample_mutable() -> Vec<u8> {
        encode(
            1,
            Metric::SqEuclidean,
            2,
            1,
            0xCAFE,
            1,
            &[BatchRecord { seq: 0, len: 1 }],
            &[MutationRecord {
                seq: 0,
                op: MutationOp::Relabel,
                index: 1,
                label: -3,
            }],
            EncodePayload::Mutable {
                main: &[1.0, 0.0],
                inter: &[-0.5, -0.5],
                train_x: &[0.25, 0.75],
                train_y: &[1, -3],
                test_x: &[0.3],
                test_y: &[1],
                rank: &[0, 1],
                colval: &[-0.5, -0.5],
                dist: &[0.0025, 0.2025],
                pos: &[0, 1],
            },
        )
    }

    /// Hand-build a VERSION-1 byte stream (pre-implicit layout: no
    /// payload-kind byte, dense matrix payload) — the read-compat fixture.
    fn sample_v1() -> Vec<u8> {
        let raw: Vec<f64> = (0..4).map(|i| i as f64).collect();
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&2u32.to_le_bytes()); // k
        out.push(metric_tag(Metric::SqEuclidean));
        out.extend_from_slice(&2u64.to_le_bytes()); // n
        out.extend_from_slice(&1u64.to_le_bytes()); // d
        out.extend_from_slice(&0x1234u64.to_le_bytes()); // fingerprint
        out.extend_from_slice(&3u64.to_le_bytes()); // tests
        out.extend_from_slice(&1u64.to_le_bytes()); // ledger len
        out.extend_from_slice(&0u64.to_le_bytes()); // seq
        out.extend_from_slice(&3u64.to_le_bytes()); // len
        for v in &raw {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let mut h = Fnv::new();
        h.write(&out);
        let sum = h.finish();
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    #[test]
    fn roundtrip_preserves_everything_bitwise() {
        let bytes = sample();
        let snap = decode(&bytes).unwrap();
        assert_eq!(snap.header.version, VERSION);
        assert_eq!(snap.header.k, 3);
        assert_eq!(snap.header.metric, Metric::SqEuclidean);
        assert_eq!(snap.header.engine, Engine::Dense);
        assert_eq!(snap.header.n, 3);
        assert_eq!(snap.header.d, 2);
        assert_eq!(snap.header.fingerprint, 0xDEAD_BEEF);
        assert_eq!(snap.header.tests, 5);
        assert_eq!(snap.header.batches, 2);
        assert_eq!(snap.ledger, vec![
            BatchRecord { seq: 0, len: 2 },
            BatchRecord { seq: 1, len: 3 },
        ]);
        let SnapshotPayload::Dense(raw) = &snap.payload else {
            panic!("dense payload expected");
        };
        for (i, v) in raw.data().iter().enumerate() {
            assert_eq!(v.to_bits(), (i as f64 * 0.25 - 1.0).to_bits());
        }
        // re-encoding the decoded snapshot reproduces the bytes exactly
        let again = encode(3, Metric::SqEuclidean, 3, 2, 0xDEAD_BEEF, 5, &snap.ledger,
            &[], EncodePayload::Dense(raw.data()));
        assert_eq!(bytes, again);
    }

    #[test]
    fn implicit_payload_roundtrips_bitwise() {
        let bytes = sample_implicit();
        let snap = decode(&bytes).unwrap();
        assert_eq!(snap.header.engine, Engine::Implicit);
        assert_eq!(snap.header.tests, 7);
        let SnapshotPayload::Implicit { main, inter } = &snap.payload else {
            panic!("implicit payload expected");
        };
        assert_eq!(main.as_slice(), &[0.5, 0.0, 1.5]);
        assert_eq!(inter.as_slice(), &[-0.25, 0.75, -1.0]);
        // no matrix ever existed → averaged_matrix is None, values work
        assert!(snap.averaged_matrix().is_none());
        let top = snap.top_k(3, crate::session::TopBy::RowSum).unwrap();
        // rowsum/7: [0.25/7, 0.75/7, 0.5/7] → index order 1, 2, 0
        assert_eq!(top[0].0, 1);
        assert_eq!(top[1].0, 2);
        assert_eq!(top[2].0, 0);
        let again = encode(2, Metric::Manhattan, 3, 4, 0xFEED_F00D, 7, &snap.ledger,
            &[], EncodePayload::Implicit { main: main.as_slice(), inter: inter.as_slice() });
        assert_eq!(bytes, again);
    }

    #[test]
    fn mutable_payload_roundtrips_bitwise() {
        let bytes = sample_mutable();
        let snap = decode(&bytes).unwrap();
        assert_eq!(snap.header.version, VERSION);
        assert_eq!(snap.header.engine, Engine::Implicit);
        assert!(snap.header.mutable);
        assert_eq!(snap.header.n, 2);
        assert_eq!(snap.header.d, 1);
        assert_eq!(snap.header.tests, 1);
        assert_eq!(
            snap.mutations,
            vec![MutationRecord {
                seq: 0,
                op: MutationOp::Relabel,
                index: 1,
                label: -3,
            }]
        );
        let SnapshotPayload::Mutable(p) = &snap.payload else {
            panic!("mutable payload expected");
        };
        assert_eq!(p.main, vec![1.0, 0.0]);
        assert_eq!(p.inter, vec![-0.5, -0.5]);
        assert_eq!(p.train_x, vec![0.25, 0.75]);
        assert_eq!(p.train_y, vec![1, -3]);
        assert_eq!(p.test_x, vec![0.3]);
        assert_eq!(p.test_y, vec![1]);
        assert_eq!(p.rank, vec![0, 1]);
        assert_eq!(p.colval, vec![-0.5, -0.5]);
        assert_eq!(p.dist, vec![0.0025, 0.2025]);
        assert_eq!(p.pos, vec![0, 1]);
        // values are answerable straight from the snapshot
        assert!(snap.averaged_matrix().is_none());
        let main = snap.point_values(crate::session::TopBy::Main).unwrap();
        assert_eq!(main, vec![1.0, 0.0]);
        // re-encode reproduces the bytes exactly
        let again = encode(
            1,
            Metric::SqEuclidean,
            2,
            1,
            0xCAFE,
            1,
            &snap.ledger,
            &snap.mutations,
            EncodePayload::Mutable {
                main: &p.main,
                inter: &p.inter,
                train_x: &p.train_x,
                train_y: &p.train_y,
                test_x: &p.test_x,
                test_y: &p.test_y,
                rank: &p.rank,
                colval: &p.colval,
                dist: &p.dist,
                pos: &p.pos,
            },
        );
        assert_eq!(bytes, again);
    }

    #[test]
    fn mutable_truncated_mutation_section_is_rejected() {
        // strip one mutation record's worth of bytes and refresh the
        // checksum: the record-count consistency check must fire
        let bytes = sample_mutable();
        let cut = bytes.len() - 8 - MUTATION_RECORD_BYTES;
        let mut bad = bytes[..cut].to_vec();
        let sum = fnv1a(&bad).to_le_bytes();
        bad.extend_from_slice(&sum);
        let err = decode(&bad).unwrap_err().to_string();
        assert!(
            err.contains("mutation") || err.contains("implies"),
            "unhelpful error: {err}"
        );
    }

    #[test]
    fn version_1_files_still_decode() {
        let snap = decode(&sample_v1()).unwrap();
        assert_eq!(snap.header.version, 1);
        assert_eq!(snap.header.engine, Engine::Dense, "v1 is always dense");
        assert_eq!(snap.header.n, 2);
        assert_eq!(snap.header.tests, 3);
        let SnapshotPayload::Dense(raw) = &snap.payload else {
            panic!("dense payload expected");
        };
        assert_eq!(raw.data(), &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn nan_and_negative_zero_cells_survive() {
        let raw = vec![f64::NAN, -0.0, f64::INFINITY, 1.5];
        let bytes = encode(1, Metric::Cosine, 2, 1, 7, 1,
            &[BatchRecord { seq: 0, len: 1 }], &[], EncodePayload::Dense(&raw));
        let snap = decode(&bytes).unwrap();
        let SnapshotPayload::Dense(m) = &snap.payload else {
            panic!("dense payload expected");
        };
        for (a, b) in raw.iter().zip(m.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn corruption_is_detected() {
        let mut bytes = sample();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn truncation_is_detected() {
        let bytes = sample();
        assert!(decode(&bytes[..bytes.len() - 9]).is_err());
        assert!(decode(&bytes[..20]).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample();
        bytes[0] = b'X';
        // checksum fails first (it covers the magic); flipping magic AND
        // refreshing the checksum must then hit the magic check itself
        let body_len = bytes.len() - 8;
        let mut h = Fnv::new();
        h.write(&bytes[..body_len]);
        let sum = h.finish().to_le_bytes();
        bytes[body_len..].copy_from_slice(&sum);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn future_version_rejected_with_clear_error() {
        let mut bytes = sample();
        bytes[8..12].copy_from_slice(&9u32.to_le_bytes());
        let body_len = bytes.len() - 8;
        let mut h = Fnv::new();
        h.write(&bytes[..body_len]);
        let sum = h.finish().to_le_bytes();
        bytes[body_len..].copy_from_slice(&sum);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("version 9"), "{err}");
    }

    #[test]
    fn unknown_payload_kind_rejected() {
        let mut bytes = sample();
        bytes[17] = 9; // payload-kind byte
        let body_len = bytes.len() - 8;
        let mut h = Fnv::new();
        h.write(&bytes[..body_len]);
        let sum = h.finish().to_le_bytes();
        bytes[body_len..].copy_from_slice(&sum);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("payload kind"), "{err}");
    }

    #[test]
    fn ledger_total_must_match_tests() {
        let raw = vec![0.0; 4];
        let bytes = encode(1, Metric::SqEuclidean, 2, 1, 0, 99,
            &[BatchRecord { seq: 0, len: 1 }], &[], EncodePayload::Dense(&raw));
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("ledger"), "{err}");
    }

    #[test]
    fn metric_tags_are_stable_and_invertible() {
        for m in [Metric::SqEuclidean, Metric::Manhattan, Metric::Cosine] {
            assert_eq!(metric_from_tag(metric_tag(m)), Some(m));
        }
        assert_eq!(metric_from_tag(3), None);
    }

    #[test]
    fn payload_tags_are_stable_and_invertible() {
        assert_eq!(payload_tag(Engine::Dense), 0);
        assert_eq!(payload_tag(Engine::Implicit), 1);
        for e in [Engine::Dense, Engine::Implicit] {
            assert_eq!(engine_from_tag(payload_tag(e)), Some(e));
        }
        // tag 2 is the mutable-session kind, not an engine
        assert_eq!(engine_from_tag(MUTABLE_TAG), None);
        assert_eq!(MUTABLE_TAG, 2);
    }

    #[test]
    fn read_header_peeks_without_reading_the_payload() {
        let p = std::env::temp_dir().join(format!(
            "stiknn_store_header_{}.snap",
            std::process::id()
        ));
        std::fs::write(&p, sample()).unwrap();
        let h = read_header(&p).unwrap();
        let full = read_snapshot(&p).unwrap();
        assert_eq!(h, full.header);
        // a garbage file fails the peek cleanly
        std::fs::write(&p, b"definitely not a snapshot, but long enough....").unwrap();
        let err = read_header(&p).unwrap_err().to_string();
        assert!(err.contains("header"), "{err}");
        // truncated-to-magic-only also errors instead of panicking
        std::fs::write(&p, &MAGIC[..]).unwrap();
        assert!(read_header(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn spill_path_is_name_scoped() {
        let p = spill_path(Path::new("/tmp/state"), "sess-1");
        assert_eq!(p, Path::new("/tmp/state/sess-1.session.snap"));
    }

    #[test]
    fn fingerprint_sensitive_to_data_and_layout() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let y = vec![0i32, 1];
        let base = dataset_fingerprint(&x, &y, 2);
        assert_eq!(base, dataset_fingerprint(&x, &y, 2), "deterministic");
        let mut x2 = x.clone();
        x2[3] = 4.0000005;
        assert_ne!(base, dataset_fingerprint(&x2, &y, 2), "feature change");
        assert_ne!(base, dataset_fingerprint(&x, &[0, 0], 2), "label change");
        assert_ne!(
            base,
            dataset_fingerprint(&x, &[0, 1, 0, 1], 1),
            "same bytes, different shape"
        );
    }
}
