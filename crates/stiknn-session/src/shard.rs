//! Multi-node test-set sharding — exact fan-out of one valuation across
//! N serve processes (DESIGN.md §13).
//!
//! STI-KNN's interaction matrix is a weighted average over test points
//! (Eq. 8/9): Φ = (1/t)·Σ_τ Φ_τ. The session layer already exploits the
//! sum's additivity across BATCHES (streaming ingest); this module
//! exploits it across PROCESSES. A [`ShardPlan`] partitions the global
//! test stream into contiguous index ranges, a [`ShardedSession`] opens
//! the same valuation on one endpoint per range, routes every ingest
//! batch by global test index, and answers merged queries by folding the
//! shards' RAW (unnormalized) sums in fixed shard order and normalizing
//! ONCE by the total test count.
//!
//! # Exactness (the honest version)
//!
//! * Each shard's raw sums are **bit-identical** to a single process
//!   that ingested only that shard's slice — that is the session layer's
//!   contiguous-partition contract, and the NDJSON transport preserves
//!   it (finite f64 round-trips the wire unchanged: integral values
//!   print as integers, everything else via Rust's shortest round-trip
//!   `Display`).
//! * For N = 1 the merge is a plain copy, so every merged answer is
//!   **bit-identical** to the single-process session.
//! * For N > 1 the cross-shard fold regroups f64 additions, so merged
//!   answers agree with the single-process session to ≤ 1e-12 — the
//!   same caveat [`ValueVector::add_assign`](crate::shapley::values::ValueVector::add_assign)
//!   documents, and the reason the fold order is FIXED (shard 0 first):
//!   the same deployment always produces the same bits.
//! * **Bit-identity across N is recovered by rescatter**: mutable shard
//!   sessions retain their test slices in v3 snapshots
//!   ([`store::MutablePayload`](crate::session::store)), so
//!   [`rescatter`] reconstructs the global stream in order and re-ingests
//!   it onto M fresh sessions. M = 1 reproduces the one-shot/
//!   single-process result bit-for-bit (`tests/shard_equivalence.rs`).
//!
//! # Transport
//!
//! [`ShardLink`] abstracts the endpoint: [`TcpLink`] speaks NDJSON to a
//! `stiknn serve --listen --shard-of J/N` server, [`SessionLink`] drives
//! an in-process [`ValuationSession`] through the identical protocol
//! code path (`protocol::handle`) — which is what makes the equivalence
//! properties testable without sockets while exercising every byte of
//! the command layer.

use crate::obs::trace::hex_id;
use crate::obs::{ObsHandle, Span, SpanCtx, SpanRecord, TraceHandle};
use crate::session::protocol;
use crate::session::{store, SessionConfig, SnapshotPayload, TopBy, ValuationSession};
use crate::util::json::Json;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::path::Path;

/// A contiguous partition of the global test-index stream: shard `s`
/// owns `[start(s), end(s))`, with the LAST shard unbounded (it absorbs
/// any tests beyond the expected total, so a plan never drops data).
/// Zero-test shards (empty ranges) are legal — they contribute exact
/// additive identities to every merge.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// `starts[s]` = first global test index of shard s; `starts[0] == 0`
    /// and the sequence is non-decreasing.
    starts: Vec<u64>,
}

impl ShardPlan {
    /// Even contiguous split of `expected_total` tests over `n_shards`,
    /// remainder spread one-per-shard from the front (the same split the
    /// coordinator's banded assembly uses for rows). Tests beyond
    /// `expected_total` land on the last shard.
    pub fn contiguous(expected_total: u64, n_shards: usize) -> ShardPlan {
        assert!(n_shards >= 1, "a shard plan needs at least 1 shard");
        let n = n_shards as u64;
        let base = expected_total / n;
        let rem = expected_total % n;
        let mut starts = Vec::with_capacity(n_shards);
        let mut at = 0u64;
        for s in 0..n {
            starts.push(at);
            at += base + u64::from(s < rem);
        }
        ShardPlan { starts }
    }

    /// A plan from explicit shard start indices (`starts[0]` must be 0,
    /// non-decreasing; equal consecutive starts make a zero-test shard).
    pub fn from_starts(starts: Vec<u64>) -> Result<ShardPlan> {
        ensure!(!starts.is_empty(), "a shard plan needs at least 1 shard");
        ensure!(
            starts[0] == 0,
            "shard 0 must start at global test index 0 (got {})",
            starts[0]
        );
        ensure!(
            starts.windows(2).all(|w| w[0] <= w[1]),
            "shard start indices must be non-decreasing"
        );
        Ok(ShardPlan { starts })
    }

    pub fn n_shards(&self) -> usize {
        self.starts.len()
    }

    /// First global test index of shard `s`.
    pub fn start(&self, s: usize) -> u64 {
        self.starts[s]
    }

    /// One-past-last global test index of shard `s`; `None` for the last
    /// shard (unbounded).
    pub fn end(&self, s: usize) -> Option<u64> {
        self.starts.get(s + 1).copied()
    }

    /// Which shard owns global test index `g`.
    pub fn shard_of(&self, g: u64) -> usize {
        // starts[0] == 0 <= g, so the partition point is always >= 1.
        self.starts.partition_point(|&st| st <= g) - 1
    }
}

/// One NDJSON request/response exchange with a shard endpoint. The
/// response is the raw protocol object — `{"ok":false}` command failures
/// come back as `Ok(json)` (the coordinator turns them into errors with
/// shard context); `Err` means the TRANSPORT failed.
pub trait ShardLink {
    fn call(&mut self, request: &Json) -> Result<Json>;
}

/// In-process shard endpoint: drives an owned [`ValuationSession`]
/// through [`protocol::handle`] — the exact code path a remote server
/// runs per line, minus the socket. The equivalence tests shard through
/// these, so the property covers the full command layer.
pub struct SessionLink {
    session: ValuationSession,
}

impl SessionLink {
    pub fn new(session: ValuationSession) -> Self {
        SessionLink { session }
    }

    pub fn session(&self) -> &ValuationSession {
        &self.session
    }

    pub fn into_session(self) -> ValuationSession {
        self.session
    }
}

impl ShardLink for SessionLink {
    fn call(&mut self, request: &Json) -> Result<Json> {
        let (response, _shutdown) = protocol::handle(&mut self.session, &request.to_string());
        Ok(response)
    }
}

/// TCP shard endpoint: one NDJSON line out, one line back, against a
/// `stiknn serve --listen` process (connections start on the server's
/// default session, so no `open` is needed before routing commands).
pub struct TcpLink {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpLink {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<TcpLink> {
        let writer = TcpStream::connect(addr).context("connecting to shard server")?;
        let reader = BufReader::new(writer.try_clone().context("cloning shard socket")?);
        Ok(TcpLink { reader, writer })
    }
}

impl ShardLink for TcpLink {
    fn call(&mut self, request: &Json) -> Result<Json> {
        writeln!(self.writer, "{request}").context("writing to shard server")?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).context("reading shard reply")?;
        ensure!(n > 0, "shard server closed the connection");
        Json::parse(line.trim()).map_err(|e| anyhow!("bad shard response: {e}"))
    }
}

/// Merged per-point values across every shard (normalized by the TOTAL
/// test count — see the module doc for the exactness contract).
#[derive(Clone, Debug)]
pub struct MergedValues {
    /// Total tests across all shards (the normalization weight).
    pub tests: u64,
    /// Averaged main terms φ_ii.
    pub main: Vec<f64>,
    /// Averaged total row sums φ_ii + Σ_{j≠i} φ_ij.
    pub rowsum: Vec<f64>,
}

/// Merged summary statistics (derived from the merged raw sums, so they
/// carry the same exactness contract as [`ShardedSession::values`]).
#[derive(Clone, Debug)]
pub struct MergedStats {
    pub n: usize,
    pub tests: u64,
    /// Tests resident on each shard, in shard order.
    pub per_shard_tests: Vec<u64>,
    pub trace: f64,
    pub mean_offdiag: f64,
    pub upper_sum: f64,
}

/// A client-side valuation fanned out over N shard endpoints: routes
/// ingest batches by global test index per the [`ShardPlan`], replicates
/// training-set edits to every shard, and merges reads by the raw-sum
/// fold described in the module doc. `links[s]` IS shard `s` — order is
/// the identity.
pub struct ShardedSession<L: ShardLink> {
    links: Vec<L>,
    plan: ShardPlan,
    d: usize,
    n: usize,
    next_global: u64,
    /// Coordinator-side telemetry (DESIGN.md §14): per-shard exchange
    /// latency (`shard.s<idx>.call_ns`) and raw-fold merge time
    /// (`shard.merge_ns`). Disabled by default; attach with [`Self::set_obs`].
    obs: ObsHandle,
    /// Coordinator-side tracing (DESIGN.md §16): `shard.values` /
    /// `shard.ingest` roots, one `shard.s<idx>.call` child per member
    /// exchange (the request then carries the `"trace"` context field and
    /// the member's echoed spans are imported back here), and a
    /// `shard.merge` child around the raw fold. Disabled by default;
    /// attach with [`Self::set_trace`].
    trace: TraceHandle,
}

impl<L: ShardLink> ShardedSession<L> {
    /// Open a FRESH sharded valuation: every endpoint must be empty
    /// (t = 0). Endpoints are pinged (train sizes must agree) and, where
    /// the endpoint speaks the server's `shard` verb, their identity is
    /// verified: shard index J must match the link's position, the group
    /// size N must match `links.len()`, and every member must serve the
    /// same train-set fingerprint. Plain single-session endpoints (no
    /// `shard` verb) are accepted as-is.
    pub fn open(links: Vec<L>, plan: ShardPlan, d: usize) -> Result<Self> {
        let (s, _shard_tests) = Self::attach(links, plan, d)?;
        ensure!(
            s.next_global == 0,
            "ShardedSession::open requires empty shards, but {} tests are already \
             resident (use ShardedSession::resume to attach to live shards)",
            s.next_global
        );
        Ok(s)
    }

    /// Attach to shards that already hold data (a restart of the
    /// coordinator, or sessions produced by [`rescatter`]): the routed
    /// count resumes at the shards' total test count, which must be
    /// distributed exactly as the plan would have routed it — otherwise
    /// future batches would interleave differently than a from-scratch
    /// run and the exactness contract would silently break.
    pub fn resume(links: Vec<L>, plan: ShardPlan, d: usize) -> Result<Self> {
        let (s, shard_tests) = Self::attach(links, plan, d)?;
        let routed = s.next_global;
        for (idx, &held) in shard_tests.iter().enumerate() {
            let lo = s.plan.start(idx).min(routed);
            let hi = s.plan.end(idx).unwrap_or(u64::MAX).min(routed);
            let expected = hi - lo;
            ensure!(
                held == expected,
                "shard {idx} holds {held} tests but the plan routes {expected} of \
                 the first {routed} there — these shards were not filled by this \
                 plan"
            );
        }
        Ok(s)
    }

    /// Shared open/resume plumbing; also returns the per-shard test
    /// counts so `resume` can check the distribution without re-pinging.
    fn attach(mut links: Vec<L>, plan: ShardPlan, d: usize) -> Result<(Self, Vec<u64>)> {
        ensure!(
            links.len() == plan.n_shards(),
            "{} shard links for a {}-shard plan",
            links.len(),
            plan.n_shards()
        );
        ensure!(d >= 1, "need at least 1 feature dimension");
        let count = links.len();
        let mut n = None;
        let mut shard_tests = Vec::with_capacity(count);
        let mut fingerprint: Option<String> = None;
        for (idx, link) in links.iter_mut().enumerate() {
            let ping = expect_ok(link.call(&cmd("ping"))?, idx, "ping")?;
            let shard_n = field_usize(&ping, "n", idx, "ping")?;
            match n {
                None => n = Some(shard_n),
                Some(n0) => ensure!(
                    n0 == shard_n,
                    "shard {idx} serves n={shard_n} train points but shard 0 serves \
                     n={n0} — every member must serve the same train set"
                ),
            }
            shard_tests.push(field_usize(&ping, "t", idx, "ping")? as u64);
            // Identity check, where the endpoint can answer it: the
            // single-session protocol has no `shard` verb and answers
            // ok:false — those endpoints are accepted unverified.
            let id = link.call(&cmd("shard"))?;
            if id.get("ok").and_then(Json::as_bool) == Some(true) {
                if let Some(j) = id.get("shard").and_then(Json::as_usize) {
                    let of = field_usize(&id, "of", idx, "shard")?;
                    ensure!(
                        j == idx && of == count,
                        "endpoint {idx} identifies as shard {j}/{of}, but this \
                         coordinator is routing to it as shard {idx}/{count}"
                    );
                }
                if let Some(fp) = id.get("fingerprint").and_then(Json::as_str) {
                    match &fingerprint {
                        None => fingerprint = Some(fp.to_string()),
                        Some(fp0) => ensure!(
                            fp0 == fp,
                            "shard {idx} serves train-set fingerprint {fp} but an \
                             earlier shard serves {fp0} — members disagree on the \
                             training data"
                        ),
                    }
                }
            }
        }
        let next_global = shard_tests.iter().sum();
        Ok((
            ShardedSession {
                links,
                plan,
                d,
                n: n.expect("at least one link was pinged"),
                next_global,
                obs: ObsHandle::disabled(),
                trace: TraceHandle::disabled(),
            },
            shard_tests,
        ))
    }

    /// Attach a metrics registry: every subsequent shard exchange records
    /// its round-trip latency into `shard.s<idx>.call_ns` and every raw
    /// fold records `shard.merge_ns` (DESIGN.md §14).
    pub fn set_obs(&mut self, obs: ObsHandle) {
        self.obs = obs;
    }

    pub fn obs(&self) -> &ObsHandle {
        &self.obs
    }

    /// Attach a tracing handle: subsequent `values`/`stats`/`top_k`
    /// fetches and `ingest` fan-outs each record one span tree (see the
    /// `trace` field docs). Disabled by default — and with tracing off,
    /// requests never gain the `"trace"` field, so every shard exchange
    /// is byte-identical to an untraced coordinator's.
    pub fn set_trace(&mut self, trace: TraceHandle) {
        self.trace = trace;
    }

    /// The coordinator's tracing handle (where assembled trees live).
    pub fn trace(&self) -> &TraceHandle {
        &self.trace
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn n_shards(&self) -> usize {
        self.links.len()
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Global test indices routed so far (== the merge's total weight).
    pub fn tests_routed(&self) -> u64 {
        self.next_global
    }

    /// Tear down the coordinator and hand back the links (e.g. to
    /// recover the sessions inside [`SessionLink`]s).
    pub fn into_links(self) -> Vec<L> {
        self.links
    }

    /// Ingest one test batch: global indices `tests_routed()..+len`,
    /// split into contiguous runs and routed to their owning shards in
    /// order. Exactly the bytes a single-process session would see, cut
    /// at shard boundaries — which is why each shard's state stays
    /// bit-identical to a solo session over its slice.
    pub fn ingest(&mut self, test_x: &[f32], test_y: &[i32]) -> Result<usize> {
        ensure!(
            test_x.len() == test_y.len() * self.d,
            "test batch shape mismatch: {} features for {} labels (d={})",
            test_x.len(),
            test_y.len(),
            self.d
        );
        let len = test_y.len() as u64;
        let mut root = self.trace.root("shard.ingest");
        if root.is_recording() {
            root.field("points", test_y.len().to_string());
        }
        let root_ctx = root.ctx();
        let mut cursor = 0u64;
        while cursor < len {
            let g = self.next_global + cursor;
            let s = self.plan.shard_of(g);
            let run_end = match self.plan.end(s) {
                Some(end) => (end - self.next_global).min(len),
                None => len,
            };
            let (lo, hi) = (cursor as usize, run_end as usize);
            let xs = &test_x[lo * self.d..hi * self.d];
            let ys = &test_y[lo..hi];
            let req = Json::obj(vec![
                ("cmd", Json::str("ingest")),
                ("x", Json::arr(xs.iter().map(|&f| Json::num(f as f64)))),
                ("y", Json::arr(ys.iter().map(|&y| Json::num(y as f64)))),
            ]);
            expect_ok(
                traced_call(&self.obs, &self.trace, root_ctx, s, &mut self.links[s], &req)?,
                s,
                "ingest",
            )?;
            cursor = run_end;
        }
        self.next_global += len;
        Ok(test_y.len())
    }

    /// Fetch every shard's raw sums and fold them in shard order.
    /// Returns (total tests, per-shard tests, raw main, raw rowsum).
    ///
    /// Collect-then-fold: every member exchange completes first (one
    /// `shard.s<idx>.call` span each when traced), then the whole fold
    /// runs under one `shard.merge` span. The fold still walks the
    /// responses in shard order, first shard by move, so the folded
    /// bits are identical to the interleaved form this replaced — and
    /// the merge span's wall clock necessarily bounds the narrower
    /// `shard.merge_ns` add-only metric measured inside it.
    fn fetch_raw(&mut self) -> Result<(u64, Vec<u64>, Vec<f64>, Vec<f64>)> {
        let req = Json::obj(vec![
            ("cmd", Json::str("values")),
            ("raw", Json::Bool(true)),
        ]);
        let root = self.trace.root("shard.values");
        let root_ctx = root.ctx();
        let mut responses = Vec::with_capacity(self.links.len());
        for (idx, link) in self.links.iter_mut().enumerate() {
            let resp = expect_ok(
                traced_call(&self.obs, &self.trace, root_ctx, idx, link, &req)?,
                idx,
                "values",
            )?;
            responses.push(resp);
        }
        let merge_span = match root_ctx {
            Some(ctx) => self.trace.child(ctx, "shard.merge"),
            None => Span::noop(),
        };
        let mut total = 0u64;
        let mut per_shard = Vec::with_capacity(responses.len());
        let mut main: Option<Vec<f64>> = None;
        let mut rowsum: Option<Vec<f64>> = None;
        let mut merge_ns = 0u64;
        for (idx, resp) in responses.iter().enumerate() {
            let tests = field_usize(resp, "tests", idx, "values")? as u64;
            total += tests;
            per_shard.push(tests);
            let m = f64_array(resp, "main", idx)?;
            let r = f64_array(resp, "rowsum", idx)?;
            ensure!(
                m.len() == self.n && r.len() == self.n,
                "shard {idx} returned {} values for n={}",
                m.len(),
                self.n
            );
            // First shard by MOVE, not fold-into-zeros: for N = 1 the
            // merge must be a bit-level copy, and 0.0 + x is not always
            // x's bits (negative zero).
            match (&mut main, &mut rowsum) {
                (None, _) => {
                    main = Some(m);
                    rowsum = Some(r);
                }
                (Some(am), Some(ar)) => {
                    let t0 = self.obs.is_enabled().then(crate::obs::now);
                    add_assign(am, &m);
                    add_assign(ar, &r);
                    if let Some(t0) = t0 {
                        merge_ns += t0.elapsed().as_nanos() as u64;
                    }
                }
                _ => unreachable!("main and rowsum are set together"),
            }
        }
        merge_span.finish();
        // One observation per fetch (the cross-shard fold as a whole);
        // for N = 1 the "merge" is the move and records 0.
        self.obs.observe_ns("shard.merge_ns", merge_ns);
        Ok((
            total,
            per_shard,
            main.expect("plans have at least one shard"),
            rowsum.expect("plans have at least one shard"),
        ))
    }

    /// Merged per-point values (see the module doc's exactness
    /// contract). Fails while every shard is empty — same contract as
    /// [`ValuationSession::point_values`].
    pub fn values(&mut self) -> Result<MergedValues> {
        let (tests, _, mut main, mut rowsum) = self.fetch_raw()?;
        ensure!(tests > 0, "no test points ingested on any shard yet");
        let inv_w = 1.0 / tests as f64;
        for v in &mut main {
            *v *= inv_w;
        }
        for v in &mut rowsum {
            *v *= inv_w;
        }
        Ok(MergedValues {
            tests,
            main,
            rowsum,
        })
    }

    /// Merged top-k (index, value), descending with index tiebreak —
    /// identical ranking semantics to [`ValuationSession::top_k`].
    pub fn top_k(&mut self, k: usize, by: TopBy) -> Result<Vec<(usize, f64)>> {
        let merged = self.values()?;
        let values = match by {
            TopBy::Main => &merged.main,
            TopBy::RowSum => &merged.rowsum,
        };
        Ok(crate::session::top_k_of(values, k))
    }

    /// Merged summary statistics, derived from the merged raw sums with
    /// the same expressions the implicit engine's `stats` uses.
    pub fn stats(&mut self) -> Result<MergedStats> {
        let (tests, per_shard_tests, main, rowsum) = self.fetch_raw()?;
        let inv_w = if tests == 0 { 0.0 } else { 1.0 / tests as f64 };
        let n = self.n;
        let pairs = (n * (n - 1) / 2) as f64;
        let trace_raw: f64 = main.iter().sum();
        let strict_upper_raw: f64 =
            main.iter().zip(&rowsum).map(|(&m, &r)| r - m).sum::<f64>() / 2.0;
        Ok(MergedStats {
            n,
            tests,
            per_shard_tests,
            trace: trace_raw * inv_w,
            mean_offdiag: if pairs > 0.0 {
                strict_upper_raw * inv_w / pairs
            } else {
                0.0
            },
            upper_sum: (trace_raw + strict_upper_raw) * inv_w,
        })
    }

    /// Merged averaged cell φ_ij: Σ_shards raw_cell / Σ_shards tests.
    /// Engine restrictions are the shards' own (a dense or retained-rows
    /// deployment answers everything; a bare implicit one rejects
    /// off-diagonals with reason `engine`, which surfaces here as an
    /// error naming the shard).
    pub fn cell(&mut self, i: usize, j: usize) -> Result<f64> {
        let req = Json::obj(vec![
            ("cmd", Json::str("query")),
            ("i", Json::num(i as f64)),
            ("j", Json::num(j as f64)),
            ("raw", Json::Bool(true)),
        ]);
        let mut total = 0u64;
        let mut sum: Option<f64> = None;
        for (idx, link) in self.links.iter_mut().enumerate() {
            let resp = expect_ok(timed_call(&self.obs, idx, link, &req)?, idx, "query")?;
            total += field_usize(&resp, "tests", idx, "query")? as u64;
            let v = resp
                .get("value")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("shard {idx} query response missing 'value'"))?;
            sum = Some(match sum {
                None => v,
                Some(acc) => acc + v,
            });
        }
        ensure!(total > 0, "no test points ingested on any shard yet");
        let sum = sum.expect("plans have at least one shard");
        Ok(sum * (1.0 / total as f64))
    }

    /// Merged averaged row i (diagonal included) — the row-level twin of
    /// [`Self::cell`].
    pub fn row(&mut self, i: usize) -> Result<Vec<f64>> {
        let req = Json::obj(vec![
            ("cmd", Json::str("query")),
            ("i", Json::num(i as f64)),
            ("raw", Json::Bool(true)),
        ]);
        let mut total = 0u64;
        let mut sum: Option<Vec<f64>> = None;
        for (idx, link) in self.links.iter_mut().enumerate() {
            let resp = expect_ok(timed_call(&self.obs, idx, link, &req)?, idx, "query")?;
            total += field_usize(&resp, "tests", idx, "query")? as u64;
            let row = f64_array(&resp, "row", idx)?;
            ensure!(
                row.len() == self.n,
                "shard {idx} returned a row of {} for n={}",
                row.len(),
                self.n
            );
            match &mut sum {
                None => sum = Some(row),
                Some(acc) => add_assign(acc, &row),
            }
        }
        ensure!(total > 0, "no test points ingested on any shard yet");
        let inv_w = 1.0 / total as f64;
        let mut row = sum.expect("plans have at least one shard");
        for v in &mut row {
            *v *= inv_w;
        }
        Ok(row)
    }

    /// Replicate a training-set edit to EVERY shard (the train set is
    /// replicated; only the test stream is sharded). All members must be
    /// mutable deployments; the new point's id (= previous n) is
    /// identical on every shard because their train sets are identical.
    pub fn add_train(&mut self, x: &[f32], y: i32) -> Result<usize> {
        ensure!(
            x.len() == self.d,
            "new train point has {} features but the coordinator's d is {}",
            x.len(),
            self.d
        );
        let req = Json::obj(vec![
            ("cmd", Json::str("add_train")),
            ("x", Json::arr(x.iter().map(|&f| Json::num(f as f64)))),
            ("y", Json::num(y as f64)),
        ]);
        let index = self.fan_edit(&req, "add_train")?;
        self.n += 1;
        Ok(index)
    }

    /// Replicate `remove_train` to every shard (indices above `i` shift
    /// down by one everywhere, keeping the shards' numbering aligned).
    pub fn remove_train(&mut self, i: usize) -> Result<()> {
        let req = Json::obj(vec![
            ("cmd", Json::str("remove_train")),
            ("i", Json::num(i as f64)),
        ]);
        self.fan_edit(&req, "remove_train")?;
        self.n -= 1;
        Ok(())
    }

    /// Replicate `relabel` to every shard.
    pub fn relabel_train(&mut self, i: usize, y: i32) -> Result<()> {
        let req = Json::obj(vec![
            ("cmd", Json::str("relabel")),
            ("i", Json::num(i as f64)),
            ("y", Json::num(y as f64)),
        ]);
        self.fan_edit(&req, "relabel")?;
        Ok(())
    }

    /// Fan one edit to all shards; returns the (agreeing) `index` field
    /// when present (add_train), else 0.
    fn fan_edit(&mut self, req: &Json, what: &str) -> Result<usize> {
        let mut root = self.trace.root("shard.edit");
        if root.is_recording() {
            root.field("op", what);
        }
        let root_ctx = root.ctx();
        let mut index = 0usize;
        for (idx, link) in self.links.iter_mut().enumerate() {
            let resp = expect_ok(
                traced_call(&self.obs, &self.trace, root_ctx, idx, link, req)?,
                idx,
                what,
            )?;
            if let Some(i) = resp.get("index").and_then(Json::as_usize) {
                index = i;
            }
        }
        Ok(index)
    }

    /// Snapshot every shard session to its own path (one per shard, in
    /// shard order; paths resolve on the SERVER side — co-locate the
    /// processes or point them at a shared filesystem). Returns total
    /// bytes written. Feed the files to [`rescatter`] to rebuild the
    /// valuation on a different shard count.
    pub fn snapshot_all<P: AsRef<Path>>(&mut self, paths: &[P]) -> Result<u64> {
        ensure!(
            paths.len() == self.links.len(),
            "{} snapshot paths for {} shards",
            paths.len(),
            self.links.len()
        );
        let mut bytes = 0u64;
        for (idx, (link, path)) in self.links.iter_mut().zip(paths).enumerate() {
            let req = Json::obj(vec![
                ("cmd", Json::str("snapshot")),
                ("path", Json::str(path.as_ref().display().to_string())),
            ]);
            let resp = expect_ok(timed_call(&self.obs, idx, link, &req)?, idx, "snapshot")?;
            bytes += field_usize(&resp, "bytes", idx, "snapshot")? as u64;
        }
        Ok(bytes)
    }
}

/// The rebalance path: rebuild a sharded valuation from per-shard v3
/// snapshots onto a DIFFERENT shard count (failover: N → N-1 after
/// losing a machine; scale-out: N → 2N; consolidation: N → 1).
///
/// Only MUTABLE shard deployments can rescatter — their snapshots retain
/// the test slices ([`store::MutablePayload`]). The global test stream
/// is reconstructed by concatenating the slices in shard order (exactly
/// the order the coordinator routed them), then re-ingested onto fresh
/// sessions under an even contiguous plan. Because re-ingest IS the
/// session layer's contiguous-partition contract, `new_shards = 1`
/// reproduces the single-process session — and a one-shot run — to the
/// bit, for ANY source shard count: rescatter is how a sharded
/// deployment recovers bit-identity, not just ≤ 1e-12 agreement.
///
/// `config` is the configuration for the REBUILT sessions; its k and
/// metric must match the snapshots' (the valuation semantics), while
/// engine/retention/mutability are free — rescattering into plain dense
/// sessions for a consolidation report is as valid as rescattering into
/// mutable ones to keep serving edits.
pub fn rescatter<P: AsRef<Path>>(
    snapshots: &[P],
    new_shards: usize,
    config: SessionConfig,
) -> Result<Rescattered> {
    ensure!(!snapshots.is_empty(), "rescatter needs at least 1 snapshot");
    ensure!(new_shards >= 1, "rescatter needs at least 1 target shard");
    let mut train: Option<(Vec<f32>, Vec<i32>, usize)> = None;
    let mut fingerprint = None;
    let mut test_x = Vec::new();
    let mut test_y = Vec::new();
    for (idx, path) in snapshots.iter().enumerate() {
        let path = path.as_ref();
        let snap = store::read_snapshot(path)
            .with_context(|| format!("reading shard {idx} snapshot {}", path.display()))?;
        let h = &snap.header;
        let SnapshotPayload::Mutable(payload) = snap.payload else {
            bail!(
                "shard {idx} snapshot {} was taken by an immutable '{}' session, \
                 which does not retain its test slice — only mutable shard \
                 deployments (serve --mutable) can rescatter",
                path.display(),
                h.engine.label()
            );
        };
        ensure!(
            h.k as usize == config.k,
            "shard {idx} snapshot was taken with k={} but the rebuilt sessions \
             are configured with k={}",
            h.k,
            config.k
        );
        ensure!(
            h.metric == config.metric,
            "shard {idx} snapshot metric {:?} != rebuilt session metric {:?}",
            h.metric,
            config.metric
        );
        match fingerprint {
            None => fingerprint = Some(h.fingerprint),
            Some(fp) => ensure!(
                fp == h.fingerprint,
                "shard {idx} snapshot fingerprint {:016x} != shard 0's {fp:016x} — \
                 the shards hold different train sets (edits must be replicated \
                 to every member)",
                h.fingerprint
            ),
        }
        if train.is_none() {
            let d = h.d as usize;
            train = Some((payload.train_x.clone(), payload.train_y.clone(), d));
        }
        test_x.extend_from_slice(&payload.test_x);
        test_y.extend_from_slice(&payload.test_y);
    }
    let (train_x, train_y, d) = train.expect("at least one snapshot was read");
    ensure!(
        test_x.len() == test_y.len() * d,
        "shard snapshots carry inconsistent test slices ({} features for {} \
         labels, d={d})",
        test_x.len(),
        test_y.len()
    );
    let total = test_y.len() as u64;
    let plan = ShardPlan::contiguous(total, new_shards);
    let mut sessions = Vec::with_capacity(new_shards);
    for s in 0..new_shards {
        let lo = plan.start(s) as usize;
        let hi = plan.end(s).unwrap_or(total) as usize;
        let mut session = ValuationSession::new(train_x.clone(), train_y.clone(), d, config)
            .with_context(|| format!("building rescatter target shard {s}"))?;
        session
            .ingest(&test_x[lo * d..hi * d], &test_y[lo..hi])
            .with_context(|| format!("re-ingesting slice [{lo}, {hi}) onto shard {s}"))?;
        sessions.push(session);
    }
    Ok(Rescattered { plan, sessions })
}

/// What [`rescatter`] rebuilds: the new plan plus one live session per
/// new shard (wrap them in [`SessionLink`]s and
/// [`ShardedSession::resume`] to keep serving, or snapshot them for the
/// replacement processes to restore).
pub struct Rescattered {
    pub plan: ShardPlan,
    pub sessions: Vec<ValuationSession>,
}

fn cmd(name: &str) -> Json {
    Json::obj(vec![("cmd", Json::str(name))])
}

/// One shard exchange, timed into `shard.s<idx>.call_ns` when the
/// coordinator has an attached registry. Only the `call` itself is
/// inside the window — request building and merging are excluded, so the
/// histogram isolates transport plus remote work.
fn timed_call<L: ShardLink>(obs: &ObsHandle, idx: usize, link: &mut L, req: &Json) -> Result<Json> {
    if !obs.is_enabled() {
        return link.call(req);
    }
    let t0 = crate::obs::now();
    let resp = link.call(req);
    obs.observe_ns(
        &format!("shard.s{idx}.call_ns"),
        t0.elapsed().as_nanos() as u64,
    );
    resp
}

/// One shard exchange under a coordinator span. With no parent context
/// (tracing off, or a sampled-out root) this IS `timed_call` — the
/// request bytes are untouched, so untraced traffic stays byte-identical.
/// Otherwise a `shard.s<idx>.call` child span brackets the exchange, the
/// request CLONE gains the `"trace"` context carrier, and any member
/// spans echoed back as `"spans"` are imported into the coordinator's
/// store — that import is what stitches the fan-out into one tree.
fn traced_call<L: ShardLink>(
    obs: &ObsHandle,
    trace: &TraceHandle,
    parent: Option<SpanCtx>,
    idx: usize,
    link: &mut L,
    req: &Json,
) -> Result<Json> {
    let Some(parent) = parent else {
        return timed_call(obs, idx, link, req);
    };
    let span = trace.child(parent, &format!("shard.s{idx}.call"));
    let Some(ctx) = span.ctx() else {
        return timed_call(obs, idx, link, req);
    };
    let mut traced_req = req.clone();
    if let Json::Obj(m) = &mut traced_req {
        m.insert(
            "trace".to_string(),
            Json::obj(vec![
                ("id", Json::str(hex_id(ctx.trace_id))),
                ("parent", Json::str(hex_id(ctx.span_id))),
            ]),
        );
    }
    let resp = timed_call(obs, idx, link, &traced_req)?;
    span.finish();
    if let Some(arr) = resp.get("spans").and_then(Json::as_arr) {
        for sp in arr {
            if let Some(rec) = SpanRecord::from_json(sp) {
                trace.import(rec);
            }
        }
    }
    Ok(resp)
}

/// Protocol-level failure → coordinator error with shard context.
fn expect_ok(resp: Json, shard: usize, what: &str) -> Result<Json> {
    if resp.get("ok").and_then(Json::as_bool) == Some(true) {
        return Ok(resp);
    }
    bail!(
        "shard {shard} {what} failed: {}",
        resp.get("error")
            .and_then(Json::as_str)
            .unwrap_or("(no error message)")
    )
}

fn field_usize(resp: &Json, key: &str, shard: usize, what: &str) -> Result<usize> {
    resp.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("shard {shard} {what} response missing numeric '{key}'"))
}

fn f64_array(resp: &Json, key: &str, shard: usize) -> Result<Vec<f64>> {
    let arr = resp
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("shard {shard} response missing array '{key}'"))?;
    arr.iter()
        .map(|v| {
            v.as_f64()
                .ok_or_else(|| anyhow!("shard {shard} response has a non-numeric '{key}' entry"))
        })
        .collect()
}

fn add_assign(acc: &mut [f64], other: &[f64]) {
    for (a, b) in acc.iter_mut().zip(other) {
        *a += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::Engine;
    use crate::util::rng::Rng;

    #[test]
    fn contiguous_plans_split_evenly_with_front_loaded_remainder() {
        let plan = ShardPlan::contiguous(10, 3);
        assert_eq!(plan.n_shards(), 3);
        // 10 over 3: 4, 3, 3
        assert_eq!((plan.start(0), plan.end(0)), (0, Some(4)));
        assert_eq!((plan.start(1), plan.end(1)), (4, Some(7)));
        assert_eq!((plan.start(2), plan.end(2)), (7, None));
        assert_eq!(plan.shard_of(0), 0);
        assert_eq!(plan.shard_of(3), 0);
        assert_eq!(plan.shard_of(4), 1);
        assert_eq!(plan.shard_of(6), 1);
        assert_eq!(plan.shard_of(7), 2);
        // the last shard is unbounded: overflow tests land there
        assert_eq!(plan.shard_of(999), 2);
    }

    #[test]
    fn zero_test_shards_are_legal_and_skipped_by_routing() {
        let plan = ShardPlan::from_starts(vec![0, 2, 2, 5]).unwrap();
        assert_eq!(plan.shard_of(1), 0);
        // index 2 belongs to shard 2, not the empty shard 1 ([2, 2))
        assert_eq!(plan.shard_of(2), 2);
        assert_eq!(plan.shard_of(5), 3);
        // fewer tests than shards: trailing shards get nothing
        let tiny = ShardPlan::contiguous(2, 4);
        assert_eq!((tiny.start(2), tiny.end(2)), (2, Some(2)));
        assert!(ShardPlan::from_starts(vec![1, 2]).is_err());
        assert!(ShardPlan::from_starts(vec![0, 3, 2]).is_err());
        assert!(ShardPlan::from_starts(Vec::new()).is_err());
    }

    fn tiny_problem(
        seed: u64,
        n: usize,
        d: usize,
        t: usize,
    ) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        (
            (0..n * d).map(|_| rng.normal() as f32).collect(),
            (0..n).map(|_| rng.below(2) as i32).collect(),
            (0..t * d).map(|_| rng.normal() as f32).collect(),
            (0..t).map(|_| rng.below(2) as i32).collect(),
        )
    }

    #[test]
    fn single_shard_merge_is_bitwise_the_solo_session() {
        let (tx, ty, qx, qy) = tiny_problem(11, 9, 2, 6);
        let config = SessionConfig::new(3);
        let mut solo = ValuationSession::new(tx.clone(), ty.clone(), 2, config).unwrap();
        solo.ingest(&qx, &qy).unwrap();

        let link = SessionLink::new(ValuationSession::new(tx, ty, 2, config).unwrap());
        let plan = ShardPlan::contiguous(6, 1);
        let mut sharded = ShardedSession::open(vec![link], plan, 2).unwrap();
        sharded.ingest(&qx, &qy).unwrap();

        let merged = sharded.values().unwrap();
        let main = solo.point_values(TopBy::Main).unwrap();
        let rowsum = solo.point_values(TopBy::RowSum).unwrap();
        for i in 0..9 {
            assert_eq!(merged.main[i].to_bits(), main[i].to_bits());
            assert_eq!(merged.rowsum[i].to_bits(), rowsum[i].to_bits());
        }
        assert_eq!(
            sharded.cell(0, 1).unwrap().to_bits(),
            solo.cell(0, 1).unwrap().to_bits()
        );
    }

    #[test]
    fn obs_times_every_shard_call_and_the_merge() {
        let (tx, ty, qx, qy) = tiny_problem(19, 8, 2, 6);
        let config = SessionConfig::new(2);
        let make = || {
            SessionLink::new(ValuationSession::new(tx.clone(), ty.clone(), 2, config).unwrap())
        };
        let plan = ShardPlan::contiguous(6, 2);
        let mut sharded = ShardedSession::open(vec![make(), make()], plan, 2).unwrap();
        let obs = ObsHandle::enabled("shard-test");
        sharded.set_obs(obs.clone());
        sharded.ingest(&qx, &qy).unwrap();
        let with_obs = sharded.values().unwrap();
        let reg = obs.registry().unwrap();
        // the 6-test batch split into one run per shard; the values
        // merge fetched raw sums from both
        assert_eq!(reg.histogram("shard.s0.call_ns").count(), 2);
        assert_eq!(reg.histogram("shard.s1.call_ns").count(), 2);
        assert_eq!(reg.histogram("shard.merge_ns").count(), 1);
        // instrumentation must not perturb the merged answers
        let mut plain =
            ShardedSession::resume(sharded.into_links(), ShardPlan::contiguous(6, 2), 2).unwrap();
        let without = plain.values().unwrap();
        for (a, b) in with_obs.main.iter().zip(&without.main) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn traced_values_fanout_assembles_one_tree() {
        let (tx, ty, qx, qy) = tiny_problem(23, 8, 2, 6);
        let config = SessionConfig::new(2);
        let make = || {
            let mut s = ValuationSession::new(tx.clone(), ty.clone(), 2, config).unwrap();
            s.set_trace(TraceHandle::enabled());
            SessionLink::new(s)
        };
        let plan = ShardPlan::contiguous(6, 2);
        let mut sharded = ShardedSession::open(vec![make(), make()], plan, 2).unwrap();
        let trace = TraceHandle::enabled();
        sharded.set_trace(trace.clone());
        sharded.ingest(&qx, &qy).unwrap();
        sharded.values().unwrap();
        let root = trace
            .recent_roots(8)
            .into_iter()
            .find(|r| r.name == "shard.values")
            .expect("the values fetch recorded a root");
        let spans = trace.spans_of(root.trace_id);
        // ONE tree: exactly one parentless span in the whole trace
        assert_eq!(
            spans.iter().filter(|s| s.parent_id.is_none()).count(),
            1,
            "{spans:?}"
        );
        // one client-side call span per member, each under the root
        let calls: Vec<_> = spans
            .iter()
            .filter(|s| s.name == "shard.s0.call" || s.name == "shard.s1.call")
            .collect();
        assert_eq!(calls.len(), 2, "{spans:?}");
        for c in &calls {
            assert_eq!(c.parent_id, Some(root.span_id));
        }
        // one ECHOED member span per member, stitched under its call span
        let members: Vec<_> = spans.iter().filter(|s| s.name == "member.values").collect();
        assert_eq!(members.len(), 2, "{spans:?}");
        for m in &members {
            assert_eq!(m.trace_id, root.trace_id);
            assert!(
                calls.iter().any(|c| Some(c.span_id) == m.parent_id),
                "member span parents under a call span: {m:?}"
            );
        }
        // the merge span sits under the root
        let merge = spans
            .iter()
            .find(|s| s.name == "shard.merge")
            .expect("merge span recorded");
        assert_eq!(merge.parent_id, Some(root.span_id));
    }

    #[test]
    fn open_rejects_mismatched_plan_and_nonempty_shards() {
        let (tx, ty, qx, qy) = tiny_problem(13, 8, 2, 4);
        let config = SessionConfig::new(2);
        let empty = ValuationSession::new(tx.clone(), ty.clone(), 2, config).unwrap();
        let links = vec![SessionLink::new(empty)];
        let plan = ShardPlan::contiguous(4, 2);
        assert!(ShardedSession::open(links, plan, 2).is_err());

        let mut pre = ValuationSession::new(tx, ty, 2, config).unwrap();
        pre.ingest(&qx, &qy).unwrap();
        let links = vec![SessionLink::new(pre)];
        let plan = ShardPlan::contiguous(4, 1);
        assert!(ShardedSession::open(links, plan, 2).is_err());
    }

    #[test]
    fn resume_checks_the_plan_distribution() {
        let (tx, ty, qx, qy) = tiny_problem(17, 8, 2, 6);
        let config = SessionConfig::new(2).with_engine(Engine::Implicit);
        let plan = ShardPlan::contiguous(6, 2);
        let make = || {
            let s = ValuationSession::new(tx.clone(), ty.clone(), 2, config).unwrap();
            SessionLink::new(s)
        };

        // fill two shards per the plan (3 + 3), then resume onto them
        let mut a = make();
        let mut b = make();
        a.session.ingest(&qx[..3 * 2], &qy[..3]).unwrap();
        b.session.ingest(&qx[3 * 2..], &qy[3..]).unwrap();
        let resumed = ShardedSession::resume(vec![a, b], plan.clone(), 2).unwrap();
        assert_eq!(resumed.tests_routed(), 6);

        // a distribution the plan could not have produced is rejected
        let mut lopsided = make();
        let empty = make();
        lopsided.session.ingest(&qx, &qy).unwrap(); // all 6 on shard 0
        let links = vec![lopsided, empty];
        assert!(ShardedSession::resume(links, plan, 2).is_err());
    }
}
