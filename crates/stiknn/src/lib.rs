//! # stiknn — exact pair-interaction Data Shapley for KNN in O(t·n²)
//!
//! Production-grade reproduction of Belaid, ElMekki, Rabus & Hüllermeier
//! (2023), *"Optimizing Data Shapley Interaction Calculation from O(2ⁿ)
//! to O(tn²) for KNN models"* (STI-KNN), as a three-layer Rust + JAX +
//! Pallas system: Pallas kernels (L1) and the JAX pipeline (L2) are AOT
//! compiled to HLO artifacts at build time; the Rust layer (L3) loads
//! them via PJRT (behind the `xla` feature) and coordinates sharded
//! valuation jobs — Python never runs on the request path.
//!
//! # Crate map (DESIGN.md §13)
//!
//! This is the FACADE crate of a four-layer workspace. It contains no
//! algorithm code of its own — it re-exports the stack under the module
//! paths the original monolith used, so `use stiknn::...` is stable
//! across the split:
//!
//! ```text
//! stiknn-core     pure algorithms: shapley (engines + delta), knn,
//!                 data, analysis, coordinator, runtime, util,
//!                 report (tables/heatmaps), bench harness
//!    ▲
//! stiknn-session  ValuationSession + snapshot store + NDJSON protocol
//!    |            + shard fan-out (ShardedSession) + iterative removal
//!    ▲
//! stiknn-server   SessionRegistry, TCP listener, LRU spill, autosave
//!    ▲
//! stiknn          this facade (old paths + report::session rendering)
//!    ▲
//! stiknn-cli      the `stiknn` binary, benches/, examples/
//! ```
//!
//! `stiknn-core` depends on no other workspace crate (enforced per crate
//! in CI), so the shard coordinator can ride on `stiknn-session` without
//! dragging in the TCP server or CLI.
//!
//! # Engines
//!
//! Two complementary engines expose Algorithm 1's results (DESIGN.md
//! §4/§10):
//!
//! * **Dense** — the full n×n interaction matrix, O(t·n²) time / O(n²)
//!   memory. A two-phase hot path: Phase 1
//!   ([`shapley::sti_knn::prepare_batch_cached`]) computes distances
//!   through runtime-dispatched SIMD kernels ([`knn::kernel`],
//!   DESIGN.md §15 — AVX2+FMA when detected, a bit-identical portable
//!   tree otherwise, `STIKNN_KERNEL` to override) with per-train-row
//!   norms cached once and test points batched through the
//!   cache-blocked [`knn::kernel::distances_block`], then ranks and
//!   folds each test's superdiagonal; Phase 2
//!   ([`shapley::sti_knn::sweep_band`]) scatters prepared rows into the
//!   accumulator. The coordinator's default row-banded assembly
//!   parallelizes the sweep over disjoint row bands of ONE shared
//!   accumulator — peak memory O(n²) at any worker count, bit-identical
//!   to the single-threaded engine (DESIGN.md §7).
//! * **Implicit** — exact per-point values (diagonal mains + interaction
//!   row sums, the aggregates every serving workload actually consumes)
//!   via the rank-space suffix-sum identity
//!   `rowsum_i = r_i·c[r_i] + suffix(c, r_i+1)` ([`shapley::values`]),
//!   O(t·n log n) time / O(n) state, no matrix anywhere — which reaches
//!   n where the dense matrix cannot even be allocated (n=100k → 80 GB).
//!   Agrees with the dense `diag + rowsums` to ≤ 1e-12 and is
//!   bit-reproducible over any contiguous ingest partition
//!   (`tests/values_equivalence.rs`); parallelized by the coordinator's
//!   value-sharded path ([`coordinator::run_values_job`]).
//!
//! On top of the one-shot pipeline sits the **session layer**
//! ([`session`], DESIGN.md §9): a [`session::ValuationSession`] holds the
//! unnormalized engine state between requests — the matrix accumulator
//! or, with `SessionConfig::with_engine(Engine::Implicit)`, the O(n)
//! value vector — ingests test batches incrementally (Eq. 9 is additive
//! over test points, so streaming is exact — bit-identical to a one-shot
//! run over the same stream), snapshots/restores through a versioned
//! binary store ([`session::store`], v3 carries any payload kind; v1/v2
//! files still restore), and serves NDJSON commands via `stiknn serve`
//! ([`session::protocol`]; queries the implicit engine cannot answer are
//! rejected with `"reason":"engine"`).
//!
//! # Live training-set mutations ([`delta`], DESIGN.md §11)
//!
//! A mutable session (`SessionConfig::with_mutable(true)`, CLI
//! `serve --mutable` / `stiknn mutate`) makes the TRAINING set itself a
//! live object: `add_train`/`remove_train`/`relabel_train` apply exact
//! edits in **O(t·(d + n)) per edit** instead of the full
//! O(t·(n·d + n log n)) recompute — an edit only shifts ranks locally,
//! so the retained per-test rank-space rows are repaired in place
//! (binary-search insert, O(n) rank shift, superdiagonal rebuild) and
//! the value vector re-folded, landing bit-identical to a from-scratch
//! session over the edited train set (`tests/delta_equivalence.rs`).
//! Every edit is recorded in a mutation ledger that v3 snapshots persist
//! together with the train set and rows, so mutable sessions restore
//! completely and their data provenance stays auditable. The exact
//! iterative removal curve (`analysis::removal::
//! sti_iterative_removal_order`) is built on the same repairs:
//! remove-best → repair → re-rank, per step in O(t·n).
//!
//! # Concurrent serving ([`server`], DESIGN.md §12)
//!
//! Above the single-session protocol sits the multi-session server: a
//! [`server::SessionRegistry`] hosts many named sessions in one process,
//! `stiknn serve --listen ADDR` multiplexes TCP clients onto them
//! (thread per connection, `open`/`use`/`close`/`list` verbs; stdio
//! still works and speaks the identical protocol), and a per-session
//! RwLock lets read queries run concurrently while writes serialize —
//! with the property that ANY interleaving of client traffic leaves each
//! session bit-identical to a serialized replay of its own write
//! commands in revision order (`tests/server_concurrency.rs`). An LRU
//! cap spills cold sessions to the v3 snapshot store and reloads them
//! transparently on next touch; a background autosave thread checkpoints
//! dirty sessions so the process survives restarts.
//!
//! # Multi-node sharding ([`coordinator::shard`], DESIGN.md §13)
//!
//! STI-KNN's utility is a sum over test points (Eq. 8), so the test set
//! partitions across PROCESSES as exactly as it does across threads:
//! `stiknn serve --shard-of J/N` gives a server a shard identity, and a
//! [`coordinator::shard::ShardedSession`] opens the same session on N
//! shard servers, routes each ingest batch by global test index, and
//! merges per-shard raw (unnormalized) sums in fixed shard order.
//! `snapshot_all` collects per-shard v3 snapshots, and `rescatter`
//! re-opens them on a DIFFERENT shard count — mutable shard snapshots
//! retain their test slices, so rebalance re-ingests the global stream
//! in order (M=1 reproduces the single-process session bit-for-bit;
//! `tests/shard_equivalence.rs`).
//!
//! Quick start:
//! ```no_run
//! use stiknn::data::load_dataset;
//! use stiknn::shapley::{sti_knn, sti_values, StiParams};
//!
//! let ds = load_dataset("circle", 120, 30, 42).unwrap();
//! let phi = sti_knn(&ds.train_x, &ds.train_y, ds.d,
//!                   &ds.test_x, &ds.test_y, &StiParams::new(5));
//! println!("interaction of points 0,1: {}", phi.get(0, 1));
//! // per-point values without materializing phi at all:
//! let pv = sti_values(&ds.train_x, &ds.train_y, ds.d,
//!                     &ds.test_x, &ds.test_y, &StiParams::new(5));
//! println!("point 0 total value: {}", pv.rowsum[0]);
//! ```
//!
//! # Observability ([`obs`], DESIGN.md §14–16)
//!
//! One telemetry vocabulary spans every layer: lock-free counters,
//! gauges and fixed-bucket latency histograms in a named
//! [`obs::MetricsRegistry`], plus a bounded structured event ring
//! (`serve --event-ring N` sets its capacity; drops are counted and
//! surfaced in the exit report) — all behind an [`obs::ObsHandle`]
//! that degrades to no-ops when disabled, so instrumented hot paths
//! cost nothing unless a registry is attached. The server exposes it
//! as the `metrics` protocol verb (per-session and process-wide JSON
//! snapshots), `stiknn metrics` renders Prometheus-style text against
//! a live server, and `serve --slow-ms N` logs structured slow-query
//! records.
//!
//! Request tracing rides the same philosophy one level up
//! ([`obs::TraceHandle`], DESIGN.md §16): `serve --trace
//! on|off|sampled:N` records per-command span trees — server command
//! roots, session ingest/edit spans, synthesized coordinator phase
//! spans — into a bounded per-process span store, and a sharded
//! fan-out stitches every member's spans into ONE tree by propagating
//! `"trace"` context on request frames and echoing finished spans
//! back on responses. Inspect via the `trace` protocol verb or
//! `stiknn trace --connect HOST:PORT [--id T]`
//! (`tests/obs_invariants.rs` proves enabling metrics OR tracing, at
//! any sampling rate, leaves every result bit-identical).
//!
//! See DESIGN.md for the system inventory and the per-experiment index,
//! and EXPERIMENTS.md for reproduction results.

pub use stiknn_core::{bench, data, knn, obs, runtime, shapley, util};
pub use stiknn_server::server;
pub use stiknn_session::session;

pub use stiknn_core::shapley::delta;

/// Analysis suite (`stiknn-core`), plus the session-backed iterative
/// removal curve stitched back into its pre-split path.
pub mod analysis {
    pub use stiknn_core::analysis::*;

    /// Removal orders and curves; `sti_iterative_removal_order` comes
    /// from `stiknn-session` (it drives a live mutable session).
    pub mod removal {
        pub use stiknn_core::analysis::removal::*;
        pub use stiknn_session::removal::sti_iterative_removal_order;
    }
}

/// Parallel coordination (`stiknn-core`), plus the multi-node shard
/// fan-out from `stiknn-session` at the path the issue tracker and docs
/// use (`coordinator::shard`).
pub mod coordinator {
    pub use stiknn_core::coordinator::*;
    pub use stiknn_session::shard;
}

/// Fuzz-harness entry points (DESIGN.md §17): the properties the
/// `fuzz/` targets drive, as ordinary library code so the checked-in
/// corpus replays under plain `cargo test`.
pub mod verify;

/// Reporting (`stiknn-core` tables/heatmaps) plus the session/server
/// rendering helpers that live in this facade crate.
pub mod report {
    pub use stiknn_core::report::*;

    pub mod session;
}
